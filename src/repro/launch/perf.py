import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# ^ must precede any jax import (see dryrun.py)

"""Perf-iteration harness: A/B roofline comparison of cell variants.

    PYTHONPATH=src python -m repro.launch.perf --arch qwen2_5_32b \
        --shape prefill_32k --variants dense,per_token,tile_consensus

Each variant re-lowers + re-compiles the cell and prints the three
roofline terms, so a hypothesis → change → measure cycle is one command.
Variants:
  dense           no sparsity (pure baseline)
  per_token       paper-faithful Amber 8:16 (per-token masks, dense GEMMs)
  tile_consensus  TPU-native compacted matmul 8:16 ((M/N)× GEMM cut)
  per_token_24 / tile_consensus_24   same at 2:4
  w8a8            per-tensor int8 weights estimate (memory-term lever —
                  modeled: bytes_accessed × param-read fraction ÷ 2)
"""
import argparse
import json


def variant_policy(name: str, cfg):
    from repro.core.policy import DENSE, paper_policy

    if name == "dense":
        return DENSE
    if name == "per_token":
        return paper_policy(8, 16, cfg.qgate_skip_layers)
    if name == "tile_consensus":
        return paper_policy(8, 16, cfg.qgate_skip_layers,
                            tile_consensus=True)
    if name == "per_token_24":
        return paper_policy(2, 4, cfg.qgate_skip_layers)
    if name == "tile_consensus_24":
        return paper_policy(2, 4, cfg.qgate_skip_layers, tile_consensus=True)
    raise ValueError(name)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="dense,per_token,tile_consensus")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    from repro.launch.dryrun import run_cell

    results = []
    base = None
    for v in args.variants.split(","):
        from repro.configs.base import get_config
        pol = variant_policy(v, get_config(args.arch))
        r = run_cell(args.arch, args.shape, args.multi_pod, policy=pol)
        r["variant"] = v
        results.append(r)
        rf = r["roofline_s"]
        line = (f"{v:18s} compute={rf['compute']:.3e} "
                f"memory={rf['memory']:.3e} coll={rf['collective']:.3e} "
                f"dom={r['dominant']}")
        if base is not None:
            brf = base["roofline_s"]
            line += ("   Δ vs dense: "
                     f"compute×{rf['compute']/max(brf['compute'],1e-30):.2f} "
                     f"memory×{rf['memory']/max(brf['memory'],1e-30):.2f} "
                     f"coll×{rf['collective']/max(brf['collective'],1e-30):.2f}")
        else:
            base = r
        print(line, flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch mixtral_8x7b] [--shape train_4k] [--multi-pod|--both] \
        [--json out.json]

For each cell this prints memory_analysis() (fits?) and cost_analysis()
(FLOPs/bytes for §Roofline), plus the parsed collective traffic.  Compile
failures (sharding mismatch, OOM, unsupported collective) are bugs and are
reported with a non-zero exit code.
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool, policy=None):
    import jax
    from repro.configs.base import get_config
    from repro.launch import roofline
    from repro.launch.cells import build_cell, cell_by_name, is_runnable
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = cell_by_name(shape_name)
    ok, why = is_runnable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "why": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch, shape_name, mesh, policy=policy)
    t0 = time.time()
    with mesh:
        lowered = cell.lower(mesh)
        compiled = lowered.compile()
    dt = time.time() - t0

    ma = compiled.memory_analysis()
    # CPU-backend artifact correction (see EXPERIMENTS.md §Dry-run): XLA's
    # CPU float-normalization pass upcasts every bf16 weight to f32 and
    # LICM hoists those converts out of the layer loop as whole-stack
    # copies (~2× the TP-sharded param bytes, verified in the buffer
    # dumps).  TPU executes bf16 natively — no such copies exist there.
    import jax as _jax
    from repro.distributed import sharding as _shd
    from repro.models import build_model as _bm
    from repro.train.optimizer import adamw_init as _ai
    _params = _jax.eval_shape(_bm(cfg).init, _jax.random.PRNGKey(0))
    _specs = _shd.param_specs(_params, mesh, cfg.n_experts)
    tp_param_bytes = 0
    for leaf, spec in zip(_jax.tree_util.tree_leaves(_params),
                          _jax.tree_util.tree_leaves(
                              _specs, is_leaf=lambda x: isinstance(
                                  x, _jax.sharding.PartitionSpec))):
        n = 1
        for d in leaf.shape:
            n *= d
        shard = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                shard *= mesh.shape[a]
        tp_param_bytes += n * leaf.dtype.itemsize // max(shard, 1)
    artifact = 2 * tp_param_bytes
    total_mem = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes)
    tpu_native_est = max(total_mem - artifact, ma.argument_size_in_bytes)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    rep = roofline.analyze(arch, shape_name, mesh_name, compiled, cfg,
                           shape.kind, tokens)
    n_dev = mesh.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok",
        "compile_s": round(dt, 1),
        "bytes_per_device": {
            "args": int(ma.argument_size_in_bytes),
            "output": int(ma.output_size_in_bytes),
            "temp": int(ma.temp_size_in_bytes),
            "total": int(total_mem),
            "cpu_f32_artifact_est": int(artifact),
            "tpu_native_est": int(tpu_native_est),
        },
        "flops_per_device": rep.flops_per_dev,
        "hbm_bytes_per_device": rep.bytes_per_dev,
        "collective_bytes_per_device": rep.coll_bytes_per_dev,
        "roofline_s": {
            "compute": rep.compute_s,
            "memory": rep.memory_s,
            "collective": rep.collective_s,
        },
        "dominant": rep.dominant,
        "model_flops": rep.model_flops_total,
        "useful_flops_ratio": rep.model_flops_total / max(
            rep.flops_per_dev * n_dev, 1.0),
    }
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape cell (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run 16x16 AND 2x16x16 meshes")
    ap.add_argument("--json", default=None, help="write results JSON here")
    ap.add_argument("--tile-consensus", action="store_true",
                    help="use the TPU-native compacted-matmul sparsity mode")
    args = ap.parse_args(argv)

    from repro.configs.base import ARCH_IDS, SHAPE_CELLS

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else [c.name for c in SHAPE_CELLS]
    meshes = [False, True] if args.both else [args.multi_pod]

    policy = None
    if args.tile_consensus:
        from repro.core.policy import paper_policy
        policy = paper_policy(8, 16, tile_consensus=True)

    results = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                try:
                    r = run_cell(arch, shape, mp, policy=policy)
                except Exception as e:
                    traceback.print_exc()
                    r = {"arch": arch, "shape": shape,
                         "mesh": "2x16x16" if mp else "16x16",
                         "status": "FAIL", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                results.append(r)
                status = r["status"]
                if status == "ok":
                    rf = r["roofline_s"]
                    print(f"[dryrun] {tag}: OK compile={r['compile_s']}s "
                          f"mem/dev={r['bytes_per_device']['total']/2**30:.2f}GiB "
                          f"(tpu-est {r['bytes_per_device']['tpu_native_est']/2**30:.2f}) "
                          f"compute={rf['compute']:.3e}s "
                          f"memory={rf['memory']:.3e}s "
                          f"coll={rf['collective']:.3e}s "
                          f"dom={r['dominant']}", flush=True)
                elif status == "skipped":
                    print(f"[dryrun] {tag}: SKIP ({r['why']})", flush=True)
                else:
                    print(f"[dryrun] {tag}: FAIL {r['error']}", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
    print(f"[dryrun] done: {sum(r['status']=='ok' for r in results)} ok, "
          f"{sum(r['status']=='skipped' for r in results)} skipped, "
          f"{failures} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

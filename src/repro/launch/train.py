"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch llama31_8b --smoke --steps 200 --sparsity 8:16

``--smoke`` uses the reduced config (CPU-runnable ~100M-and-below); the
full configs are exercised via the dry-run.  Training itself runs dense by
default (the paper confines sparsity to prefill); pass ``--sparse-train``
to ablate N:M sparsity inside the training forward pass.
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama31_8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--sparsity", default=None, help="N:M, e.g. 8:16")
    ap.add_argument("--sparse-train", action="store_true")
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    args = ap.parse_args(argv)

    import jax

    from repro.configs.base import get_config, get_smoke_config
    from repro.core.policy import DENSE, paper_policy
    from repro.data.pipeline import DataConfig
    from repro.models import build_model
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)

    policy = DENSE
    if args.sparsity:
        n, m = (int(x) for x in args.sparsity.split(":"))
        phases = ("train", "prefill") if args.sparse_train else ("prefill",)
        policy = paper_policy(n, m, cfg.qgate_skip_layers).with_(phases=phases)

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 1))
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, grad_accum=args.grad_accum,
                         resume=args.resume)
    trainer = Trainer(model, data_cfg, opt_cfg, tcfg, policy=policy)

    def log(step, metrics):
        if step % tcfg.log_every == 0:
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"gnorm {metrics['grad_norm']:.3f} "
                  f"dt {metrics['step_time_s']*1e3:.1f}ms"
                  f"{'  [straggler]' if metrics['straggler'] else ''}",
                  flush=True)

    out = trainer.run(jax.random.PRNGKey(0), hooks=log)
    losses = [m["loss"] for m in out["metrics"]]
    if losses:
        print(f"done: first loss {losses[0]:.4f} → last {losses[-1]:.4f} "
              f"(resumed_from={out['resumed_from']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

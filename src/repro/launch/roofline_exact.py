import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# ^ must precede any jax import (see dryrun.py)

"""Exact roofline extraction: layer-axis extrapolation.

XLA's HLO cost analysis counts a ``while`` body ONCE regardless of trip
count, so the production lowering (layer scan + chunked attention +
microbatch scan) under-reports FLOPs/bytes/collectives by the loop trips.
This pass rebuilds each cell in an ANALYSIS configuration where every
loop that matters is structurally removed:

  * layers unrolled (``scan_layers=False``) at 1 and 2 periods,
  * attention in a single chunk (``attn_chunk ≥ seq``  → trip-1 scans),
  * ``grad_accum = 1``;

then two-point-extrapolates every term over the layer axis:

  slope = cost(2p) − cost(1p);  total = cost(1p) − slope + slope·P_full
  (+ tail_layers/period_len · slope for non-multiple hybrids)

Residual under-count: the RWKV6 time recurrence (a per-step scan whose
state-update FLOPs are ~1% of the projection FLOPs at d=4096 — noted, not
corrected).  Memory figures still come from the production dry-run
(dryrun_results.json); this pass yields flops / bytes / collective terms.

    PYTHONPATH=src python -m repro.launch.roofline_exact \
        [--arch X] [--shape Y] [--json out.json] [--variant per_token|...]
"""
import argparse
import dataclasses
import json
import sys
import traceback


def _analysis_cfg(cfg, n_periods: int, seq: int):
    plen = len(cfg.block_pattern)
    kw = dict(
        n_layers=n_periods * plen,
        scan_layers=False,
        attn_chunk=max(seq, cfg.window + 8),
    )
    if cfg.is_encdec:
        kw["n_encoder_layers"] = n_periods
    return dataclasses.replace(cfg, **kw)


def _cell_costs(arch, cfg, shape_name, mesh, policy):
    """(flops, bytes, coll_bytes) per device for one lowered+compiled cell."""
    from repro.launch import roofline
    from repro.launch.cells import build_cell

    cell = build_cell(arch, shape_name, mesh, policy=policy, cfg=cfg,
                      grad_accum=1)
    with mesh:
        compiled = cell.lower(mesh).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = roofline.collective_bytes(compiled.as_text())["total"]
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            coll)


def run_cell_exact(arch: str, shape_name: str, policy=None,
                   multi_pod: bool = False):
    from repro.configs.base import get_config
    from repro.core.policy import paper_policy
    from repro.launch import roofline
    from repro.launch.cells import cell_by_name, is_runnable
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = cell_by_name(shape_name)
    ok, why = is_runnable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "why": why}
    if policy is None:
        policy = paper_policy(8, 16, qgate_skip_layers=())

    mesh = make_production_mesh(multi_pod=multi_pod)
    plen = len(cfg.block_pattern)
    p_full, tail = divmod(cfg.n_layers, plen)

    c1 = _cell_costs(arch, _analysis_cfg(cfg, 1, shape.seq_len),
                     shape_name, mesh, policy)
    c2 = _cell_costs(arch, _analysis_cfg(cfg, 2, shape.seq_len),
                     shape_name, mesh, policy)
    slope = tuple(b - a for a, b in zip(c1, c2))
    enc_scale = 1.0
    if cfg.is_encdec:
        # enc+dec layers were varied together; both stacks have n_layers
        pass
    total = tuple(
        max(a - s, 0.0) + s * (p_full + tail / plen)
        for a, s in zip(c1, slope)
    )
    flops, bytes_acc, coll = total

    hw = roofline.HW()
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1)
    n_dev = mesh.size
    mf = roofline.model_flops(cfg, tokens, shape.kind)
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok",
        "flops_per_device": flops,
        "hbm_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll,
        "roofline_s": {
            "compute": flops / hw.peak_flops,
            "memory": bytes_acc / hw.hbm_bw,
            "collective": coll / hw.link_bw,
        },
        "model_flops": mf,
        "useful_flops_ratio": mf / max(flops * n_dev, 1.0),
        "dominant": max(
            (("compute", flops / hw.peak_flops),
             ("memory", bytes_acc / hw.hbm_bw),
             ("collective", coll / hw.link_bw)), key=lambda kv: kv[1])[0],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--variant", default="per_token",
                    choices=["dense", "per_token", "tile_consensus"])
    args = ap.parse_args(argv)

    from repro.configs.base import ARCH_IDS, SHAPE_CELLS, get_config
    from repro.core.policy import DENSE, paper_policy

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else [c.name for c in SHAPE_CELLS]

    results = []
    fails = 0
    for arch in archs:
        for shape in shapes:
            cfgq = get_config(arch)
            pol = {
                "dense": DENSE,
                "per_token": paper_policy(8, 16, cfgq.qgate_skip_layers),
                "tile_consensus": paper_policy(
                    8, 16, cfgq.qgate_skip_layers, tile_consensus=True),
            }[args.variant]
            tag = f"{arch} × {shape}"
            try:
                r = run_cell_exact(arch, shape, policy=pol,
                                   multi_pod=args.multi_pod)
            except Exception as e:
                traceback.print_exc()
                r = {"arch": arch, "shape": shape, "status": "FAIL",
                     "error": str(e)[:200]}
                fails += 1
            r["variant"] = args.variant
            results.append(r)
            if r["status"] == "ok":
                rf = r["roofline_s"]
                print(f"[exact] {tag}: c={rf['compute']:.3e} "
                      f"m={rf['memory']:.3e} x={rf['collective']:.3e} "
                      f"dom={r['dominant']} useful={r['useful_flops_ratio']:.3f}",
                      flush=True)
            else:
                print(f"[exact] {tag}: {r['status']} {r.get('why', r.get('error',''))}",
                      flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())

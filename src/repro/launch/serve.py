"""Serving driver: Amber-sparse prefill, dense decode.

One-shot batch mode (legacy):

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen2_7b --smoke --sparsity 8:16 --batch 4 --new-tokens 32

Continuous-batching trace mode — Poisson arrivals through the scheduler,
reporting throughput, per-request latency, and retrace counts:

    PYTHONPATH=src python -m repro.launch.serve --smoke --trace \
        --num-requests 16 --rate 0.5 --len-range 8:48 --slots 4 --chunk 16
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--sparsity", default="8:16")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--pallas-kernels", action="store_true",
                    help="route sparse projections through the fused Pallas "
                         "kernels (REPRO_PALLAS_INTERPRET=0 on real TPUs)")
    ap.add_argument("--trace", action="store_true",
                    help="continuous-batching driver: Poisson request "
                         "arrivals, mixed prompt lengths, per-request "
                         "latency + throughput + retrace report")
    ap.add_argument("--num-requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per scheduler iteration (Poisson)")
    ap.add_argument("--len-range", default="8:48",
                    help="uniform prompt-length range lo:hi for --trace")
    ap.add_argument("--slots", type=int, default=4,
                    help="KV-cache slots (decode batch bucket)")
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk size (prefill shape bucket)")
    ap.add_argument("--no-paged", action="store_true",
                    help="dense per-slot KV slab instead of the paged "
                         "block pool")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV rows per block (paged allocator)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="global block-pool size; default covers "
                         "slots*max_seq (no memory pressure) — size it "
                         "lower to exercise admission gating + preemption")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable block-level prefix caching across "
                         "requests (refcounted content-addressed pool; "
                         "on by default under --trace paged serving)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend the same N-token system prompt to every "
                         "--trace request (exercises the prefix cache)")
    ap.add_argument("--ttl", type=int, default=None, metavar="ITERS",
                    help="per-request deadline in scheduler iterations "
                         "(--trace): requests exceeding it end TIMED_OUT")
    ap.add_argument("--no-fused-step", action="store_true",
                    help="legacy two-program iterations (separate prefill "
                         "and decode dispatches) instead of the fused "
                         "one-dispatch step program")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel engine replicas for --trace "
                         "(host-level: independent schedulers + block "
                         "pools behind the admission router)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel shards per replica for --trace; "
                         "dp*tp > 1 needs that many devices (fake them "
                         "with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import time

    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config, get_smoke_config
    from repro.core.policy import DENSE, paper_policy
    from repro.core.pruner import precompute_scales
    from repro.models import build_model
    from repro.serve.engine import ServeConfig, ServingEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n, m = (int(x) for x in args.sparsity.split(":"))
    policy = paper_policy(n, m, cfg.qgate_skip_layers,
                          use_pallas_kernels=args.pallas_kernels)
    params = precompute_scales(params, policy)  # offline Robust-Norm scales

    if args.trace:
        return _trace_mode(args, cfg, model, params, policy)

    scfg = ServeConfig(max_seq=args.prompt_len + args.new_tokens + 8,
                       temperature=args.temperature)
    # the one-shot batch path stays on the legacy engine (it is the
    # monolithic-prefill oracle); _via_api marks first-party use
    engine = ServingEngine(model, policy, scfg, _via_api=True)
    dense_engine = ServingEngine(model, DENSE, scfg, _via_api=True)

    batch = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size),
    }
    if cfg.is_encdec:
        batch["frame_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.encoder_seq, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.vision_stub:
        batch["pixel_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3),
            (args.batch, cfg.n_patches, cfg.d_model)).astype(jnp.bfloat16)

    for name, eng in [("dense", dense_engine), (f"amber {n}:{m}", engine)]:
        t0 = time.perf_counter()
        out = eng.generate(params, batch, max_new_tokens=args.new_tokens)
        out["tokens"].block_until_ready()
        dt = time.perf_counter() - t0
        print(f"[{name:>10s}] generated {out['tokens'].shape} in {dt:.2f}s; "
              f"first row: {out['tokens'][0, :12].tolist()}")

    agree = (dense_engine.generate(params, batch, max_new_tokens=args.new_tokens)
             ["tokens"] == engine.generate(params, batch,
                                           max_new_tokens=args.new_tokens)
             ["tokens"]).mean()
    print(f"greedy-decode agreement dense vs sparse-prefill: {float(agree):.3f}")
    return 0


def _trace_mode(args, cfg, model, params, policy):
    """Poisson-arrival request stream through the serving facade."""
    import jax
    import numpy as np

    from repro.serve.api import Engine, EngineConfig
    from repro.serve.continuous import ContinuousConfig

    rng = np.random.default_rng(args.seed)
    lo, hi = (int(x) for x in args.len_range.split(":"))
    gaps = rng.exponential(1.0 / max(args.rate, 1e-9), args.num_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    lens = rng.integers(lo, hi + 1, args.num_requests)
    max_seq = hi + args.new_tokens + 8

    max_seq += args.shared_prefix
    eng = Engine.from_config(model, EngineConfig(
        dp=args.dp, tp=args.tp,
        serving=ContinuousConfig(
            max_seq=max_seq, num_slots=args.slots, chunk_size=args.chunk,
            temperature=args.temperature, seed=args.seed,
            paged=not args.no_paged, block_size=args.block_size,
            num_blocks=args.num_blocks,
            prefix_cache=not args.no_prefix_cache,
            ttl_default=args.ttl, fused_step=not args.no_fused_step)),
        policy=policy)
    sysp = np.asarray(jax.random.randint(
        jax.random.PRNGKey(99), (args.shared_prefix,), 0, cfg.vocab_size))
    extras = {}
    for i in range(args.num_requests):
        toks = np.asarray(jax.random.randint(
            jax.random.PRNGKey(100 + i), (int(lens[i]),), 0, cfg.vocab_size))
        if args.shared_prefix:
            toks = np.concatenate([sysp, toks])
        rid = eng.submit(toks, max_new_tokens=args.new_tokens,
                         arrival=int(arrivals[i]))
        ex = {}
        if cfg.is_encdec:
            ex["frame_embeds"] = np.asarray(jax.random.normal(
                jax.random.PRNGKey(200 + i),
                (1, cfg.encoder_seq, cfg.d_model)), np.float32)
        if cfg.vision_stub:
            ex["pixel_embeds"] = np.asarray(jax.random.normal(
                jax.random.PRNGKey(300 + i),
                (1, cfg.n_patches, cfg.d_model)), np.float32)
        if ex:
            extras[rid] = ex

    eng.run(params, extras=extras)
    m = eng.metrics          # typed MetricsSnapshot (router-merged when dp>1)
    print(f"# {args.num_requests} requests, λ={args.rate}/iter, "
          f"lens {lo}..{hi}, slots={args.slots}, chunk={args.chunk}, "
          f"dp={args.dp}, tp={args.tp} "
          f"(metrics schema v{m.schema_version})")
    print("rid,prompt_len,arrival,state,first_token_iter,done_iter,"
          "latency_iters,latency_s,n_out,preemptions,retries")
    for r in sorted(m.requests, key=lambda r: r.rid):
        print(f"{r.rid},{r.prompt_len},{r.arrival},{r.state},"
              f"{r.first_token_iter},{r.done_iter},"
              f"{r.latency_iters},{r.latency_s:.3f},{r.n_out},"
              f"{r.preemptions},{r.retries}")
    lat = [r.latency_iters for r in m.requests]
    print(f"# throughput: {m.generated_tokens} tokens in "
          f"{m.wall_s:.2f}s = {m.tokens_per_s:.1f} tok/s "
          f"over {m.iterations} iterations")
    print(f"# latency iters p50/p95: {int(np.percentile(lat, 50))}/"
          f"{int(np.percentile(lat, 95))}")
    lc = m.lifecycle
    ts = lc.terminal_states
    print(f"# terminal states: done={ts.get('done', 0)} "
          f"rejected={ts.get('rejected', 0)} "
          f"timed_out={ts.get('timed_out', 0)} "
          f"cancelled={ts.get('cancelled', 0)}")
    print(f"# lifecycle: degraded_iterations={m.degraded_iterations} "
          f"admission_retries={lc.admission_retries} "
          f"watchdog_trips={lc.watchdog_trips} "
          f"restores={lc.restores} faults_fired={lc.faults_fired}")
    terminal = ("done", "rejected", "timed_out", "cancelled")
    leaked = [r.rid for r in m.requests if r.state not in terminal]
    if leaked:
        print(f"# ERROR: {len(leaked)} request(s) leaked in a non-terminal "
              f"state at drain: rids {leaked}")
        return 1
    tc = ", ".join(f"{k}={v}" for k, v in sorted(m.trace_counts.items()))
    print(f"# traces: {tc} (shape buckets: chunk={args.chunk}, "
          f"decode batch={args.slots})")
    print(f"# dispatches: {m.dispatches} programs / "
          f"{m.iterations} iterations = "
          f"{m.dispatches_per_iteration:.2f} per work iteration "
          f"({'fused one-dispatch step' if not args.no_fused_step else 'legacy two-program split'})")
    if m.replicas is not None:
        per = ", ".join(
            f"r{i}: {p.generated_tokens} tok / {p.iterations} iters / "
            f"dpi {p.dispatches_per_iteration:.2f}"
            for i, p in enumerate(m.replicas))
        print(f"# replicas ({len(m.replicas)}): {per}")
    pg = m.paged
    if pg.enabled:
        print(f"# paged KV: block_size={pg.block_size} "
              f"pool={pg.num_blocks} blocks "
              f"({pg.num_blocks * pg.block_size} rows vs "
              f"{args.slots * max_seq * args.dp} dense-slab rows); "
              f"peak_in_use={pg.peak_blocks_in_use} "
              f"preemptions={pg.preemptions} "
              f"rejections={pg.rejections}; "
              f"attention={'pallas block-walk kernel' if pg.attention_kernel else 'jnp gather oracle'} "
              f"(toggle: --pallas-kernels)")
        if pg.prefix_cache:
            pct = (100.0 * pg.tokens_skipped / max(pg.prefill_tokens, 1))
            print(f"# prefix cache: hits={pg.prefix_hits} requests, "
                  f"blocks_reused={pg.blocks_reused}, "
                  f"tokens_skipped={pg.tokens_skipped}/"
                  f"{pg.prefill_tokens} ({pct:.0f}% of prefill rows), "
                  f"cached_blocks={pg.cached_blocks}, "
                  f"evictions={pg.evictions} "
                  f"(--shared-prefix N to exercise; --no-prefix-cache "
                  f"to disable)")
        else:
            print("# prefix cache: disabled")
    else:
        print("# paged KV: disabled (dense per-slot slab)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving driver: Amber-sparse prefill, dense decode, batched requests.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen2_7b --smoke --sparsity 8:16 --batch 4 --new-tokens 32
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--sparsity", default="8:16")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--pallas-kernels", action="store_true",
                    help="route sparse projections through the fused Pallas "
                         "kernels (REPRO_PALLAS_INTERPRET=0 on real TPUs)")
    args = ap.parse_args(argv)

    import time

    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config, get_smoke_config
    from repro.core.policy import DENSE, paper_policy
    from repro.core.pruner import precompute_scales
    from repro.models import build_model
    from repro.serve.engine import ServeConfig, ServingEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n, m = (int(x) for x in args.sparsity.split(":"))
    policy = paper_policy(n, m, cfg.qgate_skip_layers,
                          use_pallas_kernels=args.pallas_kernels)
    params = precompute_scales(params, policy)  # offline Robust-Norm scales

    scfg = ServeConfig(max_seq=args.prompt_len + args.new_tokens + 8,
                       temperature=args.temperature)
    engine = ServingEngine(model, policy, scfg)
    dense_engine = ServingEngine(model, DENSE, scfg)

    batch = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size),
    }
    if cfg.is_encdec:
        batch["frame_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.encoder_seq, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.vision_stub:
        batch["pixel_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3),
            (args.batch, cfg.n_patches, cfg.d_model)).astype(jnp.bfloat16)

    for name, eng in [("dense", dense_engine), (f"amber {n}:{m}", engine)]:
        t0 = time.perf_counter()
        out = eng.generate(params, batch, max_new_tokens=args.new_tokens)
        out["tokens"].block_until_ready()
        dt = time.perf_counter() - t0
        print(f"[{name:>10s}] generated {out['tokens'].shape} in {dt:.2f}s; "
              f"first row: {out['tokens'][0, :12].tolist()}")

    agree = (dense_engine.generate(params, batch, max_new_tokens=args.new_tokens)
             ["tokens"] == engine.generate(params, batch,
                                           max_new_tokens=args.new_tokens)
             ["tokens"]).mean()
    print(f"greedy-decode agreement dense vs sparse-prefill: {float(agree):.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Production mesh definitions (TPU v5e pods).

Built as FUNCTIONS so importing this module never touches jax device
state — the 512-device dry-run sets XLA_FLAGS before the first jax init
and only then calls these.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) single pod = 256 chips; (2, 16, 16) = 2 pods / 512 chips.

    Axes: DP over ("pod", "data") — gradient/batch parallelism, hierarchical
    reduce (intra-pod reduce-scatter, inter-pod all-reduce chosen by XLA
    from the mesh nesting) — and TP over "model".
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh():
    """1×1 mesh over whatever single device the host has (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh(
        (n, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)

"""Production mesh definitions (TPU v5e pods).

Built as FUNCTIONS so importing this module never touches jax device
state — the 512-device dry-run sets XLA_FLAGS before the first jax init
and only then calls these.
"""
from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_mesh_auto",
           "make_serving_mesh", "abstract_mesh"]


def make_mesh_auto(shape, axes):
    """``jax.make_mesh`` with Auto axis types across the jax API drift.

    Newer jax grew an ``axis_types`` kwarg (and ``jax.sharding.AxisType``)
    for the explicit-sharding mode; Auto is both the new default and the
    only behaviour older versions have, so falling back to the bare call
    is semantically identical.

    Raises ValueError up front when the mesh asks for more devices than
    the backend exposes — ``jax.make_mesh``'s own error talks about array
    reshapes, which buries the actual fix (fewer dp/tp replicas, or more
    fake host devices via XLA_FLAGS).
    """
    want = math.prod(shape)
    have = len(jax.devices())
    if want > have:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {want} devices but the "
            f"backend has {have}; lower dp/tp or export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={want} "
            "BEFORE the first jax call to fake host devices")
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def abstract_mesh(shape, axes):
    """``jax.sharding.AbstractMesh`` across the positional-signature drift:
    newer jax takes ``(shape, axis_names)``, 0.4.x takes one tuple of
    ``(name, size)`` pairs.  Validates partition specs without devices."""
    try:
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) single pod = 256 chips; (2, 16, 16) = 2 pods / 512 chips.

    Axes: DP over ("pod", "data") — gradient/batch parallelism, hierarchical
    reduce (intra-pod reduce-scatter, inter-pod all-reduce chosen by XLA
    from the mesh nesting) — and TP over "model".
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def make_serving_mesh(dp: int = 1, tp: int = 1):
    """``(dp, tp)`` serving mesh with the ("data", "model") axes the
    sharded serving stack expects: the Router slices it into per-replica
    TP submeshes (``distributed.tp.replica_meshes``) and runs one engine
    per ``data`` row.  Validated against the device count up front."""
    return make_mesh_auto((dp, tp), ("data", "model"))


def make_local_mesh():
    """1×1 mesh over whatever single device the host has (tests/examples)."""
    n = len(jax.devices())
    return make_mesh_auto((n, 1), ("data", "model"))

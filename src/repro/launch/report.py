"""Render dryrun_results.json into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report dryrun_results.json
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def render(results, mesh="16x16"):
    rows = [r for r in results if r.get("mesh") == mesh]
    out = []
    out.append(
        "| arch | shape | status | mem/dev GiB (tpu-est) | compute s | "
        "memory s | collective s | dominant | MODEL_FLOPS | useful ratio |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP ({r['why']}) "
                       f"| — | — | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | — | — | — "
                       f"| — | — | — | — |")
            continue
        b = r["bytes_per_device"]
        rf = r["roofline_s"]
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{fmt_bytes(b['total'])} ({fmt_bytes(b['tpu_native_est'])}) | "
            f"{rf['compute']:.3e} | {rf['memory']:.3e} | "
            f"{rf['collective']:.3e} | **{r['dominant']}** | "
            f"{r['model_flops']:.2e} | {r['useful_flops_ratio']:.3f} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### Mesh {mesh}\n")
        print(render(results, mesh))
    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skipped" for r in results)
    fail = len(results) - ok - skip
    print(f"\ncells: {ok} ok / {skip} skipped / {fail} failed "
          f"(of {len(results)})")


if __name__ == "__main__":
    main()

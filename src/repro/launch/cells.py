"""Dry-run cell construction: (arch × shape) → lowered-compilable closure.

``build_cell`` assembles, for one architecture and one input-shape cell:
  * the step function (train_step / prefill_step / serve_step),
  * ShapeDtypeStruct stand-ins for every argument (zero allocation),
  * in/out shardings from the partition rules,
so the dry-run is exactly ``jax.jit(fn, ...).lower(*specs).compile()``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell, SHAPE_CELLS, get_config
from repro.core.policy import DENSE, SparsityPolicy, paper_policy
from repro.distributed import sharding as shd
from repro.models import build_model
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import make_train_step

__all__ = ["Cell", "build_cell", "input_specs", "cell_by_name", "is_runnable"]


@dataclasses.dataclass
class Cell:
    arch: str
    cfg: ModelConfig
    shape: ShapeCell
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()

    def lower(self, mesh: Mesh):
        with mesh:
            jitted = jax.jit(
                self.fn,
                in_shardings=self.in_shardings,
                out_shardings=self.out_shardings,
                donate_argnums=self.donate_argnums,
            )
            return jitted.lower(*self.args)


def cell_by_name(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)


def is_runnable(cfg: ModelConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """long_500k requires sub-quadratic attention (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch at 524k context (skip per spec)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the model inputs of one cell."""
    b = shape.global_batch
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len + 1), i32)}
    elif shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), i32)}
    else:  # decode: one new token against a seq_len-deep cache
        batch = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.is_encdec and shape.kind != "decode":
        batch["frame_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), bf16)
    if cfg.vision_stub and shape.kind != "decode":
        batch["pixel_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), bf16)
    return batch


def _batch_shardings(batch: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    bs = shd.batch_spec(mesh)
    dp_size = 1
    for a in shd.data_axes(mesh):
        dp_size *= mesh.shape[a]
    out = {}
    for k, v in batch.items():
        spec = [None] * len(v.shape)
        if v.shape[0] % dp_size == 0 and v.shape[0] >= dp_size:
            spec[0] = bs[0]
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def _abstract_params(model) -> Any:
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def build_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    policy: Optional[SparsityPolicy] = None,
    cfg: Optional[ModelConfig] = None,
    grad_accum: int = 16,
) -> Cell:
    cfg = cfg or get_config(arch)
    shape = cell_by_name(shape_name)
    ok, why = is_runnable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} × {shape_name} not runnable: {why}")
    if policy is None:
        # paper-faithful baseline: Amber-P 8:16 with the published skip list
        policy = paper_policy(8, 16, qgate_skip_layers=cfg.qgate_skip_layers)
    model = build_model(cfg)

    params = _abstract_params(model)
    # train: FSDP (ZeRO-3) param sharding — multi-B-param training cannot
    # fit TP-only on 16 GB chips; inference: TP-only (no per-step gathers)
    pspecs = shd.param_specs(params, mesh, cfg.n_experts,
                             fsdp=(shape.kind == "train"))
    pshard = shd.named(mesh, pspecs)
    batch = input_specs(cfg, shape)
    bshard = _batch_shardings(batch, mesh)

    if shape.kind == "train":
        opt = jax.eval_shape(adamw_init, params)
        oshard = shd.named(mesh, shd.opt_state_specs(pspecs, params, mesh))
        # training runs dense by default (the paper confines sparsity to
        # prefill); pass a policy with phases=("train",) for ablations.
        # grad_accum microbatches bound activation memory AND let XLA
        # overlap each microbatch's DP reduce with the next one's compute.
        # the microbatch must stay divisible by the DP degree.
        dp_size = 1
        for a in shd.data_axes(mesh):
            dp_size *= mesh.shape[a]
        ga = max(grad_accum, 1)
        while ga > 1 and (shape.global_batch % ga != 0
                          or (shape.global_batch // ga) % dp_size != 0):
            ga -= 1
        step = make_train_step(model, OptConfig(), policy, grad_accum=ga)
        return Cell(
            arch=arch, cfg=cfg, shape=shape, fn=step,
            args=(params, opt, batch),
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )

    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    cshard = shd.named(mesh, shd.cache_specs(cache, cfg, mesh))

    if shape.kind == "prefill":
        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache, policy=policy)

        return Cell(
            arch=arch, cfg=cfg, shape=shape, fn=prefill_step,
            args=(params, batch, cache),
            in_shardings=(pshard, bshard, cshard),
            out_shardings=(None, cshard),
            donate_argnums=(2,),
        )

    # decode: cache is pre-filled to seq_len-1; one serve step
    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache, policy=policy)

    tshard = bshard["tokens"]
    return Cell(
        arch=arch, cfg=cfg, shape=shape, fn=serve_step,
        args=(params, batch["tokens"], cache),
        in_shardings=(pshard, tshard, cshard),
        out_shardings=(None, cshard),
        donate_argnums=(2,),
    )

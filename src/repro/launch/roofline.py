"""Roofline-term derivation from a compiled dry-run artifact.

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Terms (per device — post-SPMD HLO shapes are per-device):
  compute    = flops / peak_flops
  memory     = bytes_accessed / hbm_bw
  collective = ring-model traffic of every all-gather / all-reduce /
               reduce-scatter / all-to-all / collective-permute parsed from
               the compiled HLO text / link_bw

``cost_analysis()`` provides flops & bytes; collective bytes are NOT in it,
so we regex the per-op result shapes out of the HLO and apply ring-cost
factors (all-reduce 2×result, all-gather 1×result, reduce-scatter
(g-1)×result, all-to-all 1×, permute 1×).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = ["HW", "RooflineReport", "analyze", "collective_bytes",
           "model_flops"]

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    link_bw: float = LINK_BW


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

# e.g.  %all-reduce.5 = f32[8,128]{1,0} all-reduce(
_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Ring-model per-device traffic by collective kind, from HLO text."""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        size = _shape_bytes(dtype, dims)
        # group size for reduce-scatter scaling
        g = 2
        gm = _GROUPS_RE.search(hlo_text, m.end(), m.end() + 2000)
        if gm:
            g = len(gm.group(1).split(","))
        factor = {
            "all-reduce": 2.0,
            "all-gather": 1.0,
            "reduce-scatter": float(max(g - 1, 1)),
            "all-to-all": 1.0,
            "collective-permute": 1.0,
        }[kind]
        out[kind] = out.get(kind, 0.0) + size * factor
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def model_flops(cfg, tokens: int, kind: str) -> float:
    """Analytic useful FLOPs: 6·N·D train / 2·N·D inference (N = active)."""
    n = cfg.n_active_params() if cfg.n_experts else cfg.n_params()
    per_tok = 6.0 if kind == "train" else 2.0
    return per_tok * n * tokens


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_total: float
    peak_mem_bytes: Optional[float] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops × n_devices) — remat/redundancy waste."""
        return self.model_flops_total / max(self.flops_per_dev, 1.0)

    def row(self, n_dev: int) -> str:
        total_hlo = self.flops_per_dev * n_dev
        useful = self.model_flops_total / max(total_hlo, 1.0)
        frac = max(self.compute_s, 1e-30) / max(
            self.compute_s + 0.0, 1e-30)
        return (
            f"{self.arch:24s} {self.shape:12s} {self.mesh:9s} "
            f"{self.compute_s:10.3e} {self.memory_s:10.3e} "
            f"{self.collective_s:10.3e} {self.dominant:10s} "
            f"{useful:8.3f}"
        )


def analyze(
    arch: str,
    shape_name: str,
    mesh_name: str,
    compiled,
    cfg,
    kind: str,
    tokens: int,
    hw: HW = HW(),
) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)["total"]
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(ma.temp_size_in_bytes + ma.argument_size_in_bytes +
                    ma.output_size_in_bytes)
    except Exception:
        pass
    return RooflineReport(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        flops_per_dev=flops,
        bytes_per_dev=bytes_acc,
        coll_bytes_per_dev=coll,
        compute_s=flops / hw.peak_flops,
        memory_s=bytes_acc / hw.hbm_bw,
        collective_s=coll / hw.link_bw,
        model_flops_total=model_flops(cfg, tokens, kind),
        peak_mem_bytes=mem,
    )

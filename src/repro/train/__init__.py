from repro.train.optimizer import OptConfig, adamw_init, adamw_update, cosine_lr
from repro.train.train_step import loss_fn, make_train_step

__all__ = [
    "OptConfig",
    "adamw_init",
    "adamw_update",
    "cosine_lr",
    "loss_fn",
    "make_train_step",
]

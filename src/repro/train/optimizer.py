"""AdamW + cosine schedule + global-norm clipping, pure-pytree JAX.

No optax dependency — the optimizer state is a plain pytree (mu, nu, step)
so it checkpoints and re-shards exactly like parameters (the opt state
inherits each parameter's PartitionSpec → fully sharded optimizer, ZeRO-1
style, for free under pjit).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update", "cosine_lr",
           "global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    min_lr_frac: float = 0.1


def cosine_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                    * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    cfg: OptConfig, grads: Any, opt_state: dict, params: Any
) -> Tuple[Any, dict]:
    step = opt_state["step"] + 1
    lr = cosine_lr(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) * scale, grads)

    b1t = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        from repro.distributed.sharding import shard_zero1

        # run the f32 update chain DP-sharded (free slice when params are
        # replicated, identity when params are FSDP-sharded); the cast back
        # happens before any re-gather so f32 temporaries stay sharded
        p32 = shard_zero1(p.astype(jnp.float32))
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1t
        nhat = nu / b2t
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        wd = cfg.weight_decay * p32 if p.ndim >= 2 else 0.0
        newp = p32 - lr * (delta + wd)
        return newp.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(opt_state["mu"])
    flat_nu = jax.tree_util.tree_leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}

"""Training step: loss, grads, microbatch accumulation, compression hook.

``make_train_step`` builds the jit-able function lowered by the dry-run:

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics)

Distributed behaviour comes from pjit shardings on the arguments; the step
body itself is mesh-agnostic.  Gradient accumulation runs as a
``lax.scan`` over microbatches — with ``grad_accum > 1`` XLA's
latency-hiding scheduler overlaps the DP gradient reduce of microbatch i
with the compute of microbatch i+1 (the compute/comm overlap lever
recorded in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import DENSE, SparsityPolicy
from repro.train.optimizer import OptConfig, adamw_update, global_norm

__all__ = ["loss_fn", "make_train_step"]


def loss_fn(
    model,
    params: Any,
    batch: Dict[str, jax.Array],
    policy: SparsityPolicy = DENSE,
) -> jax.Array:
    """Next-token cross-entropy in f32 (tokens (B, S+1) → inputs/labels)."""
    tokens = batch["tokens"]
    inp = {**batch, "tokens": tokens[:, :-1]}
    labels = tokens[:, 1:]
    logits = model.forward(params, inp, policy=policy, phase="train")
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def make_train_step(
    model,
    opt_cfg: OptConfig,
    policy: SparsityPolicy = DENSE,
    grad_accum: int = 1,
    compressor: Optional[Callable[[Any], Any]] = None,
) -> Callable:
    """Returns step_fn(params, opt_state, batch) → (params, opt, metrics).

    Args:
      grad_accum:  microbatches per step (global batch split on the leading
                   axis; must divide the per-step batch).
      compressor:  optional gradient transform applied before the optimizer
                   (e.g. distributed.compression.ErrorFeedbackInt8).
    """

    def compute_grads(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(model, p, batch, policy)
        )(params)

    def step_fn(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = compute_grads(params, batch)
        else:
            tokens = batch["tokens"]
            b = tokens.shape[0]
            assert b % grad_accum == 0, (b, grad_accum)
            mb = b // grad_accum
            micro = {
                k: v.reshape(grad_accum, mb, *v.shape[1:])
                for k, v in batch.items()
            }

            def accum(carry, mbatch):
                from repro.distributed.sharding import shard_zero1

                loss_acc, g_acc = carry
                loss_i, g_i = compute_grads(params, mbatch)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / grad_accum,
                    g_acc, g_i)
                # ZeRO-2: keep the f32 accumulator DP-sharded — XLA emits a
                # reduce-scatter per microbatch instead of a replicated
                # all-reduce at the end
                g_acc = shard_zero1(g_acc)
                return (loss_acc + loss_i / grad_accum, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(accum, (jnp.float32(0), g0), micro)

        if compressor is not None:
            grads = compressor(grads)

        new_params, new_opt = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = {
            "loss": loss,
            "grad_norm": global_norm(grads),
            "step": new_opt["step"],
        }
        return new_params, new_opt, metrics

    return step_fn

"""Training loop with fault tolerance and straggler monitoring.

Restart contract (1000-node story):
  * checkpoints are atomic + topology-agnostic (see repro/checkpoint);
  * the data pipeline is a pure function of the step counter — a resumed
    run consumes byte-identical batches;
  * ``resume='auto'`` picks up the newest checkpoint after any crash;
  * per-step wall-times keep a running median watermark; steps slower than
    ``straggler_factor ×`` median are logged (on a real multi-host fleet
    this feeds the controller that evicts/re-shards around slow hosts —
    here it is surfaced in metrics so the hook is testable).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint import CheckpointManager
from repro.core.policy import DENSE, SparsityPolicy
from repro.data.pipeline import DataConfig, lm_batch
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    grad_accum: int = 1
    straggler_factor: float = 2.0
    resume: str = "auto"            # auto | none


class Trainer:
    def __init__(
        self,
        model,
        data_cfg: DataConfig,
        opt_cfg: OptConfig,
        cfg: TrainerConfig,
        policy: SparsityPolicy = DENSE,
        shardings: Optional[Dict[str, Any]] = None,
    ):
        self.model = model
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg
        self.cfg = cfg
        self.policy = policy
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        step_fn = make_train_step(model, opt_cfg, policy,
                                  grad_accum=cfg.grad_accum)
        if shardings:
            self.step_fn = jax.jit(
                step_fn,
                in_shardings=(shardings["params"], shardings["opt"],
                              shardings["batch"]),
                out_shardings=(shardings["params"], shardings["opt"], None),
            )
        else:
            self.step_fn = jax.jit(step_fn)
        self._times: List[float] = []

    def init_state(self, rng) -> Dict[str, Any]:
        params = self.model.init(rng)
        return {"params": params, "opt": adamw_init(params)}

    def run(
        self,
        rng,
        hooks: Optional[Callable[[int, Dict], None]] = None,
        crash_at: Optional[int] = None,     # test hook: simulated failure
    ) -> Dict[str, Any]:
        state = self.init_state(rng)
        start = 0
        if self.cfg.resume == "auto":
            latest = self.ckpt.latest()
            if latest is not None:
                state = self.ckpt.restore(latest, state)
                start = latest
        metrics_hist = []
        for step in range(start, self.cfg.total_steps):
            if crash_at is not None and step == crash_at:
                raise RuntimeError(f"simulated node failure at step {step}")
            batch = lm_batch(self.data_cfg, step)
            t0 = time.perf_counter()
            params, opt, metrics = self.step_fn(state["params"], state["opt"],
                                                batch)
            metrics["loss"].block_until_ready()
            dt = time.perf_counter() - t0
            state = {"params": params, "opt": opt}

            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_time_s"] = dt
            metrics["straggler"] = self._straggler(dt)
            metrics_hist.append(metrics)
            if hooks:
                hooks(step, metrics)
            if (step + 1) % self.cfg.ckpt_every == 0 or \
                    step + 1 == self.cfg.total_steps:
                self.ckpt.save(step + 1, state)
        return {"state": state, "metrics": metrics_hist,
                "resumed_from": start}

    def _straggler(self, dt: float) -> bool:
        self._times.append(dt)
        if len(self._times) < 5:
            return False
        med = statistics.median(self._times[-50:])
        return dt > self.cfg.straggler_factor * med

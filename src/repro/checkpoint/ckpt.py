"""Fault-tolerant checkpointing (no orbax dependency in this container).

Properties needed at 1000-node scale, all implemented here:
  * **Atomic**: write to ``<name>.tmp`` then ``os.replace`` — a crash
    mid-write never corrupts the latest checkpoint.
  * **Topology-agnostic**: arrays are saved fully-replicated-logical
    (``jax.device_get`` gathers shards), so a restart may use a different
    mesh shape — the load path re-shards via ``jax.device_put`` with the
    *new* mesh's NamedShardings (elastic re-scale).
  * **Auto-resume**: ``CheckpointManager.latest()`` finds the newest valid
    step; the trainer resumes from it after any failure, and the stateless
    data pipeline replays the exact stream from the step counter.
  * **Keep-K GC** with the newest always protected.

(On a real multi-host deployment the ``device_get``/single-file format
would be swapped for per-host sharded files + a commit marker; the manager
API is written so only ``_write``/``_read`` change.)
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]

_SEP = "//"


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out[key] = np.asarray(jax.device_get(leaf))
    return out, treedef


def save_checkpoint(path: str, tree: Any) -> None:
    arrays, _ = _flatten(tree)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)  # atomic on POSIX


def load_checkpoint(path: str, like: Any, shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like``; optionally re-shard onto a
    (possibly different) mesh via ``shardings`` (a matching pytree)."""
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path_elems, leaf in flat:
            key = _SEP.join(
                str(p.key) if hasattr(p, "key") else str(p.idx)
                for p in path_elems
            )
            arr = data[key]
            leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


class CheckpointManager:
    """Step-indexed checkpoints with keep-K GC and auto-resume."""

    _PAT = re.compile(r"ckpt_(\d+)\.npz$")

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def steps(self):
        out = []
        for f in os.listdir(self.dir):
            m = self._PAT.match(f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree: Any) -> str:
        p = self.path(step)
        save_checkpoint(p, tree)
        self._gc()
        return p

    def restore(self, step: int, like: Any, shardings=None) -> Any:
        return load_checkpoint(self.path(step), like, shardings)

    def restore_latest(self, like: Any, shardings=None) -> Tuple[Optional[int], Any]:
        s = self.latest()
        if s is None:
            return None, like
        return s, self.restore(s, like, shardings)

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            try:
                os.remove(self.path(s))
            except OSError:
                pass

"""Partitioning rules: DP (pod × data) × TP/EP (model) for the whole zoo.

Megatron-style tensor parallelism over the ``model`` axis with
**divisibility-aware fallbacks** (a dim is sharded only when the mesh axis
divides it — e.g. whisper's vocab 51865 is odd → its embedding replicates;
granite's kv_heads=1 → KV caches replicate over model and shard on batch):

  * column-parallel (out-dim on model): q/k/v/gate/up, rwkv r/k/v/g,
    rg-lru in/gate, lm_head, router-free expert up/gate;
  * row-parallel (in-dim on model):     o_proj, down_proj, rg-lru out;
  * expert-parallel:                    MoE expert stacks shard the expert
    axis when n_experts % model == 0 (llama4-scout: 16/16 → pure EP),
    falling back to intra-expert TP otherwise (mixtral: 8 experts → d_ff);
  * everything 1D (norms, scales, biases of row-parallel layers) replicates;
    biases of column-parallel layers follow the out-dim.

Leading stack axes (scan periods, experts) are skipped by matching the
*trailing* dims, so the same rule covers unrolled and stacked params.

The optimizer state reuses the parameter specs leaf-for-leaf (mu/nu have
identical shapes) — a fully sharded (ZeRO-1-like) optimizer under pjit.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "data_axes",
    "param_specs",
    "batch_spec",
    "cache_specs",
    "opt_state_specs",
    "named",
]

# module names whose weight is column-parallel (shard trailing dim)
_COL = {
    "q_proj", "k_proj", "v_proj", "gate_proj", "up_proj", "lm_head",
    "r_proj", "k_proj_tm", "v_proj_tm", "g_proj", "gate_a", "gate_x",
}
# row-parallel (shard the d_in dim, i.e. dim -2)
_ROW = {"o_proj", "down_proj"}


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The DP axes: ("pod", "data") on a multi-pod mesh, else ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return n % mesh.shape[axis] == 0


def _spec_for(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
              n_experts: int) -> P:
    names = [p for p in path]
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    gparent = names[-3] if len(names) >= 3 else ""
    nd = len(shape)
    none = (None,) * nd

    def shard_dim(i: int) -> P:
        if not _div(shape[i], mesh, "model"):
            return P(*none)
        out = list(none)
        out[i] = "model"
        return P(*out)

    # --- embeddings ---
    if parent == "embed" and leaf == "w":
        # (V, d): prefer vocab sharding, fall back to d_model
        if _div(shape[0], mesh, "model"):
            return shard_dim(0)
        return shard_dim(1)

    # --- MoE expert stacks: (..., E, d_in, d_out) under "experts" ---
    # Default: intra-expert TP (shard d_ff) — ragged_dot's GSPMD support for
    # an expert-sharded rhs is not guaranteed, so EP (sharding the E axis)
    # is a perf-iteration lever rather than the baseline (EXPERIMENTS §Perf).
    if gparent == "experts" or (len(names) >= 4 and names[-4] == "experts"):
        if parent in _COL:
            return shard_dim(nd - 1)
        if parent in _ROW:
            return shard_dim(nd - 2)
        return P(*none)

    if leaf == "w" and parent in _COL and nd >= 2:
        return shard_dim(nd - 1)
    if leaf == "w" and parent in _ROW and nd >= 2:
        return shard_dim(nd - 2)
    if leaf == "b" and parent in _COL:
        return shard_dim(nd - 1)
    if leaf == "amber_scale" and parent in _ROW:
        # scale has length d_in — matches the sharded contraction dim
        return shard_dim(nd - 1)
    if leaf == "w" and parent == "router":
        return P(*none)
    if leaf in ("conv_w", "conv_b", "lam", "w0", "w_A", "w_B", "u",
                "mix_r", "mix_k", "mix_v", "mix_w", "mix_g"):
        return P(*none)
    return P(*none)


def param_specs(params: Any, mesh: Mesh, n_experts: int = 0,
                fsdp: bool = False) -> Any:
    """PartitionSpec pytree mirroring ``params`` (works on ShapeDtypeStructs).

    ``fsdp=True`` additionally shards each tensor's largest still-free dim
    over the DP axes (ZeRO-3): params live fully sharded and are
    all-gathered per layer by XLA at use.  This is how >10B-param training
    fits a 16 GB/chip pod; inference cells keep TP-only specs (weights are
    read once per token there, FSDP would gather every step).
    """
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)

    def visit(path, leaf):
        keys = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        spec = _spec_for(keys, leaf.shape, mesh, n_experts)
        if not fsdp or dp_entry is None:
            return spec
        spec_t = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
        dims = sorted(range(len(leaf.shape)), key=lambda i: -leaf.shape[i])
        for i in dims:
            if spec_t[i] is None and leaf.shape[i] % dp_size == 0 \
                    and leaf.shape[i] >= dp_size:
                out = list(spec_t)
                out[i] = dp_entry
                return P(*out)
        return spec

    return jax.tree_util.tree_map_with_path(visit, params)


def batch_spec(mesh: Mesh) -> P:
    """Token batches: batch dim over all DP axes."""
    dp = data_axes(mesh)
    return P(dp if len(dp) > 1 else dp[0])


def cache_specs(cache: Any, cfg, mesh: Mesh) -> Any:
    """KV/state caches: shard batch; heads on model when divisible.

    Cache layouts (see models/transformer.py):
      attn k/v:  (..., B, S, Hkv, hd) — batch on DP, Hkv on model if div.
      rwkv S:    (..., B, H, hd, hd)  — batch on DP, H on model if div.
      states:    (..., B, d)          — batch on DP.
    ``...`` = optional leading layer-stack axes.
    """
    dp = data_axes(mesh)
    dp_entry = dp if len(dp) > 1 else dp[0]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def visit(path, leaf):
        keys = [p.key if hasattr(p, "key") else str(p) for p in path]
        leafname = keys[-1]
        nd = len(leaf.shape)
        if leafname == "pos":
            return P()
        spec = [None] * nd

        def set_batch(i):
            if leaf.shape[i] % dp_size == 0 and leaf.shape[i] >= dp_size:
                spec[i] = dp_entry

        if leafname in ("k", "v", "self_k", "self_v", "cross_k", "cross_v"):
            b_dim = nd - 4
            s_dim = nd - 3
            h_dim = nd - 2
            set_batch(b_dim)
            if cfg.n_kv_heads and leaf.shape[h_dim] % mesh.shape["model"] == 0 \
                    and leaf.shape[h_dim] >= mesh.shape["model"]:
                spec[h_dim] = "model"
            elif leaf.shape[s_dim] % mesh.shape["model"] == 0 \
                    and leaf.shape[s_dim] >= mesh.shape["model"]:
                # context parallelism: GQA/MQA archs whose few KV heads
                # cannot split over TP shard the cache on SEQUENCE instead —
                # decode attention renormalizes online-softmax partials with
                # O(B·H) collectives while cache reads divide by TP degree
                # (measured −65% memory term on granite decode, §Perf C)
                spec[s_dim] = "model"
            return P(*spec)
        if leafname == "S":  # rwkv6 state (..., B, H, hd, hd)
            set_batch(nd - 4)
            if leaf.shape[nd - 3] % mesh.shape["model"] == 0:
                spec[nd - 3] = "model"
            return P(*spec)
        if leafname in ("tm_shift", "cm_shift", "h"):  # (..., B, d)
            set_batch(nd - 2)
            return P(*spec)
        if leafname == "conv":  # (..., B, cw-1, d)
            set_batch(nd - 3)
            return P(*spec)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(visit, cache)


def opt_state_specs(param_spec_tree: Any, params: Any = None,
                    mesh: Optional[Mesh] = None) -> Any:
    """Optimizer-state specs: ZeRO-1 when shapes+mesh are given.

    mu/nu start from each parameter's spec (TP), then additionally shard
    the largest still-unsharded dim over the DP axes when divisible —
    the f32 moments are 4× the bf16 params and do NOT participate in the
    forward pass, so replicating them across data (what plain mirroring
    does) wastes the dominant slice of HBM.  XLA inserts the ZeRO
    reduce-scatter/all-gather pair around the update automatically.
    """
    from jax.sharding import PartitionSpec

    if params is None or mesh is None:
        return {"mu": param_spec_tree, "nu": param_spec_tree,
                "step": PartitionSpec()}

    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    dp_entry = dp if len(dp) > 1 else dp[0]

    def widen(spec, leaf):
        spec_t = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
        flat = []
        for s in spec_t:
            flat.extend(s if isinstance(s, tuple) else (s,))
        if any(a in flat for a in dp):
            return P(*spec_t)  # already DP-sharded (FSDP params)
        dims = sorted(range(len(leaf.shape)),
                      key=lambda i: -leaf.shape[i])
        for i in dims:
            if spec_t[i] is None and leaf.shape[i] % dp_size == 0 \
                    and leaf.shape[i] >= dp_size:
                out = list(spec_t)
                out[i] = dp_entry
                return P(*out)
        return P(*spec_t)

    moment_specs = jax.tree_util.tree_map(
        widen, param_spec_tree, params,
        is_leaf=lambda x: isinstance(x, P))
    return {"mu": moment_specs, "nu": moment_specs, "step": PartitionSpec()}


def _context_mesh() -> Optional[Mesh]:
    """The mesh from an enclosing ``with mesh:`` block, or None."""
    try:
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def maybe_shard(x: jax.Array, *axes) -> jax.Array:
    """Sharding constraint by trailing-dim axis names, no-op off-mesh.

    ``axes`` gives one entry per dim: an axis name, a tuple of names, "dp"
    (expands to the mesh's DP axes), or None.  A dim is constrained only if
    its size divides the named axis product — otherwise left to GSPMD.
    """
    mesh = _context_mesh()
    if mesh is None:
        return x
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        if ax == "dp":
            names = data_axes(mesh)
            ax = names if len(names) > 1 else names[0]
        sz = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            if a not in mesh.axis_names:
                sz = 0
                break
            sz *= mesh.shape[a]
        if sz and dim % sz == 0:
            spec.append(ax)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def shard_zero1(tree: Any) -> Any:
    """ZeRO-style constraint: shard each leaf's largest un-sharded dim over
    the DP axes (divisibility-checked).  No-op off-mesh.  Used for the f32
    gradient accumulator so it is reduce-scattered per microbatch instead
    of living replicated (ZeRO-2 behaviour under pjit)."""
    mesh = _context_mesh()
    if mesh is None:
        return tree
    dp = data_axes(mesh)
    if not dp:
        return tree
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    dp_entry = dp if len(dp) > 1 else dp[0]

    def one(x):
        if not hasattr(x, "shape") or x.ndim == 0:
            return x
        dims = sorted(range(x.ndim), key=lambda i: -x.shape[i])
        for i in dims:
            if x.shape[i] % dp_size == 0 and x.shape[i] >= dp_size:
                spec = [None] * x.ndim
                spec[i] = dp_entry
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(*spec)))
        return x

    return jax.tree_util.tree_map(one, tree)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )

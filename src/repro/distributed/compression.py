"""Gradient compression with error feedback (distributed-optimization trick).

Int8 stochastic-free symmetric quantization with a persistent error-feedback
buffer: the quantization residual of step t is added back to the gradient of
step t+1, so the *accumulated* update is unbiased (Karimireddy et al. 2019,
"EF-SGD").  On a real multi-pod deployment the int8 tensors ride the
cross-pod DCI/ICI all-reduce at 4× less traffic — the cross-pod DP reduce is
the collective this targets (see EXPERIMENTS.md §Roofline, collective term).

Under single-controller pjit the collective itself is emitted by XLA, so
this module implements the *algorithmic* transform (quantize → dequantize →
error feedback) as a gradient-pipeline stage; the lowering-level traffic
reduction is modeled in the roofline analysis (collective bytes ÷ 4 for the
DP all-reduce component when compression is on).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ef_int8_init", "ef_int8_compress"]

_EPS = 1e-12


def _quant_dequant(g: jax.Array) -> jax.Array:
    """Symmetric per-tensor int8 round-trip (the lossy channel)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), _EPS) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    return q * scale


def ef_int8_init(params: Any) -> Any:
    """Zero error-feedback buffers mirroring the parameter pytree."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_int8_compress(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """(grads, ef) → (compressed grads, new ef).

    compressed = Q(g + ef);  new_ef = (g + ef) − compressed.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        comp = _quant_dequant(target)
        return comp, target - comp

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in outs]), tdef.unflatten(
        [o[1] for o in outs])

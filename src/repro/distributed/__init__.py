from repro.distributed.sharding import (
    batch_spec,
    cache_specs,
    data_axes,
    param_specs,
)

__all__ = ["batch_spec", "cache_specs", "data_axes", "param_specs"]

"""Tensor-parallel kernel sharding: a trace-time TP scope + shard_map
helpers.

The serving executor activates a :func:`scope` around every step-program
dispatch; inside it, the kernel wrappers in :mod:`repro.kernels.ops` and
the paged-attention dispatch in :mod:`repro.models.attention` consult
:func:`current` at **trace time** and, when the relevant axis divides,
wrap their Pallas call in a ``shard_map`` over the mesh's model axis:

* projection kernels (``nm_prune_matmul`` / ``nm_spmm`` /
  ``osparse_matmul`` / ``w8a8_matmul``) shard **N_out** — Megatron
  column-parallel: every device holds the full activations and a column
  slice of the weights, computes its output columns exactly as the
  single-device kernel would, and an ``all_gather(tiled=True)``
  concatenates them in axis order.  No cross-device reduction touches
  the accumulator, so the result is **bit-identical** to the unsharded
  kernel (the dp=2/tp=2 token-identity acceptance gate relies on this);
* ``paged_attention`` / ``paged_kv_scatter`` shard **KV heads**: heads
  are independent, the kernel's GQA index map (``h // g``) is preserved
  because Hq and Hkv divide by the same factor, and outputs gather (or
  stay head-sharded, for the pools) with no collectives inside the
  softmax.

Row-parallel layers (o_proj / down_proj contractions) are deliberately
NOT sharded: their ``psum`` would reorder float adds and break bit
identity.  Sharding them is the documented next step once the acceptance
gate moves from "token-identical" to "allclose" (serve/README.md).

The scope is read at trace time only — the lowered programs bake the
sharding in, exactly like the policy flags — so activating/deactivating
it never retraces an already-compiled bucket.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

try:                                    # jax ≥ 0.4.35 moved it
    from jax.experimental.shard_map import shard_map
except ImportError:                     # pragma: no cover - drift shim
    from jax.sharding import shard_map  # type: ignore

__all__ = ["TPScope", "scope", "current", "degree", "column_parallel",
           "head_sharded_attention", "head_sharded_scatter",
           "replica_meshes"]


@dataclasses.dataclass(frozen=True)
class TPScope:
    mesh: Mesh
    axis: str = "model"

    @property
    def size(self) -> int:
        return self.mesh.shape[self.axis]


_CURRENT: Optional[TPScope] = None


def current() -> Optional[TPScope]:
    return _CURRENT


def degree() -> int:
    return _CURRENT.size if _CURRENT is not None else 1


@contextlib.contextmanager
def scope(mesh: Optional[Mesh], axis: str = "model"):
    """Activate a TP scope for the dynamic extent (trace-time dispatch
    decisions only).  ``mesh=None`` (or a 1-sized axis) is a no-op scope
    so callers can wrap unconditionally."""
    global _CURRENT
    if mesh is None or mesh.shape.get(axis, 1) <= 1:
        yield None
        return
    prev = _CURRENT
    _CURRENT = TPScope(mesh, axis)
    try:
        yield _CURRENT
    finally:
        _CURRENT = prev


@contextlib.contextmanager
def _suspended():
    """Clear the scope while tracing a shard_map body: the per-shard
    kernel call must not re-enter the column-parallel branch."""
    global _CURRENT
    prev, _CURRENT = _CURRENT, None
    try:
        yield
    finally:
        _CURRENT = prev


def _col_spec(a: jax.Array, axis_name: str) -> P:
    """Partition an array along its LAST axis."""
    return P(*([None] * (a.ndim - 1) + [axis_name]))


def column_parallel(fn, cols, out_axis: int = -1):
    """Run ``fn(*cols)`` column-parallel over the active TP scope.

    ``cols`` are the column-aligned operands (weights ``(K, N)``, biases /
    scales ``(N,)``) — each is sharded along its last axis; everything
    else (activations, K-aligned scales) must be closed over by ``fn``
    and is replicated.  The per-shard outputs are ``all_gather``ed
    (tiled) along ``out_axis``, so the caller sees the full array,
    bit-identical to the unsharded call.

    Returns None when no scope is active or any column axis does not
    divide — callers fall through to the unsharded path."""
    ctx = current()
    if ctx is None:
        return None
    tp = ctx.size
    real = [c for c in cols if c is not None]
    if not real or any(c.shape[-1] % tp for c in real):
        return None
    in_specs = tuple(P() if c is None else _col_spec(c, ctx.axis)
                     for c in cols)

    def body(*local):
        with _suspended():
            y = fn(*local)
        return jax.lax.all_gather(y, ctx.axis, axis=out_axis % y.ndim,
                                  tiled=True)

    return shard_map(body, mesh=ctx.mesh, in_specs=in_specs,
                     out_specs=P(), check_rep=False)(*cols)


def head_sharded_attention(fn, q, k_pool, v_pool, rest):
    """Shard a paged-attention call over KV heads: ``q`` splits on its
    Hq axis, the pools on their Hkv axis (both axis 2), the block table /
    offsets / lengths in ``rest`` replicate, and the per-shard outputs
    gather back along the head axis.  Per-head computation is exact, so
    the gathered result is bit-identical.  Returns None when no scope is
    active or the head counts do not divide."""
    ctx = current()
    if ctx is None:
        return None
    tp = ctx.size
    hq, hkv = q.shape[2], k_pool.shape[2]
    if hq % tp or hkv % tp or (hq // tp) % (hkv // tp):
        return None
    hs = P(None, None, ctx.axis)

    def body(q_, kp_, vp_, *rest_):
        with _suspended():
            y = fn(q_, kp_, vp_, *rest_)
        return jax.lax.all_gather(y, ctx.axis, axis=2, tiled=True)

    in_specs = (hs, hs, hs) + tuple(P() for _ in rest)
    return shard_map(body, mesh=ctx.mesh, in_specs=in_specs,
                     out_specs=P(), check_rep=False)(q, k_pool, v_pool,
                                                     *rest)


def head_sharded_scatter(fn, k_new, v_new, k_pool, v_pool, rest):
    """Shard a paged KV scatter over KV heads: new rows and pools split
    on their head axis (axis 2), table/pos/len replicate, and the
    updated pools come back **gathered** (replicated) so the cache
    pytree stays a plain replicated array between steps.  Returns None
    when no scope is active or Hkv does not divide."""
    ctx = current()
    if ctx is None:
        return None
    tp = ctx.size
    if k_new.shape[2] % tp:
        return None
    hs = P(None, None, ctx.axis)

    def body(kn_, vn_, kp_, vp_, *rest_):
        with _suspended():
            k2, v2 = fn(kn_, vn_, kp_, vp_, *rest_)
        return (jax.lax.all_gather(k2, ctx.axis, axis=2, tiled=True),
                jax.lax.all_gather(v2, ctx.axis, axis=2, tiled=True))

    in_specs = (hs, hs, hs, hs) + tuple(P() for _ in rest)
    return shard_map(body, mesh=ctx.mesh, in_specs=in_specs,
                     out_specs=(P(), P()), check_rep=False)(
                         k_new, v_new, k_pool, v_pool, *rest)


def replica_meshes(mesh: Mesh, dp_axis: str = "data",
                   tp_axis: str = "model") -> List[Mesh]:
    """Slice a ``(dp, tp)`` serving mesh into per-replica TP submeshes:
    replica *i* gets ``mesh.devices[i]`` as a 1-axis ``(tp,)`` mesh.
    The router runs one engine per submesh; dp replication itself is
    host-level (no collectives span the dp axis in serving)."""
    devs = mesh.devices
    assert mesh.axis_names == (dp_axis, tp_axis), \
        f"expected ({dp_axis!r}, {tp_axis!r}) mesh, got {mesh.axis_names}"
    return [Mesh(devs[i], (tp_axis,)) for i in range(devs.shape[0])]

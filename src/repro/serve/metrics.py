"""Versioned serving-metrics schema (the API redesign's metrics
satellite).

Every layer of the serving stack used to hand back ad-hoc nested dicts
(``paged.*``, ``lifecycle.*``, ``dispatches*``, per-request fields) that
consumers poked by string key.  :class:`MetricsSnapshot` is the one
typed, versioned container: engines build it at the end of ``run()``,
the :class:`~repro.serve.router.Router` merges per-replica snapshots
into one (summed counters, relabeled requests, per-replica snapshots
attached under ``replicas``), and ``launch/serve.py --trace`` /
``benchmarks/serving.py`` read attributes instead of dict paths.

``to_dict()`` emits the exact legacy dict shape (so
``run()["metrics"]`` remains drop-in for existing callers), plus a
``schema_version`` field; ``to_json()`` is the serialized form.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

__all__ = ["SCHEMA_VERSION", "LifecycleMetrics", "PagedMetrics",
           "RequestMetrics", "MetricsSnapshot"]

SCHEMA_VERSION = 1


@dataclasses.dataclass
class LifecycleMetrics:
    terminal_states: Dict[str, int]
    admission_retries: int = 0
    watchdog_trips: int = 0
    timeouts: int = 0
    cancellations: int = 0
    restores: int = 0
    faults_fired: int = 0


@dataclasses.dataclass
class PagedMetrics:
    enabled: bool = False
    block_size: int = 0
    num_blocks: int = 0
    peak_blocks_in_use: int = 0
    preemptions: int = 0
    rejections: int = 0
    attention_kernel: bool = False
    prefix_cache: bool = False
    prefix_hits: int = 0
    blocks_reused: int = 0
    tokens_skipped: int = 0
    prefill_tokens: int = 0
    cached_blocks: int = 0
    evictions: int = 0

    def to_dict(self) -> Dict[str, Any]:
        # legacy shape: a paging-disabled engine reported the bare
        # ``{"enabled": False}`` marker, not a zeroed record
        if not self.enabled:
            return {"enabled": False}
        return dataclasses.asdict(self)


@dataclasses.dataclass
class RequestMetrics:
    rid: int
    prompt_len: int
    arrival: int
    state: str
    admitted_iter: int
    first_token_iter: int
    done_iter: int
    latency_iters: int
    latency_s: float
    n_out: int
    preemptions: int
    cached_tokens: int
    retries: int
    deadline: Optional[int]


@dataclasses.dataclass
class MetricsSnapshot:
    """One engine run's metrics.  A router-merged snapshot additionally
    carries ``replicas`` (the per-replica snapshots it was merged from)
    and reports ``dispatches_per_iteration`` as the MAX across replicas
    (the acceptance gate is per replica, not amortized)."""
    iterations: int = 0
    wall_s: float = 0.0
    generated_tokens: int = 0
    tokens_per_s: float = 0.0
    trace_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    dispatches: int = 0
    dispatches_per_iteration: float = 0.0
    degraded_iterations: int = 0
    lifecycle: LifecycleMetrics = dataclasses.field(
        default_factory=lambda: LifecycleMetrics(terminal_states={}))
    paged: PagedMetrics = dataclasses.field(default_factory=PagedMetrics)
    requests: List[RequestMetrics] = dataclasses.field(default_factory=list)
    replicas: Optional[List["MetricsSnapshot"]] = None
    schema_version: int = SCHEMA_VERSION

    def to_dict(self) -> Dict[str, Any]:
        d = {
            "schema_version": self.schema_version,
            "iterations": self.iterations,
            "wall_s": self.wall_s,
            "generated_tokens": self.generated_tokens,
            "tokens_per_s": self.tokens_per_s,
            "trace_counts": dict(self.trace_counts),
            "dispatches": self.dispatches,
            "dispatches_per_iteration": self.dispatches_per_iteration,
            "degraded_iterations": self.degraded_iterations,
            "lifecycle": dataclasses.asdict(self.lifecycle),
            "paged": self.paged.to_dict(),
            "requests": [dataclasses.asdict(r) for r in self.requests],
        }
        if self.replicas is not None:
            d["replicas"] = [r.to_dict() for r in self.replicas]
        return d

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "MetricsSnapshot":
        pg = dict(d.get("paged", {}))
        paged = (PagedMetrics(**pg) if pg.get("enabled")
                 else PagedMetrics(enabled=False))
        return cls(
            iterations=d.get("iterations", 0),
            wall_s=d.get("wall_s", 0.0),
            generated_tokens=d.get("generated_tokens", 0),
            tokens_per_s=d.get("tokens_per_s", 0.0),
            trace_counts=dict(d.get("trace_counts", {})),
            dispatches=d.get("dispatches", 0),
            dispatches_per_iteration=d.get("dispatches_per_iteration", 0.0),
            degraded_iterations=d.get("degraded_iterations", 0),
            lifecycle=LifecycleMetrics(**d.get(
                "lifecycle", {"terminal_states": {}})),
            paged=paged,
            requests=[RequestMetrics(**r) for r in d.get("requests", [])],
            replicas=([cls.from_dict(r) for r in d["replicas"]]
                      if d.get("replicas") is not None else None),
            schema_version=d.get("schema_version", SCHEMA_VERSION),
        )

    # ----------------------------------------------------------- merging
    @classmethod
    def merge(cls, parts: List["MetricsSnapshot"],
              wall_s: Optional[float] = None) -> "MetricsSnapshot":
        """Router-side merge of per-replica snapshots: counters sum,
        request records concatenate (already relabeled to global rids by
        the router), ``dispatches_per_iteration`` is the max across
        replicas, and the parts are kept under ``replicas``."""
        assert parts, "nothing to merge"
        wall = wall_s if wall_s is not None else max(
            p.wall_s for p in parts)
        gen = sum(p.generated_tokens for p in parts)
        term: Dict[str, int] = {}
        for p in parts:
            for k, v in p.lifecycle.terminal_states.items():
                term[k] = term.get(k, 0) + v
        traces: Dict[str, int] = {}
        for p in parts:
            for k, v in p.trace_counts.items():
                traces[k] = traces.get(k, 0) + v
        paged_parts = [p.paged for p in parts if p.paged.enabled]
        if paged_parts:
            paged = PagedMetrics(
                enabled=True,
                block_size=paged_parts[0].block_size,
                num_blocks=sum(p.num_blocks for p in paged_parts),
                peak_blocks_in_use=sum(p.peak_blocks_in_use
                                       for p in paged_parts),
                preemptions=sum(p.preemptions for p in paged_parts),
                rejections=sum(p.rejections for p in paged_parts),
                attention_kernel=paged_parts[0].attention_kernel,
                prefix_cache=paged_parts[0].prefix_cache,
                prefix_hits=sum(p.prefix_hits for p in paged_parts),
                blocks_reused=sum(p.blocks_reused for p in paged_parts),
                tokens_skipped=sum(p.tokens_skipped for p in paged_parts),
                prefill_tokens=sum(p.prefill_tokens for p in paged_parts),
                cached_blocks=sum(p.cached_blocks for p in paged_parts),
                evictions=sum(p.evictions for p in paged_parts),
            )
        else:
            paged = PagedMetrics(enabled=False)
        return cls(
            iterations=max(p.iterations for p in parts),
            wall_s=wall,
            generated_tokens=gen,
            tokens_per_s=gen / max(wall, 1e-9),
            trace_counts=traces,
            dispatches=sum(p.dispatches for p in parts),
            dispatches_per_iteration=max(
                p.dispatches_per_iteration for p in parts),
            degraded_iterations=sum(p.degraded_iterations for p in parts),
            lifecycle=LifecycleMetrics(
                terminal_states=term,
                admission_retries=sum(p.lifecycle.admission_retries
                                      for p in parts),
                watchdog_trips=sum(p.lifecycle.watchdog_trips
                                   for p in parts),
                timeouts=sum(p.lifecycle.timeouts for p in parts),
                cancellations=sum(p.lifecycle.cancellations for p in parts),
                restores=sum(p.lifecycle.restores for p in parts),
                faults_fired=max(p.lifecycle.faults_fired for p in parts),
            ),
            paged=paged,
            requests=[r for p in parts for r in p.requests],
            replicas=list(parts),
        )

"""Pure-host scheduling layer of the serving engine (the API split's
first layer — see serve/README.md "Architecture").

The :class:`Scheduler` owns every piece of *host* state — the
:class:`Request` lifecycle machine, slot assignment, the paged
:class:`~repro.serve.paged.BlockPool`, the prefix index bookkeeping, the
watchdog, and all scheduling counters — and **never touches device
arrays**.  Each iteration it emits a :class:`StepPlan` (or the legacy
:class:`PrefillWork` / :class:`DecodeWork` pair): a plain-numpy
description of the device work to run.  The
:class:`~repro.serve.executor.Executor` consumes plans and returns
sampled tokens; the scheduler's ``commit_*`` methods fold them back into
request state.  That contract is what makes the executor's step a pure
function of ``(params, cache, plan)`` — shardable with ``shard_map`` and
replicable behind the :class:`~repro.serve.router.Router`.

Nothing in this module imports jax.
"""
from __future__ import annotations

import copy
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.paged import (BlockPool, chain_block_hashes,
                               chain_block_keys, max_blocks_per_slot)

__all__ = ["Scheduler", "Request", "StepPlan", "PrefillWork", "DecodeWork",
           "WAITING", "PREFILL", "DECODE", "DONE", "REJECTED", "TIMED_OUT",
           "CANCELLED", "TERMINAL"]

WAITING, PREFILL, DECODE, DONE = "waiting", "prefill", "decode", "done"
# terminal without ever running: admission proved the request can NEVER
# fit the block pool (its replay sequence outgrew capacity), its transient-
# failure retry budget ran out, or the no-progress watchdog evicted it —
# rejecting keeps strict-FCFS admission from waiting on it forever and
# starving the queue behind it (head-of-line livelock, ISSUE-5 bugfix)
REJECTED = "rejected"
# deadline (submit ttl / cfg.ttl_default) passed before completion
TIMED_OUT = "timed_out"
# cancel(rid): caller withdrew the request; unwound from any phase
CANCELLED = "cancelled"
TERMINAL = (DONE, REJECTED, TIMED_OUT, CANCELLED)


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                 # (T,) prompt token ids
    max_new_tokens: int
    arrival: int = 0                   # scheduler iteration of arrival
    # --- runtime (scheduler-owned) ---
    state: str = WAITING
    slot: int = -1
    filled: int = 0                    # seq tokens prefilled so far
    cur: int = 0                       # last generated token (decode input)
    out: List[int] = dataclasses.field(default_factory=list)
    blocks: List[int] = dataclasses.field(default_factory=list)
    kv_len: int = 0                    # KV rows held (host mirror of pos)
    shared: int = 0                    # leading blocks reused from the index
    registered: int = 0                # leading blocks published to the index
    cached_tokens: int = 0             # prefill rows skipped via prefix hits
    # memoized chain hashes of this request's full blocks; token content
    # never changes for an already-hashed block (out only appends), so the
    # chain survives preemption and extends in O(new blocks)
    hash_chain: List[int] = dataclasses.field(default_factory=list)
    preempted: int = 0                 # times requeued by the block pool
    admitted_iter: int = -1
    first_token_iter: int = -1
    done_iter: int = -1
    arrival_time: float = -1.0         # wall clock when arrival was reached
    done_time: float = 0.0             # wall-clock latency from arrival
    # --- lifecycle hardening ---
    deadline: Optional[int] = None     # absolute iteration bound (TIMED_OUT)
    cancel_requested: bool = False     # processed at the next iteration start
    retries: int = 0                   # transient admission failures absorbed
    next_retry_iter: int = 0           # backoff window after a transient fail


def _dyadic_sizes(length: int, cap: int) -> List[int]:
    """Non-increasing powers of two ≤ cap summing exactly to length.

    ``length <= 0`` returns ``[]``: without the guard the inner halving
    loop decays ``c`` to 0 and ``rem -= 0`` spins forever.  A zero
    remainder is reachable — a cancel/timeout can land between scheduling
    and prefill — so this must terminate, and ``next_chunk`` must treat
    the empty ladder as "nothing to prefill" rather than index into it."""
    if length <= 0:
        return []
    sizes = []
    c = 1
    while c * 2 <= cap:
        c *= 2
    rem = length
    while rem:
        while c > rem:
            c //= 2
        sizes.append(c)
        rem -= c
    return sizes


# --------------------------------------------------------------- the plan
# The Scheduler→Executor contract: a plan is plain host data (numpy + ints
# + Request references for commit bookkeeping).  The Executor reads ONLY
# the array-ish fields (slot/tokens/chunk_len/toks/active/resets/table);
# the Request references exist so the driver can hand sampled tokens back
# to ``Scheduler.commit_*`` without re-deriving rosters.

@dataclasses.dataclass
class PrefillWork:
    req: Request
    tokens: np.ndarray         # (1, C) chunk token ids
    chunk_len: int
    first: bool                # first chunk → modality extras attach here
    replay: bool               # re-ingesting emitted tokens → dense program


@dataclasses.dataclass
class DecodeWork:
    requests: List[Request]    # frozen roster, one per active slot
    toks: np.ndarray           # (num_slots,) int32 last sampled tokens
    active: np.ndarray         # (num_slots,) bool


@dataclasses.dataclass
class StepPlan:
    """Device work for one scheduler iteration.  ``resets`` and ``table``
    are idempotent cache-side effects the Executor applies BEFORE the
    step dispatch (slot handoffs and block-table rewrites, both decided
    host-side); ``prefill``/``decode`` describe the fused step program's
    operands.  An all-``None`` plan is an idle iteration."""
    prefill: Optional[PrefillWork] = None
    decode: Optional[DecodeWork] = None
    resets: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    table: Optional[np.ndarray] = None   # host block table when dirty

    @property
    def bucket(self) -> Tuple[bool, bool, bool]:
        """(replay, has_prefill, has_decode) — the step-program shape
        bucket (static phase presence, see executor.py)."""
        return (self.prefill is not None and self.prefill.replay,
                self.prefill is not None, self.decode is not None)

    @property
    def has_work(self) -> bool:
        return self.prefill is not None or self.decode is not None


class Scheduler:
    """Admission, prefix match, preemption, TTL/cancel/watchdog — the
    pure-Python half of :class:`~repro.serve.continuous
    .ContinuousServingEngine`.  Owns all :class:`Request` state and the
    block pool; emits :class:`StepPlan`s and commits their results.  May
    mutate: its own requests/slots/pool/counters.  May NOT touch: device
    arrays, jit caches, sampling PRNGs (executor/driver territory)."""

    def __init__(self, cfg, *, paged: bool, exact_chunks: bool,
                 policy_enabled: bool, prefix_cache: bool,
                 faults=None, validate: bool = False,
                 hash_fn=chain_block_hashes):
        self.cfg = cfg
        self.faults = faults
        self._validate = validate
        self._hash_fn = hash_fn
        self._exact_chunks = exact_chunks
        self._policy_enabled = policy_enabled
        self.paged = paged
        # prefix caching needs every piece of continuation state to live
        # in the paged KV pool: archs with recurrent blocks carry scan
        # state that cached blocks cannot restore, so they stay cache-off
        self.prefix_cache = paged and prefix_cache and not exact_chunks
        self.preemptions = 0
        self.rejections = 0
        self.preempt_log: List[tuple] = []   # (rid, state-when-preempted)
        self.admission_retries = 0   # transient admission failures absorbed
        self.watchdog_trips = 0      # forced evictions by the watchdog
        self.timeouts = 0
        self.cancellations = 0
        self.prefix_hits = 0         # admissions that reused ≥ 1 block
        self.blocks_reused = 0       # total shared-block acquisitions
        self.tokens_skipped = 0      # prefill rows served from the index
        self.prefill_demand = 0      # prefill rows requested at admission
        self._extra_rids: set = set()   # requests with modality extras:
        # their hidden states depend on non-token inputs, so token-id chain
        # hashes cannot address their KV — excluded from the prefix index
        if self.paged:
            self._max_blocks = max_blocks_per_slot(cfg.max_seq,
                                                   cfg.block_size)
            nb = (cfg.num_blocks if cfg.num_blocks is not None
                  else cfg.num_slots * self._max_blocks)
            self.pool: Optional[BlockPool] = BlockPool(
                nb, cfg.block_size, prefix_cache=self.prefix_cache)
            self._host_table = np.full((cfg.num_slots, self._max_blocks),
                                       -1, np.int32)
            self._table_dirty = True
        else:
            self.pool = None
        self.requests: List[Request] = []
        self._free_slots = list(range(cfg.num_slots))
        self._slot_req: List[Optional[Request]] = [None] * cfg.num_slots
        self._pending_resets: List[Tuple[int, int]] = []
        self.it = 0                       # scheduler-iteration clock
        self._last_progress = 0           # watchdog bookkeeping

    # ------------------------------------------------------------ admission
    def submit(self, tokens, max_new_tokens: int = 32, arrival: int = 0,
               ttl: Optional[int] = None) -> int:
        """Queue a request; returns its request id (see
        ContinuousServingEngine.submit for the full contract)."""
        tokens = np.asarray(tokens).reshape(-1).astype(np.int32)
        assert tokens.size > 0, "empty prompt"
        assert tokens.size + max_new_tokens <= self.cfg.max_seq, \
            "request exceeds slot capacity (max_seq)"
        if self.paged:
            assert (self.pool.blocks_for(tokens.size + max_new_tokens)
                    <= self.pool.num_blocks), \
                "request exceeds block pool capacity"
        rid = len(self.requests)
        if ttl is None:
            ttl = self.cfg.ttl_default
        self.requests.append(Request(
            rid=rid, tokens=tokens, max_new_tokens=max_new_tokens,
            arrival=arrival,
            deadline=None if ttl is None else arrival + ttl))
        return rid

    def cancel(self, rid: int) -> bool:
        req = next((r for r in self.requests if r.rid == rid), None)
        if req is None or req.state in TERMINAL:
            return False
        req.cancel_requested = True
        return True

    def live(self) -> bool:
        return any(r.state not in TERMINAL for r in self.requests)

    def mark_extras(self, rids) -> None:
        self._extra_rids |= set(rids)

    # ---------------------------------------------------- lifecycle plumbing
    def _fire(self, site: str) -> Optional[str]:
        return self.faults.fire(site) if self.faults is not None else None

    def evict_request(self, req: Request, state: str, it: int) -> None:
        """Move ``req`` to terminal ``state`` from ANY lifecycle phase,
        unwinding whatever it holds.  Full blocks are registered before
        release — their rows are final KV, so the prefix index keeps them
        (a re-submitted prompt still hits); the partially-written frontier
        block is released unregistered, so no writable block is ever
        published (audited by ``audit_pool``)."""
        if req.state in (PREFILL, DECODE):
            if self.paged and req.blocks:
                self._register_blocks(req)
                self.pool.release(req.blocks[::-1])   # chain head → MRU end
                req.blocks = []
                req.shared = req.registered = 0
            if req.slot >= 0:
                if self.paged:
                    self._host_table[req.slot, :] = -1
                    self._table_dirty = True
                self._free_slots.append(req.slot)
                self._slot_req[req.slot] = None
                req.slot = -1
        req.state = state
        req.done_iter = it
        # terminal latency is still wall-clock since arrival — evicted
        # requests (cancelled / timed out / rejected) otherwise report the
        # -1.0 dataclass default as their latency_s
        if req.arrival_time >= 0:
            req.done_time = time.perf_counter() - req.arrival_time
        req.filled = 0
        req.kv_len = 0

    def _retry(self, req: Request, it: int) -> None:
        """Absorb a transient admission failure: exponential backoff, then
        the REJECTED backstop once the per-request retry budget is spent
        (an unbounded retry of a persistent fault would livelock strict-
        FCFS admission)."""
        req.retries += 1
        self.admission_retries += 1
        if req.retries > self.cfg.admission_retries:
            self.evict_request(req, REJECTED, it)
            self.rejections += 1
        else:
            req.next_retry_iter = it + min(
                self.cfg.retry_backoff ** req.retries, 64)

    def reap(self, it: int) -> int:
        """Process cancellations and deadlines at the iteration boundary;
        returns how many requests reached a terminal state."""
        n = 0
        for r in self.requests:
            if r.state in TERMINAL:
                continue
            if r.cancel_requested:
                self.evict_request(r, CANCELLED, it)
                self.cancellations += 1
                n += 1
            elif r.deadline is not None and it >= r.deadline:
                self.evict_request(r, TIMED_OUT, it)
                self.timeouts += 1
                n += 1
        return n

    def stamp_arrivals(self, it: int, now: float) -> None:
        """Anchor wall-clock latency at arrival.  Stamped unconditionally
        on visibility, NOT gated on WAITING: a request admitted the same
        iteration it became visible would otherwise keep the -1.0 default
        and report garbage latency."""
        for r in self.requests:
            if r.arrival <= it and r.arrival_time < 0:
                r.arrival_time = now

    def _seq(self, req: Request) -> np.ndarray:
        """Tokens to prefill: the prompt, plus — after a preemption — the
        tokens already emitted, replayed so decode resumes exactly where it
        left off (greedy outputs are chunking-invariant, so the replayed
        prefix regenerates the identical KV state)."""
        if req.out:
            return np.concatenate([req.tokens,
                                   np.asarray(req.out, np.int32)])
        return req.tokens

    def _chain_for(self, req: Request, tokens: np.ndarray,
                   n_full: int) -> List[int]:
        """First ``n_full`` chain hashes of the request's sequence,
        extending the memoized chain only over blocks not yet hashed."""
        chain = req.hash_chain
        if n_full > len(chain):
            dense_from = (len(req.tokens) if self._policy_enabled else None)
            chain.extend(self._hash_fn(
                tokens, self.pool.block_size, n_full, dense_from,
                start=len(chain), h0=chain[-1] if chain else None))
        return chain[:n_full]

    def match_prefix(self, req: Request, seq: np.ndarray) -> List[int]:
        """Longest indexed block-prefix of the request's prefill sequence.
        Capped at ``len(seq) - 1`` tokens: at least one token must run
        through prefill to produce the logits the next token samples from,
        so the request's last block is always a fresh allocation (and a
        partially-covered tail block has no full-block hash anyway) —
        shared blocks are therefore never writable."""
        if not self.prefix_cache or req.rid in self._extra_rids:
            return []
        n_full = (len(seq) - 1) // self.pool.block_size
        if n_full == 0:
            return []
        dense_from = len(req.tokens) if self._policy_enabled else None
        return self.pool.match(
            self._chain_for(req, seq, n_full),
            keys=chain_block_keys(seq, self.pool.block_size, n_full,
                                  dense_from))

    def admit(self, it: int) -> int:
        # FCFS by arrival, not submission order: requests may be submitted
        # with out-of-order arrival times (and preempted requests requeue
        # with their original arrival).  Returns how many requests changed
        # state (admitted or rejected) — the watchdog's progress signal.
        moved = 0
        for req in sorted(self.requests, key=lambda r: (r.arrival, r.rid)):
            if req.state != WAITING or req.arrival > it:
                continue
            if req.next_retry_iter > it:
                continue               # backing off a transient failure
            if self.paged:
                seq = self._seq(req)
                need = self.pool.blocks_for(len(seq))
                if need > min(self.pool.num_blocks, self._max_blocks):
                    # can NEVER fit: strict FCFS would wait on it forever
                    # and starve every request behind it (head-of-line
                    # livelock) — reject with a terminal state instead.
                    # ``submit`` already bounds prompt+max_new, and a
                    # replay sequence (prompt + emitted) stays under that
                    # bound, so through the public API this is a
                    # defense-in-depth backstop: it converts any capacity
                    # drift (out-of-band enqueues, future scheduler
                    # changes shrinking the pool) into a visible REJECTED
                    # request instead of a silent queue stall
                    self.evict_request(req, REJECTED, it)
                    self.rejections += 1
                    moved += 1
                    continue
            if not self._free_slots:
                break
            if self._fire("admit") == "transient":
                # injected transient admission failure (e.g. a control-
                # plane hiccup): backoff-and-retry before the backstop
                self._retry(req, it)
                continue
            skip = 0
            if self.paged:
                shared = self.match_prefix(req, seq)
                # full feasibility BEFORE taking anything: reviving a
                # zero-ref cached hit consumes availability (sharing a
                # live block does not), and the fresh remainder must fit
                # what is left — so a refused admission never touches the
                # pool (no rollback, no phantom peak_in_use spike)
                revive = sum(map(self.pool.is_cached, shared))
                if need - len(shared) > self.pool.available - revive:
                    # strict FCFS: the oldest waiting request admits first;
                    # skipping ahead would starve long prompts under
                    # sustained short-prompt traffic
                    break
                acquired: List[int] = []
                try:
                    for b in shared:
                        self.pool.acquire_cached(b)
                        acquired.append(b)
                    fresh = self.pool.alloc(need - len(shared))
                except RuntimeError:
                    # allocation failed mid-admission (injected pool fault,
                    # or capacity raced away): roll back the prefix refs
                    # just acquired — the pool is left exactly as found —
                    # and retry with backoff
                    self.pool.release(acquired[::-1])
                    self._retry(req, it)
                    continue
                req.blocks = shared + fresh
                req.shared = req.registered = len(shared)
                skip = len(shared) * self.pool.block_size
                req.cached_tokens += skip
                self.prefill_demand += len(seq)
                self.tokens_skipped += skip
                self.blocks_reused += len(shared)
                if shared:
                    self.prefix_hits += 1
            slot = self._free_slots.pop(0)
            # prefix-cached rows are already valid KV: the executor resets
            # the slot's pos to the first non-cached token so the first
            # prefill chunk runs mid-sequence (a deferred device-side
            # effect — the scheduler only RECORDS it; reset never touches
            # pooled leaves, so the shared blocks other slots may be
            # reading survive the slot handoff)
            self._pending_resets.append((slot, skip))
            if self.paged:
                self._host_table[slot, :] = -1
                self._host_table[slot, :len(req.blocks)] = req.blocks
                self._table_dirty = True
            req.slot, req.state = slot, PREFILL
            req.filled = req.kv_len = skip
            req.admitted_iter = it
            self._slot_req[slot] = req
            moved += 1
        return moved

    def _register_blocks(self, req: Request) -> None:
        """Publish the request's full blocks in the prefix index.  KV rows
        0..kv_len-1 hold the tokens ``(prompt ++ out)[:kv_len]`` (a freshly
        sampled token's own KV is only written when it is next fed back
        in), so full blocks are content-addressable by that token chain.
        Called whenever row content is final AND worth publishing: after
        each prefill chunk, and — to pick up decode-written rows — right
        before the blocks are released at preemption or completion."""
        if not self.prefix_cache or req.rid in self._extra_rids:
            return
        bs = self.pool.block_size
        n_full = min(req.kv_len // bs, len(req.blocks))
        if n_full <= req.registered:
            return
        seq = self._seq(req)[:req.kv_len]
        hashes = self._chain_for(req, seq, n_full)
        dense_from = len(req.tokens) if self._policy_enabled else None
        keys = chain_block_keys(seq, bs, n_full, dense_from)
        for i in range(req.registered, n_full):
            self.pool.register(req.blocks[i], hashes[i], key=keys[i])
        req.registered = n_full

    def preempt(self, req: Request) -> None:
        """Requeue ``req`` (recompute-on-readmission): its blocks return to
        the pool, its slot frees, and its emitted tokens stay on the
        request to be replayed through prefill when it is re-admitted.
        Full blocks are registered first, so as long as they survive in
        the zero-ref LRU the replay is nearly free: the replayed
        prompt+emitted prefix re-matches exactly what was just released."""
        self.preemptions += 1
        req.preempted += 1
        self.preempt_log.append((req.rid, req.state))
        self._register_blocks(req)
        # deepest blocks first: chain hashes only match a CONTIGUOUS prefix
        # from block 0, so eviction must consume chains tail-first — the
        # reversed release order parks the chain head at the MRU end
        self.pool.release(req.blocks[::-1])
        req.blocks = []
        req.shared = req.registered = 0
        self._host_table[req.slot, :] = -1
        self._table_dirty = True
        self._free_slots.append(req.slot)
        self._slot_req[req.slot] = None
        req.slot = -1
        req.state = WAITING
        req.filled = 0
        req.kv_len = 0

    def ensure_decode_blocks(self) -> None:
        """Grab a fresh block for every decoding slot crossing a block
        boundary; when the pool is dry, preempt the youngest active
        request until the oldest decoders can proceed (or the needy
        request is itself the youngest and yields)."""
        order = sorted((r for r in self.requests if r.state == DECODE),
                       key=lambda r: (r.admitted_iter, r.rid))
        for r in order:
            while r.state == DECODE:
                need = self.pool.blocks_for(r.kv_len + 1)
                if len(r.blocks) >= need:
                    break
                blk = None
                if self.pool.available:
                    try:
                        blk = self.pool.alloc(1)
                    except RuntimeError:
                        blk = None   # injected exhaustion → preempt path
                if blk is not None:
                    self._host_table[r.slot, len(r.blocks)] = blk[0]
                    r.blocks.extend(blk)
                    self._table_dirty = True
                else:
                    victim = max((v for v in self.requests
                                  if v.state in (PREFILL, DECODE)),
                                 key=lambda v: (v.admitted_iter, v.rid))
                    self.preempt(victim)

    def finish(self, req: Request, it: int, t0: float) -> None:
        req.state = DONE
        req.done_iter = it
        anchor = req.arrival_time if req.arrival_time >= 0 else t0
        req.done_time = time.perf_counter() - anchor
        if self.paged and req.blocks:
            self._register_blocks(req)
            self.pool.release(req.blocks[::-1])   # chain head → MRU end
            req.blocks = []
            req.shared = req.registered = 0
            self._host_table[req.slot, :] = -1
            self._table_dirty = True
        self._free_slots.append(req.slot)
        self._slot_req[req.slot] = None
        req.slot = -1

    def clear(self) -> None:
        """Drop completed requests (e.g. after a warmup pass) so a fresh
        stream can be submitted and measured on the already-compiled
        engine.  The prefix index deliberately survives: a warm cache
        across streams is the production behavior being measured."""
        assert all(r.state in TERMINAL for r in self.requests), \
            "cannot clear with requests in flight"
        self.requests = []
        # rids restart at 0 for the next stream: stale modality-extras
        # exclusions must not leak onto unrelated rid-colliding requests
        self._extra_rids = set()
        self.it = 0
        self._last_progress = 0

    # ------------------------------------------------------- plan building
    def next_chunk(self, req: Request):
        """(tokens (1, C), chunk_len, send_extras, is_replay) for the next
        chunk.  Chunks never span the prompt/emitted boundary, so a replay
        chunk (re-ingesting emitted tokens after a preemption) is entirely
        replay and runs through the dense program.

        Returns the ``(None, 0, False, False)`` sentinel when nothing
        remains to ingest — a fully-filled request momentarily parked in
        PREFILL must not index into an empty dyadic ladder."""
        c = self.cfg.chunk_size
        seq = self._seq(req)
        rem = len(seq) - req.filled
        if rem <= 0:
            return None, 0, False, False
        if req.filled < len(req.tokens):
            rem = min(rem, len(req.tokens) - req.filled)
            replay = False
        else:
            replay = self._policy_enabled
        if self._exact_chunks:
            size = _dyadic_sizes(rem, c)[0]
            chunk = seq[req.filled:req.filled + size]
            return chunk[None, :], size, req.filled == 0, replay
        v = min(c, rem)
        chunk = np.zeros((c,), np.int32)
        chunk[:v] = seq[req.filled:req.filled + v]
        return chunk[None, :], v, req.filled == 0, replay

    def _drain_effects(self, plan: StepPlan) -> None:
        plan.resets = self._pending_resets
        self._pending_resets = []
        if self.paged and self._table_dirty:
            plan.table = self._host_table
            self._table_dirty = False

    def _prefill_work(self) -> Optional[PrefillWork]:
        prefilling = [r for r in self.requests if r.state == PREFILL]
        if not prefilling:
            return None
        req = prefilling[0]
        tokens, clen, first, replay = self.next_chunk(req)
        if tokens is None:     # fully ingested, parked — nothing to run
            return None
        return PrefillWork(req, tokens, clen, first, replay)

    def _decode_work(self) -> Optional[DecodeWork]:
        decoding = [r for r in self.requests if r.state == DECODE]
        if not decoding:
            return None
        toks = np.zeros((self.cfg.num_slots,), np.int32)
        act = np.zeros((self.cfg.num_slots,), bool)
        for r in decoding:
            toks[r.slot], act[r.slot] = r.cur, True
        return DecodeWork(decoding, toks, act)

    def plan_step(self) -> StepPlan:
        """Fused-path plan: the active request's prefill chunk AND the
        frozen decode roster, as one step-program dispatch."""
        plan = StepPlan(prefill=self._prefill_work(),
                        decode=self._decode_work())
        if plan.has_work:
            self._drain_effects(plan)
        return plan

    def plan_prefill(self) -> StepPlan:
        """Legacy two-program split, phase 1: just the prefill chunk."""
        plan = StepPlan(prefill=self._prefill_work())
        if plan.has_work:
            self._drain_effects(plan)
        return plan

    def plan_decode(self) -> StepPlan:
        """Legacy two-program split, phase 2: the decode roster computed
        AFTER prefill (a request finishing prefill this iteration joins
        decode the same iteration — the legacy scheduling difference)."""
        plan = StepPlan(decode=self._decode_work())
        if plan.has_work:
            self._drain_effects(plan)
        return plan

    # ------------------------------------------------------------- commits
    def commit_chunk(self, req: Request, chunk_len: int) -> None:
        """Fold a completed prefill chunk back into request state and
        publish blocks the chunk just completed: a request admitted while
        this one is still decoding can already share its prompt."""
        req.filled += chunk_len
        req.kv_len += chunk_len
        self._register_blocks(req)

    def seq_complete(self, req: Request) -> bool:
        return req.filled == len(self._seq(req))

    def emit_prefill_token(self, req: Request, tok: int, it: int,
                           t0: float) -> None:
        """The chunk that completed the sequence sampled ``tok``: record
        it and transition to DECODE (or finish on eos/budget)."""
        req.out.append(tok)
        if req.first_token_iter < 0:
            req.first_token_iter = it
        if tok == self.cfg.eos_token or len(req.out) >= req.max_new_tokens:
            self.finish(req, it, t0)
        else:
            req.state, req.cur = DECODE, tok

    def emit_decode_tokens(self, work: DecodeWork, nxt: np.ndarray,
                           it: int, t0: float) -> None:
        for r in work.requests:
            r.kv_len += 1
            tok = int(nxt[r.slot])
            r.out.append(tok)
            r.cur = tok
            if tok == self.cfg.eos_token or len(r.out) >= r.max_new_tokens:
                self.finish(r, it, t0)

    # ------------------------------------------------------------ watchdog
    def observe_progress(self, it: int, progressed: bool) -> None:
        """No-progress watchdog: clean scheduling always advances
        (prefill/decode run every iteration something is active), so a
        stall with admission-eligible waiters only arises under persistent
        faults — force-reject the oldest stuck request instead of
        livelocking until max_iters."""
        pending = [r for r in self.requests
                   if r.state == WAITING and r.arrival <= it]
        if progressed or not pending:
            self._last_progress = it
        elif it - self._last_progress >= self.cfg.watchdog_iters:
            stuck = min(pending, key=lambda r: (r.arrival, r.rid))
            self.evict_request(stuck, REJECTED, it)
            self.rejections += 1
            self.watchdog_trips += 1
            self._last_progress = it

    # ---------------------------------------------------------- auditing
    def audit_pool(self) -> None:
        """Refcount/ownership invariants (cfg.validate_pool): the pool's
        internal partition holds, every live reference is accounted to
        exactly one slot-holding request, and no block is simultaneously
        writable from two slots.  A request's writable frontier is block
        ``kv_len // block_size`` onward (rows below kv_len are final);
        everything it can still write must be exclusively owned and
        unpublished — shared/registered blocks are full and immutable."""
        pool = self.pool
        pool.check_invariants()
        expect: Dict[int, int] = {}
        writable: Dict[int, int] = {}
        for r in self.requests:
            if r.state not in (PREFILL, DECODE):
                assert not r.blocks, \
                    f"r{r.rid} ({r.state}) still holds blocks {r.blocks}"
                continue
            for b in r.blocks:
                expect[b] = expect.get(b, 0) + 1
            for b in r.blocks[r.kv_len // pool.block_size:]:
                assert b not in writable, \
                    f"block {b} writable from r{writable[b]} AND r{r.rid}"
                writable[b] = r.rid
                assert pool.refcount(b) == 1, \
                    f"writable block {b} of r{r.rid} is shared"
                assert not pool.is_registered(b), \
                    f"writable block {b} of r{r.rid} is published"
        assert expect == dict(pool._ref), \
            f"refcount skew: requests hold {expect}, pool says {pool._ref}"

    # ------------------------------------------------------ crash recovery
    def host_snapshot(self) -> Dict[str, Any]:
        """Host-state copy at an iteration boundary (the scheduler's share
        of the engine snapshot — see ContinuousServingEngine.snapshot)."""
        return {
            "it": self.it,
            "requests": copy.deepcopy(self.requests),
            "slot_rids": [None if r is None else r.rid
                          for r in self._slot_req],
            "free_slots": list(self._free_slots),
            "extra_rids": set(self._extra_rids),
            "pool": self.pool.snapshot() if self.paged else None,
            "host_table": (self._host_table.copy() if self.paged else None),
            "counters": {
                "preemptions": self.preemptions,
                "rejections": self.rejections,
                "admission_retries": self.admission_retries,
                "watchdog_trips": self.watchdog_trips,
                "timeouts": self.timeouts,
                "cancellations": self.cancellations,
                "prefix_hits": self.prefix_hits,
                "blocks_reused": self.blocks_reused,
                "tokens_skipped": self.tokens_skipped,
                "prefill_demand": self.prefill_demand,
            },
        }

    def host_restore(self, snap: Dict[str, Any]) -> None:
        """Rebuild scheduler state from a :meth:`host_snapshot`.  Device
        KV is treated as LOST — in-flight requests are demoted to WAITING
        with a fresh block pool and empty prefix index, and replay through
        prefill on re-admission (the same recompute path preemption uses,
        so resumed greedy outputs are token-identical)."""
        cfg = self.cfg
        self.it = snap["it"]
        self._last_progress = self.it    # fresh watchdog grace period
        self.requests = copy.deepcopy(snap["requests"])
        self._extra_rids = set(snap["extra_rids"])
        self._free_slots = list(range(cfg.num_slots))
        self._slot_req = [None] * cfg.num_slots
        self._pending_resets = []
        for r in self.requests:
            if r.state in (PREFILL, DECODE):
                r.state = WAITING
                r.slot = -1
                r.blocks = []
                r.shared = r.registered = 0
                r.filled = 0
                r.kv_len = 0
        if self.paged:
            self.pool = BlockPool(snap["pool"]["num_blocks"],
                                  cfg.block_size,
                                  prefix_cache=self.prefix_cache)
            self._host_table = np.full((cfg.num_slots, self._max_blocks),
                                       -1, np.int32)
            self._table_dirty = True
        for name, val in snap["counters"].items():
            setattr(self, name, val)

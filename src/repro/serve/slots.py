"""Slot-axis surgery on model KV caches.

The continuous engine keeps ONE cache pytree whose batch axis is the slot
axis (``num_slots`` rows).  The model zoo stacks per-layer caches two ways:

  * ``periods`` (transformer) / ``blocks`` (encdec): leaves are
    ``(n_layers, num_slots, ...)`` — slot axis **1** (layer stacking from
    ``vmap``/``scan`` sits in front);
  * ``tail`` and any other subtree: leaves are ``(num_slots, ...)`` — slot
    axis **0**.

``pos`` is special: the engine stores a ``(num_slots,)`` int32 vector of
per-slot sequence positions where the one-shot engine stores a scalar.

Paged mode (see ``serve/paged.py``) adds two twists, driven by the
optional ``spec`` argument — a bool pytree mirroring the cache subtrees
in which True marks a **pooled** attention K/V leaf:

  * pooled leaves have NO slot axis (they are ``(num_blocks, block_size,
    ...)`` shared by every slot), so slicing passes them through whole and
    writing takes the updated pool verbatim — the model's block-table
    scatter already confined the writes to the slot's own blocks;
  * ``block_table`` rides in the cache as a ``(num_slots, max_blocks)``
    int32 leaf; slicing extracts the slot's row (kept 2-D so prefill and
    batched decode share the model-side gather code).

All helpers take traced slot indices, so one jitted program serves every
slot (no per-slot retracing).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

__all__ = ["init_slot_cache", "slice_slot", "write_slot", "reset_slot",
           "where_active", "slot_axis"]

_LAYER_STACKED = ("periods", "blocks")   # slot axis 1 under these keys
_tmap = jax.tree_util.tree_map


def slot_axis(key: str) -> int:
    return 1 if key in _LAYER_STACKED else 0


def init_slot_cache(model, num_slots: int, max_seq: int) -> Dict[str, Any]:
    """Model cache with the batch axis as slots and a per-slot pos vector."""
    cache = model.init_cache(num_slots, max_seq)
    cache["pos"] = jnp.zeros((num_slots,), jnp.int32)
    return cache


def slice_slot(cache: Dict[str, Any], slot,
               spec: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Extract slot ``slot`` as a batch-1 cache with a scalar ``pos``."""
    out: Dict[str, Any] = {}
    for key, sub in cache.items():
        if key == "pos":
            out["pos"] = jax.lax.dynamic_index_in_dim(sub, slot, 0,
                                                      keepdims=False)
        elif key == "block_table":
            out[key] = jax.lax.dynamic_slice_in_dim(sub, slot, 1, axis=0)
        else:
            ax = slot_axis(key)

            def sl(a, ax=ax):
                return jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=ax)

            if spec is None:
                out[key] = _tmap(sl, sub)
            else:
                out[key] = _tmap(lambda a, paged: a if paged else sl(a),
                                 sub, spec[key])
    return out


def write_slot(cache: Dict[str, Any], slot, sub: Dict[str, Any],
               spec: Optional[Dict[str, Any]] = None) -> Dict:
    """Write a batch-1 cache (from :func:`slice_slot`) back into the slot."""
    out: Dict[str, Any] = {}
    for key, full in cache.items():
        if key == "pos":
            out["pos"] = jax.lax.dynamic_update_index_in_dim(
                full, sub["pos"].astype(full.dtype), slot, 0)
        elif key == "block_table":
            out[key] = full          # tables are engine-owned, never model-written
        else:
            ax = slot_axis(key)

            def wr(a, u, ax=ax):
                return jax.lax.dynamic_update_slice_in_dim(
                    a, u.astype(a.dtype), slot, axis=ax)

            if spec is None:
                out[key] = _tmap(wr, full, sub[key])
            else:
                out[key] = _tmap(
                    lambda a, u, paged: u.astype(a.dtype) if paged else wr(a, u),
                    full, sub[key], spec[key])
    return out


def reset_slot(cache: Dict[str, Any], slot: int,
               spec: Optional[Dict[str, Any]] = None,
               pos: int = 0) -> Dict[str, Any]:
    """Zero one slot (host-side, static index) before admitting a request.

    Attention rows are already fenced off by kv_len / kv_position masks, but
    recurrent states (rwkv6 S / token shifts, rglru h / conv history) are
    read as the initial state of the next prefill chunk, so they MUST be
    cleared when a slot changes owner.  Pooled leaves are left untouched —
    block ownership is released host-side and stale rows are fenced by the
    block table (-1 rows scatter/gather nowhere live) and kv_len.

    ``pos`` sets the slot's starting sequence position: 0 for a cold
    request, or the number of prefix-cached KV rows when admission matched
    shared blocks (serve/paged.py prefix index) — the first prefill chunk
    then starts mid-sequence and attends over the reused prefix.  Callers
    must separately install the shared block ids in the slot's table row;
    shared blocks themselves are never cleared here (they are full,
    immutable, and possibly read by other slots).
    """
    out: Dict[str, Any] = {}
    for key, sub in cache.items():
        if key == "pos":
            out["pos"] = sub.at[slot].set(pos)
        elif key == "block_table":
            out[key] = sub.at[slot].set(-1)
        else:
            ax = slot_axis(key)

            def zero(a, ax=ax):
                return a.at[(slice(None),) * ax + (slot,)].set(0)

            if spec is None:
                out[key] = _tmap(zero, sub)
            else:
                out[key] = _tmap(lambda a, paged: a if paged else zero(a),
                                 sub, spec[key])
    return out


def where_active(active: jax.Array, new: Dict[str, Any],
                 old: Dict[str, Any],
                 spec: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Per-slot select: keep ``new`` where ``active`` else ``old``.

    Used after a batched decode step so that slots that are empty or still
    prefilling are not advanced or overwritten by the decode's cache writes.
    Pooled leaves take ``new`` verbatim: the paged decode scatter already
    drops inactive rows (empty slots carry -1 block-table entries, which
    map out of bounds), so the pool is correct as written.
    """
    out: Dict[str, Any] = {}
    for key, old_sub in old.items():
        if key == "pos":
            out["pos"] = jnp.where(active, new["pos"], old_sub)
        elif key == "block_table":
            out[key] = old_sub
        else:
            ax = slot_axis(key)

            def sel(n, o, ax=ax):
                shape = [1] * o.ndim
                shape[ax] = active.shape[0]
                return jnp.where(active.reshape(shape), n, o)

            if spec is None:
                out[key] = _tmap(sel, new[key], old_sub)
            else:
                out[key] = _tmap(
                    lambda n, o, paged: n if paged else sel(n, o),
                    new[key], old_sub, spec[key])
    return out

"""Paged KV-cache allocation: a global block pool + per-slot block tables.

The fixed ``max_seq``-per-slot KV slab of the continuous engine reserves
``num_slots * max_seq`` rows per layer even when traffic is mostly short
prompts — memory, not compute, then caps concurrency.  Paged allocation
replaces the slab with a **global pool** of fixed-size KV blocks shared by
every slot:

  * each attention layer's cache leaf becomes a pooled
    ``(num_blocks, block_size, n_kv_heads, head_dim)`` array;
  * each slot holds a **block table** — a ``(max_blocks_per_slot,)`` int32
    row mapping logical block index (``position // block_size``) to a
    physical block id, ``-1`` = unallocated;
  * logical KV row ``p`` of a slot lives at physical flat row
    ``table[p // block_size] * block_size + p % block_size``.

The :class:`BlockPool` is **host-side** (allocation decisions are
scheduler decisions, not traced computation); only the small int32
block-table array crosses to the device, so admission/release never
retraces the jitted phases.  Recurrent state leaves (rwkv6 / rglru) are
position-independent and stay per-slot; sliding-window rings are already
bounded by ``window`` and are not paged (see ``transformer.paged_kv_spec``).

Sizing the pool below ``num_slots * ceil(max_seq / block_size)`` is the
point: the engine admits by block budget instead of free slots alone, and
preempts the youngest request (recompute on re-admission) when the pool
runs dry mid-decode — see ``serve/README.md`` for the policy.

Prefix caching (ISSUE 5)
------------------------

The pool is **refcounted and content-addressed**: a block whose rows are
completely written gets a chain hash ``h_i = hash(h_{i-1}, tokens_i)``
(see :func:`chain_block_hashes`) and is published in ``_index`` so later
requests whose token prefix reproduces the chain can *acquire* the block
(refcount += 1) instead of recomputing its KV.  ``release`` decrements;
at refcount 0 a **registered** block is not freed but parked in an LRU of
zero-ref cached blocks, evicted (index entry dropped) only when ``alloc``
cannot be served from the free list — the pool never reports exhaustion
while evictable cached blocks remain.  Only full, immutable blocks are
ever registered; a request's partially-filled tail block is always a
fresh exclusively-owned allocation, so no shared block is ever writable.
"""
from __future__ import annotations

from collections import Counter, OrderedDict, deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.faults import fire as _fire_fault

# NOTE: no module-level jax import — the Scheduler layer of the serving
# split imports this module for BlockPool + chain hashing, and must stay
# pure host code (pinned by test_sharded_serving).  The one device-side
# helper, init_paged_cache, imports jax lazily.

__all__ = ["BlockPool", "chain_block_hashes", "chain_block_keys",
           "device_pool_rows", "init_paged_cache", "max_blocks_per_slot"]


# Device pool leaves carry ONE reserved row past the allocator's id space:
# the trailing SENTINEL block.  The paged KV scatter kernel's aliased
# index map parks invisible grid steps there (a fixed, never-allocated
# physical block), so a parked write-back can never race a block some
# other grid step legitimately wrote — see
# ``kernels/paged_attention._scatter_call`` and the ``races`` analyzer
# family.  BlockPool itself never hands out the sentinel id; only the
# device-side leaf shape knows about it.
SENTINEL_POOL_ROWS = 1


def device_pool_rows(num_blocks: int) -> int:
    """Rows of a device pool leaf for an allocator of ``num_blocks``
    physical blocks: the allocatable blocks plus the trailing sentinel
    row reserved for the scatter kernel's parked grid steps."""
    return num_blocks + SENTINEL_POOL_ROWS

_HASH_SEED = 0x9E3779B9


def max_blocks_per_slot(max_seq: int, block_size: int) -> int:
    """Width of a slot's block table: logical blocks covering ``max_seq``."""
    return -(-max_seq // block_size)


def chain_block_hashes(tokens, block_size: int,
                       n_blocks: Optional[int] = None,
                       dense_from: Optional[int] = None,
                       start: int = 0,
                       h0: Optional[int] = None) -> List[int]:
    """Chain hashes for full blocks ``start .. n_blocks-1`` of a sequence.

    ``h_i = hash((h_{i-1}, dense_rows_i, token_ids_in_block_i))`` — block
    ``i`` is addressed by its *whole prefix*, not just its own tokens, so
    an index hit guarantees the block's KV (which depends on every earlier
    token through attention) is reusable.

    ``dense_from`` marks the row index from which KV rows were produced by
    the DENSE program (tokens a request *emitted*, first written by the
    dense decode step and replayed dense after preemption) while rows
    before it came from the sparse prefill path.  Under a sparse prefill
    policy the same token ids yield different KV on the two paths, so the
    per-block count of dense rows is folded into the hash: a request whose
    own prompt extends into another request's emitted region hashes those
    blocks differently and correctly misses.  Pass ``None`` when every row
    takes one path (dense policy), which keeps hashes boundary-independent.

    ``start``/``h0`` resume an existing chain incrementally: ``h0`` must
    be the hash of block ``start - 1`` (``None`` = the seed, for
    ``start == 0``) — callers that hash as a sequence grows memoize their
    chain and pay only for the new blocks.

    The block length is folded into the chain seed: the same token stream
    hashed at a different ``block_size`` lands in a disjoint hash space
    (blocks of different geometry must never alias).  Hashes remain
    *probabilistic* identifiers — :meth:`BlockPool.match` additionally
    verifies stored token content (see :func:`chain_block_keys`) so a
    hash collision can never cause false sharing.
    """
    tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
    if n_blocks is None:
        n_blocks = len(tokens) // block_size
    assert n_blocks * block_size <= len(tokens), \
        "chain hashes cover full blocks only"
    assert (h0 is None) == (start == 0), "h0 must accompany a resume point"
    h = hash((_HASH_SEED, block_size)) if h0 is None else h0
    out: List[int] = []
    for i in range(start, n_blocks):
        lo, hi = i * block_size, (i + 1) * block_size
        dense = 0 if dense_from is None else max(0, hi - max(dense_from, lo))
        h = hash((h, dense, tokens[lo:hi].tobytes()))
        out.append(h)
    return out


def chain_block_keys(tokens, block_size: int,
                     n_blocks: Optional[int] = None,
                     dense_from: Optional[int] = None) -> List[Tuple]:
    """Verification keys ``(dense_rows, token_bytes)`` per full block.

    A chain hash is a probabilistic address; the key is the ground truth
    it stands for.  :meth:`BlockPool.register` stores the key alongside
    the hash and :meth:`BlockPool.match` compares keys block-by-block, so
    a hash collision between different contents is *detected* (counted in
    ``hash_collisions``) instead of silently sharing the wrong KV.
    Verification is inductive: block ``i`` only matches after blocks
    ``0..i-1`` matched with verified keys, so equal per-block keys along
    the chain imply the whole prefix (and its sparse/dense row split) is
    identical."""
    tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
    if n_blocks is None:
        n_blocks = len(tokens) // block_size
    out: List[Tuple] = []
    for i in range(n_blocks):
        lo, hi = i * block_size, (i + 1) * block_size
        dense = 0 if dense_from is None else max(0, hi - max(dense_from, lo))
        out.append((dense, tokens[lo:hi].tobytes()))
    return out


class BlockPool:
    """Host-side refcounted allocator over ``num_blocks`` fixed-size blocks.

    Every block is in exactly one of three states (asserted by
    :meth:`check_invariants`, exercised by ``tests/test_paged_kv.py`` and
    ``tests/test_prefix_cache.py``):

      * **free** — on the FIFO free list (a deque: reuse sweeps the whole
        pool instead of hammering one block under fragmenting traffic);
      * **allocated** — refcount ≥ 1 in ``_ref``; refcount > 1 means the
        block is a registered prefix block shared read-only by several
        live requests;
      * **cached** — refcount dropped to 0 but the block is registered in
        the prefix index; parked in an LRU and revived by
        :meth:`acquire_cached` or reclaimed (evicted) by :meth:`alloc`.

    ``alloc`` validates the ENTIRE operation before mutating anything
    (ISSUE-5 bugfix: the old free list popped blocks before the
    double-allocation assert could fire, corrupting pool state on the
    failure path).
    """

    def __init__(self, num_blocks: int, block_size: int,
                 prefix_cache: bool = True):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        self._free: Deque[int] = deque(range(num_blocks))
        self._ref: Dict[int, int] = {}           # block id → refcount ≥ 1
        # zero-ref registered blocks, LRU → MRU; value = registered hash
        self._cached: "OrderedDict[int, int]" = OrderedDict()
        self._index: Dict[int, int] = {}         # chain hash → block id
        self._hash_of: Dict[int, int] = {}       # block id → chain hash
        # block id → verification key (chain_block_keys): the content the
        # hash stands for, compared on match to refuse collision aliasing
        self._key_of: Dict[int, Tuple] = {}
        self.peak_in_use = 0
        self.total_allocs = 0                    # fresh allocations only
        self.evictions = 0
        self.hash_collisions = 0                 # matches refused on key skew

    # ------------------------------------------------------------ queries
    @property
    def available(self) -> int:
        """Blocks obtainable without preempting anyone: free + evictable."""
        return len(self._free) + len(self._cached)

    @property
    def in_use(self) -> int:
        """Blocks currently referenced by at least one request."""
        return len(self._ref)

    @property
    def cached_blocks(self) -> int:
        """Zero-ref blocks retained for prefix reuse (evictable)."""
        return len(self._cached)

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` KV rows."""
        return -(-n_tokens // self.block_size)

    def refcount(self, block_id: int) -> int:
        return self._ref.get(block_id, 0)

    def is_registered(self, block_id: int) -> bool:
        return block_id in self._hash_of

    def is_cached(self, block_id: int) -> bool:
        """Zero-ref parked in the LRU (counted in :attr:`available`) —
        reviving it consumes one unit of availability, unlike sharing an
        already-live block."""
        return block_id in self._cached

    # --------------------------------------------------------- allocation
    def alloc(self, n: int) -> List[int]:
        """Hand out ``n`` fresh exclusively-owned blocks (refcount 1).

        Draws from the free list first, then reclaims zero-ref cached
        blocks LRU-first (dropping their prefix-index entries); raises if
        even eviction cannot cover the request — callers check
        :attr:`available` and preempt first.  All validation happens
        before any state is mutated.

        Fault-injection site ``pool.alloc`` (serve/faults.py):
        ``"exhausted"`` raises the real exhaustion error so callers'
        recovery paths (admission retry/backoff, decode-growth preemption)
        are exercised; ``"evict_storm"`` flushes the zero-ref LRU first.
        """
        kind = _fire_fault("pool.alloc")
        if kind == "exhausted":
            raise RuntimeError(
                f"block pool exhausted (injected fault): want {n}, have "
                f"{self.available}")
        if kind == "evict_storm":
            self.flush_cached()
        if n > self.available:
            raise RuntimeError(
                f"block pool exhausted: want {n}, have {self.available} "
                f"({len(self._free)} free + {len(self._cached)} cached)")
        take_free = min(n, len(self._free))
        cand = [self._free[i] for i in range(take_free)]
        evict: List[int] = []
        if take_free < n:                        # LRU → MRU iteration order
            lru = iter(self._cached)
            evict = [next(lru) for _ in range(n - take_free)]
        for i in cand + evict:
            assert i not in self._ref, f"double allocation of block {i}"
        assert len(set(cand + evict)) == n, "free list holds duplicates"
        # ---- validated: now mutate
        for _ in range(take_free):
            self._free.popleft()
        for i in evict:
            h = self._cached.pop(i)
            if self._index.get(h) == i:
                del self._index[h]
            self._hash_of.pop(i, None)
            self._key_of.pop(i, None)
            self.evictions += 1
        ids = cand + evict
        for i in ids:
            self._ref[i] = 1
        self.total_allocs += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return ids

    def acquire_cached(self, block_id: int) -> None:
        """Take a reference on a prefix-index hit: revive a zero-ref cached
        block (keeping its registration) or share a live one (refcount+1).
        The caller may only write rows BEYOND the block — registered blocks
        are full and immutable."""
        if block_id in self._cached:
            del self._cached[block_id]
            self._ref[block_id] = 1
        else:
            assert block_id in self._ref, \
                f"acquire_cached of unallocated block {block_id}"
            self._ref[block_id] += 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)

    def release(self, ids: Sequence[int]) -> None:
        """Drop one reference per id; a block reaching refcount 0 is parked
        in the prefix LRU if registered, else returned to the free list."""
        need = Counter(ids)
        for i, k in need.items():                # validate before mutating
            assert self._ref.get(i, 0) >= k, \
                f"release of unallocated block {i}"
        for i in ids:
            self._ref[i] -= 1
            if self._ref[i] == 0:
                del self._ref[i]
                h = self._hash_of.get(i)
                if h is not None and self._index.get(h) == i:
                    self._cached[i] = h          # MRU end of the LRU
                else:
                    self._hash_of.pop(i, None)
                    self._key_of.pop(i, None)
                    self._free.append(i)

    def flush_cached(self) -> int:
        """Evict EVERY zero-ref cached block (index entries dropped, blocks
        freed).  Returns the number evicted.  Used by the ``evict_storm``
        fault and by engine restore after a crash (device KV is gone, so a
        surviving index would advertise garbage blocks)."""
        n = len(self._cached)
        for b, h in self._cached.items():
            if self._index.get(h) == b:
                del self._index[h]
            self._hash_of.pop(b, None)
            self._key_of.pop(b, None)
            self._free.append(b)
            self.evictions += 1
        self._cached.clear()
        return n

    # ------------------------------------------------------- prefix index
    def register(self, block_id: int, chain_hash: int,
                 key: Optional[Tuple] = None) -> bool:
        """Publish a FULL block under its chain hash.  Returns False when
        the hash is already indexed (first copy wins — the duplicate block
        simply stays unregistered and frees normally) or when prefix
        caching is off.

        ``key`` is the block's verification key (:func:`chain_block_keys`)
        — the actual content the hash addresses.  :meth:`match` compares
        it so a hash collision between different token contents is
        refused instead of silently sharing the wrong KV.  ``None``
        registers hash-only (legacy/debug posture: collisions under
        Python's 64-bit tuple hash are ~2^-64 per pair, but a production
        index must not bet correctness on that)."""
        if not self.prefix_cache:
            return False
        assert block_id in self._ref, "register of a block nobody owns"
        if chain_hash in self._index:
            return self._index[chain_hash] == block_id
        prev = self._hash_of.get(block_id)
        assert prev is None or prev == chain_hash, \
            f"block {block_id} re-registered under a different hash"
        self._hash_of[block_id] = chain_hash
        self._index[chain_hash] = block_id
        if key is not None:
            self._key_of[block_id] = key
        return True

    def match(self, chain_hashes: Sequence[int],
              keys: Optional[Sequence[Tuple]] = None) -> List[int]:
        """Longest indexed prefix of a hash chain → block ids (not yet
        acquired; callers :meth:`acquire_cached` each hit).

        With ``keys`` (aligned with ``chain_hashes``), every hash hit is
        verified against the registered block's stored content key; a
        mismatch — a genuine hash collision — stops the match there and
        increments ``hash_collisions``.  A block registered without a key
        matches hash-only."""
        ids: List[int] = []
        for i, h in enumerate(chain_hashes):
            b = self._index.get(h)
            if b is None:
                break
            if keys is not None:
                stored = self._key_of.get(b)
                if stored is not None and stored != keys[i]:
                    self.hash_collisions += 1
                    break
            ids.append(b)
        return ids

    # --------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """free / allocated / cached partition the pool; the prefix index
        is a bijection onto registered live-or-cached blocks."""
        free, cached, ref = list(self._free), set(self._cached), \
            set(self._ref)
        assert len(free) == len(set(free)), "free list holds duplicates"
        assert not (set(free) & cached) and not (set(free) & ref) \
            and not (cached & ref), "block in two states at once"
        assert len(free) + len(cached) + len(ref) == self.num_blocks, \
            "blocks leaked or conjured"
        assert all(c >= 1 for c in self._ref.values()), "zero-ref in _ref"
        assert set(self._index.values()) == set(self._hash_of), \
            "index/registration skew"
        assert set(self._key_of) <= set(self._hash_of), \
            "verification key for an unregistered block"
        for h, b in self._index.items():
            assert self._hash_of.get(b) == h, f"hash mismatch on block {b}"
            assert b in cached or b in ref, f"indexed block {b} is free"
        for b, h in self._cached.items():
            assert self._index.get(h) == b, f"cached block {b} unreachable"

    # ------------------------------------------------------ snapshot/restore
    def snapshot(self) -> Dict[str, Any]:
        """Copy of the full host-side pool state (free list order, refcounts,
        prefix index, zero-ref LRU order, counters).  Process-local: chain
        hashes use Python's per-process salted ``hash``."""
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "prefix_cache": self.prefix_cache,
            "free": list(self._free),
            "ref": dict(self._ref),
            "cached": list(self._cached.items()),
            "index": dict(self._index),
            "hash_of": dict(self._hash_of),
            "key_of": dict(self._key_of),
            "peak_in_use": self.peak_in_use,
            "total_allocs": self.total_allocs,
            "evictions": self.evictions,
            "hash_collisions": self.hash_collisions,
        }

    def restore(self, snap: Dict[str, Any]) -> None:
        """Rebuild the pool exactly as snapshotted (same process, and only
        meaningful when the device-side KV the blocks point at is intact —
        the engine's crash-recovery path resets a FRESH pool instead)."""
        assert snap["num_blocks"] == self.num_blocks \
            and snap["block_size"] == self.block_size, \
            "snapshot geometry mismatch"
        self.prefix_cache = snap["prefix_cache"]
        self._free = deque(snap["free"])
        self._ref = dict(snap["ref"])
        self._cached = OrderedDict(snap["cached"])
        self._index = dict(snap["index"])
        self._hash_of = dict(snap["hash_of"])
        self._key_of = dict(snap["key_of"])
        self.peak_in_use = snap["peak_in_use"]
        self.total_allocs = snap["total_allocs"]
        self.evictions = snap["evictions"]
        self.hash_collisions = snap["hash_collisions"]
        self.check_invariants()


def init_paged_cache(model, num_slots: int, max_seq: int, block_size: int,
                     num_blocks: int, spec: Dict[str, Any]) -> Dict[str, Any]:
    """Slot cache with paged attention leaves.

    ``spec`` is the bool pytree from ``model.paged_kv_spec()``: leaves
    marked True swap their ``(..., num_slots, max_seq, ...)`` axes for
    pooled ``(..., device_pool_rows(num_blocks), block_size, ...)`` —
    ``num_blocks`` allocatable blocks plus the trailing sentinel row the
    scatter kernel parks invisible grid steps on (never referenced by any
    block table); everything else keeps the slot axis.  Adds the per-slot
    ``pos`` vector and the ``-1``-filled ``block_table``.
    """
    # shapes only — materializing the dense slab just to discard its paged
    # leaves would transiently cost dense + pool memory, exactly the
    # footprint paging exists to avoid
    import jax
    import jax.numpy as jnp

    from repro.serve.slots import slot_axis
    shapes = jax.eval_shape(lambda: model.init_cache(num_slots, max_seq))
    mb = max_blocks_per_slot(max_seq, block_size)
    out: Dict[str, Any] = {
        "pos": jnp.zeros((num_slots,), jnp.int32),
        "block_table": jnp.full((num_slots, mb), -1, jnp.int32),
    }
    for key, sub in shapes.items():
        if key == "pos":
            continue
        ax = slot_axis(key)

        def pool_leaf(a, paged, ax=ax):
            if paged:
                shape = (a.shape[:ax]
                         + (device_pool_rows(num_blocks), block_size)
                         + a.shape[ax + 2:])
                return jnp.zeros(shape, a.dtype)
            return jnp.zeros(a.shape, a.dtype)

        out[key] = jax.tree_util.tree_map(pool_leaf, sub, spec[key])
    return out

"""Paged KV-cache allocation: a global block pool + per-slot block tables.

The fixed ``max_seq``-per-slot KV slab of the continuous engine reserves
``num_slots * max_seq`` rows per layer even when traffic is mostly short
prompts — memory, not compute, then caps concurrency.  Paged allocation
replaces the slab with a **global pool** of fixed-size KV blocks shared by
every slot:

  * each attention layer's cache leaf becomes a pooled
    ``(num_blocks, block_size, n_kv_heads, head_dim)`` array;
  * each slot holds a **block table** — a ``(max_blocks_per_slot,)`` int32
    row mapping logical block index (``position // block_size``) to a
    physical block id, ``-1`` = unallocated;
  * logical KV row ``p`` of a slot lives at physical flat row
    ``table[p // block_size] * block_size + p % block_size``.

The :class:`BlockPool` free list is **host-side** (allocation decisions
are scheduler decisions, not traced computation); only the small int32
block-table array crosses to the device, so admission/release never
retraces the jitted phases.  Recurrent state leaves (rwkv6 / rglru) are
position-independent and stay per-slot; sliding-window rings are already
bounded by ``window`` and are not paged (see ``transformer.paged_kv_spec``).

Sizing the pool below ``num_slots * ceil(max_seq / block_size)`` is the
point: the engine admits by block budget instead of free slots alone, and
preempts the youngest request (recompute on re-admission) when the pool
runs dry mid-decode — see ``serve/README.md`` for the policy.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.serve.slots import slot_axis

__all__ = ["BlockPool", "init_paged_cache", "max_blocks_per_slot"]


def max_blocks_per_slot(max_seq: int, block_size: int) -> int:
    """Width of a slot's block table: logical blocks covering ``max_seq``."""
    return -(-max_seq // block_size)


class BlockPool:
    """Host-side free-list allocator over ``num_blocks`` fixed-size blocks.

    Invariants (asserted, and exercised by ``tests/test_paged_kv.py``):
    a block id is never handed out twice while allocated, and never
    released twice.  Reuse is FIFO so fragmentation patterns (interleaved
    alloc/free) sweep the whole pool rather than hammering one block.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks))
        self._owned: set = set()
        self.peak_in_use = 0
        self.total_allocs = 0

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` KV rows."""
        return -(-n_tokens // self.block_size)

    def alloc(self, n: int) -> List[int]:
        """Hand out ``n`` block ids; raises if the pool cannot cover it
        (callers check :attr:`available` and preempt first)."""
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: want {n}, have {len(self._free)}")
        ids = [self._free.pop(0) for _ in range(n)]
        for i in ids:
            assert i not in self._owned, f"double allocation of block {i}"
            self._owned.add(i)
        self.total_allocs += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return ids

    def release(self, ids: List[int]) -> None:
        for i in ids:
            assert i in self._owned, f"release of unallocated block {i}"
            self._owned.remove(i)
            self._free.append(i)


def init_paged_cache(model, num_slots: int, max_seq: int, block_size: int,
                     num_blocks: int, spec: Dict[str, Any]) -> Dict[str, Any]:
    """Slot cache with paged attention leaves.

    ``spec`` is the bool pytree from ``model.paged_kv_spec()``: leaves
    marked True swap their ``(..., num_slots, max_seq, ...)`` axes for
    pooled ``(..., num_blocks, block_size, ...)``; everything else keeps
    the slot axis.  Adds the per-slot ``pos`` vector and the ``-1``-filled
    ``block_table``.
    """
    # shapes only — materializing the dense slab just to discard its paged
    # leaves would transiently cost dense + pool memory, exactly the
    # footprint paging exists to avoid
    shapes = jax.eval_shape(lambda: model.init_cache(num_slots, max_seq))
    mb = max_blocks_per_slot(max_seq, block_size)
    out: Dict[str, Any] = {
        "pos": jnp.zeros((num_slots,), jnp.int32),
        "block_table": jnp.full((num_slots, mb), -1, jnp.int32),
    }
    for key, sub in shapes.items():
        if key == "pos":
            continue
        ax = slot_axis(key)

        def pool_leaf(a, paged, ax=ax):
            if paged:
                shape = (a.shape[:ax] + (num_blocks, block_size)
                         + a.shape[ax + 2:])
                return jnp.zeros(shape, a.dtype)
            return jnp.zeros(a.shape, a.dtype)

        out[key] = jax.tree_util.tree_map(pool_leaf, sub, spec[key])
    return out

"""Deterministic fault injection for the continuous serving engine.

A :class:`FaultInjector` is a seeded, replayable source of simulated
failures that the serving stack consults at **named injection sites**:

  ==========================  ==================================================
  site                        registered at / kinds
  ==========================  ==================================================
  ``pool.alloc``              :meth:`repro.serve.paged.BlockPool.alloc` —
                              ``"exhausted"`` raises the pool's real
                              exhaustion ``RuntimeError`` (exercising every
                              caller's recovery path), ``"evict_storm"``
                              flushes the whole zero-ref prefix LRU before
                              allocating (prefix-cache pressure).
  ``admit``                   ``ContinuousServingEngine._admit`` — a
                              ``"transient"`` admission failure; the engine
                              retries with bounded exponential backoff before
                              its ``REJECTED`` backstop.
  ``prefill`` / ``decode``    the engine's jitted phases — ``"nonfinite"``
                              feeds a runtime NaN operand into the program's
                              logits (detected by the degradation ladder and
                              re-run on the jnp oracle), ``"crash"`` raises
                              :class:`EngineCrash` mid-iteration (recovered
                              via ``snapshot()/restore()``).
  ``kernel.projection``       ``repro.core.pruner.sparse_matmul`` dispatch —
                              ``"compile_error"`` raises :class:`KernelFault`
                              at trace time (simulated Mosaic lowering
                              failure), ``"fallback"`` silently takes the jnp
                              oracle branch of the dispatch ladder.
  ``kernel.paged_attention``  ``repro.models.attention.paged_attention``
                              dispatch — same kinds as above.
  ``kernel.paged_scatter``    ``repro.models.attention.paged_kv_update``
                              dispatch (in-kernel KV scatter into the pool)
                              — same kinds as above.
  ==========================  ==================================================

Determinism/replay: a schedule is a list of :class:`FaultSpec` entries,
each firing at explicit engine ``iters``, at the n-th ``calls`` of its
site, or with probability ``p`` from a per-spec ``numpy`` generator
derived from the injector seed.  The same ``(seed, schedule)`` against the
same request stream reproduces the identical fault sequence; the
``fired`` log (and :meth:`FaultInjector.to_json`) records exactly what
fired where, so a CI failure's schedule replays locally.

This module is intentionally dependency-free (stdlib + numpy only): the
kernel-dispatch sites live in ``repro.core`` / ``repro.models``, which
import it lazily without dragging the serving stack in.
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["FaultSpec", "FaultInjector", "KernelFault", "EngineCrash",
           "SITES", "activate", "deactivate", "active", "fire"]

SITES: Tuple[str, ...] = (
    "pool.alloc",
    "admit",
    "prefill",
    "decode",
    "kernel.projection",
    "kernel.paged_attention",
    "kernel.paged_scatter",
)


class KernelFault(RuntimeError):
    """Simulated kernel compile/lowering failure at a dispatch site.

    Raised at *trace* time (Python-level dispatch inside ``jax.jit``), so
    the failed trace aborts cleanly, no cache state mutates (the jitted
    phases are functional), and the engine's degradation ladder re-runs
    the iteration on the bit-exact jnp oracle program."""


class EngineCrash(RuntimeError):
    """Simulated hard mid-iteration crash of the serving engine."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.  Exactly one trigger should be set:

    * ``iters`` — fire on every consult of ``site`` during those engine
      iterations (an iteration-long storm at a multi-consult site);
    * ``calls`` — fire on the n-th consult of ``site`` (0-based, counted
      over the injector's lifetime);
    * ``p``     — fire each consult with probability ``p`` (deterministic
      given the injector seed and consult order).

    ``limit`` caps total fires of this spec (``None`` = unbounded)."""
    site: str
    kind: str
    iters: Optional[Sequence[int]] = None
    calls: Optional[Sequence[int]] = None
    p: float = 0.0
    limit: Optional[int] = None

    def __post_init__(self):
        assert self.site in SITES, f"unknown fault site {self.site!r}"

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return {k: (list(v) if isinstance(v, (tuple, list)) else v)
                for k, v in d.items() if v not in (None, 0.0)}


class FaultInjector:
    """Seeded, schedule-driven fault source (see module docstring).

    The engine calls :meth:`tick` at the top of every scheduler iteration
    and each instrumented site calls :meth:`fire`; the first matching
    spec wins and its ``kind`` is returned (``None`` = no fault)."""

    def __init__(self, seed: int = 0,
                 schedule: Sequence[Any] = ()):
        self.seed = int(seed)
        self.schedule: List[FaultSpec] = [
            s if isinstance(s, FaultSpec) else FaultSpec(**s)
            for s in schedule]
        # independent per-spec generators: adding a spec never perturbs
        # the draws of the others (schedules compose reproducibly)
        self._rng = [np.random.default_rng(self.seed * 1_000_003 + i)
                     for i in range(len(self.schedule))]
        self.it = -1                      # last ticked engine iteration
        self._site_calls: Counter = Counter()
        self._spec_fires: Counter = Counter()
        self.fired: List[Dict[str, Any]] = []

    # ------------------------------------------------------------- driving
    def tick(self, it: int) -> None:
        """Advance to engine iteration ``it`` (engine calls this once per
        scheduler iteration, before any site is consulted)."""
        self.it = it

    def fire(self, site: str) -> Optional[str]:
        """Consult ``site``: returns the fault kind to inject, or None."""
        n = self._site_calls[site]
        self._site_calls[site] = n + 1
        for idx, spec in enumerate(self.schedule):
            if spec.site != site:
                continue
            if spec.limit is not None and self._spec_fires[idx] >= spec.limit:
                continue
            if spec.iters is not None:
                hit = self.it in spec.iters
            elif spec.calls is not None:
                hit = n in spec.calls
            else:
                hit = spec.p > 0.0 and self._rng[idx].random() < spec.p
            if hit:
                self._spec_fires[idx] += 1
                self.fired.append({"it": self.it, "site": site,
                                   "kind": spec.kind, "call": n})
                return spec.kind
        return None

    @property
    def total_fired(self) -> int:
        return len(self.fired)

    def fired_kinds(self, site: Optional[str] = None) -> List[str]:
        return [f["kind"] for f in self.fired
                if site is None or f["site"] == site]

    # -------------------------------------------------------------- replay
    def to_json(self) -> str:
        """Serialize ``(seed, schedule)`` + the fired log — enough to
        replay the scenario locally (CI uploads this on chaos failures)."""
        return json.dumps({
            "seed": self.seed,
            "schedule": [s.to_dict() for s in self.schedule],
            "fired": self.fired,
        }, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FaultInjector":
        d = json.loads(text)
        return cls(seed=d.get("seed", 0), schedule=d.get("schedule", ()))


# --------------------------------------------------------------- global hook
# Kernel-dispatch sites (core/pruner.py, models/attention.py) cannot see
# the engine instance — the engine activates its injector here for the
# duration of run(), and the sites consult the module-level hook.  The
# fast path (no injector active) is a single global read.

_ACTIVE: Optional[FaultInjector] = None


def activate(injector: Optional[FaultInjector]) -> None:
    global _ACTIVE
    _ACTIVE = injector


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[FaultInjector]:
    return _ACTIVE


def fire(site: str) -> Optional[str]:
    """Consult the globally-active injector (None when inactive)."""
    return _ACTIVE.fire(site) if _ACTIVE is not None else None

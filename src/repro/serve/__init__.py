from repro.serve.continuous import (ContinuousConfig, ContinuousServingEngine,
                                    Request)
from repro.serve.engine import ServeConfig, ServingEngine

__all__ = ["ServeConfig", "ServingEngine", "ContinuousConfig",
           "ContinuousServingEngine", "Request"]

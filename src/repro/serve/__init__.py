from repro.serve.continuous import (ContinuousConfig, ContinuousServingEngine,
                                    Request)
from repro.serve.engine import ServeConfig, ServingEngine
from repro.serve.faults import (EngineCrash, FaultInjector, FaultSpec,
                                KernelFault)
from repro.serve.paged import BlockPool

__all__ = ["ServeConfig", "ServingEngine", "ContinuousConfig",
           "ContinuousServingEngine", "Request", "BlockPool",
           "FaultInjector", "FaultSpec", "KernelFault", "EngineCrash"]

"""Serving package.  Re-exports are LAZY (PEP 562): the Scheduler layer
of the scheduler/executor split (``repro.serve.scheduler`` and its deps
``paged``/``faults``) is pure host code, and an eager ``from .api import
Engine`` here would drag jax in for anyone importing it — pinned by
``test_sharded_serving.test_scheduler_layer_is_pure_host``."""
_EXPORTS = {
    "Engine": "repro.serve.api",
    "EngineConfig": "repro.serve.api",
    "Router": "repro.serve.router",
    "MetricsSnapshot": "repro.serve.metrics",
    "ServeConfig": "repro.serve.engine",
    "ServingEngine": "repro.serve.engine",
    "ContinuousConfig": "repro.serve.continuous",
    "ContinuousServingEngine": "repro.serve.continuous",
    "Request": "repro.serve.continuous",
    "BlockPool": "repro.serve.paged",
    "FaultInjector": "repro.serve.faults",
    "FaultSpec": "repro.serve.faults",
    "KernelFault": "repro.serve.faults",
    "EngineCrash": "repro.serve.faults",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.serve' has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))

"""One-shot batched serving engine: Amber-sparse prefill + dense decode.

The paper's deployment story: N:M activation sparsity runs **only during
prefill** (compute-bound), decode stays dense (memory-bound — sparsity
buys nothing there and risks KV-cache drift).  The engine makes that split
explicit:

    engine = ServingEngine(model, policy)
    out = engine.generate(params, prompts, max_new_tokens=64)

``generate`` is the legacy whole-batch path kept as a thin compatibility
wrapper (and as the bit-exactness oracle for the scheduler tests): every
request in the batch must arrive together, prefill runs as one monolithic
jit, and decode runs as a ``lax.scan`` over steps.  Production traffic —
asynchronous arrivals, mixed prompt lengths, slot reuse — goes through
:class:`repro.serve.continuous.ContinuousServingEngine`, which chunks the
sparse prefill, interleaves it with slot-batched decode, and compiles each
phase once per shape bucket (see ``serve/README.md``).
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import DENSE, SparsityPolicy

__all__ = ["ServeConfig", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 512
    temperature: float = 0.0       # 0 → greedy
    eos_token: int = -1            # -1 → never stop early
    seed: int = 0


class ServingEngine:
    def __init__(self, model, policy: SparsityPolicy = DENSE,
                 cfg: ServeConfig = ServeConfig(), *, _via_api: bool = False):
        if not _via_api:
            warnings.warn(
                "constructing ServingEngine directly is deprecated; use "
                "repro.serve.api.Engine.from_config — Engine.generate is the "
                "one-shot adapter (serve/README.md has the migration table)",
                DeprecationWarning, stacklevel=2)
        self.model = model
        self.policy = policy
        self.cfg = cfg
        self._prefill_jit = jax.jit(self._prefill)
        self._decode_loop_jit = jax.jit(self._decode_loop,
                                        static_argnames=("steps",))

    # --- jitted bodies -----------------------------------------------------
    def _prefill(self, params, batch, cache):
        return self.model.prefill(params, batch, cache, policy=self.policy)

    def _sample(self, logits, key):
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.cfg.temperature,
                                      axis=-1)

    def _decode_loop(self, params, first_tokens, cache, key, *, steps: int):
        def body(carry, i):
            tokens, cache, key, done = carry
            key, sub = jax.random.split(key)
            logits, cache = self.model.decode_step(
                params, tokens[:, None], cache, policy=DENSE)
            nxt = self._sample(logits, sub)
            nxt = jnp.where(done, tokens, nxt)
            done = done | (nxt == self.cfg.eos_token)
            return (nxt, cache, key, done), nxt

        b = first_tokens.shape[0]
        done0 = jnp.zeros((b,), bool)
        (_, cache, _, _), toks = jax.lax.scan(
            body, (first_tokens, cache, key, done0), jnp.arange(steps))
        return toks.T, cache                      # (B, steps)

    # --- public API ----------------------------------------------------------
    def generate(
        self,
        params,
        batch: Dict[str, jax.Array],
        max_new_tokens: int = 32,
    ) -> Dict[str, Any]:
        """batch must hold "tokens" (B, T_prompt) (+ modality stubs)."""
        prompts = batch["tokens"]
        b, t = prompts.shape
        assert t + max_new_tokens <= self.cfg.max_seq, "max_seq too small"
        cache = self.model.init_cache(b, self.cfg.max_seq)
        logits, cache = self._prefill_jit(params, batch, cache)
        key = jax.random.PRNGKey(self.cfg.seed)
        key, sub = jax.random.split(key)
        first = self._sample(logits, sub)
        if max_new_tokens == 1:
            return {"tokens": first[:, None], "cache": cache}
        rest, cache = self._decode_loop_jit(
            params, first, cache, key, steps=max_new_tokens - 1)
        return {
            "tokens": jnp.concatenate([first[:, None], rest], axis=1),
            "cache": cache,
        }

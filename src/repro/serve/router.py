"""Data-parallel serving router: dp-replicated engines behind one
submit/run surface (the API redesign's third layer — serve/README.md
"Architecture").

The :class:`Router` owns ``dp`` independent
:class:`~repro.serve.continuous.ContinuousServingEngine` replicas — each
a full Scheduler+Executor pair with its own slot set, block pool, and
prefix index — and load-balances admissions across them.  dp replication
is **host-level**: no collective spans the data axis in serving (replicas
never exchange activations), so dp replicas work on a single device, and
a ``(dp, tp)`` mesh (``launch.mesh.make_serving_mesh``) additionally
gives each replica its own TP submesh
(:func:`repro.distributed.tp.replica_meshes`) to shard its kernels over.

Routing is least-loaded with **prefix affinity**: requests opening with
the same leading KV block are pinned to the same replica, so the
block-level prefix index — which is replica-local device state and
cannot be shared across pools — still converges to one copy of each hot
prefix family per replica instead of dp cold misses.

Token identity: greedy outputs are batch-composition- and chunking-
invariant (the continuous engine's core equivalence), so WHERE a request
lands never changes WHAT it generates — ``dp=N`` outputs are token-
identical per request to a single-replica run.

Failover: a replica that dies mid-run (:class:`EngineCrash`) is drained
— its terminal requests keep their outputs, its in-flight/waiting
requests transplant to a surviving replica demoted to ``WAITING`` with
their emitted tokens kept for dense replay (the same recompute path
preemption uses), so resumed greedy outputs stay token-identical.  With
``dp=1`` there is no survivor and the crash propagates to the caller
(the single-engine snapshot/restore contract).
"""
from __future__ import annotations

import copy
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.policy import DENSE, SparsityPolicy
from repro.distributed import tp as tp_mod
from repro.serve.continuous import ContinuousConfig, ContinuousServingEngine
from repro.serve.faults import EngineCrash, FaultInjector
from repro.serve.metrics import MetricsSnapshot
from repro.serve.scheduler import TERMINAL, WAITING

__all__ = ["Router"]


class Router:
    """dp-replicated continuous serving behind one request surface."""

    def __init__(self, model, policy: SparsityPolicy = DENSE,
                 cfg: ContinuousConfig = ContinuousConfig(), *,
                 dp: int = 1, mesh=None,
                 faults: Optional[FaultInjector] = None):
        assert dp >= 1, "need at least one replica"
        self.cfg = cfg
        self.dp = dp
        # one TP submesh per replica when a (data, model) mesh is given;
        # the mesh's data axis must cover the replica count
        if mesh is not None:
            subs = tp_mod.replica_meshes(mesh)
            assert len(subs) >= dp, \
                f"mesh data axis {len(subs)} < dp={dp}"
        else:
            subs = [None] * dp
        # the injector is shared: site schedules (and their limits) apply
        # across the whole fleet, wherever the site happens to fire
        self.replicas: List[ContinuousServingEngine] = [
            ContinuousServingEngine(model, policy, cfg, faults=faults,
                                    mesh=subs[i], _via_api=True)
            for i in range(dp)]
        self.alive = [True] * dp
        self.crashes = 0                  # replicas lost to EngineCrash
        self.transplants = 0              # requests re-admitted to survivors
        self._rid_map: Dict[int, Tuple[int, int]] = {}  # grid → (rep, lrid)
        self._affinity: Dict[bytes, int] = {}           # first-block → rep
        self._outputs: Dict[int, List[int]] = {}   # harvested from the dead
        self.metrics_snapshot: Optional[MetricsSnapshot] = None
        self.metrics: Dict[str, Any] = {}

    # ------------------------------------------------------------- routing
    def _load(self, i: int) -> int:
        """Outstanding KV demand of a replica (tokens it still owes)."""
        return sum(len(r.tokens) + r.max_new_tokens
                   for r in self.replicas[i].requests
                   if r.state not in TERMINAL)

    def _route(self, tokens) -> int:
        """Least-loaded admission with prefix affinity: a prompt whose
        leading block matches an earlier request lands on the same replica
        (the prefix index is replica-local — affinity is what keeps reuse
        alive across the split pools)."""
        alive = [i for i in range(self.dp) if self.alive[i]]
        assert alive, "no live replicas"
        key = None
        if self.cfg.prefix_cache and len(tokens) >= self.cfg.block_size:
            key = tokens[:self.cfg.block_size].tobytes()
            hit = self._affinity.get(key)
            if hit is not None and self.alive[hit]:
                return hit
        best = min(alive, key=lambda i: (self._load(i), i))
        if key is not None:
            self._affinity[key] = best
        return best

    def submit(self, tokens, max_new_tokens: int = 32, arrival: int = 0,
               ttl: Optional[int] = None) -> int:
        """Queue a request on the best replica; returns a GLOBAL request
        id (stable across failover transplants)."""
        import numpy as np
        tokens = np.asarray(tokens).reshape(-1).astype(np.int32)
        rep = self._route(tokens)
        lrid = self.replicas[rep].submit(tokens, max_new_tokens, arrival,
                                         ttl)
        grid = len(self._rid_map)
        self._rid_map[grid] = (rep, lrid)
        return grid

    def cancel(self, grid: int) -> bool:
        if grid not in self._rid_map:
            return False
        rep, lrid = self._rid_map[grid]
        return self.replicas[rep].cancel(lrid)

    def request_state(self, grid: int) -> str:
        rep, lrid = self._rid_map[grid]
        return self.replicas[rep].requests[lrid].state

    # ------------------------------------------------------------ failover
    def _transplant(self, dead: int, dst: int) -> None:
        """Drain a dead replica: keep terminal outputs, re-admit everything
        else to ``dst`` demoted to WAITING.  Emitted tokens ride along and
        replay through dense prefill on re-admission — the preemption
        recompute path — so resumed greedy outputs are token-identical."""
        src = self.replicas[dead]
        dst_eng = self.replicas[dst]
        for grid, (rep, lrid) in list(self._rid_map.items()):
            if rep != dead:
                continue
            req = src.requests[lrid]
            if req.state in TERMINAL:
                # finished before the crash: the tokens are safe host state
                self._outputs[grid] = list(req.out)
                continue
            moved = copy.deepcopy(req)
            moved.rid = len(dst_eng.requests)
            moved.state = WAITING
            moved.slot = -1
            moved.blocks = []
            moved.shared = moved.registered = 0
            moved.filled = 0
            moved.kv_len = 0
            # hash_chain survives: chain hashes are content-addressed, so
            # they are valid against the survivor's index too (exactly the
            # host_restore demotion, which also keeps them)
            dst_eng.sched.requests.append(moved)
            self._rid_map[grid] = (dst, moved.rid)
            self.transplants += 1
        self.alive[dead] = False
        self.crashes += 1

    def _survivor(self, dead: int) -> Optional[int]:
        alive = [i for i in range(self.dp) if self.alive[i] and i != dead]
        if not alive:
            return None
        return min(alive, key=lambda i: (self._load(i), i))

    # ------------------------------------------------------------ main loop
    def run(self, params,
            extras: Optional[Dict[int, Dict]] = None) -> Dict:
        """Drive every replica to completion; returns outputs keyed by
        GLOBAL rid plus the merged :class:`MetricsSnapshot` (as the same
        legacy dict shape single engines return).

        Replicas are independent (host-level dp), so they are driven
        sequentially on this host; on hardware each replica's step stream
        is its own device program queue and the wall-clock merge reflects
        the slowest replica.  A replica that crashes is drained to a
        survivor (see class docstring), which is then re-driven."""
        extras = extras or {}
        t0 = time.perf_counter()
        # local-extras view per replica, rebuilt after any transplant
        parts: Dict[int, MetricsSnapshot] = {}
        work = [i for i in range(self.dp) if self.alive[i]]
        while work:
            i = work.pop(0)
            if not self.alive[i]:
                continue
            eng = self.replicas[i]
            local_extras = {lrid: extras[g]
                            for g, (rep, lrid) in self._rid_map.items()
                            if rep == i and g in extras}
            try:
                eng.run(params, extras=local_extras)
                parts[i] = eng.metrics_snapshot
            except EngineCrash:
                dst = self._survivor(i)
                if dst is None:
                    raise              # dp=1: the caller owns recovery
                self._transplant(i, dst)
                parts.pop(i, None)
                if dst not in work:
                    work.append(dst)
        wall = time.perf_counter() - t0
        # merged metrics: one part per live replica (its last run), request
        # records relabeled to global rids.  Requests drained off a dead
        # replica are counted where they finished; a dead replica's own
        # partial run contributes no counters (its work was re-done).
        back = {(rep, lrid): g for g, (rep, lrid) in self._rid_map.items()}
        merged_parts = []
        for i, p in sorted(parts.items()):
            p = MetricsSnapshot.from_dict(p.to_dict())    # private copy
            for r in p.requests:
                r.rid = back.get((i, r.rid), r.rid)
            merged_parts.append(p)
        self.metrics_snapshot = MetricsSnapshot.merge(merged_parts,
                                                      wall_s=wall)
        self.metrics = self.metrics_snapshot.to_dict()
        outputs = dict(self._outputs)
        for g, (rep, lrid) in self._rid_map.items():
            if g not in outputs:
                outputs[g] = list(self.replicas[rep].requests[lrid].out)
        return {"outputs": outputs, "metrics": self.metrics}

    # ------------------------------------------------------ crash recovery
    def snapshot(self) -> Dict[str, Any]:
        """Host-state snapshot of the whole fleet (iteration-boundary per
        replica).  Only valid while every replica is alive — after a
        failover the fleet shape changed and the next run re-snapshots."""
        assert all(self.alive), "cannot snapshot a degraded fleet"
        return {
            "replicas": [e.snapshot() for e in self.replicas],
            "rid_map": dict(self._rid_map),
            "affinity": dict(self._affinity),
            "outputs": {g: list(o) for g, o in self._outputs.items()},
        }

    def restore(self, snap: Dict[str, Any]) -> None:
        assert len(snap["replicas"]) == self.dp, \
            "snapshot replica count does not match this router"
        for eng, s in zip(self.replicas, snap["replicas"]):
            eng.restore(s)
        self.alive = [True] * self.dp
        self._rid_map = dict(snap["rid_map"])
        self._affinity = dict(snap["affinity"])
        self._outputs = {g: list(o) for g, o in snap["outputs"].items()}

    def clear(self) -> None:
        for e in self.replicas:
            if self.alive[self.replicas.index(e)]:
                e.clear()
        self._rid_map = {}
        self._outputs = {}

"""Continuous-batching serving engine: chunked Amber-sparse prefill
interleaved with slot-batched dense decode over a **paged** KV cache.

Requests arrive asynchronously (:meth:`ContinuousServingEngine.submit`) and
are scheduled over a fixed pool of decode **slots** whose KV rows live in a
global **block pool** (:mod:`repro.serve.paged`).  Each scheduler
iteration:

  1. **admit** — waiting requests whose arrival time has passed claim free
     slots FCFS, gated by a block-budget check (the pool must cover the
     prompt); the slot's recurrent state is zeroed and its block table row
     populated.  With prefix caching on, the longest indexed block-prefix
     of the prompt (shared system prompt, few-shot template, or this
     request's own preemption replay) is acquired instead of recomputed:
     the shared block ids go straight into the table, the slot's ``pos``
     starts at the first non-cached token, and prefill begins mid-sequence;
  2. **prefill** — the oldest admitted-but-unprefilled request advances by
     one fixed-size token chunk through the Amber-sparse projection path
     (``model.prefill_chunk``), scattering KV through its block table;
  3. **ensure/preempt** — decoding slots crossing a block boundary grab a
     fresh block; when the pool is dry the **youngest** active request is
     preempted (blocks released, request requeued; its emitted tokens are
     replayed through prefill on re-admission, so greedy output is
     unchanged);
  4. **decode** — all slots holding decoding requests take one dense decode
     step as a single padded batch (inactive slots are masked out of the
     cache update).

Since the scheduler/executor API split this class is a thin **driver**
composing the two layers (serve/README.md "Architecture"):

* :class:`~repro.serve.scheduler.Scheduler` — every piece of host state
  (request lifecycles, slots, the block pool, prefix index, watchdog,
  counters); emits :class:`~repro.serve.scheduler.StepPlan`s and commits
  their results.  Never touches device arrays.
* :class:`~repro.serve.executor.Executor` — the cache pytree, the jit'd
  phase/step programs and their oracle twins, the fault/degradation
  ladder, and (optionally) a TP mesh that shards the kernels.  Never
  touches request state.

The driver owns only the run loop, the sampling PRNG, snapshot/restore
composition, and metrics assembly.  New code should construct engines
through :class:`repro.serve.api.Engine`; direct construction still works
(every historical attribute delegates to the right layer) but warns.

Shape buckets: prefill compiles once per chunk shape (a single
``chunk_size`` bucket for attention archs; a dyadic ladder of at most
log2(chunk_size)+1 sizes for archs with recurrent blocks, whose scans
cannot mask padded tokens), and decode compiles once for the padded
``num_slots`` batch — arbitrary traffic never retraces, and block
allocation/preemption only rewrites the small int32 block-table array, so
paging does not add shape buckets.  The ``trace_counts`` attribute counts
actual retraces per phase and is asserted in the test suite.

Equivalence: with greedy decoding and **per-token** sparsity modes the
per-request output stream is token-identical to the legacy one-shot
:class:`~repro.serve.engine.ServingEngine` — a token's N:M mask doesn't
depend on which chunk carries it, chunked prefill attends over the cached
prefix so logits match, decode rows are independent of batch composition,
and preemption replays the exact emitted prefix.  ``tile_consensus``
policies remain valid N:M serving but are NOT bit-identical to one-shot
prefill: their masks are pooled over token tiles, and chunking changes
tile membership (see serve/README.md).
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import DENSE, SparsityPolicy
from repro.serve import faults as fault_mod
from repro.serve.executor import Executor
from repro.serve.faults import EngineCrash, FaultInjector
from repro.serve.metrics import (LifecycleMetrics, MetricsSnapshot,
                                 PagedMetrics, RequestMetrics)
from repro.serve.paged import chain_block_hashes, chain_block_keys
from repro.serve.scheduler import (CANCELLED, DECODE, DONE, PREFILL,
                                   REJECTED, TERMINAL, TIMED_OUT, WAITING,
                                   Request, Scheduler, StepPlan,
                                   _dyadic_sizes)

__all__ = ["ContinuousConfig", "Request", "ContinuousServingEngine"]

# historical module-level names: the lifecycle states and chunk ladder
# lived here before the scheduler split, and tests/tools import them from
# this module
_TERMINAL = TERMINAL


def _hash_blocks(*args, **kwargs):
    # late-bound so the historical patch point keeps working: tests
    # monkeypatch ``repro.serve.continuous.chain_block_hashes`` and the
    # scheduler hashes through this shim
    return chain_block_hashes(*args, **kwargs)


@dataclasses.dataclass(frozen=True)
class ContinuousConfig:
    max_seq: int = 512        # per-slot KV capacity (prompt + new tokens)
    num_slots: int = 4        # decode batch width (the padded batch bucket)
    chunk_size: int = 64      # prefill chunk bucket (tokens per chunk)
    temperature: float = 0.0  # 0 → greedy
    eos_token: int = -1       # -1 → never stop early
    seed: int = 0
    max_iters: int = 100_000  # scheduler-loop safety valve
    fused_step: bool = True
    # one-dispatch iterations: the active request's prefill chunk AND the
    # slot-batched decode run as a SINGLE compiled step program per shape
    # bucket (metrics["dispatches_per_iteration"] == 1 on clean runs).
    # False restores the legacy two-program split (prefill then decode) —
    # token-identical under greedy sampling; under temperature > 0 the
    # sampling-key split order differs on same-iteration prefill→decode
    # handoffs.  Overridable via REPRO_FUSED_STEP=0/1.
    # --- paged KV allocation (serve/paged.py) ---
    paged: bool = True        # auto-disabled where no full-attn KV exists
    block_size: int = 16      # KV rows per block
    num_blocks: Optional[int] = None
    # None → num_slots * ceil(max_seq / block_size): same capacity as the
    # dense slab, paged mechanics.  The memory win is sizing it LOWER and
    # letting admission gating + preemption absorb the pressure.
    prefix_cache: bool = True
    # block-level prefix caching across requests: full blocks are chain-
    # hashed and refcounted so a request whose prompt repeats a cached
    # prefix (shared system prompt, preemption replay) skips its prefill.
    # Auto-disabled alongside paging, and for archs with recurrent blocks
    # (their scan state cannot be restored from cached KV).
    validate_pool: bool = False
    # audit block-pool/refcount/ownership invariants after every scheduler
    # iteration (O(num_blocks) host work) — test/debug instrumentation.
    # Also forced on by REPRO_VALIDATE_POOL=1 (set by tests/conftest.py so
    # the whole serving suite runs audited).
    # --- request-lifecycle hardening (ISSUE 6) ---
    ttl_default: Optional[int] = None
    # default per-request deadline: arrival + ttl_default scheduler
    # iterations (None = no deadline); submit(ttl=...) overrides per
    # request.  Past it the request moves to terminal TIMED_OUT from any
    # phase, its slot/blocks/prefix refs unwound.
    admission_retries: int = 8
    # transient admission failures (injected pool/admit faults, or a real
    # allocation error) absorbed per request before the REJECTED backstop
    retry_backoff: int = 2
    # exponential-backoff base: after the k-th transient failure the
    # request retries no earlier than it + min(retry_backoff**k, 64)
    watchdog_iters: int = 64
    # no-progress window: if admission-eligible requests exist but nothing
    # advanced for this many iterations (possible only under persistent
    # faults — clean scheduling always progresses), the watchdog force-
    # rejects the oldest stuck request instead of livelocking to max_iters
    snapshot_every: int = 0
    # >0: keep ``last_snapshot`` refreshed every k iterations (taken at
    # the top-of-iteration boundary) so a crashed engine can be rebuilt
    # with restore() and resume token-identically.  0 = manual snapshots.


class ContinuousServingEngine:
    """Scheduler + Executor driver over a paged slot cache."""

    def __init__(self, model, policy: SparsityPolicy = DENSE,
                 cfg: ContinuousConfig = ContinuousConfig(),
                 faults: Optional[FaultInjector] = None,
                 mesh=None, _via_api: bool = False):
        if not _via_api:
            warnings.warn(
                "constructing ContinuousServingEngine directly is "
                "deprecated; use repro.serve.api.Engine.from_config "
                "(serve/README.md has the migration table)",
                DeprecationWarning, stacklevel=2)
        self.model = model
        self.policy = policy
        self.cfg = cfg
        # deterministic fault injection (serve/faults.py): consulted at the
        # engine's own sites (admit/prefill/decode) and globally activated
        # around run() for the pool + kernel-dispatch sites
        self.faults = faults
        # optional host-side hook called at the top of every scheduler
        # iteration as hook(engine, it) — external control plane (the chaos
        # harness drives cancel() through it; a server could drive
        # monitoring or load shedding)
        self.iteration_hook: Optional[Callable] = None
        self._validate = (cfg.validate_pool
                          or os.environ.get("REPRO_VALIDATE_POOL") == "1")
        self.exec = Executor(model, policy, cfg, mesh=mesh)
        self.sched = Scheduler(
            cfg, paged=self.exec.paged,
            exact_chunks=self.exec.exact_chunks,
            policy_enabled=policy.enabled, prefix_cache=cfg.prefix_cache,
            faults=faults, validate=self._validate, hash_fn=_hash_blocks)
        # one-dispatch iterations (cfg.fused_step, env-overridable so the
        # chaos-smoke CI matrix can pin either path without code changes)
        env = os.environ.get("REPRO_FUSED_STEP")
        self.fused_step = (env != "0") if env is not None else cfg.fused_step
        self.work_iterations = 0  # iterations that dispatched any program
        self.restores = 0         # times restore() rebuilt this engine
        self.metrics: Dict[str, Any] = {}
        self.metrics_snapshot: Optional[MetricsSnapshot] = None
        self._key = None                       # sampling PRNG (run-owned)
        self.last_snapshot: Optional[Dict] = None

    # -------------------------------------------------- layer delegation
    # the historical flat-engine attributes, routed to the owning layer so
    # pre-split callers (tests, benchmarks, tools) keep working unchanged
    @property
    def requests(self):
        return self.sched.requests

    @property
    def pool(self):
        return self.sched.pool

    @property
    def paged(self) -> bool:
        return self.exec.paged

    @property
    def paged_kernel(self) -> bool:
        return self.exec.paged_kernel

    @property
    def prefix_cache(self) -> bool:
        return self.sched.prefix_cache

    @property
    def preempt_log(self):
        return self.sched.preempt_log

    @property
    def trace_counts(self):
        return self.exec.trace_counts

    @property
    def dispatches(self) -> int:
        return self.exec.dispatches

    @property
    def degraded_iterations(self) -> int:
        return self.exec.degraded_iterations

    @property
    def preemptions(self) -> int:
        return self.sched.preemptions

    @property
    def rejections(self) -> int:
        return self.sched.rejections

    @property
    def admission_retries(self) -> int:
        return self.sched.admission_retries

    @property
    def watchdog_trips(self) -> int:
        return self.sched.watchdog_trips

    @property
    def timeouts(self) -> int:
        return self.sched.timeouts

    @property
    def cancellations(self) -> int:
        return self.sched.cancellations

    @property
    def prefix_hits(self) -> int:
        return self.sched.prefix_hits

    @property
    def blocks_reused(self) -> int:
        return self.sched.blocks_reused

    @property
    def tokens_skipped(self) -> int:
        return self.sched.tokens_skipped

    @property
    def cache(self):
        return self.exec.cache

    @cache.setter
    def cache(self, value):
        self.exec.cache = value

    @property
    def _spec(self):
        return self.exec._spec

    @property
    def _step_raw(self):
        return self.exec._step_raw

    @property
    def _it(self) -> int:
        return self.sched.it

    # ------------------------------------------------------------ admission
    def submit(self, tokens, max_new_tokens: int = 32, arrival: int = 0,
               ttl: Optional[int] = None) -> int:
        """Queue a request; returns its request id.

        ``arrival`` is the scheduler iteration at which the request becomes
        visible (simulated asynchronous traffic).  ``ttl`` bounds its
        lifetime: past ``arrival + ttl`` scheduler iterations the request
        is moved to terminal ``TIMED_OUT`` from whatever phase it is in
        (None → ``cfg.ttl_default``; both None → no deadline)."""
        return self.sched.submit(tokens, max_new_tokens, arrival, ttl)

    def cancel(self, rid: int) -> bool:
        """Withdraw a request from any lifecycle phase.  Processed at the
        next iteration boundary (so a jitted phase never observes a
        half-unwound slot): the request moves to terminal ``CANCELLED``
        and its slot/blocks/prefix refs are released.  Returns False if
        the request is unknown or already terminal."""
        return self.sched.cancel(rid)

    def clear(self) -> None:
        """Drop completed requests (e.g. after a warmup pass) so a fresh
        stream can be submitted and measured on the already-compiled
        engine.  The prefix index deliberately survives: a warm cache
        across streams is the production behavior being measured."""
        self.sched.clear()
        self._key = None

    # ------------------------------------------------------------- phases
    def _crash_fire(self, site: str, it: int) -> float:
        """Fire a fault site; raise on "crash", return the logits-fault
        addend ("nonfinite" → NaN, clean → 0)."""
        kind = self.sched._fire(site)
        if kind == "crash":
            raise EngineCrash(f"injected crash in {site} (it={it})")
        return float("nan") if kind == "nonfinite" else 0.0

    def _step_fused(self, params, extras: Dict[int, Dict], it: int,
                    t0: float) -> bool:
        """One-dispatch iteration: the scheduler's fused plan runs as a
        SINGLE compiled step program (executor-side bucketing by static
        phase presence).  Returns whether any model work ran.

        Identical host bookkeeping to the legacy prefill+decode pair, with
        one scheduling difference: a request whose final chunk lands this
        iteration starts decoding NEXT iteration (the decode roster is
        frozen before dispatch), where the legacy path recomputed the
        roster after prefill.  Greedy token streams are identical; see
        ``ContinuousConfig.fused_step`` for the temperature>0 caveat."""
        plan = self.sched.plan_step()
        if not plan.has_work:
            return False
        self.exec.apply_effects(plan)
        # both legacy fault sites still fire (chaos schedules target them
        # by name); either hit folds into the step's shared fault operand,
        # so a single fault degrades the WHOLE fused step to the oracle —
        # exactly the blast radius of one compiled program
        fault_val = 0.0
        if plan.prefill is not None:
            fault_val += self._crash_fire("prefill", it)
        if plan.decode is not None:
            fault_val += self._crash_fire("decode", it)
        fault = jnp.float32(fault_val)
        # key-split order matches the legacy path (prefill, then decode)
        pkey = dkey = jnp.zeros((2,), jnp.uint32)   # placeholder operands
        if plan.prefill is not None:
            self._key, pkey = jax.random.split(self._key)
        if plan.decode is not None:
            self._key, dkey = jax.random.split(self._key)
        pw = plan.prefill
        ex = extras.get(pw.req.rid, {}) if pw is not None and pw.first else {}
        res = self.exec.step(params, plan, ex, pkey, dkey, fault)
        if pw is not None:
            self.sched.commit_chunk(pw.req, pw.chunk_len)
            if self.sched.seq_complete(pw.req):   # seq ingested: sample
                self.sched.emit_prefill_token(pw.req, res.prefill_token,
                                              it, t0)
        if plan.decode is not None:
            self.sched.emit_decode_tokens(plan.decode, res.decode_tokens,
                                          it, t0)
        return True

    def _step_prefill(self, params, extras: Dict[int, Dict], it: int,
                      t0: float) -> bool:
        """Legacy two-program split, phase 1: one chunk for the oldest
        prefilling request.  Returns whether the PREFILL roster was
        non-empty (the historical progress signal — a fully-ingested
        request parked in PREFILL counts as work even though nothing
        dispatches)."""
        if not any(r.state == PREFILL for r in self.sched.requests):
            return False
        self._key, sub = jax.random.split(self._key)
        plan = self.sched.plan_prefill()
        pw = plan.prefill
        if pw is None:     # fully ingested, parked — nothing to run
            return True
        self.exec.apply_effects(plan)
        fault = jnp.float32(self._crash_fire("prefill", it))
        ex = extras.get(pw.req.rid, {}) if pw.first else {}
        logits = self.exec.prefill(params, plan, ex, fault)
        self.sched.commit_chunk(pw.req, pw.chunk_len)
        if self.sched.seq_complete(pw.req):   # seq ingested: sample
            tok = self.exec.sample_token(logits, sub)
            self.sched.emit_prefill_token(pw.req, tok, it, t0)
        return True

    def _step_decode(self, params, it: int, t0: float) -> bool:
        """Legacy two-program split, phase 2: one slot-batched decode step
        (roster computed AFTER prefill, so a request finishing prefill
        this iteration decodes the same iteration)."""
        plan = self.sched.plan_decode()
        if plan.decode is None:
            return False
        self._key, sub = jax.random.split(self._key)
        self.exec.apply_effects(plan)
        fault = jnp.float32(self._crash_fire("decode", it))
        nxt = self.exec.decode(params, plan, sub, fault)
        self.sched.emit_decode_tokens(plan.decode, nxt, it, t0)
        return True

    # ------------------------------------------------------------ main loop
    def run(self, params, extras: Optional[Dict[int, Dict]] = None) -> Dict:
        """Drive the scheduler until every submitted request completes.

        ``extras`` maps request id → modality arrays sent with the first
        prefill chunk (``frame_embeds`` for encdec, ``pixel_embeds`` for
        VLM stubs).  Returns per-request outputs and aggregate metrics.
        """
        extras = extras or {}
        sched, ex = self.sched, self.exec
        ex.init_cache(sched.pool.num_blocks if self.paged else None)
        sched.mark_extras(extras)
        if self._key is None:   # survives across run() calls and restore()
            self._key = jax.random.PRNGKey(self.cfg.seed)
        t0 = time.perf_counter()
        it0 = sched.it
        preempt0, reject0 = sched.preemptions, sched.rejections
        hits0, reused0 = sched.prefix_hits, sched.blocks_reused
        skipped0, demand0 = sched.tokens_skipped, sched.prefill_demand
        degraded0, retries0 = ex.degraded_iterations, sched.admission_retries
        wdog0, timeout0 = sched.watchdog_trips, sched.timeouts
        cancel0 = sched.cancellations
        disp0, work0 = ex.dispatches, self.work_iterations
        if self.paged:
            sched.pool.peak_in_use = sched.pool.in_use   # per-run peak
            evict0 = sched.pool.evictions
        # the kernel-dispatch fault sites (core/pruner, models/attention)
        # cannot see this engine — activate the injector globally for the
        # duration of the loop (EngineCrash still deactivates cleanly)
        fault_mod.activate(self.faults)
        try:
            while sched.live():
                it = sched.it
                assert it - it0 < self.cfg.max_iters, "scheduler stuck"
                if self.faults is not None:
                    self.faults.tick(it)
                if self.iteration_hook is not None:
                    self.iteration_hook(self, it)
                if (self.cfg.snapshot_every
                        and it % self.cfg.snapshot_every == 0):
                    # iteration boundary = consistent state: a crash later
                    # this iteration rewinds here via restore()
                    self.last_snapshot = self.snapshot()
                sched.stamp_arrivals(it, time.perf_counter())
                reaped = sched.reap(it)
                admitted = sched.admit(it)
                if self.fused_step:
                    # block grab moves BEFORE the dispatch: the fused
                    # program reads the final roster/table, and a dry-pool
                    # preemption can still unwind the prefilling request
                    # ahead of its chunk
                    if self.paged:
                        sched.ensure_decode_blocks()
                    worked = self._step_fused(params, extras, it, t0)
                else:
                    worked = self._step_prefill(params, extras, it, t0)
                    if self.paged:
                        sched.ensure_decode_blocks()
                    worked = self._step_decode(params, it, t0) or worked
                if worked:
                    self.work_iterations += 1
                if self.paged and self._validate:
                    sched.audit_pool()
                sched.observe_progress(it, bool(reaped or admitted
                                                or worked))
                sched.it += 1
        finally:
            fault_mod.deactivate()
        it = sched.it - it0
        wall = time.perf_counter() - t0
        gen = sum(len(r.out) for r in sched.requests)
        snap = MetricsSnapshot(
            iterations=it,
            wall_s=wall,
            generated_tokens=gen,
            tokens_per_s=gen / max(wall, 1e-9),
            trace_counts=dict(ex.trace_counts),
            # compiled-program launches per iteration that ran model work
            # (oracle re-runs included) — 1.0 on a clean fused run, ~2 on
            # the legacy two-program split when prefill+decode overlap
            dispatches=ex.dispatches - disp0,
            dispatches_per_iteration=(
                (ex.dispatches - disp0)
                / max(self.work_iterations - work0, 1)),
            degraded_iterations=ex.degraded_iterations - degraded0,
            lifecycle=LifecycleMetrics(
                terminal_states={
                    s: sum(1 for r in sched.requests if r.state == s)
                    for s in TERMINAL},
                admission_retries=sched.admission_retries - retries0,
                watchdog_trips=sched.watchdog_trips - wdog0,
                timeouts=sched.timeouts - timeout0,
                cancellations=sched.cancellations - cancel0,
                restores=self.restores,
                faults_fired=(self.faults.total_fired
                              if self.faults is not None else 0),
            ),
            paged=(PagedMetrics(
                enabled=True,
                block_size=sched.pool.block_size,
                num_blocks=sched.pool.num_blocks,
                peak_blocks_in_use=sched.pool.peak_in_use,
                preemptions=sched.preemptions - preempt0,
                rejections=sched.rejections - reject0,
                attention_kernel=ex.paged_kernel,
                prefix_cache=sched.prefix_cache,
                prefix_hits=sched.prefix_hits - hits0,
                blocks_reused=sched.blocks_reused - reused0,
                tokens_skipped=sched.tokens_skipped - skipped0,
                prefill_tokens=sched.prefill_demand - demand0,
                cached_blocks=sched.pool.cached_blocks,
                evictions=sched.pool.evictions - evict0,
            ) if self.paged else PagedMetrics(enabled=False)),
            requests=[RequestMetrics(
                rid=r.rid,
                prompt_len=int(len(r.tokens)),
                arrival=r.arrival,
                state=r.state,
                admitted_iter=r.admitted_iter,
                first_token_iter=r.first_token_iter,
                done_iter=r.done_iter,
                latency_iters=r.done_iter - r.arrival,
                latency_s=r.done_time,
                n_out=len(r.out),
                preemptions=r.preempted,
                cached_tokens=r.cached_tokens,
                retries=r.retries,
                deadline=r.deadline,
            ) for r in sched.requests],
        )
        self.metrics_snapshot = snap
        self.metrics = snap.to_dict()
        return {
            "outputs": {r.rid: list(r.out) for r in sched.requests},
            "metrics": self.metrics,
        }

    # ------------------------------------------------------ crash recovery
    def snapshot(self) -> Dict[str, Any]:
        """Copy of all host-side engine state at an iteration boundary:
        request lifecycles (including emitted tokens and memoized hash
        chains), slot assignment, the block pool (tables, refcounts,
        prefix index, LRU order), the iteration clock, and the sampling
        PRNG.  Process-local — chain hashes use Python's per-process
        salted ``hash()``, so a snapshot only restores into the same
        process (matching its purpose: surviving an ENGINE crash, not a
        process crash)."""
        snap = self.sched.host_snapshot()
        snap["key"] = None if self._key is None else np.asarray(self._key)
        # executor/driver counters ride along in the scheduler's counter
        # dict so the snapshot schema matches the pre-split engine's
        snap["counters"]["degraded_iterations"] = \
            self.exec.degraded_iterations
        snap["counters"]["restores"] = self.restores
        return snap

    def restore(self, snap: Dict[str, Any]) -> None:
        """Rebuild host-side state from a :meth:`snapshot` taken in this
        process (possibly by a different, now-dead engine instance over
        the same model/config).  Device KV is treated as LOST — the crash
        that motivated the restore invalidates it — so in-flight requests
        are demoted to ``WAITING`` with a fresh block pool and empty
        prefix index, and replay through prefill on re-admission: the
        same recompute path preemption uses, so resumed greedy outputs
        are token-identical to an undisturbed run."""
        counters = dict(snap["counters"])
        self.exec.degraded_iterations = counters.pop("degraded_iterations")
        self.restores = counters.pop("restores") + 1
        self.sched.host_restore({**snap, "counters": counters})
        self._key = (None if snap["key"] is None
                     else jnp.asarray(snap["key"]))
        self.exec.drop_cache()             # rebuilt lazily by run()

"""Continuous-batching serving engine: chunked Amber-sparse prefill
interleaved with slot-batched dense decode over a **paged** KV cache.

Requests arrive asynchronously (:meth:`ContinuousServingEngine.submit`) and
are scheduled over a fixed pool of decode **slots** whose KV rows live in a
global **block pool** (:mod:`repro.serve.paged`).  Each scheduler
iteration:

  1. **admit** — waiting requests whose arrival time has passed claim free
     slots FCFS, gated by a block-budget check (the pool must cover the
     prompt); the slot's recurrent state is zeroed and its block table row
     populated.  With prefix caching on, the longest indexed block-prefix
     of the prompt (shared system prompt, few-shot template, or this
     request's own preemption replay) is acquired instead of recomputed:
     the shared block ids go straight into the table, the slot's ``pos``
     starts at the first non-cached token, and prefill begins mid-sequence;
  2. **prefill** — the oldest admitted-but-unprefilled request advances by
     one fixed-size token chunk through the Amber-sparse projection path
     (``model.prefill_chunk``), scattering KV through its block table;
  3. **ensure/preempt** — decoding slots crossing a block boundary grab a
     fresh block; when the pool is dry the **youngest** active request is
     preempted (blocks released, request requeued; its emitted tokens are
     replayed through prefill on re-admission, so greedy output is
     unchanged);
  4. **decode** — all slots holding decoding requests take one dense decode
     step as a single padded batch (inactive slots are masked out of the
     cache update).

Shape buckets: prefill compiles once per chunk shape (a single
``chunk_size`` bucket for attention archs; a dyadic ladder of at most
log2(chunk_size)+1 sizes for archs with recurrent blocks, whose scans
cannot mask padded tokens), and decode compiles once for the padded
``num_slots`` batch — arbitrary traffic never retraces, and block
allocation/preemption only rewrites the small int32 block-table array, so
paging does not add shape buckets.  The ``trace_counts`` attribute counts
actual retraces per phase and is asserted in the test suite.

Equivalence: with greedy decoding and **per-token** sparsity modes the
per-request output stream is token-identical to the legacy one-shot
:class:`~repro.serve.engine.ServingEngine` — a token's N:M mask doesn't
depend on which chunk carries it, chunked prefill attends over the cached
prefix so logits match, decode rows are independent of batch composition,
and preemption replays the exact emitted prefix.  ``tile_consensus``
policies remain valid N:M serving but are NOT bit-identical to one-shot
prefill: their masks are pooled over token tiles, and chunking changes
tile membership (see serve/README.md).
"""
from __future__ import annotations

import copy
import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import DENSE, SparsityPolicy
from repro.serve import faults as fault_mod
from repro.serve import slots as slot_ops
from repro.serve.faults import EngineCrash, FaultInjector, KernelFault
from repro.serve.paged import (BlockPool, chain_block_hashes,
                               chain_block_keys, init_paged_cache,
                               max_blocks_per_slot)

__all__ = ["ContinuousConfig", "Request", "ContinuousServingEngine"]

WAITING, PREFILL, DECODE, DONE = "waiting", "prefill", "decode", "done"
# terminal without ever running: admission proved the request can NEVER
# fit the block pool (its replay sequence outgrew capacity), its transient-
# failure retry budget ran out, or the no-progress watchdog evicted it —
# rejecting keeps strict-FCFS admission from waiting on it forever and
# starving the queue behind it (head-of-line livelock, ISSUE-5 bugfix)
REJECTED = "rejected"
# deadline (submit ttl / cfg.ttl_default) passed before completion
TIMED_OUT = "timed_out"
# cancel(rid): caller withdrew the request; unwound from any phase
CANCELLED = "cancelled"
_TERMINAL = (DONE, REJECTED, TIMED_OUT, CANCELLED)


@dataclasses.dataclass(frozen=True)
class ContinuousConfig:
    max_seq: int = 512        # per-slot KV capacity (prompt + new tokens)
    num_slots: int = 4        # decode batch width (the padded batch bucket)
    chunk_size: int = 64      # prefill chunk bucket (tokens per chunk)
    temperature: float = 0.0  # 0 → greedy
    eos_token: int = -1       # -1 → never stop early
    seed: int = 0
    max_iters: int = 100_000  # scheduler-loop safety valve
    fused_step: bool = True
    # one-dispatch iterations: the active request's prefill chunk AND the
    # slot-batched decode run as a SINGLE compiled step program per shape
    # bucket (metrics["dispatches_per_iteration"] == 1 on clean runs).
    # False restores the legacy two-program split (prefill then decode) —
    # token-identical under greedy sampling; under temperature > 0 the
    # sampling-key split order differs on same-iteration prefill→decode
    # handoffs.  Overridable via REPRO_FUSED_STEP=0/1.
    # --- paged KV allocation (serve/paged.py) ---
    paged: bool = True        # auto-disabled where no full-attn KV exists
    block_size: int = 16      # KV rows per block
    num_blocks: Optional[int] = None
    # None → num_slots * ceil(max_seq / block_size): same capacity as the
    # dense slab, paged mechanics.  The memory win is sizing it LOWER and
    # letting admission gating + preemption absorb the pressure.
    prefix_cache: bool = True
    # block-level prefix caching across requests: full blocks are chain-
    # hashed and refcounted so a request whose prompt repeats a cached
    # prefix (shared system prompt, preemption replay) skips its prefill.
    # Auto-disabled alongside paging, and for archs with recurrent blocks
    # (their scan state cannot be restored from cached KV).
    validate_pool: bool = False
    # audit block-pool/refcount/ownership invariants after every scheduler
    # iteration (O(num_blocks) host work) — test/debug instrumentation.
    # Also forced on by REPRO_VALIDATE_POOL=1 (set by tests/conftest.py so
    # the whole serving suite runs audited).
    # --- request-lifecycle hardening (ISSUE 6) ---
    ttl_default: Optional[int] = None
    # default per-request deadline: arrival + ttl_default scheduler
    # iterations (None = no deadline); submit(ttl=...) overrides per
    # request.  Past it the request moves to terminal TIMED_OUT from any
    # phase, its slot/blocks/prefix refs unwound.
    admission_retries: int = 8
    # transient admission failures (injected pool/admit faults, or a real
    # allocation error) absorbed per request before the REJECTED backstop
    retry_backoff: int = 2
    # exponential-backoff base: after the k-th transient failure the
    # request retries no earlier than it + min(retry_backoff**k, 64)
    watchdog_iters: int = 64
    # no-progress window: if admission-eligible requests exist but nothing
    # advanced for this many iterations (possible only under persistent
    # faults — clean scheduling always progresses), the watchdog force-
    # rejects the oldest stuck request instead of livelocking to max_iters
    snapshot_every: int = 0
    # >0: keep ``last_snapshot`` refreshed every k iterations (taken at
    # the top-of-iteration boundary) so a crashed engine can be rebuilt
    # with restore() and resume token-identically.  0 = manual snapshots.


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                 # (T,) prompt token ids
    max_new_tokens: int
    arrival: int = 0                   # scheduler iteration of arrival
    # --- runtime (engine-owned) ---
    state: str = WAITING
    slot: int = -1
    filled: int = 0                    # seq tokens prefilled so far
    cur: int = 0                       # last generated token (decode input)
    out: List[int] = dataclasses.field(default_factory=list)
    blocks: List[int] = dataclasses.field(default_factory=list)
    kv_len: int = 0                    # KV rows held (host mirror of pos)
    shared: int = 0                    # leading blocks reused from the index
    registered: int = 0                # leading blocks published to the index
    cached_tokens: int = 0             # prefill rows skipped via prefix hits
    # memoized chain hashes of this request's full blocks; token content
    # never changes for an already-hashed block (out only appends), so the
    # chain survives preemption and extends in O(new blocks)
    hash_chain: List[int] = dataclasses.field(default_factory=list)
    preempted: int = 0                 # times requeued by the block pool
    admitted_iter: int = -1
    first_token_iter: int = -1
    done_iter: int = -1
    arrival_time: float = -1.0         # wall clock when arrival was reached
    done_time: float = 0.0             # wall-clock latency from arrival
    # --- lifecycle hardening ---
    deadline: Optional[int] = None     # absolute iteration bound (TIMED_OUT)
    cancel_requested: bool = False     # processed at the next iteration start
    retries: int = 0                   # transient admission failures absorbed
    next_retry_iter: int = 0           # backoff window after a transient fail


def _dyadic_sizes(length: int, cap: int) -> List[int]:
    """Non-increasing powers of two ≤ cap summing exactly to length.

    ``length <= 0`` returns ``[]``: without the guard the inner halving
    loop decays ``c`` to 0 and ``rem -= 0`` spins forever.  A zero
    remainder is reachable — a cancel/timeout can land between scheduling
    and prefill — so this must terminate, and ``_next_chunk`` must treat
    the empty ladder as "nothing to prefill" rather than index into it."""
    if length <= 0:
        return []
    sizes = []
    c = 1
    while c * 2 <= cap:
        c *= 2
    rem = length
    while rem:
        while c > rem:
            c //= 2
        sizes.append(c)
        rem -= c
    return sizes


class ContinuousServingEngine:
    """Scheduler + paged slot cache + shape-bucketed jitted phases."""

    def __init__(self, model, policy: SparsityPolicy = DENSE,
                 cfg: ContinuousConfig = ContinuousConfig(),
                 faults: Optional[FaultInjector] = None):
        self.model = model
        self.policy = policy
        self.cfg = cfg
        # deterministic fault injection (serve/faults.py): consulted at the
        # engine's own sites (admit/prefill/decode) and globally activated
        # around run() for the pool + kernel-dispatch sites
        self.faults = faults
        # optional host-side hook called at the top of every scheduler
        # iteration as hook(engine, it) — external control plane (the chaos
        # harness drives cancel() through it; a server could drive
        # monitoring or load shedding)
        self.iteration_hook: Optional[Callable] = None
        self._validate = (cfg.validate_pool
                          or os.environ.get("REPRO_VALIDATE_POOL") == "1")
        mcfg = model.cfg
        if getattr(mcfg, "vision_stub", False):
            assert cfg.chunk_size >= mcfg.n_patches, (
                "chunk_size must cover the VLM patch stub "
                f"({cfg.chunk_size} < {mcfg.n_patches})")
        # recurrent scans cannot mask padded tokens out of their state, so
        # hybrid/SSM archs get exact dyadic chunks instead of a padded tail
        if mcfg.is_encdec:
            self._exact_chunks = False
        else:
            from repro.models.transformer import layer_kinds
            self._exact_chunks = any(k != "attn" for k in layer_kinds(mcfg))
        if mcfg.attn_type in ("swa", "local"):
            assert cfg.chunk_size <= min(mcfg.window, cfg.max_seq), (
                "chunk_size must fit the sliding-window ring buffer")

        # paged KV: only archs with full-attention KV leaves benefit;
        # encdec (request-shaped caches), SWA rings, and pure-recurrent
        # archs fall back to the dense per-slot slab automatically
        spec = model.paged_kv_spec() if cfg.paged else None
        if spec is not None and not any(jax.tree_util.tree_leaves(spec)):
            spec = None
        self._spec = spec
        self.paged = spec is not None
        # the projections' policy flag also routes paged attention through
        # the in-kernel block-table walk (models/attention.paged_attention
        # ladder); decode runs DENSE projections but must carry the flag so
        # its attention takes the same path as prefill's
        self.paged_kernel = self.paged and bool(policy.use_pallas_kernels)
        if self.paged_kernel and not self._exact_chunks:
            # a padded prefill bucket the kernel cannot tile would silently
            # fall back to the gather oracle while metrics/--trace claimed
            # the kernel ran — reject it here instead (exact-chunk archs
            # emit power-of-two chunks, always covered; decode is T = 1)
            from repro.kernels.paged_attention import paged_kernel_covers
            assert paged_kernel_covers(cfg.chunk_size), (
                "paged-attention kernel cannot tile chunk_size="
                f"{cfg.chunk_size} (see kernels.paged_attention"
                ".paged_kernel_covers); use a power-of-two chunk_size or "
                "drop use_pallas_kernels")
        self.preemptions = 0
        self.rejections = 0
        self.preempt_log: List[tuple] = []      # (rid, state-when-preempted)
        # lifecycle-hardening counters
        self.degraded_iterations = 0  # iterations re-run on the jnp oracle
        self.admission_retries = 0    # transient admission failures absorbed
        self.watchdog_trips = 0       # forced evictions by the watchdog
        self.timeouts = 0
        self.cancellations = 0
        self.restores = 0             # times restore() rebuilt this engine
        # prefix caching needs every piece of continuation state to live in
        # the paged KV pool: archs with recurrent blocks carry scan state
        # that cached blocks cannot restore, so they stay cache-off even
        # though their attention leaves are paged
        self.prefix_cache = (self.paged and cfg.prefix_cache
                             and not self._exact_chunks)
        self.prefix_hits = 0        # admissions that reused ≥ 1 block
        self.blocks_reused = 0      # total shared-block acquisitions
        self.tokens_skipped = 0     # prefill rows served from the index
        self.prefill_demand = 0     # prefill rows requested at admission
        self._extra_rids: set = set()   # requests with modality extras:
        # their hidden states depend on non-token inputs, so token-id chain
        # hashes cannot address their KV — excluded from the prefix index
        if self.paged:
            self._max_blocks = max_blocks_per_slot(cfg.max_seq,
                                                   cfg.block_size)
            nb = (cfg.num_blocks if cfg.num_blocks is not None
                  else cfg.num_slots * self._max_blocks)
            self.pool: Optional[BlockPool] = BlockPool(
                nb, cfg.block_size, prefix_cache=self.prefix_cache)
            self._host_table = np.full((cfg.num_slots, self._max_blocks),
                                       -1, np.int32)
            self._table_dirty = True
        else:
            self.pool = None

        self.requests: List[Request] = []
        self._free_slots = list(range(cfg.num_slots))
        self._slot_req: List[Optional[Request]] = [None] * cfg.num_slots
        self.cache = None                      # built lazily per params
        self.trace_counts: Dict[str, int] = {}
        self.metrics: Dict[str, Any] = {}
        # one-dispatch iterations (cfg.fused_step, env-overridable so the
        # chaos-smoke CI matrix can pin either path without code changes)
        env = os.environ.get("REPRO_FUSED_STEP")
        self.fused_step = (env != "0") if env is not None else cfg.fused_step
        self.dispatches = 0       # compiled-program launches (incl. oracle)
        self.work_iterations = 0  # iterations that dispatched any program
        self._it = 0                           # scheduler-iteration clock
        self._key = None                       # sampling PRNG (run-owned)
        self._last_progress = 0                # watchdog bookkeeping
        self.last_snapshot: Optional[Dict] = None

        # every phase program takes a runtime ``fault`` operand added onto
        # its logits (0.0 on clean runs, NaN when the injector fires a
        # "nonfinite" fault — a runtime value, so injection never bakes
        # into or retraces the compiled program) and returns an ``ok``
        # finiteness verdict the degradation ladder checks host-side.
        # ``ok`` also trips on GENUINE non-finite logits from a kernel bug.
        def make_prefill_fn(policy, count_key):
            def prefill_fn(params, cache, slot, tokens, chunk_len, extras,
                           fault):
                # runs at trace time only
                self.trace_counts[count_key] = \
                    self.trace_counts.get(count_key, 0) + 1
                sub = slot_ops.slice_slot(cache, slot, self._spec)
                batch = {"tokens": tokens, "chunk_len": chunk_len, **extras}
                logits, sub = self.model.prefill_chunk(params, batch, sub,
                                                       policy=policy)
                logits = logits[0] + fault
                ok = jnp.all(jnp.isfinite(logits))
                return logits, slot_ops.write_slot(cache, slot, sub,
                                                   self._spec), ok
            return prefill_fn

        dense = DENSE.with_(use_pallas_kernels=policy.use_pallas_kernels)

        def make_decode_fn(policy, count_key):
            def decode_fn(params, cache, tokens, active, key, fault):
                self.trace_counts[count_key] = \
                    self.trace_counts.get(count_key, 0) + 1
                logits, new_cache = self.model.decode_step(
                    params, tokens[:, None], cache, policy=policy)
                logits = logits + fault
                new_cache = slot_ops.where_active(active, new_cache, cache,
                                                  self._spec)
                nxt = self._sample(logits, key)
                # inactive slots may legitimately hold junk logits — only
                # active rows gate the degradation ladder
                ok = jnp.all(jnp.isfinite(logits)
                             | ~active.reshape(active.shape[0],
                                               *([1] * (logits.ndim - 1))))
                return jnp.where(active, nxt, tokens), new_cache, ok
            return decode_fn

        self._prefill_jit = jax.jit(make_prefill_fn(policy, "prefill"))
        # preemption replay re-ingests tokens the request already EMITTED;
        # their KV was originally written by the dense decode step, so the
        # replay must also run dense or sparse-prefill outputs would drift
        # from the one-shot oracle.  Chunks never span the prompt/emitted
        # boundary (see _next_chunk); this program only ever traces (and
        # the "prefill_replay" key only appears) if a preemption happens
        # under a non-dense policy.
        self._prefill_replay_jit = jax.jit(
            make_prefill_fn(dense, "prefill_replay"))
        self._decode_jit = jax.jit(make_decode_fn(dense, "decode"))
        # graceful-degradation ladder: bit-exact jnp oracle twins of every
        # phase program (kernel dispatch forced off).  jax.jit is lazy, so
        # none of these trace — and no "*_oracle" trace-count key appears —
        # unless an iteration actually degrades.
        opolicy = policy.with_(use_pallas_kernels=False) \
            if policy.use_pallas_kernels else policy
        self._prefill_oracle_jit = jax.jit(
            make_prefill_fn(opolicy, "prefill_oracle"))
        self._prefill_replay_oracle_jit = jax.jit(
            make_prefill_fn(DENSE, "prefill_replay_oracle"))
        self._decode_oracle_jit = jax.jit(
            make_decode_fn(DENSE, "decode_oracle"))

        # ---- one-dispatch iterations: a single hybrid step program per
        # shape bucket runs the active request's prefill chunk AND the
        # slot-batched decode in one compiled dispatch.  Buckets are keyed
        # (replay, has_prefill, has_decode) — static phase presence, so an
        # idle phase costs nothing in the lowered program.  The prefill
        # half writes its chunk KV first; the decode half then reads the
        # already-updated cache, exactly like the legacy two-program order
        # within an iteration.  Both halves share one ``fault`` operand
        # and fold into one all-finite ``ok`` verdict (inactive decode
        # rows masked), so the degradation ladder re-runs the WHOLE step
        # on the oracle twin.
        def make_step_fn(pf_policy, dec_policy, count_key,
                         has_prefill, has_decode):
            def step_fn(params, cache, slot, tokens, chunk_len, extras,
                        toks, active, pkey, dkey, fault):
                # runs at trace time only
                self.trace_counts[count_key] = \
                    self.trace_counts.get(count_key, 0) + 1
                ok = jnp.asarray(True)
                ptok = jnp.asarray(0, jnp.int32)
                if has_prefill:
                    sub = slot_ops.slice_slot(cache, slot, self._spec)
                    batch = {"tokens": tokens, "chunk_len": chunk_len,
                             **extras}
                    p_logits, sub = self.model.prefill_chunk(
                        params, batch, sub, policy=pf_policy)
                    p_logits = p_logits[0] + fault
                    ok = ok & jnp.all(jnp.isfinite(p_logits))
                    cache = slot_ops.write_slot(cache, slot, sub,
                                                self._spec)
                    ptok = self._sample(p_logits, pkey)
                nxt = toks
                if has_decode:
                    d_logits, new_cache = self.model.decode_step(
                        params, toks[:, None], cache, policy=dec_policy)
                    d_logits = d_logits + fault
                    cache = slot_ops.where_active(active, new_cache, cache,
                                                  self._spec)
                    # inactive slots may legitimately hold junk logits —
                    # only active rows gate the degradation ladder
                    ok = ok & jnp.all(
                        jnp.isfinite(d_logits)
                        | ~active.reshape(active.shape[0],
                                          *([1] * (d_logits.ndim - 1))))
                    nxt = jnp.where(active, self._sample(d_logits, dkey),
                                    toks)
                return ptok, nxt, cache, ok
            return step_fn

        # raw (unjitted) step fns are kept for the jaxpr pins in tests
        self._step_raw: Dict[tuple, Callable] = {}
        self._step_jits: Dict[tuple, Callable] = {}
        self._step_oracle_jits: Dict[tuple, Callable] = {}
        for replay, hp, hd in ((False, True, False), (False, True, True),
                               (False, False, True), (True, True, False),
                               (True, True, True)):
            name = "step" + ("_replay" if replay else
                             ("_prefill" if hp else "")) \
                + ("_decode" if hd else "")
            pf = dense if replay else policy
            opf = DENSE if replay else opolicy
            key = (replay, hp, hd)
            self._step_raw[key] = make_step_fn(pf, dense, name, hp, hd)
            self._step_jits[key] = jax.jit(self._step_raw[key])
            self._step_oracle_jits[key] = jax.jit(
                make_step_fn(opf, DENSE, name + "_oracle", hp, hd))

    # ------------------------------------------------------------- sampling
    def _sample(self, logits, key):
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------ admission
    def submit(self, tokens, max_new_tokens: int = 32, arrival: int = 0,
               ttl: Optional[int] = None) -> int:
        """Queue a request; returns its request id.

        ``arrival`` is the scheduler iteration at which the request becomes
        visible (simulated asynchronous traffic).  ``ttl`` bounds its
        lifetime: past ``arrival + ttl`` scheduler iterations the request
        is moved to terminal ``TIMED_OUT`` from whatever phase it is in
        (None → ``cfg.ttl_default``; both None → no deadline)."""
        tokens = np.asarray(tokens).reshape(-1).astype(np.int32)
        assert tokens.size > 0, "empty prompt"
        assert tokens.size + max_new_tokens <= self.cfg.max_seq, \
            "request exceeds slot capacity (max_seq)"
        if self.paged:
            assert (self.pool.blocks_for(tokens.size + max_new_tokens)
                    <= self.pool.num_blocks), \
                "request exceeds block pool capacity"
        rid = len(self.requests)
        if ttl is None:
            ttl = self.cfg.ttl_default
        self.requests.append(Request(
            rid=rid, tokens=tokens, max_new_tokens=max_new_tokens,
            arrival=arrival,
            deadline=None if ttl is None else arrival + ttl))
        return rid

    def cancel(self, rid: int) -> bool:
        """Withdraw a request from any lifecycle phase.  Processed at the
        next iteration boundary (so a jitted phase never observes a
        half-unwound slot): the request moves to terminal ``CANCELLED``
        and its slot/blocks/prefix refs are released.  Returns False if
        the request is unknown or already terminal."""
        req = next((r for r in self.requests if r.rid == rid), None)
        if req is None or req.state in _TERMINAL:
            return False
        req.cancel_requested = True
        return True

    # ---------------------------------------------------- lifecycle plumbing
    def _fire(self, site: str) -> Optional[str]:
        return self.faults.fire(site) if self.faults is not None else None

    def _evict_request(self, req: Request, state: str, it: int) -> None:
        """Move ``req`` to terminal ``state`` from ANY lifecycle phase,
        unwinding whatever it holds.  Full blocks are registered before
        release — their rows are final KV, so the prefix index keeps them
        (a re-submitted prompt still hits); the partially-written frontier
        block is released unregistered, so no writable block is ever
        published (audited by ``_audit_pool``)."""
        if req.state in (PREFILL, DECODE):
            if self.paged and req.blocks:
                self._register_blocks(req)
                self.pool.release(req.blocks[::-1])   # chain head → MRU end
                req.blocks = []
                req.shared = req.registered = 0
            if req.slot >= 0:
                if self.paged:
                    self._host_table[req.slot, :] = -1
                    self._table_dirty = True
                self._free_slots.append(req.slot)
                self._slot_req[req.slot] = None
                req.slot = -1
        req.state = state
        req.done_iter = it
        # terminal latency is still wall-clock since arrival — evicted
        # requests (cancelled / timed out / rejected) otherwise report the
        # -1.0 dataclass default as their latency_s
        if req.arrival_time >= 0:
            req.done_time = time.perf_counter() - req.arrival_time
        req.filled = 0
        req.kv_len = 0

    def _retry(self, req: Request, it: int) -> None:
        """Absorb a transient admission failure: exponential backoff, then
        the REJECTED backstop once the per-request retry budget is spent
        (an unbounded retry of a persistent fault would livelock strict-
        FCFS admission)."""
        req.retries += 1
        self.admission_retries += 1
        if req.retries > self.cfg.admission_retries:
            self._evict_request(req, REJECTED, it)
            self.rejections += 1
        else:
            req.next_retry_iter = it + min(
                self.cfg.retry_backoff ** req.retries, 64)

    def _reap(self, it: int) -> int:
        """Process cancellations and deadlines at the iteration boundary;
        returns how many requests reached a terminal state."""
        n = 0
        for r in self.requests:
            if r.state in _TERMINAL:
                continue
            if r.cancel_requested:
                self._evict_request(r, CANCELLED, it)
                self.cancellations += 1
                n += 1
            elif r.deadline is not None and it >= r.deadline:
                self._evict_request(r, TIMED_OUT, it)
                self.timeouts += 1
                n += 1
        return n

    def _seq(self, req: Request) -> np.ndarray:
        """Tokens to prefill: the prompt, plus — after a preemption — the
        tokens already emitted, replayed so decode resumes exactly where it
        left off (greedy outputs are chunking-invariant, so the replayed
        prefix regenerates the identical KV state)."""
        if req.out:
            return np.concatenate([req.tokens,
                                   np.asarray(req.out, np.int32)])
        return req.tokens

    def _chain_for(self, req: Request, tokens: np.ndarray,
                   n_full: int) -> List[int]:
        """First ``n_full`` chain hashes of the request's sequence,
        extending the memoized chain only over blocks not yet hashed."""
        chain = req.hash_chain
        if n_full > len(chain):
            dense_from = len(req.tokens) if self.policy.enabled else None
            chain.extend(chain_block_hashes(
                tokens, self.pool.block_size, n_full, dense_from,
                start=len(chain), h0=chain[-1] if chain else None))
        return chain[:n_full]

    def _match_prefix(self, req: Request, seq: np.ndarray) -> List[int]:
        """Longest indexed block-prefix of the request's prefill sequence.
        Capped at ``len(seq) - 1`` tokens: at least one token must run
        through prefill to produce the logits the next token samples from,
        so the request's last block is always a fresh allocation (and a
        partially-covered tail block has no full-block hash anyway) —
        shared blocks are therefore never writable."""
        if not self.prefix_cache or req.rid in self._extra_rids:
            return []
        n_full = (len(seq) - 1) // self.pool.block_size
        if n_full == 0:
            return []
        dense_from = len(req.tokens) if self.policy.enabled else None
        return self.pool.match(
            self._chain_for(req, seq, n_full),
            keys=chain_block_keys(seq, self.pool.block_size, n_full,
                                  dense_from))

    def _admit(self, it: int) -> int:
        # FCFS by arrival, not submission order: requests may be submitted
        # with out-of-order arrival times (and preempted requests requeue
        # with their original arrival).  Returns how many requests changed
        # state (admitted or rejected) — the watchdog's progress signal.
        moved = 0
        for req in sorted(self.requests, key=lambda r: (r.arrival, r.rid)):
            if req.state != WAITING or req.arrival > it:
                continue
            if req.next_retry_iter > it:
                continue               # backing off a transient failure
            if self.paged:
                seq = self._seq(req)
                need = self.pool.blocks_for(len(seq))
                if need > min(self.pool.num_blocks, self._max_blocks):
                    # can NEVER fit: strict FCFS would wait on it forever
                    # and starve every request behind it (head-of-line
                    # livelock) — reject with a terminal state instead.
                    # ``submit`` already bounds prompt+max_new, and a
                    # replay sequence (prompt + emitted) stays under that
                    # bound, so through the public API this is a
                    # defense-in-depth backstop: it converts any capacity
                    # drift (out-of-band enqueues, future scheduler
                    # changes shrinking the pool) into a visible REJECTED
                    # request instead of a silent queue stall
                    self._evict_request(req, REJECTED, it)
                    self.rejections += 1
                    moved += 1
                    continue
            if not self._free_slots:
                break
            if self._fire("admit") == "transient":
                # injected transient admission failure (e.g. a control-
                # plane hiccup): backoff-and-retry before the backstop
                self._retry(req, it)
                continue
            skip = 0
            if self.paged:
                shared = self._match_prefix(req, seq)
                # full feasibility BEFORE taking anything: reviving a
                # zero-ref cached hit consumes availability (sharing a
                # live block does not), and the fresh remainder must fit
                # what is left — so a refused admission never touches the
                # pool (no rollback, no phantom peak_in_use spike)
                revive = sum(map(self.pool.is_cached, shared))
                if need - len(shared) > self.pool.available - revive:
                    # strict FCFS: the oldest waiting request admits first;
                    # skipping ahead would starve long prompts under
                    # sustained short-prompt traffic
                    break
                acquired: List[int] = []
                try:
                    for b in shared:
                        self.pool.acquire_cached(b)
                        acquired.append(b)
                    fresh = self.pool.alloc(need - len(shared))
                except RuntimeError:
                    # allocation failed mid-admission (injected pool fault,
                    # or capacity raced away): roll back the prefix refs
                    # just acquired — the pool is left exactly as found —
                    # and retry with backoff
                    self.pool.release(acquired[::-1])
                    self._retry(req, it)
                    continue
                req.blocks = shared + fresh
                req.shared = req.registered = len(shared)
                skip = len(shared) * self.pool.block_size
                req.cached_tokens += skip
                self.prefill_demand += len(seq)
                self.tokens_skipped += skip
                self.blocks_reused += len(shared)
                if shared:
                    self.prefix_hits += 1
            slot = self._free_slots.pop(0)
            # prefix-cached rows are already valid KV: start the slot's pos
            # at the first non-cached token so the first prefill chunk runs
            # mid-sequence (prefill_chunk scatters/attends at cache offsets
            # either way); reset never touches pooled leaves, so the shared
            # blocks other slots may be reading survive the slot handoff
            self.cache = slot_ops.reset_slot(self.cache, slot, self._spec,
                                             pos=skip)
            if self.paged:
                self._host_table[slot, :] = -1
                self._host_table[slot, :len(req.blocks)] = req.blocks
                self._table_dirty = True
            req.slot, req.state = slot, PREFILL
            req.filled = req.kv_len = skip
            req.admitted_iter = it
            self._slot_req[slot] = req
            moved += 1
        return moved

    def _register_blocks(self, req: Request) -> None:
        """Publish the request's full blocks in the prefix index.  KV rows
        0..kv_len-1 hold the tokens ``(prompt ++ out)[:kv_len]`` (a freshly
        sampled token's own KV is only written when it is next fed back
        in), so full blocks are content-addressable by that token chain.
        Called whenever row content is final AND worth publishing: after
        each prefill chunk, and — to pick up decode-written rows — right
        before the blocks are released at preemption or completion."""
        if not self.prefix_cache or req.rid in self._extra_rids:
            return
        bs = self.pool.block_size
        n_full = min(req.kv_len // bs, len(req.blocks))
        if n_full <= req.registered:
            return
        seq = self._seq(req)[:req.kv_len]
        hashes = self._chain_for(req, seq, n_full)
        dense_from = len(req.tokens) if self.policy.enabled else None
        keys = chain_block_keys(seq, bs, n_full, dense_from)
        for i in range(req.registered, n_full):
            self.pool.register(req.blocks[i], hashes[i], key=keys[i])
        req.registered = n_full

    def _preempt(self, req: Request) -> None:
        """Requeue ``req`` (recompute-on-readmission): its blocks return to
        the pool, its slot frees, and its emitted tokens stay on the
        request to be replayed through prefill when it is re-admitted.
        Full blocks are registered first, so as long as they survive in
        the zero-ref LRU the replay is nearly free: the replayed
        prompt+emitted prefix re-matches exactly what was just released."""
        self.preemptions += 1
        req.preempted += 1
        self.preempt_log.append((req.rid, req.state))
        self._register_blocks(req)
        # deepest blocks first: chain hashes only match a CONTIGUOUS prefix
        # from block 0, so eviction must consume chains tail-first — the
        # reversed release order parks the chain head at the MRU end
        self.pool.release(req.blocks[::-1])
        req.blocks = []
        req.shared = req.registered = 0
        self._host_table[req.slot, :] = -1
        self._table_dirty = True
        self._free_slots.append(req.slot)
        self._slot_req[req.slot] = None
        req.slot = -1
        req.state = WAITING
        req.filled = 0
        req.kv_len = 0

    def _ensure_decode_blocks(self) -> None:
        """Grab a fresh block for every decoding slot crossing a block
        boundary; when the pool is dry, preempt the youngest active
        request until the oldest decoders can proceed (or the needy
        request is itself the youngest and yields)."""
        order = sorted((r for r in self.requests if r.state == DECODE),
                       key=lambda r: (r.admitted_iter, r.rid))
        for r in order:
            while r.state == DECODE:
                need = self.pool.blocks_for(r.kv_len + 1)
                if len(r.blocks) >= need:
                    break
                blk = None
                if self.pool.available:
                    try:
                        blk = self.pool.alloc(1)
                    except RuntimeError:
                        blk = None   # injected exhaustion → preempt path
                if blk is not None:
                    self._host_table[r.slot, len(r.blocks)] = blk[0]
                    r.blocks.extend(blk)
                    self._table_dirty = True
                else:
                    victim = max((v for v in self.requests
                                  if v.state in (PREFILL, DECODE)),
                                 key=lambda v: (v.admitted_iter, v.rid))
                    self._preempt(victim)

    def _finish(self, req: Request, it: int, t0: float) -> None:
        req.state = DONE
        req.done_iter = it
        anchor = req.arrival_time if req.arrival_time >= 0 else t0
        req.done_time = time.perf_counter() - anchor
        if self.paged and req.blocks:
            self._register_blocks(req)
            self.pool.release(req.blocks[::-1])   # chain head → MRU end
            req.blocks = []
            req.shared = req.registered = 0
            self._host_table[req.slot, :] = -1
            self._table_dirty = True
        self._free_slots.append(req.slot)
        self._slot_req[req.slot] = None
        req.slot = -1

    def clear(self) -> None:
        """Drop completed requests (e.g. after a warmup pass) so a fresh
        stream can be submitted and measured on the already-compiled
        engine.  The prefix index deliberately survives: a warm cache
        across streams is the production behavior being measured."""
        assert all(r.state in _TERMINAL for r in self.requests), \
            "cannot clear with requests in flight"
        self.requests = []
        # rids restart at 0 for the next stream: stale modality-extras
        # exclusions must not leak onto unrelated rid-colliding requests
        self._extra_rids = set()
        self._it = 0
        self._key = None
        self._last_progress = 0

    # ---------------------------------------------------------- auditing
    def _audit_pool(self) -> None:
        """Refcount/ownership invariants (cfg.validate_pool): the pool's
        internal partition holds, every live reference is accounted to
        exactly one slot-holding request, and no block is simultaneously
        writable from two slots.  A request's writable frontier is block
        ``kv_len // block_size`` onward (rows below kv_len are final);
        everything it can still write must be exclusively owned and
        unpublished — shared/registered blocks are full and immutable."""
        pool = self.pool
        pool.check_invariants()
        expect: Dict[int, int] = {}
        writable: Dict[int, int] = {}
        for r in self.requests:
            if r.state not in (PREFILL, DECODE):
                assert not r.blocks, \
                    f"r{r.rid} ({r.state}) still holds blocks {r.blocks}"
                continue
            for b in r.blocks:
                expect[b] = expect.get(b, 0) + 1
            for b in r.blocks[r.kv_len // pool.block_size:]:
                assert b not in writable, \
                    f"block {b} writable from r{writable[b]} AND r{r.rid}"
                writable[b] = r.rid
                assert pool.refcount(b) == 1, \
                    f"writable block {b} of r{r.rid} is shared"
                assert not pool.is_registered(b), \
                    f"writable block {b} of r{r.rid} is published"
        assert expect == dict(pool._ref), \
            f"refcount skew: requests hold {expect}, pool says {pool._ref}"

    # ------------------------------------------------------------ phases
    def _sync_table(self) -> None:
        if self.paged and self._table_dirty:
            self.cache["block_table"] = jnp.asarray(self._host_table)
            self._table_dirty = False

    def _next_chunk(self, req: Request):
        """(tokens (1, C), chunk_len, send_extras, is_replay) for the next
        chunk.  Chunks never span the prompt/emitted boundary, so a replay
        chunk (re-ingesting emitted tokens after a preemption) is entirely
        replay and runs through the dense program.

        Returns the ``(None, 0, False, False)`` sentinel when nothing
        remains to ingest — a fully-filled request momentarily parked in
        PREFILL must not index into an empty dyadic ladder."""
        c = self.cfg.chunk_size
        seq = self._seq(req)
        rem = len(seq) - req.filled
        if rem <= 0:
            return None, 0, False, False
        if req.filled < len(req.tokens):
            rem = min(rem, len(req.tokens) - req.filled)
            replay = False
        else:
            replay = self.policy.enabled
        if self._exact_chunks:
            size = _dyadic_sizes(rem, c)[0]
            chunk = seq[req.filled:req.filled + size]
            return chunk[None, :], size, req.filled == 0, replay
        v = min(c, rem)
        chunk = np.zeros((c,), np.int32)
        chunk[:v] = seq[req.filled:req.filled + v]
        return chunk[None, :], v, req.filled == 0, replay

    def _prefill_one(self, params, req: Request, extras: Dict, it: int,
                     t0: float, key) -> None:
        tokens, clen, first, replay = self._next_chunk(req)
        if tokens is None:
            return
        ex = extras if first else {}
        self._sync_table()
        kind = self._fire("prefill")
        if kind == "crash":
            raise EngineCrash(f"injected crash in prefill (it={it})")
        fault = jnp.float32(np.nan if kind == "nonfinite" else 0.0)
        fn = self._prefill_replay_jit if replay else self._prefill_jit
        args = (params, self.cache, jnp.asarray(req.slot, jnp.int32),
                jnp.asarray(tokens), jnp.asarray(clen, jnp.int32), ex)
        self.dispatches += 1
        try:
            logits, new_cache, ok = fn(*args, fault)
            ok = bool(ok)
        except KernelFault:
            # kernel compile/lowering failure at trace time: the failed
            # trace aborted before any output existed (and was not cached)
            ok = False
        if not ok:
            # degradation ladder: discard the faulted outputs (functional
            # jit — self.cache is untouched) and re-run the SAME operands
            # on the bit-exact jnp oracle program
            self.degraded_iterations += 1
            ofn = (self._prefill_replay_oracle_jit if replay
                   else self._prefill_oracle_jit)
            self.dispatches += 1
            logits, new_cache, ok = ofn(*args, jnp.float32(0.0))
            assert bool(ok), "oracle prefill produced non-finite logits"
        self.cache = new_cache
        req.filled += clen
        req.kv_len += clen
        # publish blocks the chunk just completed: a request admitted
        # while this one is still decoding can already share its prompt
        self._register_blocks(req)
        if req.filled == len(self._seq(req)):   # seq ingested: sample
            tok = int(self._sample(logits, key))
            req.out.append(tok)
            if req.first_token_iter < 0:
                req.first_token_iter = it
            if tok == self.cfg.eos_token or len(req.out) >= req.max_new_tokens:
                self._finish(req, it, t0)
            else:
                req.state, req.cur = DECODE, tok

    def _decode_all(self, params, decoding: Sequence[Request], it: int,
                    t0: float, key) -> None:
        toks = np.zeros((self.cfg.num_slots,), np.int32)
        act = np.zeros((self.cfg.num_slots,), bool)
        for r in decoding:
            toks[r.slot], act[r.slot] = r.cur, True
        self._sync_table()
        kind = self._fire("decode")
        if kind == "crash":
            raise EngineCrash(f"injected crash in decode (it={it})")
        fault = jnp.float32(np.nan if kind == "nonfinite" else 0.0)
        args = (params, self.cache, jnp.asarray(toks), jnp.asarray(act), key)
        self.dispatches += 1
        try:
            nxt, new_cache, ok = self._decode_jit(*args, fault)
            ok = bool(ok)
        except KernelFault:
            ok = False
        if not ok:
            # same degradation ladder as prefill (argmax over NaN logits
            # silently yields token 0, so tokens alone cannot reveal the
            # fault — the program's ``ok`` verdict gates instead)
            self.degraded_iterations += 1
            self.dispatches += 1
            nxt, new_cache, ok = self._decode_oracle_jit(
                *args, jnp.float32(0.0))
            assert bool(ok), "oracle decode produced non-finite logits"
        self.cache = new_cache
        nxt = np.asarray(nxt)
        for r in decoding:
            r.kv_len += 1
            tok = int(nxt[r.slot])
            r.out.append(tok)
            r.cur = tok
            if tok == self.cfg.eos_token or len(r.out) >= r.max_new_tokens:
                self._finish(r, it, t0)

    def _step_all(self, params, extras: Dict[int, Dict], it: int,
                  t0: float) -> bool:
        """One-dispatch iteration: the active request's prefill chunk and
        the slot-batched decode run in a SINGLE compiled step program
        (bucketed by (replay, has_prefill, has_decode) — static phase
        presence keeps idle halves out of the lowered program).  Returns
        whether any model work ran this iteration.

        Identical host bookkeeping to the legacy ``_prefill_one`` +
        ``_decode_all`` pair, with one scheduling difference: a request
        whose final chunk lands this iteration starts decoding NEXT
        iteration (the decode roster is frozen before dispatch), where
        the legacy path recomputed the roster after prefill.  Greedy
        token streams are identical; see ``ContinuousConfig.fused_step``
        for the temperature>0 caveat."""
        prefilling = [r for r in self.requests if r.state == PREFILL]
        decoding = [r for r in self.requests if r.state == DECODE]
        req = prefilling[0] if prefilling else None
        tokens = None
        clen, first, replay = 0, False, False
        if req is not None:
            tokens, clen, first, replay = self._next_chunk(req)
            if tokens is None:     # fully ingested, parked — nothing to run
                req = None
        has_p = req is not None
        has_d = bool(decoding)
        if not (has_p or has_d):
            return False
        self._sync_table()
        # both legacy fault sites still fire (chaos schedules target them
        # by name); either hit folds into the step's shared fault operand,
        # so a single fault degrades the WHOLE fused step to the oracle —
        # exactly the blast radius of one compiled program
        fault_val = 0.0
        if has_p:
            kind = self._fire("prefill")
            if kind == "crash":
                raise EngineCrash(f"injected crash in prefill (it={it})")
            if kind == "nonfinite":
                fault_val = float("nan")
        if has_d:
            kind = self._fire("decode")
            if kind == "crash":
                raise EngineCrash(f"injected crash in decode (it={it})")
            if kind == "nonfinite":
                fault_val = float("nan")
        fault = jnp.float32(fault_val)
        # key-split order matches the legacy path (prefill, then decode)
        pkey = dkey = jnp.zeros((2,), jnp.uint32)   # placeholder operands
        if has_p:
            self._key, pkey = jax.random.split(self._key)
        if has_d:
            self._key, dkey = jax.random.split(self._key)
        toks = np.zeros((self.cfg.num_slots,), np.int32)
        act = np.zeros((self.cfg.num_slots,), bool)
        for r in decoding:
            toks[r.slot], act[r.slot] = r.cur, True
        if has_p:
            ex = extras.get(req.rid, {}) if first else {}
            slot = jnp.asarray(req.slot, jnp.int32)
            ptoks = jnp.asarray(tokens)
            pclen = jnp.asarray(clen, jnp.int32)
        else:
            ex = {}
            slot = jnp.asarray(0, jnp.int32)
            ptoks = jnp.zeros((1, 1), jnp.int32)
            pclen = jnp.asarray(0, jnp.int32)
        bucket = (replay, has_p, has_d)
        args = (params, self.cache, slot, ptoks, pclen, ex,
                jnp.asarray(toks), jnp.asarray(act), pkey, dkey)
        self.dispatches += 1
        try:
            ptok, nxt, new_cache, ok = self._step_jits[bucket](*args, fault)
            ok = bool(ok)
        except KernelFault:
            ok = False     # trace aborted before any output was cached
        if not ok:
            # degradation ladder: one oracle re-run replaces the one
            # faulted dispatch — same operands, zero fault
            self.degraded_iterations += 1
            self.dispatches += 1
            ptok, nxt, new_cache, ok = self._step_oracle_jits[bucket](
                *args, jnp.float32(0.0))
            assert bool(ok), "oracle step produced non-finite logits"
        self.cache = new_cache
        if has_p:
            req.filled += clen
            req.kv_len += clen
            self._register_blocks(req)
            if req.filled == len(self._seq(req)):   # seq ingested: sample
                tok = int(ptok)
                req.out.append(tok)
                if req.first_token_iter < 0:
                    req.first_token_iter = it
                if (tok == self.cfg.eos_token
                        or len(req.out) >= req.max_new_tokens):
                    self._finish(req, it, t0)
                else:
                    req.state, req.cur = DECODE, tok
        if has_d:
            nxt = np.asarray(nxt)
            for r in decoding:
                r.kv_len += 1
                tok = int(nxt[r.slot])
                r.out.append(tok)
                r.cur = tok
                if (tok == self.cfg.eos_token
                        or len(r.out) >= r.max_new_tokens):
                    self._finish(r, it, t0)
        return True

    # ------------------------------------------------------------ main loop
    def run(self, params, extras: Optional[Dict[int, Dict]] = None) -> Dict:
        """Drive the scheduler until every submitted request completes.

        ``extras`` maps request id → modality arrays sent with the first
        prefill chunk (``frame_embeds`` for encdec, ``pixel_embeds`` for
        VLM stubs).  Returns per-request outputs and aggregate metrics.
        """
        extras = extras or {}
        if self.cache is None:
            if self.paged:
                self.cache = init_paged_cache(
                    self.model, self.cfg.num_slots, self.cfg.max_seq,
                    self.cfg.block_size, self.pool.num_blocks, self._spec)
            else:
                self.cache = slot_ops.init_slot_cache(
                    self.model, self.cfg.num_slots, self.cfg.max_seq)
        self._extra_rids |= set(extras)
        if self._key is None:   # survives across run() calls and restore()
            self._key = jax.random.PRNGKey(self.cfg.seed)
        t0 = time.perf_counter()
        it0 = self._it
        preempt0, reject0 = self.preemptions, self.rejections
        hits0, reused0 = self.prefix_hits, self.blocks_reused
        skipped0, demand0 = self.tokens_skipped, self.prefill_demand
        degraded0, retries0 = self.degraded_iterations, self.admission_retries
        wdog0, timeout0 = self.watchdog_trips, self.timeouts
        cancel0 = self.cancellations
        disp0, work0 = self.dispatches, self.work_iterations
        if self.paged:
            self.pool.peak_in_use = self.pool.in_use   # per-run peak
            evict0 = self.pool.evictions
        # the kernel-dispatch fault sites (core/pruner, models/attention)
        # cannot see this engine — activate the injector globally for the
        # duration of the loop (EngineCrash still deactivates cleanly)
        fault_mod.activate(self.faults)
        try:
            while any(r.state not in _TERMINAL for r in self.requests):
                it = self._it
                assert it - it0 < self.cfg.max_iters, "scheduler stuck"
                if self.faults is not None:
                    self.faults.tick(it)
                if self.iteration_hook is not None:
                    self.iteration_hook(self, it)
                if (self.cfg.snapshot_every
                        and it % self.cfg.snapshot_every == 0):
                    # iteration boundary = consistent state: a crash later
                    # this iteration rewinds here via restore()
                    self.last_snapshot = self.snapshot()
                now = time.perf_counter()
                for r in self.requests:  # anchor wall-clock latency at arrival
                    # stamped unconditionally on visibility, NOT gated on
                    # WAITING: a request admitted the same iteration it
                    # became visible would otherwise keep the -1.0 default
                    # and report garbage latency
                    if r.arrival <= it and r.arrival_time < 0:
                        r.arrival_time = now
                reaped = self._reap(it)
                admitted = self._admit(it)
                if self.fused_step:
                    # block grab moves BEFORE the dispatch: the fused
                    # program reads the final roster/table, and a dry-pool
                    # preemption can still unwind the prefilling request
                    # ahead of its chunk
                    if self.paged:
                        self._ensure_decode_blocks()
                    worked = self._step_all(params, extras, it, t0)
                else:
                    prefilling = [r for r in self.requests
                                  if r.state == PREFILL]
                    if prefilling:
                        self._key, sub = jax.random.split(self._key)
                        req = prefilling[0]
                        self._prefill_one(params, req,
                                          extras.get(req.rid, {}),
                                          it, t0, sub)
                    if self.paged:
                        self._ensure_decode_blocks()
                    decoding = [r for r in self.requests
                                if r.state == DECODE]
                    if decoding:
                        self._key, sub = jax.random.split(self._key)
                        self._decode_all(params, decoding, it, t0, sub)
                    worked = bool(prefilling or decoding)
                if worked:
                    self.work_iterations += 1
                if self.paged and self._validate:
                    self._audit_pool()
                # no-progress watchdog: clean scheduling always advances
                # (prefill/decode run every iteration something is active),
                # so a stall with admission-eligible waiters only arises
                # under persistent faults — force-reject the oldest stuck
                # request instead of livelocking until max_iters
                progressed = bool(reaped or admitted or worked)
                pending = [r for r in self.requests
                           if r.state == WAITING and r.arrival <= it]
                if progressed or not pending:
                    self._last_progress = it
                elif it - self._last_progress >= self.cfg.watchdog_iters:
                    stuck = min(pending, key=lambda r: (r.arrival, r.rid))
                    self._evict_request(stuck, REJECTED, it)
                    self.rejections += 1
                    self.watchdog_trips += 1
                    self._last_progress = it
                self._it += 1
        finally:
            fault_mod.deactivate()
        it = self._it - it0
        wall = time.perf_counter() - t0
        gen = sum(len(r.out) for r in self.requests)
        self.metrics = {
            "iterations": it,
            "wall_s": wall,
            "generated_tokens": gen,
            "tokens_per_s": gen / max(wall, 1e-9),
            "trace_counts": dict(self.trace_counts),
            # compiled-program launches per iteration that ran model work
            # (oracle re-runs included) — 1.0 on a clean fused run, ~2 on
            # the legacy two-program split when prefill+decode overlap
            "dispatches": self.dispatches - disp0,
            "dispatches_per_iteration": (
                (self.dispatches - disp0)
                / max(self.work_iterations - work0, 1)),
            "degraded_iterations": self.degraded_iterations - degraded0,
            "lifecycle": {
                "terminal_states": {
                    s: sum(1 for r in self.requests if r.state == s)
                    for s in _TERMINAL},
                "admission_retries": self.admission_retries - retries0,
                "watchdog_trips": self.watchdog_trips - wdog0,
                "timeouts": self.timeouts - timeout0,
                "cancellations": self.cancellations - cancel0,
                "restores": self.restores,
                "faults_fired": (self.faults.total_fired
                                 if self.faults is not None else 0),
            },
            "paged": ({
                "enabled": True,
                "block_size": self.pool.block_size,
                "num_blocks": self.pool.num_blocks,
                "peak_blocks_in_use": self.pool.peak_in_use,
                "preemptions": self.preemptions - preempt0,
                "rejections": self.rejections - reject0,
                "attention_kernel": self.paged_kernel,
                "prefix_cache": self.prefix_cache,
                "prefix_hits": self.prefix_hits - hits0,
                "blocks_reused": self.blocks_reused - reused0,
                "tokens_skipped": self.tokens_skipped - skipped0,
                "prefill_tokens": self.prefill_demand - demand0,
                "cached_blocks": self.pool.cached_blocks,
                "evictions": self.pool.evictions - evict0,
            } if self.paged else {"enabled": False}),
            "requests": [{
                "rid": r.rid,
                "prompt_len": int(len(r.tokens)),
                "arrival": r.arrival,
                "state": r.state,
                "admitted_iter": r.admitted_iter,
                "first_token_iter": r.first_token_iter,
                "done_iter": r.done_iter,
                "latency_iters": r.done_iter - r.arrival,
                "latency_s": r.done_time,
                "n_out": len(r.out),
                "preemptions": r.preempted,
                "cached_tokens": r.cached_tokens,
                "retries": r.retries,
                "deadline": r.deadline,
            } for r in self.requests],
        }
        return {
            "outputs": {r.rid: list(r.out) for r in self.requests},
            "metrics": self.metrics,
        }

    # ------------------------------------------------------ crash recovery
    def snapshot(self) -> Dict[str, Any]:
        """Copy of all host-side engine state at an iteration boundary:
        request lifecycles (including emitted tokens and memoized hash
        chains), slot assignment, the block pool (tables, refcounts,
        prefix index, LRU order), the iteration clock, and the sampling
        PRNG.  Process-local — chain hashes use Python's per-process
        salted ``hash()``, so a snapshot only restores into the same
        process (matching its purpose: surviving an ENGINE crash, not a
        process crash)."""
        return {
            "it": self._it,
            "key": None if self._key is None else np.asarray(self._key),
            "requests": copy.deepcopy(self.requests),
            "slot_rids": [None if r is None else r.rid
                          for r in self._slot_req],
            "free_slots": list(self._free_slots),
            "extra_rids": set(self._extra_rids),
            "pool": self.pool.snapshot() if self.paged else None,
            "host_table": (self._host_table.copy() if self.paged else None),
            "counters": {
                "preemptions": self.preemptions,
                "rejections": self.rejections,
                "degraded_iterations": self.degraded_iterations,
                "admission_retries": self.admission_retries,
                "watchdog_trips": self.watchdog_trips,
                "timeouts": self.timeouts,
                "cancellations": self.cancellations,
                "restores": self.restores,
                "prefix_hits": self.prefix_hits,
                "blocks_reused": self.blocks_reused,
                "tokens_skipped": self.tokens_skipped,
                "prefill_demand": self.prefill_demand,
            },
        }

    def restore(self, snap: Dict[str, Any]) -> None:
        """Rebuild host-side state from a :meth:`snapshot` taken in this
        process (possibly by a different, now-dead engine instance over
        the same model/config).  Device KV is treated as LOST — the crash
        that motivated the restore invalidates it — so in-flight requests
        are demoted to ``WAITING`` with a fresh block pool and empty
        prefix index, and replay through prefill on re-admission: the
        same recompute path preemption uses, so resumed greedy outputs
        are token-identical to an undisturbed run."""
        cfg = self.cfg
        self._it = snap["it"]
        self._key = (None if snap["key"] is None
                     else jnp.asarray(snap["key"]))
        self._last_progress = self._it     # fresh watchdog grace period
        self.requests = copy.deepcopy(snap["requests"])
        self._extra_rids = set(snap["extra_rids"])
        self._free_slots = list(range(cfg.num_slots))
        self._slot_req = [None] * cfg.num_slots
        self.cache = None                  # rebuilt lazily by run()
        for r in self.requests:
            if r.state in (PREFILL, DECODE):
                r.state = WAITING
                r.slot = -1
                r.blocks = []
                r.shared = r.registered = 0
                r.filled = 0
                r.kv_len = 0
        if self.paged:
            self.pool = BlockPool(snap["pool"]["num_blocks"],
                                  cfg.block_size,
                                  prefix_cache=self.prefix_cache)
            self._host_table = np.full((cfg.num_slots, self._max_blocks),
                                       -1, np.int32)
            self._table_dirty = True
        for name, val in snap["counters"].items():
            setattr(self, name, val)
        self.restores += 1

"""Continuous-batching serving engine: chunked Amber-sparse prefill
interleaved with slot-batched dense decode over a **paged** KV cache.

Requests arrive asynchronously (:meth:`ContinuousServingEngine.submit`) and
are scheduled over a fixed pool of decode **slots** whose KV rows live in a
global **block pool** (:mod:`repro.serve.paged`).  Each scheduler
iteration:

  1. **admit** — waiting requests whose arrival time has passed claim free
     slots FCFS, gated by a block-budget check (the pool must cover the
     prompt); the slot's recurrent state is zeroed and its block table row
     populated.  With prefix caching on, the longest indexed block-prefix
     of the prompt (shared system prompt, few-shot template, or this
     request's own preemption replay) is acquired instead of recomputed:
     the shared block ids go straight into the table, the slot's ``pos``
     starts at the first non-cached token, and prefill begins mid-sequence;
  2. **prefill** — the oldest admitted-but-unprefilled request advances by
     one fixed-size token chunk through the Amber-sparse projection path
     (``model.prefill_chunk``), scattering KV through its block table;
  3. **ensure/preempt** — decoding slots crossing a block boundary grab a
     fresh block; when the pool is dry the **youngest** active request is
     preempted (blocks released, request requeued; its emitted tokens are
     replayed through prefill on re-admission, so greedy output is
     unchanged);
  4. **decode** — all slots holding decoding requests take one dense decode
     step as a single padded batch (inactive slots are masked out of the
     cache update).

Shape buckets: prefill compiles once per chunk shape (a single
``chunk_size`` bucket for attention archs; a dyadic ladder of at most
log2(chunk_size)+1 sizes for archs with recurrent blocks, whose scans
cannot mask padded tokens), and decode compiles once for the padded
``num_slots`` batch — arbitrary traffic never retraces, and block
allocation/preemption only rewrites the small int32 block-table array, so
paging does not add shape buckets.  The ``trace_counts`` attribute counts
actual retraces per phase and is asserted in the test suite.

Equivalence: with greedy decoding and **per-token** sparsity modes the
per-request output stream is token-identical to the legacy one-shot
:class:`~repro.serve.engine.ServingEngine` — a token's N:M mask doesn't
depend on which chunk carries it, chunked prefill attends over the cached
prefix so logits match, decode rows are independent of batch composition,
and preemption replays the exact emitted prefix.  ``tile_consensus``
policies remain valid N:M serving but are NOT bit-identical to one-shot
prefill: their masks are pooled over token tiles, and chunking changes
tile membership (see serve/README.md).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import DENSE, SparsityPolicy
from repro.serve import slots as slot_ops
from repro.serve.paged import (BlockPool, chain_block_hashes,
                               init_paged_cache, max_blocks_per_slot)

__all__ = ["ContinuousConfig", "Request", "ContinuousServingEngine"]

WAITING, PREFILL, DECODE, DONE = "waiting", "prefill", "decode", "done"
# terminal without ever running: admission proved the request can NEVER
# fit the block pool (its replay sequence outgrew capacity) — rejecting it
# keeps strict-FCFS admission from waiting on it forever and starving the
# queue behind it (head-of-line livelock, ISSUE-5 bugfix)
REJECTED = "rejected"
_TERMINAL = (DONE, REJECTED)


@dataclasses.dataclass(frozen=True)
class ContinuousConfig:
    max_seq: int = 512        # per-slot KV capacity (prompt + new tokens)
    num_slots: int = 4        # decode batch width (the padded batch bucket)
    chunk_size: int = 64      # prefill chunk bucket (tokens per chunk)
    temperature: float = 0.0  # 0 → greedy
    eos_token: int = -1       # -1 → never stop early
    seed: int = 0
    max_iters: int = 100_000  # scheduler-loop safety valve
    # --- paged KV allocation (serve/paged.py) ---
    paged: bool = True        # auto-disabled where no full-attn KV exists
    block_size: int = 16      # KV rows per block
    num_blocks: Optional[int] = None
    # None → num_slots * ceil(max_seq / block_size): same capacity as the
    # dense slab, paged mechanics.  The memory win is sizing it LOWER and
    # letting admission gating + preemption absorb the pressure.
    prefix_cache: bool = True
    # block-level prefix caching across requests: full blocks are chain-
    # hashed and refcounted so a request whose prompt repeats a cached
    # prefix (shared system prompt, preemption replay) skips its prefill.
    # Auto-disabled alongside paging, and for archs with recurrent blocks
    # (their scan state cannot be restored from cached KV).
    validate_pool: bool = False
    # audit block-pool/refcount/ownership invariants after every scheduler
    # iteration (O(num_blocks) host work) — test/debug instrumentation.


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                 # (T,) prompt token ids
    max_new_tokens: int
    arrival: int = 0                   # scheduler iteration of arrival
    # --- runtime (engine-owned) ---
    state: str = WAITING
    slot: int = -1
    filled: int = 0                    # seq tokens prefilled so far
    cur: int = 0                       # last generated token (decode input)
    out: List[int] = dataclasses.field(default_factory=list)
    blocks: List[int] = dataclasses.field(default_factory=list)
    kv_len: int = 0                    # KV rows held (host mirror of pos)
    shared: int = 0                    # leading blocks reused from the index
    registered: int = 0                # leading blocks published to the index
    cached_tokens: int = 0             # prefill rows skipped via prefix hits
    # memoized chain hashes of this request's full blocks; token content
    # never changes for an already-hashed block (out only appends), so the
    # chain survives preemption and extends in O(new blocks)
    hash_chain: List[int] = dataclasses.field(default_factory=list)
    preempted: int = 0                 # times requeued by the block pool
    admitted_iter: int = -1
    first_token_iter: int = -1
    done_iter: int = -1
    arrival_time: float = -1.0         # wall clock when arrival was reached
    done_time: float = 0.0             # wall-clock latency from arrival


def _dyadic_sizes(length: int, cap: int) -> List[int]:
    """Descending powers of two ≤ cap summing to length (exact chunks)."""
    sizes = []
    c = 1
    while c * 2 <= cap:
        c *= 2
    rem = length
    while rem:
        while c > rem:
            c //= 2
        sizes.append(c)
        rem -= c
    return sizes


class ContinuousServingEngine:
    """Scheduler + paged slot cache + shape-bucketed jitted phases."""

    def __init__(self, model, policy: SparsityPolicy = DENSE,
                 cfg: ContinuousConfig = ContinuousConfig()):
        self.model = model
        self.policy = policy
        self.cfg = cfg
        mcfg = model.cfg
        if getattr(mcfg, "vision_stub", False):
            assert cfg.chunk_size >= mcfg.n_patches, (
                "chunk_size must cover the VLM patch stub "
                f"({cfg.chunk_size} < {mcfg.n_patches})")
        # recurrent scans cannot mask padded tokens out of their state, so
        # hybrid/SSM archs get exact dyadic chunks instead of a padded tail
        if mcfg.is_encdec:
            self._exact_chunks = False
        else:
            from repro.models.transformer import layer_kinds
            self._exact_chunks = any(k != "attn" for k in layer_kinds(mcfg))
        if mcfg.attn_type in ("swa", "local"):
            assert cfg.chunk_size <= min(mcfg.window, cfg.max_seq), (
                "chunk_size must fit the sliding-window ring buffer")

        # paged KV: only archs with full-attention KV leaves benefit;
        # encdec (request-shaped caches), SWA rings, and pure-recurrent
        # archs fall back to the dense per-slot slab automatically
        spec = model.paged_kv_spec() if cfg.paged else None
        if spec is not None and not any(jax.tree_util.tree_leaves(spec)):
            spec = None
        self._spec = spec
        self.paged = spec is not None
        # the projections' policy flag also routes paged attention through
        # the in-kernel block-table walk (models/attention.paged_attention
        # ladder); decode runs DENSE projections but must carry the flag so
        # its attention takes the same path as prefill's
        self.paged_kernel = self.paged and bool(policy.use_pallas_kernels)
        if self.paged_kernel and not self._exact_chunks:
            # a padded prefill bucket the kernel cannot tile would silently
            # fall back to the gather oracle while metrics/--trace claimed
            # the kernel ran — reject it here instead (exact-chunk archs
            # emit power-of-two chunks, always covered; decode is T = 1)
            from repro.kernels.paged_attention import paged_kernel_covers
            assert paged_kernel_covers(cfg.chunk_size), (
                "paged-attention kernel cannot tile chunk_size="
                f"{cfg.chunk_size} (see kernels.paged_attention"
                ".paged_kernel_covers); use a power-of-two chunk_size or "
                "drop use_pallas_kernels")
        self.preemptions = 0
        self.rejections = 0
        self.preempt_log: List[tuple] = []      # (rid, state-when-preempted)
        # prefix caching needs every piece of continuation state to live in
        # the paged KV pool: archs with recurrent blocks carry scan state
        # that cached blocks cannot restore, so they stay cache-off even
        # though their attention leaves are paged
        self.prefix_cache = (self.paged and cfg.prefix_cache
                             and not self._exact_chunks)
        self.prefix_hits = 0        # admissions that reused ≥ 1 block
        self.blocks_reused = 0      # total shared-block acquisitions
        self.tokens_skipped = 0     # prefill rows served from the index
        self.prefill_demand = 0     # prefill rows requested at admission
        self._extra_rids: set = set()   # requests with modality extras:
        # their hidden states depend on non-token inputs, so token-id chain
        # hashes cannot address their KV — excluded from the prefix index
        if self.paged:
            self._max_blocks = max_blocks_per_slot(cfg.max_seq,
                                                   cfg.block_size)
            nb = (cfg.num_blocks if cfg.num_blocks is not None
                  else cfg.num_slots * self._max_blocks)
            self.pool: Optional[BlockPool] = BlockPool(
                nb, cfg.block_size, prefix_cache=self.prefix_cache)
            self._host_table = np.full((cfg.num_slots, self._max_blocks),
                                       -1, np.int32)
            self._table_dirty = True
        else:
            self.pool = None

        self.requests: List[Request] = []
        self._free_slots = list(range(cfg.num_slots))
        self._slot_req: List[Optional[Request]] = [None] * cfg.num_slots
        self.cache = None                      # built lazily per params
        self.trace_counts: Dict[str, int] = {"prefill": 0, "decode": 0}
        self.metrics: Dict[str, Any] = {}

        def make_prefill_fn(policy, count_key):
            def prefill_fn(params, cache, slot, tokens, chunk_len, extras):
                # runs at trace time only
                self.trace_counts[count_key] = \
                    self.trace_counts.get(count_key, 0) + 1
                sub = slot_ops.slice_slot(cache, slot, self._spec)
                batch = {"tokens": tokens, "chunk_len": chunk_len, **extras}
                logits, sub = self.model.prefill_chunk(params, batch, sub,
                                                       policy=policy)
                return logits[0], slot_ops.write_slot(cache, slot, sub,
                                                      self._spec)
            return prefill_fn

        dense = DENSE.with_(use_pallas_kernels=policy.use_pallas_kernels)

        def decode_fn(params, cache, tokens, active, key):
            self.trace_counts["decode"] += 1
            logits, new_cache = self.model.decode_step(
                params, tokens[:, None], cache, policy=dense)
            new_cache = slot_ops.where_active(active, new_cache, cache,
                                              self._spec)
            nxt = self._sample(logits, key)
            return jnp.where(active, nxt, tokens), new_cache

        self._prefill_jit = jax.jit(make_prefill_fn(policy, "prefill"))
        # preemption replay re-ingests tokens the request already EMITTED;
        # their KV was originally written by the dense decode step, so the
        # replay must also run dense or sparse-prefill outputs would drift
        # from the one-shot oracle.  Chunks never span the prompt/emitted
        # boundary (see _next_chunk); this program only ever traces (and
        # the "prefill_replay" key only appears) if a preemption happens
        # under a non-dense policy.
        self._prefill_replay_jit = jax.jit(
            make_prefill_fn(dense, "prefill_replay"))
        self._decode_jit = jax.jit(decode_fn)

    # ------------------------------------------------------------- sampling
    def _sample(self, logits, key):
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------ admission
    def submit(self, tokens, max_new_tokens: int = 32, arrival: int = 0) -> int:
        """Queue a request; returns its request id.

        ``arrival`` is the scheduler iteration at which the request becomes
        visible (simulated asynchronous traffic)."""
        tokens = np.asarray(tokens).reshape(-1).astype(np.int32)
        assert tokens.size > 0, "empty prompt"
        assert tokens.size + max_new_tokens <= self.cfg.max_seq, \
            "request exceeds slot capacity (max_seq)"
        if self.paged:
            assert (self.pool.blocks_for(tokens.size + max_new_tokens)
                    <= self.pool.num_blocks), \
                "request exceeds block pool capacity"
        rid = len(self.requests)
        self.requests.append(Request(rid=rid, tokens=tokens,
                                     max_new_tokens=max_new_tokens,
                                     arrival=arrival))
        return rid

    def _seq(self, req: Request) -> np.ndarray:
        """Tokens to prefill: the prompt, plus — after a preemption — the
        tokens already emitted, replayed so decode resumes exactly where it
        left off (greedy outputs are chunking-invariant, so the replayed
        prefix regenerates the identical KV state)."""
        if req.out:
            return np.concatenate([req.tokens,
                                   np.asarray(req.out, np.int32)])
        return req.tokens

    def _chain_for(self, req: Request, tokens: np.ndarray,
                   n_full: int) -> List[int]:
        """First ``n_full`` chain hashes of the request's sequence,
        extending the memoized chain only over blocks not yet hashed."""
        chain = req.hash_chain
        if n_full > len(chain):
            dense_from = len(req.tokens) if self.policy.enabled else None
            chain.extend(chain_block_hashes(
                tokens, self.pool.block_size, n_full, dense_from,
                start=len(chain), h0=chain[-1] if chain else None))
        return chain[:n_full]

    def _match_prefix(self, req: Request, seq: np.ndarray) -> List[int]:
        """Longest indexed block-prefix of the request's prefill sequence.
        Capped at ``len(seq) - 1`` tokens: at least one token must run
        through prefill to produce the logits the next token samples from,
        so the request's last block is always a fresh allocation (and a
        partially-covered tail block has no full-block hash anyway) —
        shared blocks are therefore never writable."""
        if not self.prefix_cache or req.rid in self._extra_rids:
            return []
        n_full = (len(seq) - 1) // self.pool.block_size
        if n_full == 0:
            return []
        return self.pool.match(self._chain_for(req, seq, n_full))

    def _admit(self, it: int) -> None:
        # FCFS by arrival, not submission order: requests may be submitted
        # with out-of-order arrival times (and preempted requests requeue
        # with their original arrival)
        for req in sorted(self.requests, key=lambda r: (r.arrival, r.rid)):
            if req.state != WAITING or req.arrival > it:
                continue
            if self.paged:
                seq = self._seq(req)
                need = self.pool.blocks_for(len(seq))
                if need > min(self.pool.num_blocks, self._max_blocks):
                    # can NEVER fit: strict FCFS would wait on it forever
                    # and starve every request behind it (head-of-line
                    # livelock) — reject with a terminal state instead.
                    # ``submit`` already bounds prompt+max_new, and a
                    # replay sequence (prompt + emitted) stays under that
                    # bound, so through the public API this is a
                    # defense-in-depth backstop: it converts any capacity
                    # drift (out-of-band enqueues, future scheduler
                    # changes shrinking the pool) into a visible REJECTED
                    # request instead of a silent queue stall
                    req.state = REJECTED
                    req.done_iter = it
                    self.rejections += 1
                    continue
            if not self._free_slots:
                break
            skip = 0
            if self.paged:
                shared = self._match_prefix(req, seq)
                # full feasibility BEFORE taking anything: reviving a
                # zero-ref cached hit consumes availability (sharing a
                # live block does not), and the fresh remainder must fit
                # what is left — so a refused admission never touches the
                # pool (no rollback, no phantom peak_in_use spike)
                revive = sum(map(self.pool.is_cached, shared))
                if need - len(shared) > self.pool.available - revive:
                    # strict FCFS: the oldest waiting request admits first;
                    # skipping ahead would starve long prompts under
                    # sustained short-prompt traffic
                    break
                for b in shared:
                    self.pool.acquire_cached(b)
                req.blocks = shared + self.pool.alloc(need - len(shared))
                req.shared = req.registered = len(shared)
                skip = len(shared) * self.pool.block_size
                req.cached_tokens += skip
                self.prefill_demand += len(seq)
                self.tokens_skipped += skip
                self.blocks_reused += len(shared)
                if shared:
                    self.prefix_hits += 1
            slot = self._free_slots.pop(0)
            # prefix-cached rows are already valid KV: start the slot's pos
            # at the first non-cached token so the first prefill chunk runs
            # mid-sequence (prefill_chunk scatters/attends at cache offsets
            # either way); reset never touches pooled leaves, so the shared
            # blocks other slots may be reading survive the slot handoff
            self.cache = slot_ops.reset_slot(self.cache, slot, self._spec,
                                             pos=skip)
            if self.paged:
                self._host_table[slot, :] = -1
                self._host_table[slot, :len(req.blocks)] = req.blocks
                self._table_dirty = True
            req.slot, req.state = slot, PREFILL
            req.filled = req.kv_len = skip
            req.admitted_iter = it
            self._slot_req[slot] = req

    def _register_blocks(self, req: Request) -> None:
        """Publish the request's full blocks in the prefix index.  KV rows
        0..kv_len-1 hold the tokens ``(prompt ++ out)[:kv_len]`` (a freshly
        sampled token's own KV is only written when it is next fed back
        in), so full blocks are content-addressable by that token chain.
        Called whenever row content is final AND worth publishing: after
        each prefill chunk, and — to pick up decode-written rows — right
        before the blocks are released at preemption or completion."""
        if not self.prefix_cache or req.rid in self._extra_rids:
            return
        bs = self.pool.block_size
        n_full = min(req.kv_len // bs, len(req.blocks))
        if n_full <= req.registered:
            return
        hashes = self._chain_for(req, self._seq(req)[:req.kv_len], n_full)
        for i in range(req.registered, n_full):
            self.pool.register(req.blocks[i], hashes[i])
        req.registered = n_full

    def _preempt(self, req: Request) -> None:
        """Requeue ``req`` (recompute-on-readmission): its blocks return to
        the pool, its slot frees, and its emitted tokens stay on the
        request to be replayed through prefill when it is re-admitted.
        Full blocks are registered first, so as long as they survive in
        the zero-ref LRU the replay is nearly free: the replayed
        prompt+emitted prefix re-matches exactly what was just released."""
        self.preemptions += 1
        req.preempted += 1
        self.preempt_log.append((req.rid, req.state))
        self._register_blocks(req)
        # deepest blocks first: chain hashes only match a CONTIGUOUS prefix
        # from block 0, so eviction must consume chains tail-first — the
        # reversed release order parks the chain head at the MRU end
        self.pool.release(req.blocks[::-1])
        req.blocks = []
        req.shared = req.registered = 0
        self._host_table[req.slot, :] = -1
        self._table_dirty = True
        self._free_slots.append(req.slot)
        self._slot_req[req.slot] = None
        req.slot = -1
        req.state = WAITING
        req.filled = 0
        req.kv_len = 0

    def _ensure_decode_blocks(self) -> None:
        """Grab a fresh block for every decoding slot crossing a block
        boundary; when the pool is dry, preempt the youngest active
        request until the oldest decoders can proceed (or the needy
        request is itself the youngest and yields)."""
        order = sorted((r for r in self.requests if r.state == DECODE),
                       key=lambda r: (r.admitted_iter, r.rid))
        for r in order:
            while r.state == DECODE:
                need = self.pool.blocks_for(r.kv_len + 1)
                if len(r.blocks) >= need:
                    break
                if self.pool.available:
                    blk = self.pool.alloc(1)
                    self._host_table[r.slot, len(r.blocks)] = blk[0]
                    r.blocks.extend(blk)
                    self._table_dirty = True
                else:
                    victim = max((v for v in self.requests
                                  if v.state in (PREFILL, DECODE)),
                                 key=lambda v: (v.admitted_iter, v.rid))
                    self._preempt(victim)

    def _finish(self, req: Request, it: int, t0: float) -> None:
        req.state = DONE
        req.done_iter = it
        anchor = req.arrival_time if req.arrival_time >= 0 else t0
        req.done_time = time.perf_counter() - anchor
        if self.paged and req.blocks:
            self._register_blocks(req)
            self.pool.release(req.blocks[::-1])   # chain head → MRU end
            req.blocks = []
            req.shared = req.registered = 0
            self._host_table[req.slot, :] = -1
            self._table_dirty = True
        self._free_slots.append(req.slot)
        self._slot_req[req.slot] = None

    def clear(self) -> None:
        """Drop completed requests (e.g. after a warmup pass) so a fresh
        stream can be submitted and measured on the already-compiled
        engine.  The prefix index deliberately survives: a warm cache
        across streams is the production behavior being measured."""
        assert all(r.state in _TERMINAL for r in self.requests), \
            "cannot clear with requests in flight"
        self.requests = []
        # rids restart at 0 for the next stream: stale modality-extras
        # exclusions must not leak onto unrelated rid-colliding requests
        self._extra_rids = set()

    # ---------------------------------------------------------- auditing
    def _audit_pool(self) -> None:
        """Refcount/ownership invariants (cfg.validate_pool): the pool's
        internal partition holds, every live reference is accounted to
        exactly one slot-holding request, and no block is simultaneously
        writable from two slots.  A request's writable frontier is block
        ``kv_len // block_size`` onward (rows below kv_len are final);
        everything it can still write must be exclusively owned and
        unpublished — shared/registered blocks are full and immutable."""
        pool = self.pool
        pool.check_invariants()
        expect: Dict[int, int] = {}
        writable: Dict[int, int] = {}
        for r in self.requests:
            if r.state not in (PREFILL, DECODE):
                assert not r.blocks, \
                    f"r{r.rid} ({r.state}) still holds blocks {r.blocks}"
                continue
            for b in r.blocks:
                expect[b] = expect.get(b, 0) + 1
            for b in r.blocks[r.kv_len // pool.block_size:]:
                assert b not in writable, \
                    f"block {b} writable from r{writable[b]} AND r{r.rid}"
                writable[b] = r.rid
                assert pool.refcount(b) == 1, \
                    f"writable block {b} of r{r.rid} is shared"
                assert not pool.is_registered(b), \
                    f"writable block {b} of r{r.rid} is published"
        assert expect == dict(pool._ref), \
            f"refcount skew: requests hold {expect}, pool says {pool._ref}"

    # ------------------------------------------------------------ phases
    def _sync_table(self) -> None:
        if self.paged and self._table_dirty:
            self.cache["block_table"] = jnp.asarray(self._host_table)
            self._table_dirty = False

    def _next_chunk(self, req: Request):
        """(tokens (1, C), chunk_len, send_extras, is_replay) for the next
        chunk.  Chunks never span the prompt/emitted boundary, so a replay
        chunk (re-ingesting emitted tokens after a preemption) is entirely
        replay and runs through the dense program."""
        c = self.cfg.chunk_size
        seq = self._seq(req)
        rem = len(seq) - req.filled
        if req.filled < len(req.tokens):
            rem = min(rem, len(req.tokens) - req.filled)
            replay = False
        else:
            replay = self.policy.enabled
        if self._exact_chunks:
            size = _dyadic_sizes(rem, c)[0]
            chunk = seq[req.filled:req.filled + size]
            return chunk[None, :], size, req.filled == 0, replay
        v = min(c, rem)
        chunk = np.zeros((c,), np.int32)
        chunk[:v] = seq[req.filled:req.filled + v]
        return chunk[None, :], v, req.filled == 0, replay

    def _prefill_one(self, params, req: Request, extras: Dict, it: int,
                     t0: float, key) -> None:
        tokens, clen, first, replay = self._next_chunk(req)
        ex = extras if first else {}
        self._sync_table()
        fn = self._prefill_replay_jit if replay else self._prefill_jit
        logits, self.cache = fn(
            params, self.cache, jnp.asarray(req.slot, jnp.int32),
            jnp.asarray(tokens), jnp.asarray(clen, jnp.int32), ex)
        req.filled += clen
        req.kv_len += clen
        # publish blocks the chunk just completed: a request admitted
        # while this one is still decoding can already share its prompt
        self._register_blocks(req)
        if req.filled == len(self._seq(req)):   # seq ingested: sample
            tok = int(self._sample(logits, key))
            req.out.append(tok)
            if req.first_token_iter < 0:
                req.first_token_iter = it
            if tok == self.cfg.eos_token or len(req.out) >= req.max_new_tokens:
                self._finish(req, it, t0)
            else:
                req.state, req.cur = DECODE, tok

    def _decode_all(self, params, decoding: Sequence[Request], it: int,
                    t0: float, key) -> None:
        toks = np.zeros((self.cfg.num_slots,), np.int32)
        act = np.zeros((self.cfg.num_slots,), bool)
        for r in decoding:
            toks[r.slot], act[r.slot] = r.cur, True
        self._sync_table()
        nxt, self.cache = self._decode_jit(
            params, self.cache, jnp.asarray(toks), jnp.asarray(act), key)
        nxt = np.asarray(nxt)
        for r in decoding:
            r.kv_len += 1
            tok = int(nxt[r.slot])
            r.out.append(tok)
            r.cur = tok
            if tok == self.cfg.eos_token or len(r.out) >= r.max_new_tokens:
                self._finish(r, it, t0)

    # ------------------------------------------------------------ main loop
    def run(self, params, extras: Optional[Dict[int, Dict]] = None) -> Dict:
        """Drive the scheduler until every submitted request completes.

        ``extras`` maps request id → modality arrays sent with the first
        prefill chunk (``frame_embeds`` for encdec, ``pixel_embeds`` for
        VLM stubs).  Returns per-request outputs and aggregate metrics.
        """
        extras = extras or {}
        if self.cache is None:
            if self.paged:
                self.cache = init_paged_cache(
                    self.model, self.cfg.num_slots, self.cfg.max_seq,
                    self.cfg.block_size, self.pool.num_blocks, self._spec)
            else:
                self.cache = slot_ops.init_slot_cache(
                    self.model, self.cfg.num_slots, self.cfg.max_seq)
        self._extra_rids |= set(extras)
        key = jax.random.PRNGKey(self.cfg.seed)
        t0 = time.perf_counter()
        preempt0, reject0 = self.preemptions, self.rejections
        hits0, reused0 = self.prefix_hits, self.blocks_reused
        skipped0, demand0 = self.tokens_skipped, self.prefill_demand
        if self.paged:
            self.pool.peak_in_use = self.pool.in_use   # per-run peak
            evict0 = self.pool.evictions
        it = 0
        while any(r.state not in _TERMINAL for r in self.requests):
            assert it < self.cfg.max_iters, "scheduler stuck"
            now = time.perf_counter()
            for r in self.requests:      # anchor wall-clock latency at arrival
                if r.state == WAITING and r.arrival <= it and r.arrival_time < 0:
                    r.arrival_time = now
            self._admit(it)
            prefilling = [r for r in self.requests if r.state == PREFILL]
            if prefilling:
                key, sub = jax.random.split(key)
                req = prefilling[0]
                self._prefill_one(params, req, extras.get(req.rid, {}),
                                  it, t0, sub)
            if self.paged:
                self._ensure_decode_blocks()
            decoding = [r for r in self.requests if r.state == DECODE]
            if decoding:
                key, sub = jax.random.split(key)
                self._decode_all(params, decoding, it, t0, sub)
            if self.paged and self.cfg.validate_pool:
                self._audit_pool()
            it += 1
        wall = time.perf_counter() - t0
        gen = sum(len(r.out) for r in self.requests)
        self.metrics = {
            "iterations": it,
            "wall_s": wall,
            "generated_tokens": gen,
            "tokens_per_s": gen / max(wall, 1e-9),
            "trace_counts": dict(self.trace_counts),
            "paged": ({
                "enabled": True,
                "block_size": self.pool.block_size,
                "num_blocks": self.pool.num_blocks,
                "peak_blocks_in_use": self.pool.peak_in_use,
                "preemptions": self.preemptions - preempt0,
                "rejections": self.rejections - reject0,
                "attention_kernel": self.paged_kernel,
                "prefix_cache": self.prefix_cache,
                "prefix_hits": self.prefix_hits - hits0,
                "blocks_reused": self.blocks_reused - reused0,
                "tokens_skipped": self.tokens_skipped - skipped0,
                "prefill_tokens": self.prefill_demand - demand0,
                "cached_blocks": self.pool.cached_blocks,
                "evictions": self.pool.evictions - evict0,
            } if self.paged else {"enabled": False}),
            "requests": [{
                "rid": r.rid,
                "prompt_len": int(len(r.tokens)),
                "arrival": r.arrival,
                "state": r.state,
                "admitted_iter": r.admitted_iter,
                "first_token_iter": r.first_token_iter,
                "done_iter": r.done_iter,
                "latency_iters": r.done_iter - r.arrival,
                "latency_s": r.done_time,
                "n_out": len(r.out),
                "preemptions": r.preempted,
                "cached_tokens": r.cached_tokens,
            } for r in self.requests],
        }
        return {
            "outputs": {r.rid: list(r.out) for r in self.requests},
            "metrics": self.metrics,
        }

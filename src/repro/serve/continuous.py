"""Continuous-batching serving engine: chunked Amber-sparse prefill
interleaved with slot-batched dense decode.

Requests arrive asynchronously (:meth:`ContinuousServingEngine.submit`) and
are scheduled over a fixed pool of KV-cache **slots**.  Each scheduler
iteration:

  1. **admit** — waiting requests whose arrival time has passed claim free
     slots (FCFS); the slot's cache rows and recurrent state are zeroed;
  2. **prefill** — the oldest admitted-but-unprefilled request advances by
     one fixed-size token chunk through the Amber-sparse projection path
     (``model.prefill_chunk``), writing KV at its cache offset;
  3. **decode** — all slots holding decoding requests take one dense decode
     step as a single padded batch (inactive slots are masked out of the
     cache update).

Shape buckets: prefill compiles once per chunk shape (a single
``chunk_size`` bucket for attention archs; a dyadic ladder of at most
log2(chunk_size)+1 sizes for archs with recurrent blocks, whose scans
cannot mask padded tokens), and decode compiles once for the padded
``num_slots`` batch — arbitrary traffic never retraces.  The
``trace_counts`` attribute counts actual retraces per phase and is asserted
in the test suite.

Equivalence: with greedy decoding and **per-token** sparsity modes the
per-request output stream is token-identical to the legacy one-shot
:class:`~repro.serve.engine.ServingEngine` — a token's N:M mask doesn't
depend on which chunk carries it, chunked prefill attends over the cached
prefix so logits match, and decode rows are independent of batch
composition.  ``tile_consensus`` policies remain valid N:M serving but are
NOT bit-identical to one-shot prefill: their masks are pooled over token
tiles, and chunking changes tile membership (see serve/README.md).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import DENSE, SparsityPolicy
from repro.serve import slots as slot_ops

__all__ = ["ContinuousConfig", "Request", "ContinuousServingEngine"]

WAITING, PREFILL, DECODE, DONE = "waiting", "prefill", "decode", "done"


@dataclasses.dataclass(frozen=True)
class ContinuousConfig:
    max_seq: int = 512        # per-slot KV capacity (prompt + new tokens)
    num_slots: int = 4        # decode batch width (the padded batch bucket)
    chunk_size: int = 64      # prefill chunk bucket (tokens per chunk)
    temperature: float = 0.0  # 0 → greedy
    eos_token: int = -1       # -1 → never stop early
    seed: int = 0
    max_iters: int = 100_000  # scheduler-loop safety valve


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                 # (T,) prompt token ids
    max_new_tokens: int
    arrival: int = 0                   # scheduler iteration of arrival
    # --- runtime (engine-owned) ---
    state: str = WAITING
    slot: int = -1
    filled: int = 0                    # prompt tokens prefilled so far
    cur: int = 0                       # last generated token (decode input)
    out: List[int] = dataclasses.field(default_factory=list)
    admitted_iter: int = -1
    first_token_iter: int = -1
    done_iter: int = -1
    arrival_time: float = -1.0         # wall clock when arrival was reached
    done_time: float = 0.0             # wall-clock latency from arrival


def _dyadic_sizes(length: int, cap: int) -> List[int]:
    """Descending powers of two ≤ cap summing to length (exact chunks)."""
    sizes = []
    c = 1
    while c * 2 <= cap:
        c *= 2
    rem = length
    while rem:
        while c > rem:
            c //= 2
        sizes.append(c)
        rem -= c
    return sizes


class ContinuousServingEngine:
    """Scheduler + slot cache + shape-bucketed jitted phases."""

    def __init__(self, model, policy: SparsityPolicy = DENSE,
                 cfg: ContinuousConfig = ContinuousConfig()):
        self.model = model
        self.policy = policy
        self.cfg = cfg
        mcfg = model.cfg
        if getattr(mcfg, "vision_stub", False):
            assert cfg.chunk_size >= mcfg.n_patches, (
                "chunk_size must cover the VLM patch stub "
                f"({cfg.chunk_size} < {mcfg.n_patches})")
        # recurrent scans cannot mask padded tokens out of their state, so
        # hybrid/SSM archs get exact dyadic chunks instead of a padded tail
        if mcfg.is_encdec:
            self._exact_chunks = False
        else:
            from repro.models.transformer import layer_kinds
            self._exact_chunks = any(k != "attn" for k in layer_kinds(mcfg))
        if mcfg.attn_type in ("swa", "local"):
            assert cfg.chunk_size <= min(mcfg.window, cfg.max_seq), (
                "chunk_size must fit the sliding-window ring buffer")

        self.requests: List[Request] = []
        self._free_slots = list(range(cfg.num_slots))
        self._slot_req: List[Optional[Request]] = [None] * cfg.num_slots
        self.cache = None                      # built lazily per params
        self.trace_counts: Dict[str, int] = {"prefill": 0, "decode": 0}
        self.metrics: Dict[str, Any] = {}

        def prefill_fn(params, cache, slot, tokens, chunk_len, extras):
            self.trace_counts["prefill"] += 1      # runs at trace time only
            sub = slot_ops.slice_slot(cache, slot)
            batch = {"tokens": tokens, "chunk_len": chunk_len, **extras}
            logits, sub = self.model.prefill_chunk(params, batch, sub,
                                                   policy=self.policy)
            return logits[0], slot_ops.write_slot(cache, slot, sub)

        def decode_fn(params, cache, tokens, active, key):
            self.trace_counts["decode"] += 1
            logits, new_cache = self.model.decode_step(
                params, tokens[:, None], cache, policy=DENSE)
            new_cache = slot_ops.where_active(active, new_cache, cache)
            nxt = self._sample(logits, key)
            return jnp.where(active, nxt, tokens), new_cache

        self._prefill_jit = jax.jit(prefill_fn)
        self._decode_jit = jax.jit(decode_fn)

    # ------------------------------------------------------------- sampling
    def _sample(self, logits, key):
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------ admission
    def submit(self, tokens, max_new_tokens: int = 32, arrival: int = 0) -> int:
        """Queue a request; returns its request id.

        ``arrival`` is the scheduler iteration at which the request becomes
        visible (simulated asynchronous traffic)."""
        tokens = np.asarray(tokens).reshape(-1).astype(np.int32)
        assert tokens.size > 0, "empty prompt"
        assert tokens.size + max_new_tokens <= self.cfg.max_seq, \
            "request exceeds slot capacity (max_seq)"
        rid = len(self.requests)
        self.requests.append(Request(rid=rid, tokens=tokens,
                                     max_new_tokens=max_new_tokens,
                                     arrival=arrival))
        return rid

    def _admit(self, it: int) -> None:
        for req in self.requests:
            if req.state == WAITING and req.arrival <= it and self._free_slots:
                slot = self._free_slots.pop(0)
                self.cache = slot_ops.reset_slot(self.cache, slot)
                req.slot, req.state = slot, PREFILL
                req.admitted_iter = it
                self._slot_req[slot] = req

    def _finish(self, req: Request, it: int, t0: float) -> None:
        req.state = DONE
        req.done_iter = it
        anchor = req.arrival_time if req.arrival_time >= 0 else t0
        req.done_time = time.perf_counter() - anchor
        self._free_slots.append(req.slot)
        self._slot_req[req.slot] = None

    def clear(self) -> None:
        """Drop completed requests (e.g. after a warmup pass) so a fresh
        stream can be submitted and measured on the already-compiled
        engine."""
        assert all(r.state == DONE for r in self.requests), \
            "cannot clear with requests in flight"
        self.requests = []

    # ------------------------------------------------------------ phases
    def _next_chunk(self, req: Request):
        """(tokens (1, C), chunk_len, send_extras) for the next chunk."""
        c = self.cfg.chunk_size
        rem = len(req.tokens) - req.filled
        if self._exact_chunks:
            size = _dyadic_sizes(rem, c)[0]
            chunk = req.tokens[req.filled:req.filled + size]
            return chunk[None, :], size, req.filled == 0
        v = min(c, rem)
        chunk = np.zeros((c,), np.int32)
        chunk[:v] = req.tokens[req.filled:req.filled + v]
        return chunk[None, :], v, req.filled == 0

    def _prefill_one(self, params, req: Request, extras: Dict, it: int,
                     t0: float, key) -> None:
        tokens, clen, first = self._next_chunk(req)
        ex = extras if first else {}
        logits, self.cache = self._prefill_jit(
            params, self.cache, jnp.asarray(req.slot, jnp.int32),
            jnp.asarray(tokens), jnp.asarray(clen, jnp.int32), ex)
        req.filled += clen
        if req.filled == len(req.tokens):       # prompt ingested: sample
            tok = int(self._sample(logits, key))
            req.out.append(tok)
            req.first_token_iter = it
            if tok == self.cfg.eos_token or req.max_new_tokens == 1:
                self._finish(req, it, t0)
            else:
                req.state, req.cur = DECODE, tok

    def _decode_all(self, params, decoding: Sequence[Request], it: int,
                    t0: float, key) -> None:
        toks = np.zeros((self.cfg.num_slots,), np.int32)
        act = np.zeros((self.cfg.num_slots,), bool)
        for r in decoding:
            toks[r.slot], act[r.slot] = r.cur, True
        nxt, self.cache = self._decode_jit(
            params, self.cache, jnp.asarray(toks), jnp.asarray(act), key)
        nxt = np.asarray(nxt)
        for r in decoding:
            tok = int(nxt[r.slot])
            r.out.append(tok)
            r.cur = tok
            if tok == self.cfg.eos_token or len(r.out) >= r.max_new_tokens:
                self._finish(r, it, t0)

    # ------------------------------------------------------------ main loop
    def run(self, params, extras: Optional[Dict[int, Dict]] = None) -> Dict:
        """Drive the scheduler until every submitted request completes.

        ``extras`` maps request id → modality arrays sent with the first
        prefill chunk (``frame_embeds`` for encdec, ``pixel_embeds`` for
        VLM stubs).  Returns per-request outputs and aggregate metrics.
        """
        extras = extras or {}
        if self.cache is None:
            self.cache = slot_ops.init_slot_cache(
                self.model, self.cfg.num_slots, self.cfg.max_seq)
        key = jax.random.PRNGKey(self.cfg.seed)
        t0 = time.perf_counter()
        it = 0
        while any(r.state != DONE for r in self.requests):
            assert it < self.cfg.max_iters, "scheduler stuck"
            now = time.perf_counter()
            for r in self.requests:      # anchor wall-clock latency at arrival
                if r.state == WAITING and r.arrival <= it and r.arrival_time < 0:
                    r.arrival_time = now
            self._admit(it)
            prefilling = [r for r in self.requests if r.state == PREFILL]
            if prefilling:
                key, sub = jax.random.split(key)
                req = prefilling[0]
                self._prefill_one(params, req, extras.get(req.rid, {}),
                                  it, t0, sub)
            decoding = [r for r in self.requests if r.state == DECODE]
            if decoding:
                key, sub = jax.random.split(key)
                self._decode_all(params, decoding, it, t0, sub)
            it += 1
        wall = time.perf_counter() - t0
        gen = sum(len(r.out) for r in self.requests)
        self.metrics = {
            "iterations": it,
            "wall_s": wall,
            "generated_tokens": gen,
            "tokens_per_s": gen / max(wall, 1e-9),
            "trace_counts": dict(self.trace_counts),
            "requests": [{
                "rid": r.rid,
                "prompt_len": int(len(r.tokens)),
                "arrival": r.arrival,
                "admitted_iter": r.admitted_iter,
                "first_token_iter": r.first_token_iter,
                "done_iter": r.done_iter,
                "latency_iters": r.done_iter - r.arrival,
                "latency_s": r.done_time,
                "n_out": len(r.out),
            } for r in self.requests],
        }
        return {
            "outputs": {r.rid: list(r.out) for r in self.requests},
            "metrics": self.metrics,
        }

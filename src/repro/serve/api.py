"""Public serving facade — the one supported entry point.

Everything under ``repro.serve`` below this module is implementation
detail with a stability contract only through here::

    from repro.serve.api import Engine, EngineConfig

    eng = Engine.from_config(model, EngineConfig(dp=2, tp=1))
    rid = eng.submit(prompt_tokens, max_new_tokens=32)
    res = eng.run(params)                # res["outputs"][rid]
    print(eng.metrics.to_json(indent=2))

``EngineConfig`` wraps the per-replica :class:`ContinuousConfig` plus the
parallelism layout: ``dp`` replicas (host-level — each an independent
Scheduler+Executor with its own slot/block pool, load-balanced by the
:class:`~repro.serve.router.Router`) by ``tp`` tensor-parallel shards per
replica (device-level — column-parallel projections and head-sharded
paged attention, see ``distributed/tp.py``).  ``dp*tp > 1`` builds a
``(data, model)`` mesh via ``launch.mesh.make_serving_mesh``, which
validates the device count up front.

The legacy entry points survive as thin adapters over this stack:
``ContinuousServingEngine`` is exactly a dp=1 router replica and
``ServingEngine.generate`` (one-shot) is "submit the whole batch, close
admission, run" — both now raise ``DeprecationWarning`` on direct
construction.  serve/README.md has the migration table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

from repro.core.policy import DENSE, SparsityPolicy
from repro.serve.continuous import ContinuousConfig
from repro.serve.faults import FaultInjector
from repro.serve.metrics import MetricsSnapshot
from repro.serve.router import Router

__all__ = ["EngineConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Serving-wide configuration: parallel layout + per-replica knobs."""
    dp: int = 1                    # data-parallel engine replicas
    tp: int = 1                    # tensor-parallel shards per replica
    serving: ContinuousConfig = ContinuousConfig()

    def __post_init__(self):
        assert self.dp >= 1 and self.tp >= 1, "dp/tp must be positive"


class Engine:
    """User-facing serving engine: a Router with a typed config and a
    :class:`MetricsSnapshot`-returning metrics surface."""

    def __init__(self, router: Router, cfg: EngineConfig):
        self._router = router
        self.cfg = cfg

    @classmethod
    def from_config(cls, model, cfg: EngineConfig = EngineConfig(), *,
                    policy: SparsityPolicy = DENSE,
                    faults: Optional[FaultInjector] = None,
                    mesh=None) -> "Engine":
        """Build the full serving stack for ``cfg``'s layout.

        ``mesh`` overrides the auto-built one (useful in tests that fake
        host devices); otherwise ``tp > 1`` builds a ``(dp, tp)`` mesh —
        and raises a clear ValueError when the backend lacks the devices.
        ``tp == 1`` never touches jax device state (pure host dp).
        """
        if mesh is None and cfg.tp > 1:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh(cfg.dp, cfg.tp)
        router = Router(model, policy, cfg.serving, dp=cfg.dp, mesh=mesh,
                        faults=faults)
        return cls(router, cfg)

    # ------------------------------------------------------------ requests
    def submit(self, tokens, max_new_tokens: int = 32, arrival: int = 0,
               ttl: Optional[int] = None) -> int:
        return self._router.submit(tokens, max_new_tokens, arrival, ttl)

    def cancel(self, rid: int) -> bool:
        return self._router.cancel(rid)

    def run(self, params, extras: Optional[Dict[int, Dict]] = None) -> Dict:
        return self._router.run(params, extras=extras)

    def generate(self, params, prompts: Sequence, max_new_tokens: int = 32
                 ) -> List[List[int]]:
        """One-shot convenience (the old ``ServingEngine.generate`` shape):
        submit the whole batch at arrival 0, run to completion with
        admission closed, return outputs in submission order."""
        rids = [self.submit(p, max_new_tokens) for p in prompts]
        res = self.run(params)
        return [res["outputs"][r] for r in rids]

    # ------------------------------------------------------------- observe
    @property
    def metrics(self) -> Optional[MetricsSnapshot]:
        """Merged fleet metrics from the last ``run()`` (None before)."""
        return self._router.metrics_snapshot

    def request_state(self, rid: int) -> str:
        return self._router.request_state(rid)

    @property
    def replicas(self):
        """The underlying per-replica engines (read-only introspection)."""
        return tuple(self._router.replicas)

    # ---------------------------------------------------- state management
    def snapshot(self) -> Dict[str, Any]:
        return self._router.snapshot()

    def restore(self, snap: Dict[str, Any]) -> None:
        self._router.restore(snap)

    def clear(self) -> None:
        self._router.clear()

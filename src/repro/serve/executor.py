"""Device-program layer of the serving engine (the API split's second
layer — see serve/README.md "Architecture").

The :class:`Executor` owns everything that touches jax: the cache
pytree, the jit'd step-program buckets (PR 7's one-dispatch iterations
plus the legacy two-program split), their bit-exact jnp oracle twins,
and the fault/degradation ladder.  It consumes
:class:`~repro.serve.scheduler.StepPlan`s — plain host data — and
returns sampled tokens; it never reads or mutates request state.

Every step program is a **pure function** of ``(params, cache, plan
operands)``: the only Python-side reads inside a traced body are
static configuration (model, policy, slot spec, temperature) and the
trace-counter side effect, which runs at trace time only.  That is what
makes the program ``shard_map``-able: when the executor is built with a
``mesh``, each dispatch runs under a :func:`repro.distributed.tp.scope`
and the projection kernels / paged attention shard themselves across
the mesh's model axis (column-parallel N_out, KV-head split) with
bit-identical results — see ``distributed/tp.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import DENSE, SparsityPolicy
from repro.distributed import tp as tp_mod
from repro.serve import slots as slot_ops
from repro.serve.faults import KernelFault
from repro.serve.paged import init_paged_cache
from repro.serve.scheduler import StepPlan

__all__ = ["Executor", "StepResult", "STEP_BUCKETS", "declared_trace_keys"]

# The fused one-dispatch step buckets, keyed (replay, has_prefill,
# has_decode) — static phase presence (PR 7).  This table is THE
# enumeration: ``Executor.__init__`` builds one program (plus its jnp
# oracle twin) per row, and ``repro.analysis`` sweeps its jaxpr/trace
# rules over exactly these buckets, so adding a bucket here is
# automatically adding it to the checked contract.
STEP_BUCKETS: Dict[Tuple[bool, bool, bool], str] = {
    (False, True, False): "step_prefill",
    (False, True, True): "step_prefill_decode",
    (False, False, True): "step_decode",
    (True, True, False): "step_replay",
    (True, True, True): "step_replay_decode",
}

# legacy two-program split (still served by ``prefill()``/``decode()``)
_LEGACY_TRACE_KEYS = ("prefill", "prefill_replay", "decode")


def declared_trace_keys() -> Tuple[str, ...]:
    """Every ``trace_counts`` key an :class:`Executor` may legitimately
    record: the fused buckets, the legacy split, and the ``_oracle``
    degradation twins of each.  The retrace rule treats any key outside
    this set as an undeclared (hence unbounded) trace bucket."""
    base = tuple(STEP_BUCKETS.values()) + _LEGACY_TRACE_KEYS
    return base + tuple(k + "_oracle" for k in base)


@dataclasses.dataclass
class StepResult:
    """Host-side result of one executed plan."""
    prefill_token: Optional[int] = None   # sampled iff the plan had prefill
    decode_tokens: Optional[np.ndarray] = None  # (num_slots,) iff decode
    degraded: bool = False                # re-ran on the jnp oracle twin


class Executor:
    """Owns the cache pytree + jit'd phase programs; executes plans.

    May mutate: ``self.cache``, its own dispatch/degradation counters,
    ``trace_counts``.  May NOT touch: requests, slots bookkeeping, the
    block pool (scheduler territory).  ``mesh`` (a 1-axis TP mesh, see
    ``distributed/tp.replica_meshes``) shards the kernels; ``mesh=None``
    is the single-device executor."""

    def __init__(self, model, policy: SparsityPolicy, cfg,
                 mesh=None, tp_axis: str = "model"):
        self.model = model
        self.policy = policy
        self.cfg = cfg
        self.mesh = mesh
        self.tp_axis = tp_axis
        mcfg = model.cfg
        if getattr(mcfg, "vision_stub", False):
            assert cfg.chunk_size >= mcfg.n_patches, (
                "chunk_size must cover the VLM patch stub "
                f"({cfg.chunk_size} < {mcfg.n_patches})")
        # recurrent scans cannot mask padded tokens out of their state, so
        # hybrid/SSM archs get exact dyadic chunks instead of a padded tail
        if mcfg.is_encdec:
            self.exact_chunks = False
        else:
            from repro.models.transformer import layer_kinds
            self.exact_chunks = any(k != "attn" for k in layer_kinds(mcfg))
        if mcfg.attn_type in ("swa", "local"):
            assert cfg.chunk_size <= min(mcfg.window, cfg.max_seq), (
                "chunk_size must fit the sliding-window ring buffer")
        # paged KV: only archs with full-attention KV leaves benefit;
        # encdec (request-shaped caches), SWA rings, and pure-recurrent
        # archs fall back to the dense per-slot slab automatically
        spec = model.paged_kv_spec() if cfg.paged else None
        if spec is not None and not any(jax.tree_util.tree_leaves(spec)):
            spec = None
        self._spec = spec
        self.paged = spec is not None
        # the projections' policy flag also routes paged attention through
        # the in-kernel block-table walk (models/attention.paged_attention
        # ladder); decode runs DENSE projections but must carry the flag so
        # its attention takes the same path as prefill's
        self.paged_kernel = self.paged and bool(policy.use_pallas_kernels)
        if self.paged_kernel and not self.exact_chunks:
            # a padded prefill bucket the kernel cannot tile would silently
            # fall back to the gather oracle while metrics/--trace claimed
            # the kernel ran — reject it here instead (exact-chunk archs
            # emit power-of-two chunks, always covered; decode is T = 1)
            from repro.kernels.paged_attention import paged_kernel_covers
            assert paged_kernel_covers(cfg.chunk_size), (
                "paged-attention kernel cannot tile chunk_size="
                f"{cfg.chunk_size} (see kernels.paged_attention"
                ".paged_kernel_covers); use a power-of-two chunk_size or "
                "drop use_pallas_kernels")
        self.cache = None               # built lazily per params
        self.trace_counts: Dict[str, int] = {}
        self.dispatches = 0       # compiled-program launches (incl. oracle)
        self.degraded_iterations = 0  # iterations re-run on the jnp oracle

        # every phase program takes a runtime ``fault`` operand added onto
        # its logits (0.0 on clean runs, NaN when the injector fires a
        # "nonfinite" fault — a runtime value, so injection never bakes
        # into or retraces the compiled program) and returns an ``ok``
        # finiteness verdict the degradation ladder checks host-side.
        # ``ok`` also trips on GENUINE non-finite logits from a kernel bug.
        def make_prefill_fn(policy, count_key):
            def prefill_fn(params, cache, slot, tokens, chunk_len, extras,
                           fault):
                # runs at trace time only
                self.trace_counts[count_key] = \
                    self.trace_counts.get(count_key, 0) + 1
                sub = slot_ops.slice_slot(cache, slot, self._spec)
                batch = {"tokens": tokens, "chunk_len": chunk_len, **extras}
                logits, sub = self.model.prefill_chunk(params, batch, sub,
                                                       policy=policy)
                logits = logits[0] + fault
                ok = jnp.all(jnp.isfinite(logits))
                return logits, slot_ops.write_slot(cache, slot, sub,
                                                   self._spec), ok
            return prefill_fn

        dense = DENSE.with_(use_pallas_kernels=policy.use_pallas_kernels)

        def make_decode_fn(policy, count_key):
            def decode_fn(params, cache, tokens, active, key, fault):
                self.trace_counts[count_key] = \
                    self.trace_counts.get(count_key, 0) + 1
                logits, new_cache = self.model.decode_step(
                    params, tokens[:, None], cache, policy=policy)
                logits = logits + fault
                new_cache = slot_ops.where_active(active, new_cache, cache,
                                                  self._spec)
                nxt = self._sample(logits, key)
                # inactive slots may legitimately hold junk logits — only
                # active rows gate the degradation ladder
                ok = jnp.all(jnp.isfinite(logits)
                             | ~active.reshape(active.shape[0],
                                               *([1] * (logits.ndim - 1))))
                return jnp.where(active, nxt, tokens), new_cache, ok
            return decode_fn

        self._prefill_jit = jax.jit(make_prefill_fn(policy, "prefill"))
        # preemption replay re-ingests tokens the request already EMITTED;
        # their KV was originally written by the dense decode step, so the
        # replay must also run dense or sparse-prefill outputs would drift
        # from the one-shot oracle.  Chunks never span the prompt/emitted
        # boundary (see Scheduler.next_chunk); this program only ever
        # traces (and the "prefill_replay" key only appears) if a
        # preemption happens under a non-dense policy.
        self._prefill_replay_jit = jax.jit(
            make_prefill_fn(dense, "prefill_replay"))
        self._decode_jit = jax.jit(make_decode_fn(dense, "decode"))
        # graceful-degradation ladder: bit-exact jnp oracle twins of every
        # phase program (kernel dispatch forced off).  jax.jit is lazy, so
        # none of these trace — and no "*_oracle" trace-count key appears —
        # unless an iteration actually degrades.
        opolicy = policy.with_(use_pallas_kernels=False) \
            if policy.use_pallas_kernels else policy
        self._prefill_oracle_jit = jax.jit(
            make_prefill_fn(opolicy, "prefill_oracle"))
        self._prefill_replay_oracle_jit = jax.jit(
            make_prefill_fn(DENSE, "prefill_replay_oracle"))
        self._decode_oracle_jit = jax.jit(
            make_decode_fn(DENSE, "decode_oracle"))

        # ---- one-dispatch iterations: a single hybrid step program per
        # shape bucket runs the active request's prefill chunk AND the
        # slot-batched decode in one compiled dispatch.  Buckets are keyed
        # (replay, has_prefill, has_decode) — static phase presence, so an
        # idle phase costs nothing in the lowered program.  The prefill
        # half writes its chunk KV first; the decode half then reads the
        # already-updated cache, exactly like the legacy two-program order
        # within an iteration.  Both halves share one ``fault`` operand
        # and fold into one all-finite ``ok`` verdict (inactive decode
        # rows masked), so the degradation ladder re-runs the WHOLE step
        # on the oracle twin.
        def make_step_fn(pf_policy, dec_policy, count_key,
                         has_prefill, has_decode):
            def step_fn(params, cache, slot, tokens, chunk_len, extras,
                        toks, active, pkey, dkey, fault):
                # runs at trace time only
                self.trace_counts[count_key] = \
                    self.trace_counts.get(count_key, 0) + 1
                ok = jnp.asarray(True)
                ptok = jnp.asarray(0, jnp.int32)
                if has_prefill:
                    sub = slot_ops.slice_slot(cache, slot, self._spec)
                    batch = {"tokens": tokens, "chunk_len": chunk_len,
                             **extras}
                    p_logits, sub = self.model.prefill_chunk(
                        params, batch, sub, policy=pf_policy)
                    p_logits = p_logits[0] + fault
                    ok = ok & jnp.all(jnp.isfinite(p_logits))
                    cache = slot_ops.write_slot(cache, slot, sub,
                                                self._spec)
                    ptok = self._sample(p_logits, pkey)
                nxt = toks
                if has_decode:
                    d_logits, new_cache = self.model.decode_step(
                        params, toks[:, None], cache, policy=dec_policy)
                    d_logits = d_logits + fault
                    cache = slot_ops.where_active(active, new_cache, cache,
                                                  self._spec)
                    # inactive slots may legitimately hold junk logits —
                    # only active rows gate the degradation ladder
                    ok = ok & jnp.all(
                        jnp.isfinite(d_logits)
                        | ~active.reshape(active.shape[0],
                                          *([1] * (d_logits.ndim - 1))))
                    nxt = jnp.where(active, self._sample(d_logits, dkey),
                                    toks)
                return ptok, nxt, cache, ok
            return step_fn

        # raw (unjitted) step fns are kept for the jaxpr pins in tests and
        # repro.analysis — ``step_program(bucket)`` is the public accessor
        self._step_raw: Dict[tuple, Callable] = {}
        self._step_oracle_raw: Dict[tuple, Callable] = {}
        self._step_jits: Dict[tuple, Callable] = {}
        self._step_oracle_jits: Dict[tuple, Callable] = {}
        for key, name in STEP_BUCKETS.items():
            replay, hp, hd = key
            pf = dense if replay else policy
            opf = DENSE if replay else opolicy
            self._step_raw[key] = make_step_fn(pf, dense, name, hp, hd)
            self._step_oracle_raw[key] = make_step_fn(
                opf, DENSE, name + "_oracle", hp, hd)
            self._step_jits[key] = jax.jit(self._step_raw[key])
            self._step_oracle_jits[key] = jax.jit(self._step_oracle_raw[key])

    # ------------------------------------------------------------- sampling
    def _sample(self, logits, key):
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    def sample_token(self, logits, key) -> int:
        return int(self._sample(logits, key))

    # ------------------------------------------------------------ the cache
    def init_cache(self, num_blocks: Optional[int] = None) -> None:
        if self.cache is not None:
            return
        if self.paged:
            self.cache = init_paged_cache(
                self.model, self.cfg.num_slots, self.cfg.max_seq,
                self.cfg.block_size, num_blocks, self._spec)
        else:
            self.cache = slot_ops.init_slot_cache(
                self.model, self.cfg.num_slots, self.cfg.max_seq)

    def drop_cache(self) -> None:
        """Forget device state (restore path: the crash that motivated a
        restore invalidates the KV anyway)."""
        self.cache = None

    def apply_effects(self, plan: StepPlan) -> None:
        """Apply the plan's idempotent cache-side effects BEFORE the step:
        slot resets decided at admission and the host block table when the
        scheduler rewrote it."""
        for slot, pos in plan.resets:
            self.cache = slot_ops.reset_slot(self.cache, slot, self._spec,
                                             pos=pos)
        if plan.table is not None:
            self.cache["block_table"] = jnp.asarray(plan.table)

    # ----------------------------------------------------------- dispatch
    def _tp_scope(self):
        return tp_mod.scope(self.mesh, self.tp_axis)

    def _run_ladder(self, fn, ofn, args, fault):
        """One dispatch + the degradation ladder: on a KernelFault (trace-
        time kernel failure — the failed trace aborted before any output
        existed) or a non-finite ``ok`` verdict, discard the faulted
        outputs (functional jit — ``self.cache`` is untouched) and re-run
        the SAME operands on the bit-exact jnp oracle program."""
        self.dispatches += 1
        try:
            with self._tp_scope():
                out = fn(*args, fault)
            ok = bool(out[-1])
        except KernelFault:
            ok = False
        if not ok:
            self.degraded_iterations += 1
            self.dispatches += 1
            with self._tp_scope():
                out = ofn(*args, jnp.float32(0.0))
            assert bool(out[-1]), "oracle produced non-finite logits"
        return out

    def step(self, params, plan: StepPlan, extras: Dict, pkey, dkey,
             fault) -> StepResult:
        """Execute a fused one-dispatch plan.  ``extras`` are the modality
        arrays for the chunk (already resolved to {} by the driver when
        this is not the request's first chunk)."""
        degraded0 = self.degraded_iterations
        pw, dw = plan.prefill, plan.decode
        if pw is not None:
            slot = jnp.asarray(pw.req.slot, jnp.int32)
            ptoks = jnp.asarray(pw.tokens)
            pclen = jnp.asarray(pw.chunk_len, jnp.int32)
            ex = extras
        else:
            ex = {}
            slot = jnp.asarray(0, jnp.int32)
            ptoks = jnp.zeros((1, 1), jnp.int32)
            pclen = jnp.asarray(0, jnp.int32)
        if dw is not None:
            toks, act = jnp.asarray(dw.toks), jnp.asarray(dw.active)
        else:
            toks = jnp.zeros((self.cfg.num_slots,), jnp.int32)
            act = jnp.zeros((self.cfg.num_slots,), bool)
        bucket = plan.bucket
        args = (params, self.cache, slot, ptoks, pclen, ex, toks, act,
                pkey, dkey)
        ptok, nxt, new_cache, _ = self._run_ladder(
            self._step_jits[bucket], self._step_oracle_jits[bucket],
            args, fault)
        self.cache = new_cache
        return StepResult(
            prefill_token=int(ptok) if pw is not None else None,
            decode_tokens=np.asarray(nxt) if dw is not None else None,
            degraded=self.degraded_iterations > degraded0)

    def prefill(self, params, plan: StepPlan, extras: Dict, fault):
        """Legacy two-program split, phase 1: run the chunk, return its
        final-position logits (the driver samples only when the chunk
        completed the sequence — matching the historical dispatch
        pattern)."""
        pw = plan.prefill
        fn = self._prefill_replay_jit if pw.replay else self._prefill_jit
        ofn = (self._prefill_replay_oracle_jit if pw.replay
               else self._prefill_oracle_jit)
        args = (params, self.cache, jnp.asarray(pw.req.slot, jnp.int32),
                jnp.asarray(pw.tokens), jnp.asarray(pw.chunk_len, jnp.int32),
                extras)
        logits, new_cache, _ = self._run_ladder(fn, ofn, args, fault)
        self.cache = new_cache
        return logits

    def decode(self, params, plan: StepPlan, key, fault) -> np.ndarray:
        """Legacy two-program split, phase 2: one slot-batched decode
        step; returns the (num_slots,) next-token array."""
        dw = plan.decode
        args = (params, self.cache, jnp.asarray(dw.toks),
                jnp.asarray(dw.active), key)
        nxt, new_cache, _ = self._run_ladder(
            self._decode_jit, self._decode_oracle_jit, args, fault)
        self.cache = new_cache
        return np.asarray(nxt)

    # ----------------------------------------------------------- test hooks
    def step_program(self, bucket: Tuple[bool, bool, bool],
                     oracle: bool = False):
        """The raw (unjitted) step program for a phase-presence bucket —
        a pure function of its operands, used by the jaxpr purity pins
        (buckets enumerated by :data:`STEP_BUCKETS`).  ``oracle=True``
        returns the bit-exact jnp degradation twin, so the analyzer can
        check the kernels-off program as well (and prove the kernels-on
        pins aren't vacuously true)."""
        return (self._step_oracle_raw if oracle else self._step_raw)[bucket]

    def step_programs(self, oracle: bool = False):
        """Sweep hook: yield ``(bucket, name, program)`` for EVERY
        :data:`STEP_BUCKETS` row — the analyzer iterates this (rather
        than hand-listing buckets) so a new bucket is in the checked
        contract the moment it exists."""
        for bucket, name in STEP_BUCKETS.items():
            yield bucket, name, self.step_program(bucket, oracle=oracle)

"""Deterministic synthetic LM data pipeline.

Design goals (1000-node deployment):
  * **Stateless-deterministic**: a batch is a pure function of
    (seed, step) — restart after a node failure replays the exact stream
    with no data-loader state to checkpoint, and elastic re-scaling only
    needs the step counter.
  * **Learnable structure**: tokens follow a Zipf marginal over the vocab
    composed with a first-order "template" process (each position copies
    the token k steps back with probability p) so a real LM objective has
    signal — the quickstart example's loss visibly drops.
  * **Shardable**: the global batch is generated whole and sharded by the
    caller's in_shardings; per-host generation would slice by
    ``jax.process_index()`` (documented; single-process here).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["DataConfig", "lm_batch", "calibration_stream"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1
    copy_prob: float = 0.35
    copy_back: int = 8


def _zipf_tokens(key: jax.Array, shape, vocab: int, alpha: float) -> jax.Array:
    """Inverse-CDF Zipf sampling (approximate, O(1) memory)."""
    u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0)
    # inverse CDF of p(k) ∝ k^-alpha on [1, V]
    inv = (1.0 - u * (1.0 - float(vocab) ** (1.0 - alpha))) ** (1.0 / (1.0 - alpha))
    return jnp.clip(inv.astype(jnp.int32) - 1, 0, vocab - 1)


def lm_batch(cfg: DataConfig, step: int | jax.Array) -> dict:
    """Batch for a given step: {"tokens": (B, S+1) int32} — callers slice
    inputs/labels.  Pure function of (cfg.seed, step)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2 = jax.random.split(key)
    b, s = cfg.global_batch, cfg.seq_len + 1
    base = _zipf_tokens(k1, (b, s), cfg.vocab_size, cfg.zipf_alpha)
    copy = jax.random.uniform(k2, (b, s)) < cfg.copy_prob
    shifted = jnp.roll(base, cfg.copy_back, axis=1)
    tokens = jnp.where(copy, shifted, base)
    return {"tokens": tokens}


def calibration_stream(cfg: DataConfig, n_batches: int):
    """Yields small prompt batches for SmoothQuant / sensitivity calibration."""
    for i in range(n_batches):
        yield lm_batch(cfg, 10_000_000 + i)

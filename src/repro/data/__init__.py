from repro.data.pipeline import DataConfig, lm_batch, calibration_stream

__all__ = ["DataConfig", "lm_batch", "calibration_stream"]

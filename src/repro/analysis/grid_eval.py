"""Symbolic grid evaluator: race/aliasing/bounds checks for Pallas grids.

Interpret-mode CPU tests execute every grid step sequentially over one
shared buffer, which MASKS the two hazards Mosaic's pipelined lowering
actually has:

  * **discontiguous output revisit** — Mosaic keeps an output block
    resident in VMEM across *consecutive* grid steps that map to it and
    writes it back when the index changes.  A block revisited after the
    pipeline moved off it is write-after-write through a stale copy.
  * **aliased refetch-after-write** — with ``input_output_aliases`` the
    input side re-FETCHES a block from HBM at the start of each of its
    runs.  If an earlier grid step already wrote that block, the fetch
    races the in-flight write-back (RAW) — exactly the hazard a wrong
    scalar-prefetch index remap creates in ``paged_kv_scatter_pallas``.
  * **out-of-bounds block indices** — Pallas clamps them silently, so a
    table bug reads/writes the wrong block instead of failing.

None of this needs hardware to check: grids are static, and every
BlockSpec index map is a tiny jaxpr we can evaluate CONCRETELY for all
grid steps once the scalar-prefetch operands (block tables, positions,
lengths) are known.  This module

  1. traces a callable and walks its jaxpr with a constant-propagation
     pass (:func:`trace_and_collect`) that resolves small operand values
     through ``pjit``/``scan``/``cond``/... down to each ``pallas_call``
     equation — so the *serving step programs'* kernels are checked with
     their real block tables, not hand-built ones;
  2. enumerates the grid row-major (last axis innermost, the sequential
     order Mosaic pipelines in) and evaluates every index map for every
     step (:func:`eval_pallas_eqn`), via ``discharge_state`` + vmap;
  3. checks bounds / revisit-contiguity / aliased-RAW over the resulting
     per-step block-index sequences (:func:`check_grid`).

Skipped-step index remaps (PR 4's refetch-elision trick) are covered by
the same two write checks: a remap that parks on a block some other step
writes shows up as a discontiguous revisit or an aliased refetch of a
written block.  The one legal parking target is the pool's SENTINEL row
(``serve/paged.device_pool_rows``): the trailing block the allocator
never hands out.  Scalar-dependent aliased operands may park there
freely (content is never consumed), and the checker exempts exactly
that — last axis-0 block, aliased, scalar-fed — reporting the parked
step count as an ``info`` datum instead.

The ``races`` rules sweep the concrete kernel zoo
(:func:`repro.analysis.vmem.grid_zoo_entries` — coverage is derived
from ``kernel_zoo_entries``, so new kernels cannot silently skip) and
every ``STEP_BUCKETS`` step program of ``serve/executor.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis import Context, Finding, rule

__all__ = [
    "UNKNOWN",
    "ResolvedCall",
    "trace_and_collect",
    "OperandGrid",
    "GridEval",
    "eval_pallas_eqn",
    "check_grid",
]


class _Unknown:
    """Sentinel for values the const-prop pass could not resolve."""

    def __repr__(self):
        return "UNKNOWN"


UNKNOWN = _Unknown()

# Propagate only smallish values: block tables / positions / smoke-model
# tensors resolve; nothing big enough to make eager evaluation costly.
_MAX_PROP_ELEMS = 1 << 16


@dataclasses.dataclass
class ResolvedCall:
    """One ``pallas_call`` equation with const-propagated operand values
    (``UNKNOWN`` where resolution failed) and the jaxpr path to it."""
    eqn: Any
    invals: List[Any]
    path: str


def _aval_small(aval) -> bool:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return False
    try:
        return math.prod(int(d) for d in shape) <= _MAX_PROP_ELEMS
    except (TypeError, ValueError):
        return False


def _closed(j):
    """(jaxpr, consts) from a ClosedJaxpr or open Jaxpr param value."""
    from jax import core as jax_core
    if isinstance(j, jax_core.ClosedJaxpr):
        return j.jaxpr, list(j.consts)
    return j, []


def trace_and_collect(fn, *args) -> List[ResolvedCall]:
    """Trace ``fn(*args)`` and return every ``pallas_call`` equation in
    the program (recursing through pjit/scan/while/cond/custom_*), with
    operand values constant-propagated from the concrete ``args``."""
    import jax

    closed = jax.make_jaxpr(fn)(*args)
    flat = jax.tree_util.tree_leaves(args)
    invals: List[Any] = list(flat)
    if len(invals) != len(closed.jaxpr.invars):
        invals = [UNKNOWN] * len(closed.jaxpr.invars)
    calls: List[ResolvedCall] = []
    _eval_jaxpr(closed.jaxpr, list(closed.consts), invals, calls, "")
    return calls


def _eval_jaxpr(jaxpr, consts, invals, calls: List[ResolvedCall],
                path: str) -> List[Any]:
    """Mixed concrete/abstract evaluation: known small values propagate
    through first-order primitives eagerly; higher-order primitives are
    recursed for ``pallas_call`` collection.  Returns outvar values
    (``UNKNOWN``-filled where resolution stopped)."""
    from jax import core as jax_core

    env: Dict[Any, Any] = {}

    def read(v):
        if isinstance(v, jax_core.Literal):
            return v.val
        return env.get(v, UNKNOWN)

    def write(vs, vals):
        for v, val in zip(vs, vals):
            env[v] = val

    write(jaxpr.constvars, consts)
    write(jaxpr.invars, invals)

    for eqn in jaxpr.eqns:
        p = eqn.primitive
        vals = [read(v) for v in eqn.invars]
        known = all(not isinstance(v, _Unknown) for v in vals)
        name = p.name
        outs: List[Any] = [UNKNOWN] * len(eqn.outvars)

        if name == "pallas_call":
            calls.append(ResolvedCall(eqn, vals, path))
        elif name == "pjit":
            j, c = _closed(eqn.params["jaxpr"])
            outs = _eval_jaxpr(j, c, vals, calls, path + "/pjit")
        elif name in ("custom_jvp_call", "custom_vjp_call"):
            j, c = _closed(eqn.params["call_jaxpr"])
            outs = _eval_jaxpr(j, c, vals, calls, path + "/" + name)
        elif name in ("remat", "checkpoint", "remat2", "core_call",
                      "closed_call", "call"):
            j, c = _closed(eqn.params.get("jaxpr")
                           or eqn.params.get("call_jaxpr"))
            outs = _eval_jaxpr(j, c, vals, calls, path + "/" + name)
        elif name == "scan":
            # one body pass: consts + INITIAL carry are seeded (block
            # tables / positions are loop-invariant in the step
            # programs), per-iteration xs slices stay UNKNOWN.  Loop
            # outputs are not short-circuited.
            j, c = _closed(eqn.params["jaxpr"])
            nc = eqn.params["num_consts"]
            ncar = eqn.params["num_carry"]
            body_in = (vals[:nc + ncar]
                       + [UNKNOWN] * (len(j.invars) - nc - ncar))
            _eval_jaxpr(j, c, body_in, calls, path + "/scan")
        elif name == "while":
            j, c = _closed(eqn.params["body_jaxpr"])
            cn = eqn.params["cond_nconsts"]
            bn = eqn.params["body_nconsts"]
            body_in = vals[cn:cn + bn] + vals[cn + bn:]
            body_in = body_in[:len(j.invars)] + [UNKNOWN] * max(
                0, len(j.invars) - len(body_in))
            _eval_jaxpr(j, c, body_in, calls, path + "/while")
        elif name == "cond":
            branches = eqn.params["branches"]
            pred, ops = vals[0], vals[1:]
            for bi, br in enumerate(branches):
                j, c = _closed(br)
                bouts = _eval_jaxpr(j, c, list(ops), calls,
                                    path + f"/cond[{bi}]")
                if not isinstance(pred, _Unknown) and int(pred) == bi:
                    outs = bouts
        else:
            sub = [v for v in eqn.params.values()
                   if isinstance(v, (jax_core.Jaxpr, jax_core.ClosedJaxpr))]
            if sub:
                for s in sub:  # unknown higher-order: collect, no values
                    j, c = _closed(s)
                    _eval_jaxpr(j, c, [UNKNOWN] * len(j.invars), calls,
                                path + "/" + name)
            elif known and all(_aval_small(v.aval) for v in eqn.outvars):
                try:
                    res = p.bind(*vals, **eqn.params)
                    outs = list(res) if p.multiple_results else [res]
                except Exception:  # noqa: BLE001 — resolution is optional
                    outs = [UNKNOWN] * len(eqn.outvars)
        write(eqn.outvars, outs)

    return [read(v) for v in jaxpr.outvars]


# ------------------------------------------------------- grid evaluation

@dataclasses.dataclass
class OperandGrid:
    """Per-grid-step block indices for one blocked operand."""
    role: str                       # "in" | "out"
    idx: int                        # index within role ordering
    block_shape: Tuple[int, ...]
    array_shape: Tuple[int, ...]
    block_bytes: int
    indices: Any                    # (steps, ndim) int ndarray
    scalar_dependent: bool

    @property
    def label(self) -> str:
        return f"{self.role}[{self.idx}]"

    def nblocks(self) -> Tuple[int, ...]:
        return tuple(-(-d // b) for d, b in
                     zip(self.array_shape, self.block_shape))


@dataclasses.dataclass
class GridEval:
    """Fully-enumerated grid semantics of one ``pallas_call``."""
    kernel: str
    grid: Tuple[int, ...]
    steps: int
    inputs: List[OperandGrid]
    outputs: List[OperandGrid]
    aliases: List[Tuple[int, int]]   # (input idx, output idx), bm-relative


def _block_dims(block_shape) -> Tuple[int, ...]:
    return tuple(int(d) if isinstance(d, int) else 1 for d in block_shape)


def _scalar_dependent(index_map_jaxpr, n_grid: int) -> bool:
    """Does the (undischarged) index-map jaxpr's output depend on its
    scalar-prefetch ref arguments?"""
    jx = index_map_jaxpr.jaxpr
    marked = set(jx.invars[n_grid:])
    if not marked:
        return False
    for eqn in jx.eqns:
        if any(v in marked for v in eqn.invars
               if not hasattr(v, "val")):
            marked.update(eqn.outvars)
    return any(v in marked for v in jx.outvars if not hasattr(v, "val"))


def eval_pallas_eqn(eqn, invals: Sequence[Any]):
    """Evaluate every BlockSpec index map of one ``pallas_call`` equation
    over its full (static) grid.  Returns a :class:`GridEval`, or an
    error string when the grid/scalars cannot be resolved statically."""
    import jax
    import numpy as np
    from jax import core as jax_core
    from jax._src import state
    try:
        from jax._src.state import discharge as state_discharge
    except ImportError:  # pragma: no cover - layout varies across versions
        state_discharge = state.discharge  # type: ignore[attr-defined]

    gm = eqn.params["grid_mapping"]
    name_info = eqn.params.get("name_and_src_info")
    kernel = getattr(name_info, "name", None) or "pallas_call"
    try:
        grid = tuple(int(g) for g in gm.grid)
    except (TypeError, ValueError):
        return f"{kernel}: dynamic grid {gm.grid!r} — cannot enumerate"
    if getattr(gm, "num_dynamic_grid_bounds", 0):
        return f"{kernel}: dynamic grid bounds — cannot enumerate"

    n_idx = gm.num_index_operands
    scalars = list(invals[:n_idx])
    if any(isinstance(s, _Unknown) for s in scalars):
        return (f"{kernel}: {sum(isinstance(s, _Unknown) for s in scalars)}"
                f"/{n_idx} scalar-prefetch operand(s) unresolved — index "
                "maps cannot be evaluated")
    scalars = [np.asarray(s) for s in scalars]

    naxes = len(grid)
    steps = int(math.prod(grid)) if grid else 1
    if grid:
        mesh = np.meshgrid(*[np.arange(g, dtype=np.int32) for g in grid],
                           indexing="ij")
        grid_idx = np.stack(mesh, axis=-1).reshape(steps, naxes)
    else:
        grid_idx = np.zeros((1, 0), np.int32)

    inputs: List[OperandGrid] = []
    outputs: List[OperandGrid] = []
    for bi, bm in enumerate(gm.block_mappings):
        is_out = bi >= gm.num_inputs
        cj = bm.index_map_jaxpr
        dis_jaxpr, dis_consts = state_discharge.discharge_state(
            cj.jaxpr, cj.consts)
        fn = jax_core.jaxpr_as_fun(
            jax_core.ClosedJaxpr(dis_jaxpr, dis_consts))
        n_ref = len(cj.jaxpr.invars) - naxes
        ref_args = tuple(scalars[:n_ref])
        axes = (0,) * naxes + (None,) * n_ref
        vm = jax.vmap(fn, in_axes=axes if (naxes + n_ref) else None)
        call_args = tuple(grid_idx[:, i] for i in range(naxes)) + ref_args
        outs = vm(*call_args) if call_args else fn()
        bdims = _block_dims(bm.block_shape)
        nd = len(bdims)
        idx = np.stack([np.broadcast_to(np.asarray(o), (steps,))
                        for o in outs[:nd]], axis=-1).astype(np.int64)
        arr = bm.array_shape_dtype
        og = OperandGrid(
            role="out" if is_out else "in",
            idx=(bi - gm.num_inputs) if is_out else bi,
            block_shape=bdims,
            array_shape=tuple(int(d) for d in arr.shape),
            block_bytes=(math.prod(bdims)
                         * np.dtype(arr.dtype).itemsize),
            indices=idx,
            scalar_dependent=_scalar_dependent(cj, naxes))
        (outputs if is_out else inputs).append(og)

    aliases: List[Tuple[int, int]] = []
    for op_idx, out_idx in tuple(eqn.params.get("input_output_aliases",
                                                ()) or ()):
        aliases.append((int(op_idx) - n_idx, int(out_idx)))

    return GridEval(kernel=kernel, grid=grid, steps=steps, inputs=inputs,
                    outputs=outputs, aliases=aliases)


def _runs(indices) -> List[Tuple[Tuple[int, ...], int, int]]:
    """Run-length compress per-step block tuples: maximal runs of equal
    consecutive indices, as ``(block, first_step, last_step)`` — the
    granularity Mosaic's pipeline fetches/writes blocks at (consecutive
    equal indices elide the refetch/write-back)."""
    out: List[Tuple[Tuple[int, ...], int, int]] = []
    prev: Optional[Tuple[int, ...]] = None
    start = 0
    for s in range(indices.shape[0]):
        cur = tuple(int(x) for x in indices[s])
        if cur != prev:
            if prev is not None:
                out.append((prev, start, s - 1))
            prev, start = cur, s
    if prev is not None:
        out.append((prev, start, indices.shape[0] - 1))
    return out


def _is_sentinel(og: OperandGrid, block: Tuple[int, ...],
                 aliased: bool) -> bool:
    """The one legal parked target: scalar-fed aliased operands may map
    skipped steps onto the LAST axis-0 block — the reserved sentinel row
    of the paged pool (``serve/paged.device_pool_rows``), which the
    allocator never hands out and no table references."""
    return (aliased and og.scalar_dependent
            and block[0] == og.nblocks()[0] - 1)


def check_grid(ge: GridEval) -> List[Dict[str, Any]]:
    """Race/aliasing/bounds issues for one evaluated grid; one aggregated
    issue dict per (kind, operand)."""
    issues: List[Dict[str, Any]] = []
    aliased_out = {o for _, o in ge.aliases}
    aliased_in = {i for i, _ in ge.aliases}

    # (c/d) every computed block index in-bounds — OOB is silently
    # clamped at runtime, which turns table bugs into wrong-block I/O
    for og in ge.inputs + ge.outputs:
        nblk = og.nblocks()
        bad = [(s, tuple(int(x) for x in og.indices[s]))
               for s in range(ge.steps)
               if any(x < 0 or x >= n
                      for x, n in zip(og.indices[s], nblk))]
        if bad:
            issues.append({
                "kind": "oob", "operand": og.label, "kernel": ge.kernel,
                "count": len(bad), "nblocks": list(nblk),
                "first": {"step": bad[0][0], "block": list(bad[0][1])}})

    # (a) non-aliased outputs: a block revisited in >1 run is written
    # back through a stale VMEM copy (WAW) under Mosaic pipelining
    for oi, og in enumerate(ge.outputs):
        if oi in aliased_out:
            continue
        runs = _runs(og.indices)
        seen: Dict[Tuple[int, ...], int] = {}
        racy: List[Tuple[int, ...]] = []
        for block, _, _ in runs:
            seen[block] = seen.get(block, 0) + 1
        racy = [b for b, n in seen.items() if n > 1
                and not _is_sentinel(og, b, aliased=False)]
        if racy:
            issues.append({
                "kind": "out-revisit", "operand": og.label,
                "kernel": ge.kernel, "blocks": [list(b) for b in racy[:8]],
                "count": len(racy)})

    # (b) aliased pairs: the input side re-fetches at every run start; a
    # fetch of a block an EARLIER run already wrote races the in-flight
    # aliased write-back (RAW)
    for ii, oi in ge.aliases:
        if ii >= len(ge.inputs) or oi >= len(ge.outputs):
            continue
        og_in, og_out = ge.inputs[ii], ge.outputs[oi]
        write_end: Dict[Tuple[int, ...], int] = {}
        for block, _, last in _runs(og_out.indices):
            if block not in write_end:
                write_end[block] = last
        racy = []
        parked = 0
        for block, first, _ in _runs(og_in.indices):
            if _is_sentinel(og_in, block, aliased=True):
                parked += 1
                continue
            if block in write_end and write_end[block] < first:
                racy.append(block)
        if racy:
            issues.append({
                "kind": "aliased-raw",
                "operand": f"{og_in.label}->{og_out.label}",
                "kernel": ge.kernel,
                "blocks": [list(b) for b in racy[:8]], "count": len(racy)})
        elif parked:
            issues.append({
                "kind": "sentinel-parked", "info": True,
                "operand": og_in.label, "kernel": ge.kernel,
                "count": parked})
    return issues


# ---------------------------------------------------------------- rules

def _check_calls(obj: str, calls: List[ResolvedCall],
                 findings: List[Finding]) -> int:
    """Evaluate+check every collected call; append error findings.
    Returns the number of calls successfully enumerated."""
    ok = 0
    for call in calls:
        ge = eval_pallas_eqn(call.eqn, call.invals)
        if isinstance(ge, str):
            findings.append(Finding(
                rule="races", severity="error", obj=obj,
                message=f"{obj}: {ge} (at {call.path or '<top>'})"))
            continue
        ok += 1
        for issue in check_grid(ge):
            if issue.get("info"):
                continue
            findings.append(Finding(
                rule="races", severity="error", obj=obj,
                message=(f"{obj}: kernel {ge.kernel} grid {ge.grid} "
                         f"{issue['kind']} on {issue['operand']} "
                         f"({issue['count']} block(s)/step(s))"),
                data=issue))
    return ok


@rule("races.kernel-zoo", family="races")
def rule_races_kernel_zoo(ctx: Context) -> List[Finding]:
    """Every kernel-zoo entry point, at concrete non-degenerate geometry:
    enumerate each pallas_call's grid, evaluate all index maps, check
    bounds / output-revisit contiguity / aliased RAW.  Coverage is pinned
    against ``kernel_zoo_entries`` — a kernel in the vmem zoo without a
    grid-zoo twin is an error, and an entry tracing zero pallas_calls is
    an error (a silent fallback would fake a green run)."""
    from repro.analysis.vmem import grid_zoo_entries, kernel_zoo_entries
    from repro.configs.base import get_smoke_config

    cfg = get_smoke_config(ctx.arch)
    entries = grid_zoo_entries(cfg)
    required = {name for name, _ in kernel_zoo_entries(cfg)}
    findings: List[Finding] = []
    coverage: Dict[str, int] = {}
    for e in entries:
        fname = f"races.kernel-zoo:{e.name}"
        calls = trace_and_collect(e.fn, *e.args)
        if not calls:
            findings.append(Finding(
                rule="races.kernel-zoo", severity="error", obj=e.name,
                message=f"{e.name}: traced ZERO pallas_calls — the "
                "dispatch silently fell back"))
            continue
        errs: List[Finding] = []
        _check_calls(e.name, calls, errs)
        for f in errs:
            f.rule = "races.kernel-zoo"
        findings.extend(errs)
        coverage[e.name] = len(calls)
    for missing in sorted(required - {e.name for e in entries}):
        findings.append(Finding(
            rule="races.kernel-zoo", severity="error", obj=missing,
            message=f"{missing} is in kernel_zoo_entries but has no "
            "grid_zoo_entries twin — grid semantics unchecked"))
    errors = any(f.severity == "error" for f in findings)
    findings.append(Finding(
        rule="races.kernel-zoo",
        severity="info", obj="kernel-zoo",
        message=(f"enumerated {sum(coverage.values())} pallas_call(s) "
                 f"across {len(coverage)} zoo entries"
                 + ("" if not errors else " (with errors)")),
        data={"coverage": coverage, "required": sorted(required)}))
    return findings


@rule("races.step-buckets", family="races")
def rule_races_step_buckets(ctx: Context) -> List[Finding]:
    """Every ``STEP_BUCKETS`` step program: const-propagate the fixture's
    real block tables / positions through the traced program and check
    every pallas_call's grid semantics.  Buckets must enumerate ≥ 1
    pallas_call (kernels-on programs with none mean the dispatch fell
    back) and no kernel may be skipped as unresolvable."""
    from repro.analysis.jaxpr_rules import _step_fixture

    eng, _, args = _step_fixture(ctx)
    findings: List[Finding] = []
    coverage: Dict[str, int] = {}
    for bucket, name, step in eng.exec.step_programs():
        calls = trace_and_collect(step, *args)
        if not calls:
            findings.append(Finding(
                rule="races.step-buckets", severity="error", obj=name,
                message=f"{name}: traced ZERO pallas_calls — kernels-on "
                "step program fell back to the oracle"))
            continue
        errs: List[Finding] = []
        _check_calls(name, calls, errs)
        for f in errs:
            f.rule = "races.step-buckets"
        findings.extend(errs)
        coverage[name] = len(calls)
    errors = any(f.severity == "error" for f in findings)
    findings.append(Finding(
        rule="races.step-buckets", severity="info", obj="executor",
        message=(f"enumerated {sum(coverage.values())} pallas_call(s) "
                 f"across {len(coverage)} step buckets"
                 + ("" if not errors else " (with errors)")),
        data={"coverage": coverage}))
    return findings


@rule("races.extra-entries", family="races")
def rule_races_extra(ctx: Context) -> List[Finding]:
    """Fixture hook: ``--grid-extra`` module's ``GRID_ENTRIES`` (name,
    fn, args) triples get the same enumerate+check treatment — the
    analyzer's own tests seed known-racy grids here."""
    if not ctx.grid_extra:
        return [Finding(rule="races.extra-entries", severity="info",
                        obj="fixtures", message="no extra grid entries")]
    mod = ctx.load_extra(ctx.grid_extra)
    findings: List[Finding] = []
    for name, fn, fargs in mod.GRID_ENTRIES:
        errs: List[Finding] = []
        _check_calls(name, trace_and_collect(fn, *fargs), errs)
        for f in errs:
            f.rule = "races.extra-entries"
        findings.extend(errs)
    if not findings:
        findings.append(Finding(
            rule="races.extra-entries", severity="info", obj="fixtures",
            message=f"{len(mod.GRID_ENTRIES)} extra entries clean"))
    return findings

"""Quantization/softmax numerics lints over Pallas kernel bodies.

Interpret-mode CPU tests run the kernels through XLA, which hides a
class of numerics bugs that only bite on real hardware or at real model
scale: an int8×int8 ``dot_general`` without ``preferred_element_type``
accumulates in int8 on the MXU (wraps at ±127 — CPU interpret happily
widens), a quant-scale divide by an unguarded computed amax produces
inf/NaN exactly when a block is all zeros, and an online-softmax body
that reinvents the running-max update with literal ``-inf`` produces
NaN (``-inf - -inf``) for fully-masked rows.  These are properties of
the kernel JAXPR, so they are lintable statically.

Lints (each aggregated to at most one finding per kernel):

  ``int8-accum``     every dot_general whose operands are both int8 must
                     set ``preferred_element_type`` to int32/float32.
  ``div-guard``      float divides whose divisor is COMPUTED inside the
                     body (not a ref load / input) must have a
                     ``max``/``clamp`` in the divisor's def-chain —
                     ``jnp.maximum(amax, eps)`` style.  Ref-load
                     divisors are exempt: ``x / smooth`` is the
                     SmoothQuant input contract.
  ``softmax-guard``  bodies containing ``exp`` must carry the shared
                     online-softmax guard shape (a running ``max``
                     reduction and a ``select``/``where`` rescue) and no
                     ``±inf`` literals — the shared helpers use a finite
                     ``_NEG`` sentinel for exactly this reason.
  ``f64``            no float64 anywhere in a kernel body (TPU has no
                     f64; interpret mode silently does).
  ``cast-roundtrip`` no lossy dtype round-trip ``a → b → a`` with
                     ``b`` narrower than ``a`` (precision silently
                     dropped and re-widened).
"""
from __future__ import annotations

from typing import Any, Dict, List, Set, Tuple

from repro.analysis import Context, Finding, rule

__all__ = ["lint_kernel_body"]

_ACCUM_OK = ("int32", "float32")
_GUARD_PRIMS = {"max", "clamp"}
_LOAD_PRIMS = {"get", "masked_load", "load", "swap", "masked_swap"}
# pure data movement: a value that is just a moved ref-load stays exempt
_MOVE_PRIMS = {"broadcast_in_dim", "reshape", "squeeze", "slice",
               "dynamic_slice", "transpose", "convert_element_type",
               "expand_dims"}


def _all_eqns(jaxpr) -> List[Any]:
    """Flatten a kernel jaxpr including sub-jaxprs (``pl.when`` lowers
    to ``cond``; loops carry bodies in params)."""
    from jax import core as jax_core

    out = []
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            out.append(eqn)
            for v in eqn.params.values():
                vs = v if isinstance(v, (tuple, list)) else (v,)
                for s in vs:
                    if isinstance(s, jax_core.ClosedJaxpr):
                        stack.append(s.jaxpr)
                    elif isinstance(s, jax_core.Jaxpr):
                        stack.append(s)
    return out


def _def_chain_has(var, defs: Dict[Any, Any], prims: Set[str],
                   stop: Set[str]) -> bool:
    """BFS the def-chain of ``var``: True iff some defining primitive is
    in ``prims`` before hitting one in ``stop``."""
    seen: Set[int] = set()
    frontier = [var]
    while frontier:
        v = frontier.pop()
        if hasattr(v, "val") or id(v) in seen:
            continue
        seen.add(id(v))
        eqn = defs.get(v)
        if eqn is None:
            continue
        if eqn.primitive.name in prims:
            return True
        if eqn.primitive.name in stop:
            continue
        frontier.extend(eqn.invars)
    return False


def _is_loaded(var, defs: Dict[Any, Any]) -> bool:
    """Is ``var`` a ref load / kernel input (possibly through pure data
    movement)?  Such values are inputs by contract, not computed."""
    v = var
    while True:
        if hasattr(v, "val"):
            return False
        eqn = defs.get(v)
        if eqn is None:
            return True                      # invar / constvar
        nm = eqn.primitive.name
        if nm in _LOAD_PRIMS:
            return True
        if nm in _MOVE_PRIMS:
            v = eqn.invars[0]
            continue
        return False


def lint_kernel_body(name: str, jaxpr) -> List[Dict[str, Any]]:
    """All numerics lint hits for one kernel-body jaxpr, aggregated to
    one issue dict per lint kind."""
    import numpy as np

    eqns = _all_eqns(jaxpr)
    defs: Dict[Any, Any] = {}
    for eqn in eqns:
        for ov in eqn.outvars:
            defs[ov] = eqn

    hits: Dict[str, Dict[str, Any]] = {}

    def hit(kind: str, detail: str):
        h = hits.setdefault(kind, {"kind": kind, "kernel": name,
                                   "count": 0, "detail": detail})
        h["count"] += 1

    has_exp = False
    has_reduce_max = False
    has_select = False
    inf_literals = 0

    for eqn in eqns:
        nm = eqn.primitive.name
        if nm in ("exp", "exp2"):
            has_exp = True
        if nm in ("reduce_max", "cummax", "argmax"):
            has_reduce_max = True
        if nm in ("select_n", "select"):
            has_select = True
        for iv in eqn.invars:
            if hasattr(iv, "val"):
                val = np.asarray(iv.val)
                if val.dtype.kind == "f" and val.size and np.isinf(val).any():
                    inf_literals += 1

        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and str(getattr(aval, "dtype", "")
                                        ) == "float64":
                hit("f64", f"{nm} touches float64")
                break

        if nm == "dot_general":
            lhs, rhs = eqn.invars[0].aval.dtype, eqn.invars[1].aval.dtype
            if str(lhs) == "int8" and str(rhs) == "int8":
                pet = eqn.params.get("preferred_element_type")
                if pet is None or str(np.dtype(pet)) not in _ACCUM_OK:
                    hit("int8-accum",
                        f"int8xint8 dot_general accumulates in "
                        f"{pet or 'int8 (default)'} — must set "
                        "preferred_element_type to int32/float32")

        if nm == "div" and eqn.invars[0].aval.dtype.kind == "f":
            divisor = eqn.invars[1]
            if hasattr(divisor, "val"):
                val = np.asarray(divisor.val)
                if (val == 0).any():
                    hit("div-guard", "literal zero divisor")
            elif not _is_loaded(divisor, defs):
                if not _def_chain_has(divisor, defs, _GUARD_PRIMS,
                                      _LOAD_PRIMS):
                    hit("div-guard",
                        "computed divisor has no max/clamp guard in its "
                        "def-chain — divide-by-zero on all-zero blocks")

        if nm == "convert_element_type":
            inner = defs.get(eqn.invars[0])
            if inner is not None and \
                    inner.primitive.name == "convert_element_type":
                src = inner.invars[0].aval.dtype
                mid = inner.outvars[0].aval.dtype
                dst = eqn.outvars[0].aval.dtype
                if (str(src) == str(dst) and str(mid) != str(src)
                        and np.dtype(mid).itemsize
                        < np.dtype(src).itemsize):
                    hit("cast-roundtrip",
                        f"{src}->{mid}->{dst} round-trip silently drops "
                        "precision")

    if has_exp:
        if not (has_reduce_max and has_select):
            hit("softmax-guard",
                "body computes exp without the shared online-softmax "
                "guard shape (running max reduction + select rescue)")
        if inf_literals:
            hit("softmax-guard",
                f"{inf_literals} ±inf literal(s) in an exp-carrying body "
                "— use the finite _NEG sentinel (softmax helpers) so "
                "fully-masked rows don't produce -inf - -inf = NaN")

    return list(hits.values())


def _lint_traced(name: str, fn, args) -> Tuple[int, List[Dict[str, Any]]]:
    """(bodies linted, issues) over every pallas_call in a trace."""
    from repro.analysis.grid_eval import trace_and_collect

    issues: List[Dict[str, Any]] = []
    calls = trace_and_collect(fn, *args)
    for call in calls:
        body = call.eqn.params["jaxpr"]
        kernel = getattr(call.eqn.params.get("name_and_src_info"),
                         "name", None) or name
        issues.extend(lint_kernel_body(f"{name}:{kernel}", body.jaxpr
                      if hasattr(body, "jaxpr") else body))
    return len(calls), issues


def _issues_to_findings(rule_name: str, obj: str,
                        issues: List[Dict[str, Any]]) -> List[Finding]:
    return [Finding(
        rule=rule_name, severity="error", obj=obj,
        message=(f"{issue['kernel']}: [{issue['kind']}] "
                 f"{issue['detail']} (x{issue['count']})"),
        data=issue) for issue in issues]


@rule("numerics.kernel-zoo", family="numerics")
def rule_numerics_kernel_zoo(ctx: Context) -> List[Finding]:
    """Every kernel-zoo entry's pallas bodies pass the numerics lints;
    an entry with zero linted bodies is an error (silent fallback)."""
    from repro.analysis.vmem import grid_zoo_entries
    from repro.configs.base import get_smoke_config

    cfg = get_smoke_config(ctx.arch)
    findings: List[Finding] = []
    linted = 0
    for e in grid_zoo_entries(cfg):
        n, issues = _lint_traced(e.name, e.fn, e.args)
        if n == 0:
            findings.append(Finding(
                rule="numerics.kernel-zoo", severity="error", obj=e.name,
                message=f"{e.name}: zero pallas bodies to lint — the "
                "dispatch silently fell back"))
        linted += n
        findings.extend(_issues_to_findings("numerics.kernel-zoo",
                                            e.name, issues))
    findings.append(Finding(
        rule="numerics.kernel-zoo", severity="info", obj="kernel-zoo",
        message=f"linted {linted} kernel bodies"))
    return findings


@rule("numerics.extra-entries", family="numerics")
def rule_numerics_extra(ctx: Context) -> List[Finding]:
    """Fixture hook: ``--numerics-extra`` module's ``NUMERICS_ENTRIES``
    ``(name, fn, args)`` bodies get the same lints."""
    if not ctx.numerics_extra:
        return [Finding(rule="numerics.extra-entries", severity="info",
                        obj="fixtures", message="no extra bodies")]
    mod = ctx.load_extra(ctx.numerics_extra)
    findings: List[Finding] = []
    for name, fn, args in mod.NUMERICS_ENTRIES:
        _, issues = _lint_traced(name, fn, args)
        findings.extend(_issues_to_findings("numerics.extra-entries",
                                            name, issues))
    if not findings:
        findings.append(Finding(
            rule="numerics.extra-entries", severity="info", obj="fixtures",
            message=f"{len(mod.NUMERICS_ENTRIES)} extra bodies clean"))
    return findings

"""CLI for the static contract checker::

    PYTHONPATH=src python -m repro.analysis \
        [--rules jaxpr,vmem,purity,retrace] [--json-out analysis.json]

Exit status 1 iff any ``error`` finding was produced (rules that cannot
run here emit ``skip`` findings, which are reported but do not fail —
a green run that silently checked nothing is its own bug class).
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import (DEFAULT_SMEM_BUDGET_BYTES,
                            DEFAULT_VMEM_BUDGET_BYTES, RULE_FAMILIES,
                            Context, findings_to_json, load_rules,
                            run_rules)

_SEV_ORDER = {"error": 0, "warning": 1, "skip": 2, "info": 3}


def _parse_args(argv):
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static jaxpr/Pallas contract checker (no TPU needed)")
    ap.add_argument("--rules", default=",".join(RULE_FAMILIES),
                    help="comma-separated rule families (default: all of "
                         f"{','.join(RULE_FAMILIES)}) and/or full rule "
                         "names like vmem.budget")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="write the structured findings document here")
    ap.add_argument("--list", action="store_true",
                    help="list the selected rules and exit")
    ap.add_argument("--arch", default="llama31_8b",
                    help="smoke arch for engine-shaped rules")
    ap.add_argument("--configs", default=None,
                    help="comma-separated config ids for the vmem sweep "
                         "(default: the full shipped zoo)")
    ap.add_argument("--vmem-budget-mib", type=float,
                    default=DEFAULT_VMEM_BUDGET_BYTES / 2**20,
                    help="per-core VMEM budget in MiB (default 16)")
    ap.add_argument("--smem-budget-kib", type=float,
                    default=DEFAULT_SMEM_BUDGET_BYTES / 2**10,
                    help="per-core SMEM budget in KiB (default 256)")
    ap.add_argument("--vmem-table", action="store_true",
                    help="print the per-kernel worst-case footprint table "
                         "(the source of the kernels/__init__.py doc "
                         "table) and exit")
    # fixture hooks — the analyzer's own tests point these at known-bad
    # inputs and assert each rule fires
    ap.add_argument("--vmem-extra", default=None, metavar="PY",
                    help="extra module with TRACE_ENTRIES for the vmem "
                         "sweep")
    ap.add_argument("--jaxpr-extra", default=None, metavar="PY",
                    help="extra module with JAXPR_ENTRIES for the "
                         "pool-containment pin")
    ap.add_argument("--purity-root", default=None, metavar="DIR",
                    help="source root for the purity pass (default: the "
                         "installed repro tree)")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse_args(argv)
    selected = [tok.strip() for tok in args.rules.split(",") if tok.strip()]
    families = [t for t in selected if "." not in t]
    names = [t for t in selected if "." in t]
    for fam in families:
        if fam not in RULE_FAMILIES:
            print(f"error: unknown rule family {fam!r} "
                  f"(families: {', '.join(RULE_FAMILIES)})",
                  file=sys.stderr)
            return 2
    if names and not families:
        # full rule names imply their families
        families = sorted({n.split(".", 1)[0] for n in names})

    if args.list:
        for name, r in sorted(load_rules(families).items()):
            print(f"{name:28s} {r.doc.splitlines()[0] if r.doc else ''}")
        return 0

    ctx = Context(
        arch=args.arch,
        configs=tuple(args.configs.split(",")) if args.configs else (),
        vmem_budget_bytes=int(args.vmem_budget_mib * 2**20),
        smem_budget_bytes=int(args.smem_budget_kib * 2**10),
        vmem_extra=args.vmem_extra,
        jaxpr_extra=args.jaxpr_extra,
        purity_root=args.purity_root,
    )

    if args.vmem_table:
        from repro.analysis.vmem import footprint_table
        rows = footprint_table(ctx.config_zoo())
        w = max(len(r["entry"]) for r in rows)
        for r in rows:
            grid = "x".join(str(g) for g in r["grid"])
            print(f"{r['entry']:{w}s}  {r['vmem_bytes'] / 2**20:7.2f} MiB"
                  f"  smem {r['smem_bytes']:6d} B"
                  f"  worst: {r['config']} grid=({grid})")
        return 0

    findings = run_rules(ctx, families=families, names=names or None)
    findings.sort(key=lambda f: (_SEV_ORDER.get(f.severity, 9), f.rule))
    for f in findings:
        print(f"[{f.severity.upper():5s}] {f.rule}: {f.obj} — {f.message}")
    n_err = sum(1 for f in findings if f.severity == "error")
    n_skip = sum(1 for f in findings if f.severity == "skip")
    print(f"\n{len(findings)} finding(s): {n_err} error(s), "
          f"{n_skip} skipped rule(s)")

    if args.json_out:
        doc = findings_to_json(findings, rules=args.rules)
        with open(args.json_out, "w", encoding="utf-8") as fh:
            fh.write(doc + "\n")
        print(f"wrote {args.json_out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())

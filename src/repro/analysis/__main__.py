"""CLI for the static contract checker::

    PYTHONPATH=src python -m repro.analysis \
        [--rules jaxpr,vmem,races,hbm,...] [--severity error] \
        [--baseline analysis_baseline.json] [--json-out analysis.json]

Exit status 1 iff any ``error`` finding was produced (rules that cannot
run here emit ``skip`` findings, which are reported but do not fail —
a green run that silently checked nothing is its own bug class).

``--rules`` accepts families, full rule names, and ``fnmatch`` globs
over either (``races.*``, ``*zoo*``).  ``--severity`` filters the
REPORT (errors still fail even when filtered out of the listing).
``--baseline`` demotes known error findings — matched by
``(rule, obj)`` — to warnings, so a pre-existing defect can be tracked
without masking new ones.
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import sys

from repro.analysis import (DEFAULT_SMEM_BUDGET_BYTES,
                            DEFAULT_VMEM_BUDGET_BYTES, RULE_FAMILIES,
                            Context, findings_to_json, load_rules,
                            run_rules)

_SEV_ORDER = {"error": 0, "warning": 1, "skip": 2, "info": 3}


def _parse_args(argv):
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static jaxpr/Pallas contract checker (no TPU needed)")
    ap.add_argument("--rules", default=",".join(RULE_FAMILIES),
                    help="comma-separated rule families (default: all of "
                         f"{','.join(RULE_FAMILIES)}), full rule names "
                         "like vmem.budget, or fnmatch globs over either "
                         "(races.*, *zoo*)")
    ap.add_argument("--severity", default=None, metavar="LEVEL",
                    choices=sorted(_SEV_ORDER, key=_SEV_ORDER.get),
                    help="only report findings at or above this severity "
                         "(error > warning > skip > info); the exit code "
                         "still reflects ALL errors")
    ap.add_argument("--baseline", default=None, metavar="JSON",
                    help="baseline file: error findings matching its "
                         "(rule, obj) entries are demoted to warnings "
                         "(tracked, not failing)")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="write the structured findings document here")
    ap.add_argument("--list", action="store_true",
                    help="list the selected rules and exit")
    ap.add_argument("--arch", default="llama31_8b",
                    help="smoke arch for engine-shaped rules")
    ap.add_argument("--configs", default=None,
                    help="comma-separated config ids for the vmem sweep "
                         "(default: the full shipped zoo)")
    ap.add_argument("--vmem-budget-mib", type=float,
                    default=DEFAULT_VMEM_BUDGET_BYTES / 2**20,
                    help="per-core VMEM budget in MiB (default 16)")
    ap.add_argument("--smem-budget-kib", type=float,
                    default=DEFAULT_SMEM_BUDGET_BYTES / 2**10,
                    help="per-core SMEM budget in KiB (default 256)")
    ap.add_argument("--vmem-table", action="store_true",
                    help="print the per-kernel worst-case footprint table "
                         "(the source of the kernels/__init__.py doc "
                         "table) and exit")
    ap.add_argument("--hbm-table", action="store_true",
                    help="print the generated COST_MODEL doc table (the "
                         "kernels/__init__.py HBM section) and exit")
    # fixture hooks — the analyzer's own tests point these at known-bad
    # inputs and assert each rule fires
    ap.add_argument("--vmem-extra", default=None, metavar="PY",
                    help="extra module with TRACE_ENTRIES for the vmem "
                         "sweep")
    ap.add_argument("--jaxpr-extra", default=None, metavar="PY",
                    help="extra module with JAXPR_ENTRIES for the "
                         "pool-containment pin")
    ap.add_argument("--grid-extra", default=None, metavar="PY",
                    help="extra module with GRID_ENTRIES for the races "
                         "grid checks")
    ap.add_argument("--numerics-extra", default=None, metavar="PY",
                    help="extra module with NUMERICS_ENTRIES for the "
                         "kernel-body lints")
    ap.add_argument("--hbm-extra", default=None, metavar="PY",
                    help="extra module with COST_ENTRIES for the HBM "
                         "cost-model check")
    ap.add_argument("--purity-root", default=None, metavar="DIR",
                    help="source root for the purity pass (default: the "
                         "installed repro tree)")
    return ap.parse_args(argv)


def _select_rules(tokens):
    """Resolve ``--rules`` tokens (families, rule names, globs) to
    (families-to-load, rule-name-subset-or-None).  Unknown non-glob
    tokens raise ValueError; a glob matching nothing does too (a typo'd
    glob must not silently select zero checks)."""
    fam_tokens = [t for t in tokens if "." not in t]
    name_tokens = [t for t in tokens if "." in t]
    globby = [t for t in fam_tokens if any(c in t for c in "*?[")]
    exact_fams = [t for t in fam_tokens if t not in globby]
    for fam in exact_fams:
        if fam not in RULE_FAMILIES:
            raise ValueError(
                f"unknown rule family {fam!r} "
                f"(families: {', '.join(RULE_FAMILIES)})")
    families = set(exact_fams)
    for g in globby:
        got = fnmatch.filter(RULE_FAMILIES, g)
        if not got:
            raise ValueError(f"family glob {g!r} matches nothing")
        families.update(got)

    if not name_tokens:
        return sorted(families) or None, None

    # full rule names / globs: load their families, then filter names
    fams_for_names = sorted({t.split(".", 1)[0].rstrip("*?[")
                             for t in name_tokens})
    load = sorted(families | {f for f in RULE_FAMILIES
                              if any(f.startswith(p) for p in
                                     fams_for_names)}) or None
    all_rules = load_rules(load)
    names = set()
    for t in name_tokens:
        if any(c in t for c in "*?["):
            got = fnmatch.filter(all_rules, t)
            if not got:
                raise ValueError(f"rule glob {t!r} matches nothing")
            names.update(got)
        else:
            if t not in all_rules:
                raise ValueError(f"unknown rule {t!r}")
            names.add(t)
    # families selected alongside explicit names contribute all their rules
    names.update(n for n, r in all_rules.items() if r.family in families)
    return load, sorted(names)


def _apply_baseline(findings, path: str) -> int:
    """Demote error findings matching the baseline's (rule, obj) pairs
    to warnings; returns how many were demoted.  The baseline document
    is ``{"suppressions": [{"rule": ..., "obj": ..., "reason": ...}]}``."""
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    pairs = {(s["rule"], s["obj"]): s.get("reason", "")
             for s in doc.get("suppressions", [])}
    demoted = 0
    for f in findings:
        if f.severity == "error" and (f.rule, f.obj) in pairs:
            f.severity = "warning"
            f.data = dict(f.data, baselined=True,
                          baseline_reason=pairs[(f.rule, f.obj)])
            demoted += 1
    return demoted


def main(argv=None) -> int:
    args = _parse_args(argv)
    tokens = [tok.strip() for tok in args.rules.split(",") if tok.strip()]
    try:
        families, names = _select_rules(tokens)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2

    if args.list:
        rules = load_rules(families)
        if names:
            rules = {n: r for n, r in rules.items() if n in names}
        for name, r in sorted(rules.items()):
            print(f"{name:28s} {r.doc.splitlines()[0] if r.doc else ''}")
        return 0

    if args.hbm_table:
        from repro.kernels import cost_model_doc
        print(cost_model_doc())
        return 0

    ctx = Context(
        arch=args.arch,
        configs=tuple(args.configs.split(",")) if args.configs else (),
        vmem_budget_bytes=int(args.vmem_budget_mib * 2**20),
        smem_budget_bytes=int(args.smem_budget_kib * 2**10),
        vmem_extra=args.vmem_extra,
        jaxpr_extra=args.jaxpr_extra,
        purity_root=args.purity_root,
        grid_extra=args.grid_extra,
        numerics_extra=args.numerics_extra,
        hbm_extra=args.hbm_extra,
    )

    if args.vmem_table:
        from repro.analysis.vmem import footprint_table
        rows = footprint_table(ctx.config_zoo())
        w = max(len(r["entry"]) for r in rows)
        for r in rows:
            grid = "x".join(str(g) for g in r["grid"])
            print(f"{r['entry']:{w}s}  {r['vmem_bytes'] / 2**20:7.2f} MiB"
                  f"  smem {r['smem_bytes']:6d} B"
                  f"  worst: {r['config']} grid=({grid})")
        return 0

    findings = run_rules(ctx, families=families, names=names or None)
    demoted = 0
    if args.baseline:
        try:
            demoted = _apply_baseline(findings, args.baseline)
        except (OSError, json.JSONDecodeError, KeyError) as exc:
            print(f"error: bad baseline {args.baseline}: {exc}",
                  file=sys.stderr)
            return 2
    findings.sort(key=lambda f: (_SEV_ORDER.get(f.severity, 9), f.rule))

    threshold = _SEV_ORDER[args.severity] if args.severity else None
    shown = 0
    for f in findings:
        if threshold is not None and \
                _SEV_ORDER.get(f.severity, 9) > threshold:
            continue
        shown += 1
        print(f"[{f.severity.upper():5s}] {f.rule}: {f.obj} — {f.message}")
    n_err = sum(1 for f in findings if f.severity == "error")
    n_skip = sum(1 for f in findings if f.severity == "skip")
    hidden = len(findings) - shown
    tail = f" ({hidden} below --severity {args.severity})" if hidden else ""
    base = f", {demoted} baselined" if demoted else ""
    print(f"\n{len(findings)} finding(s): {n_err} error(s), "
          f"{n_skip} skipped rule(s){base}{tail}")

    if args.json_out:
        doc = findings_to_json(findings, rules=args.rules,
                               baselined=demoted)
        with open(args.json_out, "w", encoding="utf-8") as fh:
            fh.write(doc + "\n")
        print(f"wrote {args.json_out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())

"""Host/device purity lint — the AST import-graph pass behind the
``purity`` rule family.

The PR-8 layering contract, as code instead of a subprocess test:

  * ``repro.serve.scheduler`` (and every module it pulls in at import
    time, transitively) is **jax-free** — plans are numpy + ints, and a
    jax import sneaking into the host layer would silently re-couple
    admission logic to device state;
  * ``repro.serve.metrics`` is jax-free the same way (it is consumed by
    pure-host reporting paths);
  * ``repro.serve.paged`` holds the **lazy-jax contract**: jax may be
    imported only inside ``init_paged_cache`` (the one function that
    builds device arrays) — never at module level, never from another
    function;
  * ``repro.serve.__init__`` stays lazy (PEP 562) — an eager re-export
    would drag jax in for every host-layer importer;
  * ``repro.kernels.*`` never imports ``repro.serve`` (kernels are the
    bottom layer; the dispatch ladder lives in ``models``/``serve``);
  * ``repro.configs.*`` are **effect-free**: module level is docstring +
    imports (stdlib typing/dataclasses + ``repro.configs``) +
    assignments + defs, nothing that could touch jax, I/O, or global
    state at import time (jitted step functions close over configs
    statically, so config import must be pure).

Unlike the subprocess test this replaced, violations come back with the
offending **import chain** (``scheduler → paged → X → jax``), and the
pass needs no interpreter spawn — it parses source with ``ast`` only,
so it runs (and is importable) without jax installed.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis import Context, Finding, rule

__all__ = [
    "ModuleImports",
    "scan_tree",
    "import_chain",
    "check_jax_free",
    "check_no_import",
    "check_lazy_import",
    "check_effect_free",
    "run_layering",
]


@dataclasses.dataclass
class ModuleImports:
    """Import surface of one module, split by when the import runs."""
    name: str                                 # dotted module name
    path: str
    module_level: Set[str]                    # imported at import time
    deferred: Dict[str, Set[str]]             # function name -> imports
    toplevel_statements: List[str] = dataclasses.field(default_factory=list)

    def all_deferred(self) -> Set[str]:
        out: Set[str] = set()
        for mods in self.deferred.values():
            out |= mods
        return out


_EFFECT_FREE_NODES = (ast.Import, ast.ImportFrom, ast.Assign, ast.AnnAssign,
                      ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


class _ImportVisitor(ast.NodeVisitor):
    def __init__(self):
        self.module_level: Set[str] = set()
        self.deferred: Dict[str, Set[str]] = {}
        self._fn_stack: List[str] = []

    def _sink(self) -> Set[str]:
        if self._fn_stack:
            return self.deferred.setdefault(self._fn_stack[0], set())
        return self.module_level

    def visit_Import(self, node):
        for alias in node.names:
            self._sink().add(alias.name)

    def visit_ImportFrom(self, node):
        if node.level:       # relative import — resolve later if needed;
            return           # the repo uses absolute imports throughout
        mod = node.module or ""
        sink = self._sink()
        sink.add(mod)
        # ``from pkg import sub`` may bind a submodule: record the
        # candidate so layering sees pkg.sub edges too (harmless when it
        # is just an attribute — the module simply won't exist on disk)
        for alias in node.names:
            if alias.name != "*":
                sink.add(f"{mod}.{alias.name}")

    def visit_FunctionDef(self, node):
        self._fn_stack.append(node.name)
        self.generic_visit(node)
        self._fn_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        # lambdas defer their body like functions do
        self._fn_stack.append("<lambda>")
        self.generic_visit(node)
        self._fn_stack.pop()


def _module_name(root: str, path: str) -> str:
    rel = os.path.relpath(path, root)
    parts = rel[:-3].split(os.sep)          # strip .py
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def scan_tree(root: str) -> Dict[str, ModuleImports]:
    """Parse every ``.py`` under ``root`` into a ModuleImports map keyed
    by dotted module name (``root`` is the import root, e.g. ``src/``)."""
    out: Dict[str, ModuleImports] = {}
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in filenames:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path, "r", encoding="utf-8") as fh:
                try:
                    tree = ast.parse(fh.read(), filename=path)
                except SyntaxError as exc:
                    raise SyntaxError(f"{path}: {exc}") from exc
            v = _ImportVisitor()
            v.visit(tree)
            name = _module_name(root, path)
            stmts = [type(n).__name__ for n in tree.body]
            out[name] = ModuleImports(name, path, v.module_level,
                                      v.deferred, stmts)
    return out


def _expand_with_packages(name: str) -> List[str]:
    """Importing ``a.b.c`` also executes ``a`` and ``a.b`` __init__s."""
    parts = name.split(".")
    return [".".join(parts[:i]) for i in range(1, len(parts) + 1)]


def import_chain(tree: Dict[str, ModuleImports], start: str,
                 banned_prefix: str) -> Optional[List[str]]:
    """BFS over *module-level* import edges from ``start``; return the
    shortest chain ``[start, ..., offender, banned_module]`` reaching a
    module whose name is/starts with ``banned_prefix``, or None."""
    def hits(mod: str) -> bool:
        return mod == banned_prefix or mod.startswith(banned_prefix + ".")

    seen: Set[str] = set()
    # importing a.b.c executes a and a.b __init__s too — seed them all
    queue: List[List[str]] = [[s] for s in _expand_with_packages(start)
                              if s == start or s in tree]
    while queue:
        chain = queue.pop(0)
        mod = chain[-1]
        if mod in seen:
            continue
        seen.add(mod)
        info = tree.get(mod)
        if info is None:
            continue
        for imp in sorted(info.module_level):
            if hits(imp):
                return chain + [imp]
            for sub in _expand_with_packages(imp):
                if sub in tree and sub not in seen:
                    queue.append(chain + [sub])
    return None


def check_jax_free(tree: Dict[str, ModuleImports], module: str,
                   banned: str = "jax") -> Optional[List[str]]:
    """None when ``module`` (transitively, at import time) never pulls in
    ``banned``; otherwise the offending chain."""
    return import_chain(tree, module, banned)


def check_no_import(tree: Dict[str, ModuleImports], modules: Sequence[str],
                    banned_prefix: str) -> List[Tuple[str, List[str]]]:
    """Chains for every module in ``modules`` that reaches
    ``banned_prefix`` at import time."""
    out = []
    for m in modules:
        chain = import_chain(tree, m, banned_prefix)
        if chain is not None:
            out.append((m, chain))
    return out


def check_lazy_import(info: ModuleImports, banned: str,
                      allowed_fns: Sequence[str]) -> List[str]:
    """Violations of a lazy-import contract: ``banned`` must appear
    neither at module level nor in any function outside ``allowed_fns``."""
    def hits(mods: Set[str]) -> bool:
        return any(m == banned or m.startswith(banned + ".") for m in mods)

    problems = []
    if hits(info.module_level):
        problems.append(f"{info.name} imports {banned} at module level")
    for fn, mods in sorted(info.deferred.items()):
        if fn not in allowed_fns and hits(mods):
            problems.append(
                f"{info.name}.{fn} imports {banned} (only "
                f"{'/'.join(allowed_fns)} may)")
    return problems


# stdlib surface a config module may touch; anything else (jax, numpy,
# os, ...) is an import-time effect risk
_CONFIG_ALLOWED_IMPORTS = ("__future__", "dataclasses", "typing",
                           "importlib", "repro.configs")
_CONFIG_ALLOWED_NODES = _EFFECT_FREE_NODES + (ast.Expr,)


def check_effect_free(info: ModuleImports) -> List[str]:
    """Effect-free contract for config modules: only benign imports and
    only declarative top-level statement kinds."""
    problems = []
    for imp in sorted(info.module_level):
        if not any(imp == a or imp.startswith(a + ".")
                   for a in _CONFIG_ALLOWED_IMPORTS):
            problems.append(f"{info.name} imports {imp} at module level "
                            f"(configs may import only "
                            f"{', '.join(_CONFIG_ALLOWED_IMPORTS)})")
    with open(info.path, "r", encoding="utf-8") as fh:
        tree = ast.parse(fh.read(), filename=info.path)
    for i, node in enumerate(tree.body):
        if isinstance(node, ast.Expr) and i == 0 and isinstance(
                node.value, ast.Constant) and isinstance(node.value.value,
                                                         str):
            continue                       # module docstring
        if not isinstance(node, _EFFECT_FREE_NODES):
            problems.append(
                f"{info.name}:{node.lineno} top-level {type(node).__name__} "
                "statement (configs must be declarative)")
    return problems


def run_layering(root: str) -> List[Finding]:
    """Apply the full layering spec to a source tree and return findings.
    Modules missing from ``root`` are skipped (so the fixture trees in
    tests, which mimic only a slice of the repo, still exercise rules)."""
    tree = scan_tree(root)
    findings: List[Finding] = []

    def err(rule_name, obj, msg, **data):
        findings.append(Finding(rule=rule_name, severity="error", obj=obj,
                                message=msg, data=data))

    # 1. host scheduler layer (and the lazy serve __init__) is jax-free
    for mod in ("repro.serve.scheduler", "repro.serve.metrics",
                "repro.serve"):
        if mod not in tree:
            continue
        chain = check_jax_free(tree, mod)
        if chain is not None:
            err("purity.scheduler-jax-free", mod,
                f"host-layer module {mod} reaches jax at import time: "
                + " -> ".join(chain), chain=chain)

    # 2. paged.py lazy-jax contract
    paged = tree.get("repro.serve.paged")
    if paged is not None:
        for msg in check_lazy_import(paged, "jax", ("init_paged_cache",)):
            err("purity.paged-lazy-jax", "repro.serve.paged", msg)

    # 3. kernels never import serve
    kernel_mods = [m for m in tree if m == "repro.kernels"
                   or m.startswith("repro.kernels.")]
    for mod, chain in check_no_import(tree, kernel_mods, "repro.serve"):
        err("purity.kernels-no-serve", mod,
            f"kernel module {mod} reaches repro.serve at import time: "
            + " -> ".join(chain), chain=chain)

    # 4. configs are effect-free
    cfg_mods = [m for m in tree if m.startswith("repro.configs.")]
    for mod in sorted(cfg_mods):
        for msg in check_effect_free(tree[mod]):
            err("purity.configs-effect-free", mod, msg)

    if not findings:
        findings.append(Finding(
            rule="purity.layering", severity="info", obj=root,
            message=f"layering clean over {len(tree)} modules",
            data={"modules": len(tree)}))
    return findings


@rule("purity.layering", family="purity")
def rule_layering(ctx: Context) -> List[Finding]:
    """Host/device layering: jax-free scheduler scope, lazy paged jax,
    kernels below serve, effect-free configs."""
    return run_layering(ctx.purity_root or ctx.src_root)

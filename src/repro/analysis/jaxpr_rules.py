"""jaxpr contract rules — dispatch pins checked over traced programs.

Every rule here is a statement about what a program *lowers to*, checked
abstractly with :func:`jax.make_jaxpr` (``jax.ShapeDtypeStruct`` operands
where possible — nothing runs, no TPU needed):

  * ``jaxpr.projection-dispatch``  each ``sparse_linear`` mode (per-token
    N:M, tile-consensus, Outstanding-sparse W8A8 prefill AND decode) is
    exactly ONE fused ``pallas_call`` with kernels on, zero with kernels
    off, and zero on the ``layer_flag`` fallback;
  * ``jaxpr.step-contracts``  for every fused step bucket of
    ``serve/executor.py`` (enumerated from ``STEP_BUCKETS``, never
    hand-listed): zero pool-shaped gathers/scatters outside kernels, no
    jax effects (the shard_map-ability pin), identical jaxpr on retrace,
    no f64 leakage — and the jnp oracle twins must still CONTAIN pool
    gathers/scatters, proving the kernels-on pins aren't vacuous;
  * ``jaxpr.tp-shards``  under a ≥2-device TP scope the column-parallel
    projection keeps one ``pallas_call``, gathers with ``all_gather``,
    and has NO ``psum`` (bit-identity forbids cross-device reductions);
    emits a ``skip`` finding on single-device hosts;
  * ``jaxpr.extra-entries``  fixture hook: trace ``JAXPR_ENTRIES`` from
    ``ctx.jaxpr_extra`` and apply the pool-containment pin, so the
    analyzer's own tests can seed a known-bad step.
"""
from __future__ import annotations

from typing import List

from repro.analysis import Context, Finding, rule
from repro.analysis.jaxpr_utils import (count_pallas_calls, eqn_dtypes,
                                        iter_eqns, pool_eqn_count)

__all__ = []


def _err(rule_name, obj, msg, **data):
    return Finding(rule=rule_name, severity="error", obj=obj, message=msg,
                   data=data)


def _ok(rule_name, obj, msg, **data):
    return Finding(rule=rule_name, severity="info", obj=obj, message=msg,
                   data=data)


def _policy(**kw):
    from repro.core.policy import SparsityPolicy
    base = dict(n=8, m=16, score_mode="naive", skip_modules=(),
                skip_layers={})
    base.update(kw)
    return SparsityPolicy(**base)


def _prim_count(jaxpr, name: str) -> int:
    return sum(1 for e in iter_eqns(jaxpr) if e.primitive.name == name)


# --------------------------------------------------- projection dispatch

def _projection_cases():
    """(case name, jaxpr thunk, expected pallas_call count) triples.

    Shapes are tiny but aligned (t=32, d=128, n_out=64) so every kernel
    dispatches without the padding fallback muddying the count.
    """
    import jax
    import jax.numpy as jnp

    from repro.layers.linear import sparse_linear

    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    wq = jax.ShapeDtypeStruct((128, 64), jnp.int8)
    w_scale = jax.ShapeDtypeStruct((64,), jnp.float32)
    smooth = jax.ShapeDtypeStruct((128,), jnp.float32)
    act_scale = jax.ShapeDtypeStruct((), jnp.float32)

    on = _policy(use_pallas_kernels=True)
    off = _policy()

    def trace(fn, *args):
        return lambda: jax.make_jaxpr(fn)(*args)

    def proj(pol, phase="prefill", **kw):
        return lambda x, w: sparse_linear(x, {"w": w}, "down_proj", pol,
                                          phase, **kw)

    def qproj(pol, phase="prefill"):
        return lambda x, wq, ws, sm, asc: sparse_linear(
            x, {"wq": wq, "w_scale": ws, "smooth": sm, "act_scale": asc},
            "q_proj", pol, phase)

    flag_on = _policy(use_pallas_kernels=True)

    return [
        ("per-token kernels-on", trace(proj(on), x, w), 1),
        ("per-token kernels-off", trace(proj(off), x, w), 0),
        ("tile-consensus kernels-on",
         trace(proj(_policy(use_pallas_kernels=True, tile_consensus=True,
                            tile_size=32)), x, w), 1),
        ("tile-consensus kernels-off",
         trace(proj(_policy(tile_consensus=True, tile_size=32)), x, w), 0),
        ("w8a8-prefill kernels-on", trace(qproj(on), x, wq, w_scale,
                                          smooth, act_scale), 1),
        ("w8a8-prefill kernels-off", trace(qproj(off), x, wq, w_scale,
                                           smooth, act_scale), 0),
        # decode: prune=False statically — still ONE fused W8A8 GEMM
        ("w8a8-decode kernels-on", trace(qproj(on, "decode"), x, wq,
                                         w_scale, smooth, act_scale), 1),
        # scan-stacked layer_flag models must stay on the jnp fallback
        ("layer-flag fallback",
         trace(lambda x, w: sparse_linear(
             x, {"w": w}, "down_proj", flag_on, "prefill",
             layer_flag=jnp.array(True)), x, w), 0),
    ]


@rule("jaxpr.projection-dispatch", family="jaxpr")
def rule_projection_dispatch(ctx: Context) -> List[Finding]:
    """One fused pallas_call per sparse projection (per-token,
    tile-consensus, W8A8 prefill/decode); zero on the jnp oracle and
    layer_flag paths."""
    findings: List[Finding] = []
    for name, thunk, want in _projection_cases():
        got = count_pallas_calls(thunk())
        if got != want:
            findings.append(_err(
                "jaxpr.projection-dispatch", name,
                f"{name}: expected {want} pallas_call(s), traced {got}",
                expected=want, got=got))
    if not findings:
        findings.append(_ok("jaxpr.projection-dispatch", "sparse_linear",
                            f"{len(_projection_cases())} dispatch pins hold"))
    return findings


# ------------------------------------------------------- step programs

def _step_fixture(ctx: Context):
    """(engine, pool_shapes, args) for tracing step buckets — one cache /
    operand set shared by every bucket (phase presence is static, unused
    operands are simply dead in the traced program)."""
    if "step_fixture" in ctx._cache:
        return ctx._cache["step_fixture"]
    import jax.numpy as jnp
    import numpy as np

    from repro.core.policy import DENSE
    from repro.serve.continuous import (ContinuousConfig,
                                        ContinuousServingEngine)
    from repro.serve.paged import (device_pool_rows, init_paged_cache,
                                   max_blocks_per_slot)

    cfg, model, params = ctx.smoke_model()
    slots, bs, max_seq = 2, 8, 64
    mb = max_blocks_per_slot(max_seq, bs)
    nb = slots * mb
    rows = device_pool_rows(nb)   # +1 sentinel row on device leaves
    pol = DENSE.with_(use_pallas_kernels=True)
    eng = ContinuousServingEngine(model, pol, ContinuousConfig(
        max_seq=max_seq, num_slots=slots, chunk_size=8, block_size=bs),
        _via_api=True)
    cache = init_paged_cache(model, slots, max_seq, bs, nb, eng._spec)
    tab = np.full((slots, mb), -1, np.int32)
    tab[0, :3], tab[1, :3] = [1, 2, 3], [4, 5, 6]
    cache["block_table"] = jnp.asarray(tab)
    cache["pos"] = jnp.asarray([10, 7], jnp.int32)
    pool_shapes = {(rows, bs, cfg.n_kv_heads, cfg.head_dim),
                   (rows * bs, cfg.n_kv_heads, cfg.head_dim)}
    args = (params, cache, jnp.asarray(0, jnp.int32),
            jnp.zeros((1, 8), jnp.int32), jnp.asarray(8, jnp.int32),
            {}, jnp.zeros((slots,), jnp.int32),
            jnp.asarray([False, True]), jnp.zeros((2,), jnp.uint32),
            jnp.zeros((2,), jnp.uint32), jnp.float32(0.0))
    ctx._cache["step_fixture"] = (eng, pool_shapes, args)
    return ctx._cache["step_fixture"]


@rule("jaxpr.step-contracts", family="jaxpr")
def rule_step_contracts(ctx: Context) -> List[Finding]:
    """Every fused step bucket: pool ops stay in-kernel, no jax effects,
    stable retrace, no f64; oracle twins keep pool ops (vacuity check)."""
    import jax

    from repro.serve.executor import STEP_BUCKETS

    eng, pool_shapes, args = _step_fixture(ctx)
    findings: List[Finding] = []
    for bucket, name in STEP_BUCKETS.items():
        for oracle in (False, True):
            label = name + ("_oracle" if oracle else "")
            step = eng.exec.step_program(bucket, oracle=oracle)
            closed = jax.make_jaxpr(step)(*args)
            gathers = pool_eqn_count(closed, pool_shapes, "gather")
            scatters = pool_eqn_count(closed, pool_shapes, "scatter")
            if not oracle:
                for prim, n in (("gather", gathers), ("scatter", scatters)):
                    if n:
                        findings.append(_err(
                            "jaxpr.step-contracts", label,
                            f"{label}: {n} pool-shaped {prim}(s) escaped "
                            "the kernels", prim=prim, count=n))
            else:
                # the oracle must still do pool-shaped work, or the
                # kernels-on zero-counts above prove nothing
                if gathers == 0 and scatters == 0:
                    findings.append(_err(
                        "jaxpr.step-contracts", label,
                        f"{label}: oracle twin has NO pool-shaped ops — "
                        "the kernels-on containment pin is vacuous"))
            if closed.effects:
                findings.append(_err(
                    "jaxpr.step-contracts", label,
                    f"{label}: step program carries jax effects "
                    f"{closed.effects} (not shard_map-able)",
                    effects=str(closed.effects)))
            f64 = {d for d in eqn_dtypes(closed) if d == "float64"}
            if f64:
                findings.append(_err(
                    "jaxpr.step-contracts", label,
                    f"{label}: float64 leaked into the traced program"))
            again = jax.make_jaxpr(step)(*args)
            if str(closed) != str(again):
                findings.append(_err(
                    "jaxpr.step-contracts", label,
                    f"{label}: retracing from identical operands changed "
                    "the program (trace-time mutable-state dependence)"))
    if not findings:
        findings.append(_ok(
            "jaxpr.step-contracts", "executor",
            f"{len(STEP_BUCKETS)} buckets (+oracle twins) hold all pins"))
    return findings


# ------------------------------------------------------------- tp shards

@rule("jaxpr.tp-shards", family="jaxpr")
def rule_tp_shards(ctx: Context) -> List[Finding]:
    """Column-parallel projection under a 2-device TP scope: one
    pallas_call inside shard_map, gathered with all_gather, no psum."""
    import jax

    if jax.device_count() < 2:
        return [Finding(
            rule="jaxpr.tp-shards", severity="skip", obj="tp",
            message="needs >=2 devices (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=2)")]
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.distributed import tp
    from repro.layers.linear import sparse_linear

    mesh = Mesh(np.array(jax.devices()[:2]), ("model",))
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    pol = _policy(use_pallas_kernels=True)
    fn = lambda x, w: sparse_linear(x, {"w": w}, "down_proj", pol,
                                    "prefill")
    with tp.scope(mesh, "model"):
        closed = jax.make_jaxpr(fn)(x, w)
    findings: List[Finding] = []
    checks = (("pallas_call", count_pallas_calls(closed), "== 1",
               lambda n: n == 1),
              ("shard_map", _prim_count(closed, "shard_map"), ">= 1",
               lambda n: n >= 1),
              ("all_gather", _prim_count(closed, "all_gather"), ">= 1",
               lambda n: n >= 1),
              ("psum", _prim_count(closed, "psum"), "== 0",
               lambda n: n == 0))
    for prim, n, want, pred in checks:
        if not pred(n):
            findings.append(_err(
                "jaxpr.tp-shards", prim,
                f"tp-sharded projection: {prim} count {n}, expected "
                f"{want}", count=n))
    if not findings:
        findings.append(_ok("jaxpr.tp-shards", "column_parallel",
                            "sharded projection pins hold (2 devices)"))
    return findings


# ------------------------------------------------------- fixture entries

@rule("jaxpr.extra-entries", family="jaxpr")
def rule_extra_entries(ctx: Context) -> List[Finding]:
    """Pool-containment pin over fixture ``JAXPR_ENTRIES``:
    ``(name, fn, args, pool_shapes)`` tuples traced and checked like the
    step buckets (analyzer-test hook)."""
    if not ctx.jaxpr_extra:
        return []
    import jax

    findings: List[Finding] = []
    mod = ctx.load_extra(ctx.jaxpr_extra)
    for name, fn, fargs, pool_shapes in mod.JAXPR_ENTRIES:
        closed = jax.make_jaxpr(fn)(*fargs)
        for prim in ("gather", "scatter"):
            n = pool_eqn_count(closed, pool_shapes, prim)
            if n:
                findings.append(_err(
                    "jaxpr.extra-entries", name,
                    f"{name}: {n} pool-shaped {prim}(s) outside "
                    "pallas_call", prim=prim, count=n))
    if not findings:
        findings.append(_ok("jaxpr.extra-entries", ctx.jaxpr_extra,
                            f"{len(mod.JAXPR_ENTRIES)} entries clean"))
    return findings

"""Static per-core VMEM/SMEM budget estimator for every Pallas kernel.

The ROADMAP's standing gap: kernels validated in interpret mode can
still die at Mosaic lowering on a real TPU when their working set
exceeds VMEM (~16 MB/core — pallas guide "Memory Hierarchy").  Nothing
about that failure needs hardware to predict: the working set is fully
determined by the traced program's BlockSpecs, grid, and scratch shapes.
This module walks each ``pallas_call`` equation of a traced call and
computes a worst-case footprint:

    vmem  =  2 x (sum of in/out block bytes)   # double-buffered pipeline
           + vmem scratch bytes                # single-buffered
    smem  =  scalar-prefetch operands + smem scratch

The x2 models Mosaic's pipelined double buffering of every streamed
block (see pallas guide "Patterns: Double Buffering"); scratch buffers
persist across grid steps and are not double-buffered.  Grids with a
single step skip the x2.  The estimate is deliberately conservative —
it does not model Mosaic's own temporaries, so a kernel near the budget
is already a finding.

``kernel_zoo_entries`` builds one representative traced call per kernel
in ``repro.kernels`` (nm_prune, nm_prune_matmul, nm_spmm,
osparse_matmul prefill + its static ``prune=False`` decode form,
w8a8_matmul, flash attention, paged attention, paged_kv_scatter) from a
``ModelConfig``'s real dims, so the ``vmem.budget`` rule sweeps the
whole shipped config zoo without materializing a single array
(``jax.make_jaxpr`` over ``ShapeDtypeStruct``s — no TPU, no FLOPs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis import Context, Finding, rule
from repro.analysis.jaxpr_utils import pallas_call_eqns

__all__ = [
    "PallasFootprint",
    "estimate_jaxpr",
    "estimate_call",
    "kernel_zoo_entries",
    "GridZooEntry",
    "grid_zoo_entries",
    "footprint_table",
]


@dataclasses.dataclass
class PallasFootprint:
    """Static memory footprint of ONE ``pallas_call`` equation."""
    kernel: str                    # inner kernel function name
    grid: Tuple[int, ...]
    block_bytes: int               # one copy of every in/out block
    vmem_scratch_bytes: int
    smem_bytes: int                # scalar prefetch + smem scratch
    double_buffered: bool

    @property
    def vmem_bytes(self) -> int:
        mult = 2 if self.double_buffered else 1
        return mult * self.block_bytes + self.vmem_scratch_bytes

    def to_dict(self) -> Dict[str, Any]:
        return {"kernel": self.kernel, "grid": list(self.grid),
                "block_bytes": self.block_bytes,
                "vmem_scratch_bytes": self.vmem_scratch_bytes,
                "smem_bytes": self.smem_bytes,
                "vmem_bytes": self.vmem_bytes,
                "double_buffered": self.double_buffered}


def _itemsize(dtype) -> int:
    import numpy as np
    return np.dtype(dtype).itemsize


def _block_numel(block_shape) -> int:
    # squeezed/mapped dims may appear as non-ints; they contribute 1 row
    return math.prod(int(d) if isinstance(d, int) else 1
                     for d in block_shape)


def _ref_space_and_bytes(aval) -> Tuple[str, int]:
    """(memory space, bytes) of a kernel ref aval (AbstractMemoryRef)."""
    inner = getattr(aval, "inner_aval", aval)
    shape = getattr(inner, "shape", getattr(aval, "shape", ()))
    dtype = getattr(inner, "dtype", getattr(aval, "dtype", None))
    space = getattr(aval, "memory_space", None)
    space = str(space).lower() if space is not None else "vmem"
    nbytes = math.prod(int(d) for d in shape) * _itemsize(dtype)
    return ("smem" if "smem" in space else "vmem"), nbytes


def estimate_jaxpr(jaxpr) -> List[PallasFootprint]:
    """Footprints for every ``pallas_call`` in a (Closed)Jaxpr."""
    out: List[PallasFootprint] = []
    for eqn in pallas_call_eqns(jaxpr):
        gm = eqn.params["grid_mapping"]
        name_info = eqn.params.get("name_and_src_info")
        name = getattr(name_info, "name", None) or "pallas_call"
        grid = tuple(int(g) for g in gm.grid)

        block_bytes = 0
        for bm in gm.block_mappings:
            arr = bm.array_shape_dtype
            block_bytes += _block_numel(bm.block_shape) * _itemsize(arr.dtype)

        inner = eqn.params["jaxpr"]
        invars = inner.jaxpr.invars if hasattr(inner, "jaxpr") \
            else inner.invars
        n_idx = gm.num_index_operands
        n_scratch = gm.num_scratch_operands
        smem_bytes = 0
        vmem_scratch = 0
        for v in invars[:n_idx]:               # scalar prefetch (SMEM)
            _, nb = _ref_space_and_bytes(v.aval)
            smem_bytes += nb
        if n_scratch:
            for v in invars[len(invars) - n_scratch:]:
                space, nb = _ref_space_and_bytes(v.aval)
                if space == "smem":
                    smem_bytes += nb
                else:
                    vmem_scratch += nb

        out.append(PallasFootprint(
            kernel=name, grid=grid, block_bytes=block_bytes,
            vmem_scratch_bytes=vmem_scratch, smem_bytes=smem_bytes,
            double_buffered=math.prod(grid) > 1 if grid else False))
    return out


def estimate_call(fn, *args, **kwargs) -> List[PallasFootprint]:
    """Trace ``fn(*args)`` abstractly and estimate every pallas_call in
    it.  ``args`` may be ``jax.ShapeDtypeStruct``s — nothing is ever
    computed or materialized."""
    import jax
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    return estimate_jaxpr(closed)


# --------------------------------------------------------------- kernel zoo

def _nm_for(d: int) -> Tuple[int, int]:
    """An N:M pattern whose group size divides the channel axis."""
    for m in (16, 8, 4, 2):
        if d % m == 0:
            return m // 2, m
    return 1, 1


def kernel_zoo_entries(cfg, *, chunk: int = 256, decode_slots: int = 8,
                       max_seq: int = 4096, block_size: int = 16):
    """``(entry_name, thunk)`` pairs, one per kernel entry point, with
    shapes drawn from ``cfg``'s real dims (a ``ModelConfig``).  Each
    thunk returns the footprint list for one representative call."""
    return _zoo(cfg, chunk, decode_slots, max_seq, block_size)


def _zoo(cfg, chunk, decode_slots, max_seq, block_size):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.paged_attention import (paged_attention_pallas,
                                               paged_kv_scatter_pallas)

    S = jax.ShapeDtypeStruct
    d = cfg.d_model
    n_out = max(cfg.d_ff, cfg.q_dim, cfg.moe_d_ff or 0)
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    n, m = _nm_for(d)

    x = S((chunk, d), jnp.float32)
    xd = S((decode_slots, d), jnp.float32)
    w = S((d, n_out), jnp.float32)
    wq = S((d, n_out), jnp.int8)
    scale = S((d,), jnp.float32)
    w_scale = S((n_out,), jnp.float32)
    bias = S((n_out,), jnp.float32)
    act = S((1,), jnp.float32)

    entries = [
        ("nm_prune", lambda: estimate_call(
            lambda x_, s_: ops.nm_prune(x_, s_, n, m), x, scale)),
        ("nm_prune_matmul", lambda: estimate_call(
            lambda x_, w_, s_, b_: ops.nm_prune_matmul(
                x_, w_, s_, n, m, bias=b_), x, w, scale, bias)),
        ("nm_spmm", lambda: estimate_call(
            lambda x_, w_, s_: ops.nm_spmm(x_, w_, s_, n, m), x, w, scale)),
        # prefill Outstanding-sparse with per-token scales (the extra
        # absmax sweep is the worst case of the two scale modes)
        ("osparse_matmul", lambda: estimate_call(
            lambda x_, wq_, sm_, am_, ws_, b_: ops.osparse_matmul(
                x_, wq_, sm_, am_, ws_, n, m, bias=b_, per_token=True),
            x, wq, scale, scale, w_scale, bias)),
        # decode-phase W8A8: same kernel, static prune=False
        ("osparse_w8a8_decode", lambda: estimate_call(
            lambda x_, wq_, sm_, ws_, a_, b_: ops.osparse_matmul(
                x_, wq_, sm_, None, ws_, n, m, act_scale=a_, bias=b_,
                prune=False), xd, wq, scale, w_scale, act, bias)),
        ("w8a8_matmul", lambda: estimate_call(
            lambda xq_, wq_, a_, ws_: ops.w8a8_matmul(xq_, wq_, a_, ws_),
            S((chunk, d), jnp.int8), wq, act, w_scale)),
    ]

    # attention kernels: one batch row of a 1024-token self-attn tile is
    # representative — block sizes are clamped at 128 so longer sequences
    # only grow the grid, never the VMEM working set
    t_attn = 1024
    q4 = S((1, hq, t_attn, hd), jnp.float32)
    kv4 = S((1, hkv, t_attn, hd), jnp.float32)
    entries.append(("flash_attention", lambda: estimate_call(
        lambda q_, k_, v_: flash_attention_pallas(
            q_, k_, v_, causal=True, interpret=True), q4, kv4, kv4)))

    mb = max_seq // block_size
    nb = decode_slots * mb
    qp = S((decode_slots, chunk, hq, hd), jnp.float32)
    pool = S((nb, block_size, hkv, hd), jnp.float32)
    tab = S((decode_slots, mb), jnp.int32)
    vec = S((decode_slots,), jnp.int32)
    entries.append(("paged_attention", lambda: estimate_call(
        lambda q_, k_, v_, t_, o_, l_: paged_attention_pallas(
            q_, k_, v_, t_, o_, l_, interpret=True),
        qp, pool, pool, tab, vec, vec)))

    knew = S((decode_slots, chunk, hkv, hd), jnp.float32)
    entries.append(("paged_kv_scatter", lambda: estimate_call(
        lambda kn_, vn_, kp_, vp_, t_, p_, c_: paged_kv_scatter_pallas(
            kn_, vn_, kp_, vp_, t_, p_, c_, interpret=True),
        knew, knew, pool, pool, tab, vec, vec)))
    return entries


@dataclasses.dataclass
class GridZooEntry:
    """One CONCRETE small-geometry kernel call for the grid-semantics
    (``races``) and HBM cost-model (``hbm``) rules.

    Unlike the abstract ``kernel_zoo_entries`` sweep (ShapeDtypeStructs,
    full-config dims), these entries carry real operand values — the
    scalar-prefetched block tables / positions / lengths must be concrete
    so every BlockSpec index map can be *evaluated* over the enumerated
    grid.  Geometry is chosen so every grid axis has ≥ 2 steps (tiled
    matmuls get I, J, K ≥ 2): degenerate single-step grids would make the
    revisit/elision checks and the closed-form byte model vacuously agree.

    ``dims`` feeds ``repro.kernels.COST_MODEL[name]["bytes"]`` — logical
    quantities (t, d, n_out, tile sizes, tables) the documented formulas
    are written in.  Entry names MUST mirror ``kernel_zoo_entries`` —
    the races rule derives its required coverage set from the vmem zoo,
    so a kernel added there without a grid-zoo twin is an error finding,
    not a silent skip.
    """
    name: str
    fn: Any
    args: Tuple[Any, ...]
    dims: Dict[str, Any]


def grid_zoo_entries(cfg) -> List[GridZooEntry]:
    """Concrete-operand kernel calls over ``cfg``'s dims at a small,
    non-degenerate geometry (see :class:`GridZooEntry`).  Paged entries
    follow the serving pool convention: device pools carry the trailing
    sentinel row (``serve/paged.device_pool_rows``) and block tables
    never reference it."""
    import functools

    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.paged_attention import (paged_attention_pallas,
                                               paged_kv_scatter_pallas)
    from repro.serve.paged import device_pool_rows

    d = cfg.d_model
    n_out = max(cfg.d_ff, cfg.q_dim, cfg.moe_d_ff or 0)
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    n, m = _nm_for(d)

    t, bt = 32, 16                                    # I = 2
    bo = n_out // 2 if n_out % 2 == 0 else n_out      # J = 2
    bk = d // 2 if d % 2 == 0 and (d // 2) % m == 0 else d   # K = 2
    bk8 = d // 2 if d % 2 == 0 else d                 # no %m constraint

    x = jnp.zeros((t, d), jnp.float32)
    xd = jnp.zeros((2, d), jnp.float32)
    w = jnp.zeros((d, n_out), jnp.float32)
    wq = jnp.zeros((d, n_out), jnp.int8)
    vec_d = jnp.ones((d,), jnp.float32)
    vec_o = jnp.ones((n_out,), jnp.float32)
    act = jnp.float32(1.0)

    mm = dict(t=t, d=d, n_out=n_out, bt=bt, bo=bo, bk=bk)
    entries = [
        GridZooEntry(
            "nm_prune",
            lambda x_, s_: ops.nm_prune(x_, s_, n, m, block_t=bt,
                                        block_d=bk),
            (x, vec_d), dict(t=t, d=d, bt=bt, bd=bk)),
        GridZooEntry(
            "nm_prune_matmul",
            lambda x_, w_, s_, b_: ops.nm_prune_matmul(
                x_, w_, s_, n, m, bias=b_, block_t=bt, block_o=bo,
                block_k=bk),
            (x, w, vec_d, vec_o), dict(mm)),
        GridZooEntry(
            "nm_spmm",
            lambda x_, w_, s_: ops.nm_spmm(x_, w_, s_, n, m, tile=bt,
                                           block_o=bo, block_k=bk),
            (x, w, vec_d), dict(mm)),
        GridZooEntry(
            "osparse_matmul",
            lambda x_, wq_, sm_, am_, ws_, b_: ops.osparse_matmul(
                x_, wq_, sm_, am_, ws_, n, m, bias=b_, per_token=True,
                block_t=bt, block_o=bo, block_k=bk),
            (x, wq, vec_d, vec_d, vec_o, vec_o), dict(mm)),
        GridZooEntry(
            "osparse_w8a8_decode",
            lambda x_, wq_, sm_, ws_, a_, b_: ops.osparse_matmul(
                x_, wq_, sm_, None, ws_, n, m, act_scale=a_, bias=b_,
                prune=False, block_t=1, block_o=bo, block_k=bk),
            (xd, wq, vec_d, vec_o, act, vec_o),
            dict(mm, t=2, bt=1)),
        GridZooEntry(
            "w8a8_matmul",
            lambda xq_, wq_, a_, ws_: ops.w8a8_matmul(
                xq_, wq_, a_, ws_, block_t=bt, block_o=bo, block_k=bk8),
            (jnp.zeros((t, d), jnp.int8), wq, act, vec_o),
            dict(mm, bk=bk8)),
    ]

    t_attn, ba = 64, 16
    q4 = jnp.zeros((1, hq, t_attn, hd), jnp.float32)
    kv4 = jnp.zeros((1, hkv, t_attn, hd), jnp.float32)
    entries.append(GridZooEntry(
        "flash_attention",
        functools.partial(flash_attention_pallas, causal=True, block_q=ba,
                          block_k=ba, interpret=True),
        (q4, kv4, kv4),
        dict(b=1, h=hq, hkv=hkv, t=t_attn, s_kv=t_attn, bq=ba, bk=ba,
             hd=hd)))

    # paged pool: 2 rows, 16 allocatable blocks + the trailing sentinel
    # row (never in any table).  Row 0 is a from-zero prefill (kv_len =
    # its chunk); row 1 sits mid-sequence at pos 12/16.
    bs, mb, nb = 8, 8, 16
    rows = device_pool_rows(nb)
    pool = jnp.zeros((rows, bs, hkv, hd), jnp.float32)
    atab = np.full((2, mb), -1, np.int32)
    atab[0, :4] = [1, 2, 3, 4]
    atab[1, :6] = [5, 6, 7, 8, 9, 10]
    tq = 32
    qoff = np.asarray([0, 16], np.int32)
    kvl = np.asarray([tq, 16 + tq], np.int32)
    entries.append(GridZooEntry(
        "paged_attention",
        functools.partial(paged_attention_pallas, causal=True, block_q=16,
                          interpret=True),
        (jnp.zeros((2, tq, hq, hd), jnp.float32), pool, pool,
         jnp.asarray(atab), jnp.asarray(qoff), jnp.asarray(kvl)),
        dict(b=2, h=hq, hkv=hkv, t=tq, bq=16, bs=bs, mb=mb, rows=rows,
             hd=hd, tab=atab, qoff=qoff, kvl=kvl)))

    stab = np.full((2, mb), -1, np.int32)
    stab[0, :2] = [1, 2]
    stab[1, 1:4] = [5, 6, 7]
    ts = 16
    pos = np.asarray([0, 12], np.int32)
    cl = np.asarray([ts, ts], np.int32)
    knew = jnp.zeros((2, ts, hkv, hd), jnp.float32)
    entries.append(GridZooEntry(
        "paged_kv_scatter",
        functools.partial(paged_kv_scatter_pallas, interpret=True),
        (knew, knew, pool, pool, jnp.asarray(stab), jnp.asarray(pos),
         jnp.asarray(cl)),
        dict(b=2, t=ts, bs=bs, mb=mb, rows=rows, hkv=hkv, hd=hd, tab=stab,
             pos=pos, cl=cl)))
    return entries


def kernel_zoo_footprints(cfg, *, chunk: int = 256, decode_slots: int = 8,
                          max_seq: int = 4096, block_size: int = 16
                          ) -> Dict[str, List[PallasFootprint]]:
    """Footprints for every kernel entry point under ``cfg``'s dims."""
    out: Dict[str, List[PallasFootprint]] = {}
    for name, thunk in _zoo(cfg, chunk, decode_slots, max_seq, block_size):
        out[name] = thunk()
    return out


def footprint_table(config_names: Sequence[str],
                    **zoo_kw) -> List[Dict[str, Any]]:
    """Per-kernel worst-case rows across ``config_names`` (full, non-smoke
    configs): the table ``kernels/__init__.py`` documents and the CLI
    emits under ``vmem_table``."""
    from repro.configs.base import get_config

    worst: Dict[str, Dict[str, Any]] = {}
    for cname in config_names:
        cfg = get_config(cname)
        for entry, fps in kernel_zoo_footprints(cfg, **zoo_kw).items():
            for fp in fps:
                row = worst.get(entry)
                if row is None or fp.vmem_bytes > row["vmem_bytes"]:
                    worst[entry] = {"entry": entry, "config": cname,
                                    **fp.to_dict()}
    return [worst[k] for k in sorted(worst)]


# ------------------------------------------------------------------- rule

def _mib(b: int) -> float:
    return b / (1024.0 * 1024.0)


@rule("vmem.budget", family="vmem")
def rule_vmem_budget(ctx: Context) -> List[Finding]:
    """Every kernel's static VMEM footprint, across the shipped config
    zoo, must fit the per-core budget (default 16 MiB); SMEM usage
    (scalar-prefetch tables) must stay tiny."""
    findings: List[Finding] = []
    budget, sbudget = ctx.vmem_budget_bytes, ctx.smem_budget_bytes

    def check(entry: str, where: str, fps: List[PallasFootprint]):
        if not fps:
            findings.append(Finding(
                rule="vmem.budget", severity="error", obj=entry,
                message=f"{entry} ({where}) lowered no pallas_call — "
                "the kernel dispatch silently fell back"))
            return
        for fp in fps:
            data = {"where": where, **fp.to_dict(),
                    "budget_bytes": budget}
            if fp.vmem_bytes > budget:
                findings.append(Finding(
                    rule="vmem.budget", severity="error", obj=entry,
                    message=(f"{entry} ({where}): static VMEM "
                             f"{_mib(fp.vmem_bytes):.2f} MiB exceeds the "
                             f"{_mib(budget):.0f} MiB per-core budget "
                             f"(kernel {fp.kernel}, grid {fp.grid})"),
                    data=data))
            elif fp.smem_bytes > sbudget:
                findings.append(Finding(
                    rule="vmem.budget", severity="error", obj=entry,
                    message=(f"{entry} ({where}): SMEM "
                             f"{fp.smem_bytes} B exceeds the "
                             f"{sbudget} B scalar budget"),
                    data=data))

    for cname in ctx.config_zoo():
        from repro.configs.base import get_config
        cfg = get_config(cname)
        for entry, fps in kernel_zoo_footprints(cfg).items():
            check(entry, cname, fps)

    if ctx.vmem_extra:
        mod = ctx.load_extra(ctx.vmem_extra)
        for entry_name, fn, args in mod.TRACE_ENTRIES:
            check(entry_name, ctx.vmem_extra, estimate_call(fn, *args))

    if not any(f.severity == "error" for f in findings):
        findings.append(Finding(
            rule="vmem.budget", severity="info", obj="kernels",
            message=(f"all kernels fit {_mib(budget):.0f} MiB across "
                     f"{len(ctx.config_zoo())} configs")))
    return findings

"""``repro.analysis`` — the static jaxpr/Pallas contract checker.

The repo's hot-path guarantees (one ``pallas_call`` per projection, zero
pool-shaped gathers/scatters outside kernels, one dispatch per
iteration, a jax-free scheduler) used to live as ad-hoc helpers
copy-pasted across test files.  This subsystem makes them a checked
contract: a rule registry plus a CLI —

    python -m repro.analysis [--rules jaxpr,vmem,purity,retrace] \
        [--json-out analysis.json]

— that runs WITHOUT a TPU (jaxpr tracing is abstract; Pallas stays in
interpret mode) and exits non-zero on violations.

Rule families (one module each):

  ``jaxpr``   (:mod:`.jaxpr_rules`)  dispatch pins over traced programs:
              pallas_call count per projection, pool-op containment for
              every step bucket of ``serve/executor.py`` (enumerated
              from ``Executor.STEP_BUCKETS``, not hand-listed), step
              purity/effects, f64 leakage, tp-shard pins.
  ``vmem``    (:mod:`.vmem`)  static per-core VMEM/SMEM budget estimator
              over every kernel's BlockSpecs/grid/scratch across the
              shipped config zoo — catches the "works in interpret mode,
              fails Mosaic lowering" class before real-TPU validation.
  ``purity``  (:mod:`.purity`)  AST import-graph layering lint: the
              scheduler host layer is jax-free, kernels never import
              serve, configs are effect-free, paged.py's jax import is
              lazy.
  ``retrace`` (:mod:`.retrace`)  trace-budget rules: observed
              ``trace_counts`` from a dry engine run vs the declared
              bucket set, and closure-captured array/container operands
              that would bloat or silently invalidate traces.
  ``races``   (:mod:`.grid_eval`)  symbolic grid evaluator: enumerates
              every ``pallas_call``'s static grid (kernel zoo + all
              ``STEP_BUCKETS`` step programs), concretely evaluates each
              BlockSpec index map (scalar-prefetch tables included), and
              checks output-revisit contiguity, aliased
              refetch-after-write hazards, and block-index bounds.
  ``hbm``     (:mod:`.hbm`)  machine-verified HBM cost model: measured
              bytes per kernel call (block footprints × grid fetch/write
              runs, refetch elision modelled) vs the closed-form
              ``repro.kernels.COST_MODEL`` formulas, >10% divergence
              fails; plus doc-table sync for ``kernels/__init__.py``.
  ``numerics`` (:mod:`.numerics`)  jaxpr lints over kernel bodies: int8
              GEMMs accumulate in i32/f32, computed quant-scale divisors
              are zero-guarded, online-softmax bodies use the shared
              finite ``_NEG`` guards (no ``-inf``), no f64, no
              back-to-back dtype round-trip casts.

Each rule is a callable ``fn(ctx) -> list[Finding]`` registered with
:func:`rule`.  ``Finding(severity="error")`` fails the CLI; rules that
cannot run in the current environment (e.g. tp pins on a 1-device host)
emit ``severity="skip"`` instead of silently passing.

This module itself imports neither jax nor numpy — ``purity`` checks
stay importable from pure-host contexts; the jax-heavy rule modules are
imported lazily by :func:`load_rules`.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import os
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Finding",
    "Rule",
    "Context",
    "rule",
    "registered_rules",
    "load_rules",
    "run_rules",
    "RULE_FAMILIES",
    "DEFAULT_VMEM_BUDGET_BYTES",
    "DEFAULT_SMEM_BUDGET_BYTES",
]

RULE_FAMILIES = ("jaxpr", "vmem", "purity", "retrace", "races", "hbm",
                 "numerics")

# ~16 MB usable VMEM per TPU core (pallas guide "Memory Hierarchy");
# SMEM is "small" — we budget 256 KiB for scalar-prefetch tables, which
# is far below any real limit but far above any sane table size.
DEFAULT_VMEM_BUDGET_BYTES = 16 * 1024 * 1024
DEFAULT_SMEM_BUDGET_BYTES = 256 * 1024

_SRC_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


@dataclasses.dataclass
class Finding:
    """One structured analyzer result.

    ``severity``: ``error`` (fails the CLI), ``warning`` (reported, does
    not fail), ``info`` (table/metric rows), ``skip`` (rule could not
    run here — visible so a green run never silently means "not
    checked").
    """
    rule: str
    severity: str
    obj: str                       # what the finding is about
    message: str
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"rule": self.rule, "severity": self.severity,
                "obj": self.obj, "message": self.message, "data": self.data}


@dataclasses.dataclass
class Rule:
    name: str                      # e.g. "vmem.budget"
    family: str                    # one of RULE_FAMILIES
    fn: Callable[["Context"], List[Finding]]
    doc: str


_REGISTRY: Dict[str, Rule] = {}


def rule(name: str, family: str):
    """Register ``fn(ctx) -> list[Finding]`` under ``name``."""
    assert family in RULE_FAMILIES, family

    def deco(fn):
        _REGISTRY[name] = Rule(name, family, fn, (fn.__doc__ or "").strip())
        return fn
    return deco


def registered_rules() -> Dict[str, Rule]:
    return dict(_REGISTRY)


def load_rules(families: Optional[Sequence[str]] = None) -> Dict[str, Rule]:
    """Import the rule modules for ``families`` (default: all), which
    registers their rules, and return the registry subset."""
    families = tuple(families or RULE_FAMILIES)
    mods = {"jaxpr": "jaxpr_rules", "vmem": "vmem", "purity": "purity",
            "retrace": "retrace", "races": "grid_eval", "hbm": "hbm",
            "numerics": "numerics"}
    for fam in families:
        if fam not in mods:
            raise ValueError(
                f"unknown rule family {fam!r}; pick from {RULE_FAMILIES}")
        importlib.import_module(f"repro.analysis.{mods[fam]}")
    return {n: r for n, r in _REGISTRY.items() if r.family in families}


@dataclasses.dataclass
class Context:
    """Everything a rule may consult.  The fixture hooks (``*_extra``,
    ``purity_root``) exist so the analyzer's own tests can point it at
    known-bad inputs and assert each rule fires."""
    src_root: str = _SRC_ROOT
    arch: str = "llama31_8b"        # smoke arch for engine-shaped rules
    configs: Tuple[str, ...] = ()   # () → the full shipped zoo
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES
    smem_budget_bytes: int = DEFAULT_SMEM_BUDGET_BYTES
    vmem_extra: Optional[str] = None    # path: module with TRACE_ENTRIES
    jaxpr_extra: Optional[str] = None   # path: module with JAXPR_ENTRIES
    purity_root: Optional[str] = None   # override source root for purity
    grid_extra: Optional[str] = None    # path: module with GRID_ENTRIES
    numerics_extra: Optional[str] = None  # path: module w/ NUMERICS_ENTRIES
    hbm_extra: Optional[str] = None     # path: module with COST_ENTRIES
    _cache: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # ---- shared lazy fixtures (built once, reused across rules) ----
    def smoke_model(self):
        """(cfg, model, params) for the smoke arch — used by the
        engine-shaped jaxpr and retrace rules."""
        if "model" not in self._cache:
            import dataclasses as dc

            import jax

            from repro.configs.base import get_smoke_config
            from repro.models import build_model

            cfg = dc.replace(get_smoke_config(self.arch), dtype="float32")
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            self._cache["model"] = (cfg, model, params)
        return self._cache["model"]

    def config_zoo(self) -> Tuple[str, ...]:
        if self.configs:
            return self.configs
        from repro.configs.base import ARCH_IDS, PAPER_ARCH_IDS
        return tuple(PAPER_ARCH_IDS) + tuple(ARCH_IDS)

    def load_extra(self, path: str):
        """Import a fixture module by file path (no sys.path games)."""
        spec = importlib.util.spec_from_file_location(
            "repro_analysis_fixture_" + os.path.basename(path).replace(
                ".py", ""), path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod


def run_rules(ctx: Context,
              families: Optional[Sequence[str]] = None,
              names: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the selected rules; a rule that raises becomes an ``error``
    finding (the analyzer must never pass by crashing)."""
    rules = load_rules(families)
    if names:
        unknown = set(names) - set(rules)
        if unknown:
            raise ValueError(f"unknown rules: {sorted(unknown)}")
        rules = {n: rules[n] for n in names}
    findings: List[Finding] = []
    for name in sorted(rules):
        try:
            findings.extend(rules[name].fn(ctx))
        except Exception as exc:  # noqa: BLE001 — reported, not swallowed
            findings.append(Finding(
                rule=name, severity="error", obj="analyzer",
                message=f"rule crashed: {type(exc).__name__}: {exc}"))
    return findings


def findings_to_json(findings: Sequence[Finding], **extra) -> str:
    by_sev: Dict[str, int] = {}
    for f in findings:
        by_sev[f.severity] = by_sev.get(f.severity, 0) + 1
    doc = {"schema_version": 1,
           "summary": by_sev,
           "failed": by_sev.get("error", 0) > 0,
           "findings": [f.to_dict() for f in findings]}
    doc.update(extra)
    return json.dumps(doc, indent=2, default=str)

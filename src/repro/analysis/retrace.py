"""Trace-budget rules — the ``retrace`` family.

One-dispatch serving only pays off if every shape bucket compiles ONCE.
Two rules guard that:

  * ``retrace.trace-budget``  drives a real (tiny, CPU-interpret) engine
    run end-to-end and compares the executor's observed ``trace_counts``
    against :func:`repro.serve.executor.declared_trace_keys`: every
    observed key must be declared, and every declared-and-hit bucket must
    have traced exactly once.  An undeclared key is an unbounded bucket
    (something is keying traces on a value, not a shape class); a count
    > 1 is a retrace — both error.
  * ``retrace.closure-captures``  inspects the raw step programs'
    closures (``__closure__`` cells, recursively through nested
    functions): a captured jax/numpy array or mutable container would
    either bake silently-stale data into the trace or defeat jit caching
    — the step programs may close over static config objects and the
    executor only.
"""
from __future__ import annotations

from typing import Any, List, Set

from repro.analysis import Context, Finding, rule

__all__ = []


def _err(rule_name, obj, msg, **data):
    return Finding(rule=rule_name, severity="error", obj=obj, message=msg,
                   data=data)


# ------------------------------------------------------ dry-run budget

def _dry_run(ctx: Context):
    """Serve a few tiny prompts through the fused engine and return it
    (cached — the jaxpr rules' fixture engine is separate on purpose:
    this one must actually execute)."""
    if "dry_engine" in ctx._cache:
        return ctx._cache["dry_engine"]
    import jax
    import numpy as np

    from repro.core.policy import DENSE
    from repro.serve.continuous import (ContinuousConfig,
                                        ContinuousServingEngine)

    cfg, model, params = ctx.smoke_model()
    pol = DENSE.with_(use_pallas_kernels=True)
    eng = ContinuousServingEngine(model, pol, ContinuousConfig(
        max_seq=64, num_slots=2, chunk_size=8, block_size=8,
        fused_step=True), _via_api=True)
    # staggered arrivals so prefill-only, hybrid, and decode-only buckets
    # all occur; lengths force multi-chunk prefill
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(i), (l,), 0, cfg.vocab_size))
        for i, l in enumerate((9, 17, 12))]
    for i, (p, a) in enumerate(zip(prompts, (0, 0, 3))):
        eng.submit(p, max_new_tokens=5, arrival=a)
    res = eng.run(params)
    ctx._cache["dry_engine"] = (eng, res)
    return ctx._cache["dry_engine"]


@rule("retrace.trace-budget", family="retrace")
def rule_trace_budget(ctx: Context) -> List[Finding]:
    """Observed trace_counts from a dry run ⊆ declared buckets, each
    traced exactly once."""
    from repro.serve.executor import declared_trace_keys

    eng, _res = _dry_run(ctx)
    declared = set(declared_trace_keys())
    findings: List[Finding] = []
    for key, n in sorted(eng.trace_counts.items()):
        if key not in declared:
            findings.append(_err(
                "retrace.trace-budget", key,
                f"undeclared trace bucket {key!r} (observed {n} traces); "
                "declare it in executor.STEP_BUCKETS/declared_trace_keys",
                count=n))
        elif n != 1:
            findings.append(_err(
                "retrace.trace-budget", key,
                f"bucket {key!r} traced {n} times — a retrace means some "
                "operand is keying compilation on a value", count=n))
    if not eng.trace_counts:
        findings.append(_err(
            "retrace.trace-budget", "engine",
            "dry run recorded no trace_counts — the probe is broken"))
    if not findings:
        findings.append(Finding(
            rule="retrace.trace-budget", severity="info", obj="engine",
            message=f"{len(eng.trace_counts)} buckets, one trace each "
                    f"({sorted(eng.trace_counts)})",
            data={"trace_counts": dict(eng.trace_counts)}))
    return findings


# ------------------------------------------------- closure-capture lint

_BAD_CAPTURE_TYPES = (dict, list, set, bytearray)


def _is_array(obj: Any) -> bool:
    # duck-typed: jax.Array and np.ndarray both carry shape+dtype
    return hasattr(obj, "shape") and hasattr(obj, "dtype")


def _scan_closure(fn, path: str, seen: Set[int], findings: List[Finding],
                  rule_name: str) -> None:
    if not callable(fn) or id(fn) in seen:
        return
    seen.add(id(fn))
    closure = getattr(fn, "__closure__", None) or ()
    names = getattr(getattr(fn, "__code__", None), "co_freevars", ())
    for name, cell in zip(names, closure):
        try:
            val = cell.cell_contents
        except ValueError:          # empty cell
            continue
        where = f"{path} captures {name!r}"
        if _is_array(val):
            findings.append(_err(
                rule_name, path,
                f"{where}: a {type(val).__name__} array — traced programs "
                "must take arrays as operands, not closure state",
                capture=name))
        elif isinstance(val, _BAD_CAPTURE_TYPES):
            findings.append(_err(
                rule_name, path,
                f"{where}: a mutable {type(val).__name__} — step closures "
                "may hold only static config/callables", capture=name))
        elif callable(val) and getattr(val, "__closure__", None):
            _scan_closure(val, f"{path}.{name}", seen, findings, rule_name)


@rule("retrace.closure-captures", family="retrace")
def rule_closure_captures(ctx: Context) -> List[Finding]:
    """No raw step program closes over arrays or mutable containers."""
    from repro.serve.executor import STEP_BUCKETS

    eng, _res = _dry_run(ctx)
    findings: List[Finding] = []
    seen: Set[int] = set()
    for bucket, name in STEP_BUCKETS.items():
        for oracle in (False, True):
            label = name + ("_oracle" if oracle else "")
            _scan_closure(eng.exec.step_program(bucket, oracle=oracle),
                          label, seen, findings,
                          "retrace.closure-captures")
    if not findings:
        findings.append(Finding(
            rule="retrace.closure-captures", severity="info",
            obj="executor",
            message=f"{2 * len(STEP_BUCKETS)} step closures clean"))
    return findings

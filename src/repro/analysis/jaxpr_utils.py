"""Shared jaxpr traversal for dispatch-layer contracts.

Several invariants in this repo are statements about what a traced
program *lowers to* (exactly one ``pallas_call`` per projection, zero
pool-view gathers/scatters outside kernels, no stray effects).  They all
need the same recursive walk over sub-jaxprs (scan / pjit / remat /
custom_vjp / shard_map carry their bodies in eqn params), so the walk —
and the counters built on it — lives here once.  jax API drift in jaxpr
internals (this repo already shims 0.4.37 drift elsewhere) then has a
single place to land.

Promoted from ``tests/jaxpr_utils.py`` (ISSUE 9): the test helpers
``_count_pallas_calls`` / ``_pool_gather_count`` / ``_pool_eqn_count``
that used to be copy-pasted across suites are now the public
:func:`count_pallas_calls` / :func:`pool_eqn_count`; a thin re-export
shim remains in ``tests/`` for old imports.

This module deliberately does NOT import jax — it only walks objects it
is handed, so the pure-host analysis rules can import it freely.
"""
from __future__ import annotations

from typing import Any, Iterable, Iterator, Set, Tuple, Union

__all__ = [
    "iter_eqns",
    "count_pallas_calls",
    "has_pallas_call",
    "pallas_call_eqns",
    "pool_eqn_count",
    "eqn_dtypes",
]


def unwrap_jaxpr(j):
    """ClosedJaxpr → Jaxpr (anything with ``.eqns`` passes through)."""
    return j.jaxpr if hasattr(j, "jaxpr") else j


def iter_eqns(jaxpr) -> Iterator[Any]:
    """Yield every equation in ``jaxpr`` and, recursively, in any jaxpr
    nested inside equation params (ClosedJaxpr, Jaxpr, or lists thereof).
    Accepts a ClosedJaxpr or a raw Jaxpr."""
    jaxpr = unwrap_jaxpr(jaxpr)

    def sub(v):
        if hasattr(v, "jaxpr"):              # ClosedJaxpr
            return [v.jaxpr]
        if hasattr(v, "eqns"):               # Jaxpr
            return [v]
        if isinstance(v, (tuple, list)):
            out = []
            for item in v:
                out.extend(sub(item))
            return out
        return []

    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for j in sub(v):
                yield from iter_eqns(j)


def pallas_call_eqns(jaxpr) -> Iterator[Any]:
    """Every ``pallas_call`` equation anywhere in the program."""
    for e in iter_eqns(jaxpr):
        if e.primitive.name == "pallas_call":
            yield e


def count_pallas_calls(jaxpr) -> int:
    return sum(1 for _ in pallas_call_eqns(jaxpr))


def has_pallas_call(jaxpr) -> bool:
    return any(True for _ in pallas_call_eqns(jaxpr))


def _as_shape_set(shapes) -> Set[Tuple[int, ...]]:
    """Accept one shape tuple or an iterable of them."""
    if shapes and isinstance(next(iter(shapes)), int):
        return {tuple(shapes)}
    return {tuple(s) for s in shapes}


def pool_eqn_count(
    jaxpr,
    pool_shapes: Union[Tuple[int, ...], Iterable[Tuple[int, ...]]],
    prim: str = "gather",
) -> int:
    """Count ``prim`` equations (``gather``/``scatter`` & friends) whose
    operands or outputs carry any of ``pool_shapes`` (the 4D KV pool or
    its flattened row view), recursing into sub-jaxprs.

    In-kernel refs are block-shaped, so anything this counts lives
    OUTSIDE a ``pallas_call`` by construction — a nonzero count on a
    kernels-on step program means a pool-sized gather/scatter escaped to
    HBM.
    """
    shapes = _as_shape_set(pool_shapes)
    return sum(
        1 for eqn in iter_eqns(jaxpr)
        if eqn.primitive.name == prim and any(
            tuple(getattr(getattr(v, "aval", None), "shape", ()))
            in shapes for v in list(eqn.invars) + list(eqn.outvars)))


def eqn_dtypes(jaxpr) -> Set[str]:
    """The set of dtype names appearing on any equation operand/output
    anywhere in the program (used by the f64-leak rule)."""
    seen: Set[str] = set()
    for eqn in iter_eqns(jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None:
                seen.add(str(dt))
    return seen

"""Machine-verified HBM cost model.

``repro.kernels.COST_MODEL`` documents, as closed-form formulas, how
many HBM bytes each kernel call moves under Mosaic's pipelined
fetch/write semantics.  Docs rot; this module makes them a checked
contract by MEASURING the same quantity from the kernels' real grids:

    measured = Σ_inputs  (fetch runs  × block bytes)
             + Σ_outputs (write runs × block bytes)

where "runs" is the maximal-constant-run compression of each operand's
per-grid-step block-index sequence, obtained by concretely evaluating
every BlockSpec index map over the enumerated grid
(:mod:`repro.analysis.grid_eval`) — i.e. exactly the refetch/write-back
elision Mosaic's pipeline performs.  The formulas are an independent
re-derivation from the documented contract, so >10% divergence means a
kernel's grid/BlockSpecs changed without the cost model (or the model
was wrong all along) — either way, CI fails until they agree.

Three rules:

  ``hbm.cost-model``  measured vs formula for every grid-zoo entry, both
                      directions of coverage (a zoo entry without a
                      formula and a formula without a zoo entry are
                      errors — silent gaps would fake a green run).
  ``hbm.doc-sync``    the generated table in the ``repro.kernels``
                      docstring must equal ``cost_model_doc()``.
  ``hbm.extra-entries``  fixture hook (``--hbm-extra``): COST_ENTRIES
                      ``(name, fn, args, bytes_fn, dims)`` tuples get
                      the same measured-vs-formula treatment.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

from repro.analysis import Context, Finding, rule
from repro.analysis.grid_eval import (GridEval, _runs, eval_pallas_eqn,
                                      trace_and_collect)

__all__ = ["measured_call_bytes", "DIVERGENCE_TOLERANCE"]

DIVERGENCE_TOLERANCE = 0.10


def measured_call_bytes(ge: GridEval) -> Tuple[int, Dict[str, Any]]:
    """(total bytes, per-operand breakdown) for one evaluated grid."""
    total = 0
    detail: Dict[str, Any] = {}
    for og in ge.inputs + ge.outputs:
        runs = len(_runs(og.indices))
        byts = runs * og.block_bytes
        total += byts
        detail[og.label] = {"runs": runs, "block_bytes": og.block_bytes,
                            "bytes": byts}
    return total, detail


def _measure_traced(name: str, fn, args) -> Any:
    """Measured bytes summed over every pallas_call the trace contains,
    or an error-message string."""
    calls = trace_and_collect(fn, *args)
    if not calls:
        return f"{name}: traced zero pallas_calls — nothing to measure"
    total = 0
    details = []
    for call in calls:
        ge = eval_pallas_eqn(call.eqn, call.invals)
        if isinstance(ge, str):
            return f"{name}: {ge}"
        byts, detail = measured_call_bytes(ge)
        total += byts
        details.append({"kernel": ge.kernel, "grid": list(ge.grid),
                        "bytes": byts, "operands": detail})
    return total, details


def _compare(name: str, rule_name: str, measured, predicted: int,
             details) -> Finding:
    denom = max(measured, 1)
    div = abs(measured - predicted) / denom
    if div > DIVERGENCE_TOLERANCE:
        return Finding(
            rule=rule_name, severity="error", obj=name,
            message=(f"{name}: measured {measured} B vs COST_MODEL "
                     f"{predicted} B — {div:.1%} divergence (> "
                     f"{DIVERGENCE_TOLERANCE:.0%}); the kernel's "
                     "grid/BlockSpecs and its documented cost formula "
                     "disagree"),
            data={"measured": measured, "predicted": predicted,
                  "divergence": div, "calls": details})
    return Finding(
        rule=rule_name, severity="info", obj=name,
        message=(f"{name}: measured {measured} B, model {predicted} B "
                 f"({div:.1%} divergence)"),
        data={"measured": measured, "predicted": predicted,
              "divergence": div})


@rule("hbm.cost-model", family="hbm")
def rule_hbm_cost_model(ctx: Context) -> List[Finding]:
    """Measured HBM bytes (block footprints × grid fetch/write runs,
    refetch elision modelled) vs ``repro.kernels.COST_MODEL`` for every
    grid-zoo entry, with two-directional coverage."""
    from repro.analysis.vmem import grid_zoo_entries
    from repro.configs.base import get_smoke_config
    from repro.kernels import COST_MODEL

    cfg = get_smoke_config(ctx.arch)
    entries = grid_zoo_entries(cfg)
    findings: List[Finding] = []
    seen = set()
    for e in entries:
        seen.add(e.name)
        if e.name not in COST_MODEL:
            findings.append(Finding(
                rule="hbm.cost-model", severity="error", obj=e.name,
                message=f"{e.name} has no COST_MODEL entry — its HBM "
                "traffic is undocumented"))
            continue
        res = _measure_traced(e.name, e.fn, e.args)
        if isinstance(res, str):
            findings.append(Finding(rule="hbm.cost-model",
                                    severity="error", obj=e.name,
                                    message=res))
            continue
        measured, details = res
        predicted = int(COST_MODEL[e.name]["bytes"](e.dims))
        findings.append(_compare(e.name, "hbm.cost-model", measured,
                                 predicted, details))
    for name in sorted(set(COST_MODEL) - seen):
        findings.append(Finding(
            rule="hbm.cost-model", severity="error", obj=name,
            message=f"COST_MODEL documents {name} but grid_zoo_entries "
            "has no such kernel — stale model entry"))
    return findings


@rule("hbm.doc-sync", family="hbm")
def rule_hbm_doc_sync(ctx: Context) -> List[Finding]:
    """The marker-delimited table in the ``repro.kernels`` docstring is
    generated from ``COST_MODEL`` — drift means someone edited one
    without the other (regenerate: ``python -m repro.analysis
    --hbm-table``)."""
    import repro.kernels as kernels_mod

    want = kernels_mod.cost_model_doc()
    doc = kernels_mod.__doc__ or ""
    start = want.splitlines()[0]
    end = want.splitlines()[-1]
    i, j = doc.find(start), doc.find(end)
    if i < 0 or j < 0:
        return [Finding(
            rule="hbm.doc-sync", severity="error", obj="repro.kernels",
            message="kernels/__init__.py docstring lost the generated "
            "HBM table markers")]
    got = doc[i:j + len(end)]
    if got != want:
        return [Finding(
            rule="hbm.doc-sync", severity="error", obj="repro.kernels",
            message="kernels/__init__.py HBM table drifted from "
            "COST_MODEL — regenerate with `python -m repro.analysis "
            "--hbm-table`",
            data={"want": want, "got": got})]
    return [Finding(rule="hbm.doc-sync", severity="info",
                    obj="repro.kernels",
                    message="generated HBM table matches COST_MODEL")]


@rule("hbm.extra-entries", family="hbm")
def rule_hbm_extra(ctx: Context) -> List[Finding]:
    """Fixture hook: ``--hbm-extra`` module's ``COST_ENTRIES``
    ``(name, fn, args, bytes_fn, dims)`` get measured-vs-model checks —
    the analyzer's own tests seed a deliberately stale formula here."""
    if not ctx.hbm_extra:
        return [Finding(rule="hbm.extra-entries", severity="info",
                        obj="fixtures", message="no extra cost entries")]
    mod = ctx.load_extra(ctx.hbm_extra)
    findings: List[Finding] = []
    for name, fn, args, bytes_fn, dims in mod.COST_ENTRIES:
        res = _measure_traced(name, fn, args)
        if isinstance(res, str):
            findings.append(Finding(rule="hbm.extra-entries",
                                    severity="error", obj=name,
                                    message=res))
            continue
        measured, details = res
        f = _compare(name, "hbm.extra-entries", measured,
                     int(bytes_fn(dims)), details)
        findings.append(f)
    return findings

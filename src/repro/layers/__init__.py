from repro.layers.linear import dense_linear, init_linear, sparse_linear

__all__ = ["dense_linear", "init_linear", "sparse_linear"]

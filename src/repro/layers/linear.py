"""SparseLinear — every projection in the model zoo goes through here.

Dispatch order per call (all static except the per-layer skip flag):

  1. quantized?   (``wq`` present → W8A8 path; Outstanding-sparse prunes the
                   *smoothed* activations, paper §Outstanding-sparse)
  2. prunable?    (policy says this module is pruned in this phase)
  3. mode:        per-token N:M mask (paper-faithful) or tile-consensus
                   compacted matmul (TPU-native, DESIGN.md §2)
  4. backend:     ``policy.use_pallas_kernels`` lowers the pruned matmul /
                   Outstanding-sparse chain to one fused pallas_call
                   (``repro.kernels.ops``); the jnp forms below remain the
                   bit-exact oracle and the ``layer_flag`` fallback

``layer_flag`` supports ``lax.scan``-stacked layers: the per-layer q/gate
skip list becomes a boolean vector scanned alongside the weights, selecting
pruned vs dense input with a ``jnp.where`` (element-wise; leaves matmul
FLOPs untouched in per-token mode).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import pruner, quant
from repro.core.policy import SparsityPolicy
from repro.core.pruner import SCALE_KEY

__all__ = ["init_linear", "dense_linear", "sparse_linear"]


def init_linear(
    rng: jax.Array,
    d_in: int,
    d_out: int,
    bias: bool = False,
    dtype: Any = jnp.float32,
    scale: Optional[float] = None,
) -> Dict[str, jax.Array]:
    """He/Glorot-ish init: normal with std 1/sqrt(d_in) (or ``scale``)."""
    std = scale if scale is not None else d_in**-0.5
    p = {"w": (jax.random.normal(rng, (d_in, d_out)) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_linear(x: jax.Array, p: Dict[str, jax.Array]) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def _quantized(x: jax.Array, p: Dict[str, jax.Array], prune: bool,
               policy: SparsityPolicy, layer_flag) -> jax.Array:
    """Outstanding-sparse path: smooth → (prune) → int8 matmul.

    With ``policy.use_pallas_kernels`` the whole chain collapses into one
    fused ``osparse_matmul`` pallas_call (no smoothed/masked/quantized
    copies in HBM) — for BOTH phases: the decode-phase call sets
    ``prune=False`` statically, which skips the N:M selection in-kernel and
    runs the plain smoothed W8A8 GEMM, and the bias-add rides the dequant
    epilogue.  ``layer_flag`` models keep the jnp mask-select form — the
    flag picks pruned vs dense *input*, which the fused GEMM cannot
    express without computing both.
    """
    per_token = bool(p.get("per_token", False))
    if layer_flag is None and policy.use_pallas_kernels:
        from repro.kernels import ops

        y = ops.osparse_matmul(
            x, p["wq"], p["smooth"], p.get(SCALE_KEY), p["w_scale"],
            policy.n, policy.m,
            act_scale=None if per_token else p["act_scale"],
            bias=p.get("b"), prune=prune, per_token=per_token)
        return y.astype(x.dtype)

    xs = x.astype(jnp.float32) / p["smooth"]
    if prune:
        xp = pruner.prune_input(xs, p.get(SCALE_KEY), policy)
        if layer_flag is not None:
            xp = jnp.where(layer_flag, xp, xs)
        xs = xp
    if per_token:
        xq, ts = quant.quantize_act_per_token(xs)
        y = quant.quantized_matmul(xq, p["wq"], ts, p["w_scale"])
    else:
        xq = quant.quantize_act_per_tensor(xs, p["act_scale"])
        y = quant.quantized_matmul(xq, p["wq"], p["act_scale"], p["w_scale"])
    y = y.astype(x.dtype)
    if "b" in p:
        y = y + p["b"]
    return y


def sparse_linear(
    x: jax.Array,
    p: Dict[str, jax.Array],
    module: str,
    policy: SparsityPolicy,
    phase: str,
    layer_idx: Optional[int] = None,
    layer_flag: Optional[jax.Array] = None,
) -> jax.Array:
    """Linear projection honoring the Amber Pruner policy.

    Args:
      module:     canonical projection name ('q_proj', 'down_proj', ...).
      layer_idx:  static layer index (unrolled models) — consults the
                  policy's skip list directly.
      layer_flag: traced bool (scan-stacked models) — True ⇒ prune here.
    """
    prune = policy.active(phase) and policy.should_prune(module, layer_idx)

    if "wq" in p:  # Outstanding-sparse / plain W8A8
        return _quantized(x, p, prune, policy, layer_flag if prune else None)

    if not prune:
        return dense_linear(x, p)

    scale = p.get(SCALE_KEY)
    use_fused = policy.use_pallas_kernels and layer_flag is None
    if policy.tile_consensus:
        pol = policy if use_fused else policy.with_(use_pallas_kernels=False)
        y = pruner.sparse_matmul(x, p["w"], scale, pol, bias=p.get("b"))
        if layer_flag is not None:
            # compacted inputs can't be element-wise selected against the
            # dense ones, so flagged layers pick between the two outputs
            y = jnp.where(layer_flag, y, dense_linear(x, p))
    elif use_fused:
        # fused prune+GEMM path (one pallas_call under the policy flag,
        # bias-add folded into the kernel epilogue)
        y = pruner.sparse_matmul(x, p["w"], scale, policy, bias=p.get("b"))
    else:
        # mask-select form: scan-stacked models pick pruned vs dense input
        # with a traced per-layer flag, so the mask must be materialized
        xp = pruner.prune_input(x, scale, policy)
        if layer_flag is not None:
            xp = jnp.where(layer_flag, xp, x)
        y = xp @ p["w"]
        if "b" in p:
            y = y + p["b"]
    return y

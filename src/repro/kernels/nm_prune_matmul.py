"""Pallas TPU kernel: fused per-token N:M prune + GEMM (one X pass).

The naive per-token path (``prune_input`` then ``xp @ w``) materializes the
masked activations: X streams HBM→VMEM for scoring/masking, the masked copy
is written back to HBM, then the dense matmul reads it again — three full
passes over a T×D tensor that exists only to be multiplied once.  This
kernel fuses score → iterative top-N select → mask → GEMM into a single
``pallas_call``: the masked copy lives only in registers and is never
materialized in HBM.  The GEMM's own block streaming (each X block is
re-fetched once per output block, as in any tiled matmul — dense included)
is identical in both forms, so the fusion saves exactly the prune stage's
traffic: one full X write plus one full X read per call.

Extra HBM traffic vs the dense GEMM:   none          (fused, this kernel)
                                 vs:   write Xp + read Xp   (jnp path)

The grid is (T/bt, N_out/bo, D/bk) with a float32 accumulator scratch; the
per-token N:M selection is local to each contiguous group of M channels, so
k-blocking (bk % m == 0) is exact — every k-step prunes its own groups and
accumulates its partial product.  Selection is the same iterative
first-occurrence argmax as ``nm_prune_pallas`` (lowest index wins on ties),
so masks are bit-identical to the ``nm.apply_nm`` oracle.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.nm_prune import _select_topn_mask

__all__ = ["nm_prune_matmul_pallas"]


def _kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref, *, n: int,
            m: int, has_scale: bool, has_bias: bool, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                     # (bt, bk)
    s = jnp.abs(x.astype(jnp.float32))
    if has_scale:
        s = s * scale_ref[...].astype(jnp.float32)[None, :]
    bt, bk = s.shape
    keep = _select_topn_mask(s.reshape(bt, bk // m, m), n, m).reshape(bt, bk)
    xp = jnp.where(keep, x.astype(jnp.float32), 0.0)
    acc_ref[...] += jnp.dot(xp, w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _finish():
        acc = acc_ref[...]
        if has_bias:  # bias-add folded into the epilogue (free: acc is hot)
            acc = acc + bias_ref[...].astype(jnp.float32)[None, :]
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "m", "block_t", "block_o",
                                             "block_k", "interpret"))
def nm_prune_matmul_pallas(
    x: jax.Array,                       # (T, D)
    w: jax.Array,                       # (D, N_out)
    scale: Optional[jax.Array],         # (D,) or None
    n: int,
    m: int,
    bias: Optional[jax.Array] = None,   # (N_out,) or None — epilogue add
    block_t: int = 256,
    block_o: int = 256,
    block_k: int = 512,
    interpret: bool = True,             # CPU container default; False on TPU
) -> jax.Array:
    t, d = x.shape
    n_out = w.shape[-1]
    bt = min(block_t, t)
    bo = min(block_o, n_out)
    bk = min(block_k, d)
    assert t % bt == 0 and n_out % bo == 0 and d % bk == 0 and bk % m == 0, (
        t, d, n_out, bt, bo, bk, m)
    k_steps = d // bk
    has_scale = scale is not None
    if not has_scale:
        scale = jnp.ones((d,), jnp.float32)
    has_bias = bias is not None
    if not has_bias:
        bias = jnp.zeros((n_out,), jnp.float32)

    out_dtype = jnp.result_type(x.dtype, w.dtype)
    return pl.pallas_call(
        functools.partial(_kernel, n=n, m=m, has_scale=has_scale,
                          has_bias=has_bias, k_steps=k_steps),
        grid=(t // bt, n_out // bo, k_steps),
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bo), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
            pl.BlockSpec((bo,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, bo), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n_out), out_dtype),
        scratch_shapes=[pltpu.VMEM((bt, bo), jnp.float32)],
        interpret=interpret,
    )(x, w, scale, bias)

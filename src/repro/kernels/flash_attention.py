"""Pallas TPU kernel: causal flash attention (online softmax, VMEM tiles).

Why it exists here: the exact-roofline pass (EXPERIMENTS.md §Roofline)
shows every 32k prefill/train cell is MEMORY-bound, and the dominant
traffic is the O(T·S) f32 score/probability tensors the jnp online-softmax
attention materializes in HBM at every chunk (fusion cannot keep a dot's
output resident on CPU/TPU XLA).  Flash attention keeps the (bt × bk)
score tile in VMEM/registers: HBM traffic drops from O(T·S) to
O(T·S/bt · d) operand reads — i.e. the memory term collapses to operand
streaming (napkin math in §Perf A, iteration A4).

Layout: q (B, Hq, T, d), k/v (B, Hkv, S, d) with Hq a multiple of Hkv —
GQA is resolved in the index map (query head h streams KV head
h // (Hq/Hkv)), so grouped KV is never head-repeated in HBM.  Grid
(B, Hq, T/bt, S/bk) with the KV-block axis innermost; scratch (m, l, acc)
carries the running softmax state across KV blocks; finalization divides
on the last block.  Causal masking by absolute block offsets.  MXU
alignment: bt, bk multiples of 128 on real hardware (any value in
interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG = -1e30


# The online-softmax scratch state machine, shared with the paged-attention
# kernel (kernels/paged_attention.py): this is the numerically delicate part
# (fully-masked-row guard, l clamp), so it lives in exactly one place while
# each kernel keeps its own masking and block-walk logic.

def softmax_init(m_ref, l_ref, acc_ref) -> None:
    m_ref[...] = jnp.full_like(m_ref, _NEG)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def softmax_update(s, v, m_ref, l_ref, acc_ref) -> None:
    """Fold one KV block into the running (m, l, acc) scratch.

    ``s`` is the already-masked (bq, bk) score tile — invalid lanes hold
    ``_NEG``, which the ``s > _NEG / 2`` guard turns into exactly-zero
    probabilities (a fully-masked row would otherwise yield
    exp(_NEG − _NEG) = 1 per lane).  ``v`` is the (bk, d) value tile.
    """
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(s > _NEG / 2, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def softmax_finalize(l_ref, acc_ref, dtype):
    """Normalized (bq, d) output tile; rows that never saw a valid lane
    (l = 0) come out as zeros instead of dividing by zero."""
    return (acc_ref[...] /
            jnp.maximum(l_ref[...], 1e-20)[:, None]).astype(dtype)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_kv: int, bq: int, bk: int, causal: bool, scale: float,
            window: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        softmax_init(m_ref, l_ref, acc_ref)

    # block-level band check: a KV block entirely outside
    # (q_lo − window, q_hi] contributes nothing — skip its matmuls (on TPU
    # Mosaic this prunes the MXU work, making SWA prefill O(T·window))
    q_lo, q_hi = qi * bq, qi * bq + bq - 1
    k_lo, k_hi = ki * bk, ki * bk + bk - 1
    visible = jnp.bool_(True)
    if causal:
        visible = visible & (k_lo <= q_hi)
    if window > 0:
        visible = visible & (k_hi > q_lo - window)

    @pl.when(visible)
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        if causal:
            s = jnp.where(k_pos <= q_pos, s, _NEG)
        if window > 0:
            s = jnp.where(k_pos > q_pos - window, s, _NEG)

        softmax_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = softmax_finalize(l_ref, acc_ref, o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_pallas(
    q: jax.Array,                 # (B, Hq, T, d)
    k: jax.Array,                 # (B, Hkv, S, d); Hq % Hkv == 0
    v: jax.Array,                 # (B, Hkv, S, d)
    causal: bool = True,
    window: int = 0,              # >0 → sliding-window (SWA/local) band
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,       # CPU container default
) -> jax.Array:
    b, h, t, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = h // hkv
    bq = min(block_q, t)
    bk = min(block_k, s)
    assert h % hkv == 0 and t % bq == 0 and s % bk == 0, (h, hkv, t, s)
    n_kv = s // bk
    scale = d**-0.5

    # query head hi streams KV head hi // g straight from the grouped
    # layout — no head-repeated KV copy ever lands in HBM
    out = pl.pallas_call(
        functools.partial(_kernel, n_kv=n_kv, bq=bq, bk=bk, causal=causal,
                          scale=scale, window=window),
        grid=(b, h, t // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out

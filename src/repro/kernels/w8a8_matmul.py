"""Pallas TPU kernel: W8A8 int8 matmul with per-channel dequant.

The Outstanding-sparse runtime GEMM: int8 × int8 → int32 accumulation on
the MXU, dequantized on the way out with the static per-tensor activation
scale and per-output-channel weight scales (SmoothQuant rewrite done
offline in ``repro/core/quant.py``).

Classic 3D matmul grid (T/bt, N/bo, D/bk) with an int32 VMEM accumulator
scratch; the dequant multiply happens once, on the final reduction step —
int8 tiles stream through VMEM at half the bf16 footprint, doubling
effective HBM bandwidth (the reason W8A8 helps decode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["w8a8_matmul_pallas"]


def _kernel(x_ref, w_ref, ws_ref, xs_ref, o_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == k_steps - 1)
    def _finish():
        x_scale = xs_ref[0]
        w_scale = ws_ref[...].astype(jnp.float32)
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32) * x_scale * w_scale[None, :]
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_o", "block_k",
                                             "interpret"))
def w8a8_matmul_pallas(
    xq: jax.Array,                      # (T, D) int8
    wq: jax.Array,                      # (D, N_out) int8
    x_scale: jax.Array,                 # scalar f32
    w_scale: jax.Array,                 # (N_out,) f32
    block_t: int = 256,
    block_o: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    t, d = xq.shape
    n_out = wq.shape[-1]
    bt, bo, bk = min(block_t, t), min(block_o, n_out), min(block_k, d)
    assert t % bt == 0 and n_out % bo == 0 and d % bk == 0
    k_steps = d // bk

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=(t // bt, n_out // bo, k_steps),
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bo), lambda i, j, k: (k, j)),
            pl.BlockSpec((bo,), lambda i, j, k: (j,)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, bo), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n_out), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bt, bo), jnp.int32)],
        interpret=interpret,
    )(xq, wq, w_scale, x_scale.reshape(1))

"""Pallas TPU kernels for the Amber Pruner hot paths.

Kernel family (each is ONE ``pallas_call`` — every intermediate lives in
VMEM/registers, never HBM):

  nm_prune         — fused scoring + per-token N:M top-k + mask
  nm_prune_matmul  — fused prune + GEMM (the per-token projection itself)
  nm_spmm          — tile-consensus compacted matmul (TPU-native SpMM),
                     k-blocked over D with an f32 accumulator scratch
  osparse_matmul   — Outstanding-sparse chain: smooth-divide → prune →
                     int8 quantize (static or per-token) → int8 GEMM →
                     dequant
  w8a8_matmul      — plain int8×int8→int32 GEMM with SmoothQuant dequant
  flash_attention  — causal online-softmax attention, VMEM score tiles
                     (full and sliding-window self-attention; off-band KV
                     blocks are skipped at block granularity)
  paged_attention  — flash attention over the paged KV pool: the KV grid
                     axis walks the per-row block table (scalar prefetch,
                     ``pltpu.PrefetchScalarGridSpec``), streaming each
                     physical block through VMEM and skipping ``-1`` /
                     ≥ ``kv_len`` / off-band blocks before their matmuls
                     issue — chunked prefill at cache offsets and
                     vector-position decode share one kernel
  paged_kv_scatter — the write side of the paged pool (same module): per
                     logical block the scalar-prefetched table picks the
                     physical block, a one-hot selection matmul merges the
                     chunk's rows into it, and ``input_output_aliases``
                     updates the pool in place — invisible grid steps
                     write nothing, so untouched blocks keep their
                     content.  Replaces the host-side flat-index
                     ``.at[].set`` scatter in the serving hot path.

Dispatch order for model projections (``layers.linear.sparse_linear``):

  1. ``SparsityPolicy.use_pallas_kernels`` — the policy flag routes each
     prunable linear onto the fused kernel for its mode (per-token →
     ``nm_prune_matmul``; tile-consensus → ``nm_spmm``; Outstanding-sparse
     W8A8 → ``osparse_matmul``; decode-phase W8A8 → ``osparse_matmul``
     with static ``prune=False``, skipping selection in-kernel).  A
     projection bias rides the kernels' f32 dequant/accumulator epilogue
     instead of a separate HBM pass.  Scan-stacked ``layer_flag`` models
     always fall back to the jnp mask-select form.
  2. ``REPRO_PALLAS_INTERPRET`` env switch — ``1`` (default, CPU container)
     runs the kernels through the Pallas interpreter; ``0`` compiles the
     same BlockSpecs to Mosaic on a real TPU.
  3. The pure-jnp implementations in ``repro.core`` remain the bit-exact
     oracles (``kernels.ref`` wraps them per kernel for the test sweeps).

One-pass HBM cost model (per projection call, activation bytes B = T·D·s;
"pass" = one full traversal of X *beyond* the tiled GEMM's own block
streaming, which is identical for the fused and unfused forms):

  nm_prune_matmul   0 extra passes — the mask lives in registers; the jnp
                    chain spends 2 (write the masked copy, re-read it).
  osparse_matmul    static scale: 0 extra passes; per-token scale: 1 (the
                    absmax sweep, run once per token block) — and ZERO
                    intermediate writes either way, vs the jnp chain's ~4
                    reads + 3 writes (smoothed, masked, quantized copies).
  nm_spmm           0 extra passes at (n/m) of the dense MXU FLOPs; VMEM
                    residency is per k-block (bt·bk + bk·bo), so reduction
                    depth D is unbounded (16k+ tiles fine).

Paged-attention HBM cost model (per serving call over a pool of
``num_blocks`` blocks of ``bs`` rows, table width ``mb``, per-row valid
length ``kv_len``; row bytes r = Hkv·hd·s):

  gather oracle     materializes the (B, mb·bs, Hkv, hd) logical view in
                    HBM — B·mb·bs·r written then re-read by the attention
                    scan (2 extra logical-view passes per layer per call),
                    and the traffic is O(mb·bs) regardless of how little
                    of the table is allocated.  For decode (T = 1) this is
                    the dominant term of the whole step.
  paged_attention   0 extra passes — each allocated block streams HBM→VMEM
                    exactly once per (head, q-tile); traffic is
                    O(ceil(kv_len/bs)·bs) ≈ O(kv_len) per row, so decode
                    attention reads O(pos) rows instead of O(mb·bs), and
                    skipped blocks (unallocated tail, causal future,
                    off-window) never issue their DMA-consuming matmuls.
  flat-idx scatter  the jnp KV write builds (B·T,) flat indices and
                    scatters through the POOL-SIZED flat view — XLA
                    round-trips the full pool value per chunk/decode call
                    (read + write of num_blocks·bs·r per K and V leaf),
                    independent of how few rows change.
  paged_kv_scatter  touches only the ≤ ceil(T/bs)+1 logical blocks a
                    chunk overlaps, per batch row: each visible block is
                    one bs·r read + write through the aliased output;
                    invisible grid steps elide even the refetch (their
                    index map parks on an already-resident block and the
                    kernel writes nothing).

Dispatch for the paged pool (``models/attention.paged_attention`` reads,
``models/attention.paged_kv_update`` writes) runs the same ladder as the
projections: ``SparsityPolicy.use_pallas_kernels`` →
``REPRO_PALLAS_INTERPRET`` (interpret vs Mosaic) → the jnp
gather-then-attend / flat-index-scatter oracles (the gather oracle is
always used for windowed paged shapes and non-tile-divisible query
counts).  Both directions carry chaos-harness sites
(``kernel.paged_attention``, ``kernel.paged_scatter``).

Static VMEM footprints (worst case across the shipped config zoo, from
``PYTHONPATH=src python -m repro.analysis --vmem-table`` — regenerate
after changing any BlockSpec/grid/scratch; the ``vmem.budget`` analyzer
rule fails CI past 16 MiB/core).  The estimate is 2x the in/out block
bytes (Mosaic double buffering) + VMEM scratch; SMEM carries the
scalar-prefetched block tables:

  kernel               VMEM       SMEM     worst config, grid
  flash_attention       1.13 MiB     0 B   recurrentgemma_2b (1,10,8,8)
  nm_prune              2.00 MiB     0 B   llama31_8b        (1,8)
  nm_prune_matmul       2.76 MiB     0 B   llama31_8b        (1,56,8)
  nm_spmm               8.77 MiB     0 B   llama31_8b        (1,56,2)
  osparse_matmul        2.01 MiB     0 B   llama31_8b        (1,56,16)
  osparse_w8a8_decode   0.32 MiB     0 B   llama31_8b        (1,56,8)
  paged_attention       0.69 MiB  8256 B   recurrentgemma_2b (8,10,2,256)
  paged_kv_scatter     10.00 MiB  8256 B   rwkv6_7b          (8,9)
  w8a8_matmul           1.25 MiB     0 B   llama31_8b        (1,56,8)

(``paged_kv_scatter``'s bound holds because the wrapper splits chunks
whose resident tile would exceed ~2 MiB/leaf into sub-chunk calls —
MHA-width caches at chunk 256 used to hit 18 MiB.)

``ops``  — jit'd wrappers (batched, padded, interpret-mode switch)
``ref``  — pure-jnp oracles used by the allclose test sweeps
"""
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import (paged_attention_pallas,
                                           paged_kv_scatter_pallas)
from repro.kernels.ops import (
    nm_prune,
    nm_prune_matmul,
    nm_spmm,
    osparse_matmul,
    w8a8_matmul,
)

__all__ = [
    "nm_prune",
    "nm_prune_matmul",
    "nm_spmm",
    "osparse_matmul",
    "w8a8_matmul",
    "flash_attention_pallas",
    "paged_attention_pallas",
    "paged_kv_scatter_pallas",
]

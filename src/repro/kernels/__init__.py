"""Pallas TPU kernels for the Amber Pruner hot paths.

Kernel family (each is ONE ``pallas_call`` — every intermediate lives in
VMEM/registers, never HBM):

  nm_prune         — fused scoring + per-token N:M top-k + mask
  nm_prune_matmul  — fused prune + GEMM (the per-token projection itself)
  nm_spmm          — tile-consensus compacted matmul (TPU-native SpMM),
                     k-blocked over D with an f32 accumulator scratch
  osparse_matmul   — Outstanding-sparse chain: smooth-divide → prune →
                     int8 quantize (static or per-token) → int8 GEMM →
                     dequant
  w8a8_matmul      — plain int8×int8→int32 GEMM with SmoothQuant dequant
  flash_attention  — causal online-softmax attention, VMEM score tiles
                     (full and sliding-window self-attention; off-band KV
                     blocks are skipped at block granularity)
  paged_attention  — flash attention over the paged KV pool: the KV grid
                     axis walks the per-row block table (scalar prefetch,
                     ``pltpu.PrefetchScalarGridSpec``), streaming each
                     physical block through VMEM and skipping ``-1`` /
                     ≥ ``kv_len`` / off-band blocks before their matmuls
                     issue — chunked prefill at cache offsets and
                     vector-position decode share one kernel
  paged_kv_scatter — the write side of the paged pool (same module): per
                     logical block the scalar-prefetched table picks the
                     physical block, a one-hot selection matmul merges the
                     chunk's rows into it, and ``input_output_aliases``
                     updates the pool in place — invisible grid steps
                     write nothing, so untouched blocks keep their
                     content.  Replaces the host-side flat-index
                     ``.at[].set`` scatter in the serving hot path.

Dispatch order for model projections (``layers.linear.sparse_linear``):

  1. ``SparsityPolicy.use_pallas_kernels`` — the policy flag routes each
     prunable linear onto the fused kernel for its mode (per-token →
     ``nm_prune_matmul``; tile-consensus → ``nm_spmm``; Outstanding-sparse
     W8A8 → ``osparse_matmul``; decode-phase W8A8 → ``osparse_matmul``
     with static ``prune=False``, skipping selection in-kernel).  A
     projection bias rides the kernels' f32 dequant/accumulator epilogue
     instead of a separate HBM pass.  Scan-stacked ``layer_flag`` models
     always fall back to the jnp mask-select form.
  2. ``REPRO_PALLAS_INTERPRET`` env switch — ``1`` (default, CPU container)
     runs the kernels through the Pallas interpreter; ``0`` compiles the
     same BlockSpecs to Mosaic on a real TPU.
  3. The pure-jnp implementations in ``repro.core`` remain the bit-exact
     oracles (``kernels.ref`` wraps them per kernel for the test sweeps).

HBM cost model — ``COST_MODEL`` below is the machine-readable contract:
exact bytes moved per kernel call under Mosaic's pipelined fetch/write
semantics (a block is fetched at the start of each maximal RUN of grid
steps mapping to it, written back once per output run — consecutive
equal block indices elide the refetch/write-back).  The ``hbm`` analyzer
family enumerates every kernel's real grid + index maps and fails CI
when the measured bytes diverge >10% from these formulas, so the table
below cannot rot.  Versus the jnp oracles: the fused projections spend
ZERO extra X passes (the mask/quantized copies live in registers; the
jnp chains write + re-read them), the gather oracle round-trips the full
(B, mb·bs, Hkv, hd) logical view per call while ``paged_attention``
streams O(kv_len) rows, and the flat-index scatter round-trips the whole
pool per leaf while ``paged_kv_scatter`` touches only the blocks a chunk
overlaps.

--- HBM bytes per call (generated from COST_MODEL; do not edit) ---
  flash_attention      s*(2*B*H*T*hd + 2*B*H*(T/bq)*S*hd)
  nm_prune             s*(2*T*D + I*D)
  nm_prune_matmul      s*(J*T*D + I*D*N + I*J*D + I*N + T*N)
  nm_spmm              s*(J*T*D + I*D*N + I*J*D + T*N)
  osparse_matmul       s*(2*J*T*D + 4*I*J*D + 2*I*N + T*N) + 2*I*D*N
  osparse_w8a8_decode  s*(J*T*D + 2*I*J*D + 2*I*N + T*N + 1) + I*D*N
  paged_attention      s*(2*B*H*T*hd + runs(kv walk)*2*bs*hd)
  paged_kv_scatter     s*(2*B*T*r + runs(pool walk)*4*bs*r)
  w8a8_matmul          J*T*D + I*D*N + s*(I*N + 1 + T*N)
--- end generated table ---

Symbols: T tokens, D in-features, N out-features, s dtype bytes (f32:
4); grid extents I = T/bt, J = N/bo, K = D/bk (K-refetch of X/W blocks
is why J·T·D and I·D·N appear, not T·D and D·N); attention B, H, hd,
query tile bq, KV length S; paged r = Hkv·hd.  ``runs(·)`` counts
maximal constant runs of the scalar-prefetched block walk — the paged
formulas replay the documented table/visibility contract over the real
block table (invisible steps park on the row-0/sentinel block, so
consecutive skips fetch nothing).

Dispatch for the paged pool (``models/attention.paged_attention`` reads,
``models/attention.paged_kv_update`` writes) runs the same ladder as the
projections: ``SparsityPolicy.use_pallas_kernels`` →
``REPRO_PALLAS_INTERPRET`` (interpret vs Mosaic) → the jnp
gather-then-attend / flat-index-scatter oracles (the gather oracle is
always used for windowed paged shapes and non-tile-divisible query
counts).  Both directions carry chaos-harness sites
(``kernel.paged_attention``, ``kernel.paged_scatter``).

Static VMEM footprints (worst case across the shipped config zoo, from
``PYTHONPATH=src python -m repro.analysis --vmem-table`` — regenerate
after changing any BlockSpec/grid/scratch; the ``vmem.budget`` analyzer
rule fails CI past 16 MiB/core).  The estimate is 2x the in/out block
bytes (Mosaic double buffering) + VMEM scratch; SMEM carries the
scalar-prefetched block tables:

  kernel               VMEM       SMEM     worst config, grid
  flash_attention       1.13 MiB     0 B   recurrentgemma_2b (1,10,8,8)
  nm_prune              2.00 MiB     0 B   llama31_8b        (1,8)
  nm_prune_matmul       2.76 MiB     0 B   llama31_8b        (1,56,8)
  nm_spmm               8.77 MiB     0 B   llama31_8b        (1,56,2)
  osparse_matmul        2.01 MiB     0 B   llama31_8b        (1,56,16)
  osparse_w8a8_decode   0.32 MiB     0 B   llama31_8b        (1,56,8)
  paged_attention       0.69 MiB  8256 B   recurrentgemma_2b (8,10,2,256)
  paged_kv_scatter     10.00 MiB  8256 B   rwkv6_7b          (8,9)
  w8a8_matmul           1.25 MiB     0 B   llama31_8b        (1,56,8)

(``paged_kv_scatter``'s bound holds because the wrapper splits chunks
whose resident tile would exceed ~2 MiB/leaf into sub-chunk calls —
MHA-width caches at chunk 256 used to hit 18 MiB.)

``ops``  — jit'd wrappers (batched, padded, interpret-mode switch)
``ref``  — pure-jnp oracles used by the allclose test sweeps
"""
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import (paged_attention_pallas,
                                           paged_kv_scatter_pallas)
from repro.kernels.ops import (
    nm_prune,
    nm_prune_matmul,
    nm_spmm,
    osparse_matmul,
    w8a8_matmul,
)

__all__ = [
    "nm_prune",
    "nm_prune_matmul",
    "nm_spmm",
    "osparse_matmul",
    "w8a8_matmul",
    "flash_attention_pallas",
    "paged_attention_pallas",
    "paged_kv_scatter_pallas",
    "COST_MODEL",
    "cost_model_doc",
]


# --------------------------------------------------------------------------
# COST_MODEL: closed-form HBM bytes per kernel call.
#
# Each entry maps a kernel-zoo name to {"formula": <doc string — MUST match
# the generated table in the module docstring>, "bytes": fn(dims) -> int}.
# ``dims`` is the geometry dict a ``grid_zoo_entries`` entry carries
# (tokens/features/block sizes, and for the paged kernels the concrete
# block table / positions / lengths).  The formulas model Mosaic's
# pipelined traffic: one fetch per maximal RUN of grid steps mapping an
# operand to the same block (row-major grid order, last axis innermost),
# one write-back per output run.  ``repro.analysis.hbm`` measures the same
# quantity from the kernels' REAL BlockSpec index maps and fails on >10%
# divergence — these formulas are the independent re-derivation from the
# documented contract, not a transcription of the measurement.
#
# Pure Python on purpose (no jax/numpy): the model is consultable from
# host-only contexts and the purity rules keep this module import-light.

def _run_count(seq) -> int:
    """Maximal constant runs in a sequence — the number of block
    fetches Mosaic's refetch elision leaves in a grid walk."""
    runs, prev = 0, object()
    for item in seq:
        if item != prev:
            runs, prev = runs + 1, item
    return runs


def _mm_dims(d):
    s = d.get("s", 4)
    t, dd, n = d["t"], d["d"], d["n_out"]
    i, j = t // d["bt"], n // d["bo"]
    return s, t, dd, n, i, j


def _nm_prune_bytes(d):
    s = d.get("s", 4)
    i = d["t"] // d["bt"]
    return s * (2 * d["t"] * d["d"] + i * d["d"])


def _nm_prune_matmul_bytes(d):
    s, t, dd, n, i, j = _mm_dims(d)
    return s * (j * t * dd + i * dd * n + i * j * dd + i * n + t * n)


def _nm_spmm_bytes(d):
    s, t, dd, n, i, j = _mm_dims(d)
    return s * (j * t * dd + i * dd * n + i * j * dd + t * n)


def _osparse_matmul_bytes(d):
    # per-token scale: the k axis runs twice (absmax pass + GEMM pass),
    # doubling X/weight/channel-vector traffic; wq is int8 (1 byte)
    s, t, dd, n, i, j = _mm_dims(d)
    return (s * (2 * j * t * dd + 4 * i * j * dd + 2 * i * n + t * n)
            + 2 * i * dd * n)


def _osparse_w8a8_decode_bytes(d):
    # static scale (prune=False decode form): single k pass, scalar
    # act-scale is one 4-byte fetch for the whole grid.  The amber
    # channel vector streams even when unused (the kernel's operand list
    # is static — a ones placeholder rides next to smooth), hence 2·I·J·D
    s, t, dd, n, i, j = _mm_dims(d)
    return (s * (j * t * dd + 2 * i * j * dd + 2 * i * n + t * n + 1)
            + i * dd * n)


def _w8a8_matmul_bytes(d):
    # xq/wq int8; w_scale f32 per output run; x_scale one scalar fetch
    s, t, dd, n, i, j = _mm_dims(d)
    return j * t * dd + i * dd * n + s * (i * n + 1 + t * n)


def _flash_attention_bytes(d):
    # q/out resident across the KV axis (1 run per (b,h,q-tile)); k/v
    # blocks are fetched every step — causal masking skips the COMPUTE
    # of future blocks, not their DMA (the index map is unconditional)
    s = d.get("s", 4)
    b, h, t, skv, bq, hd = d["b"], d["h"], d["t"], d["s_kv"], d["bq"], d["hd"]
    return s * (2 * b * h * t * hd + 2 * b * h * (t // bq) * skv * hd)


def _paged_attention_bytes(d):
    # replay the documented block walk: grid (B, H, T/bq, mb), mb
    # innermost; invisible steps (unallocated / beyond kv_len / causally
    # future) remap to the row's FIRST block so consecutive skips elide
    # their fetch.  GQA: query head h reads KV head h // (H/Hkv).
    s = d.get("s", 4)
    b, h, hkv, t = d["b"], d["h"], d["hkv"], d["t"]
    bq, bs, mb, hd = d["bq"], d["bs"], d["mb"], d["hd"]
    tab, qoff, kvl = d["tab"], d["qoff"], d["kvl"]
    g = h // hkv
    walk = []
    for bi in range(b):
        for hh in range(h):
            for qi in range(t // bq):
                for ki in range(mb):
                    pb = int(tab[bi][ki])
                    k_lo = ki * bs
                    q_lo = int(qoff[bi]) + qi * bq
                    vis = (pb >= 0 and k_lo < int(kvl[bi])
                           and k_lo <= q_lo + bq - 1)       # causal
                    if not vis:
                        pb = int(tab[bi][0])
                    walk.append((max(pb, 0), hh // g))
    q_out = 2 * b * h * t * hd * s
    return q_out + 2 * _run_count(walk) * bs * hd * s       # k and v


def _paged_kv_scatter_bytes(d):
    # grid (B, n_lb) over the ≤ ceil(T/bs)+1 logical blocks a chunk can
    # overlap; visible steps resolve table[pos//bs + ci], invisible ones
    # park on the pool's reserved SENTINEL row (rows-1).  Each pool run
    # costs a fetch AND an aliased write-back, for K and V (×4); k_new /
    # v_new are resident per batch row (×2 fetches of T rows).
    s = d.get("s", 4)
    b, t, bs, mb, rows = d["b"], d["t"], d["bs"], d["mb"], d["rows"]
    r = d["hkv"] * d["hd"]
    tab, pos, cl = d["tab"], d["pos"], d["cl"]
    n_lb = min((t - 1) // bs + 2, t)
    walk = []
    for bi in range(b):
        for ci in range(n_lb):
            lb = int(pos[bi]) // bs + ci
            pb = int(tab[bi][min(max(lb, 0), mb - 1)])
            lo = lb * bs
            vis = (lb < mb and pb >= 0 and lo < int(pos[bi]) + int(cl[bi])
                   and lo + bs > int(pos[bi]))
            walk.append(max(pb, 0) if vis else rows - 1)
    return s * (2 * b * t * r + 4 * _run_count(walk) * bs * r)


COST_MODEL = {
    "nm_prune": {
        "formula": "s*(2*T*D + I*D)",
        "bytes": _nm_prune_bytes},
    "nm_prune_matmul": {
        "formula": "s*(J*T*D + I*D*N + I*J*D + I*N + T*N)",
        "bytes": _nm_prune_matmul_bytes},
    "nm_spmm": {
        "formula": "s*(J*T*D + I*D*N + I*J*D + T*N)",
        "bytes": _nm_spmm_bytes},
    "osparse_matmul": {
        "formula": "s*(2*J*T*D + 4*I*J*D + 2*I*N + T*N) + 2*I*D*N",
        "bytes": _osparse_matmul_bytes},
    "osparse_w8a8_decode": {
        "formula": "s*(J*T*D + 2*I*J*D + 2*I*N + T*N + 1) + I*D*N",
        "bytes": _osparse_w8a8_decode_bytes},
    "w8a8_matmul": {
        "formula": "J*T*D + I*D*N + s*(I*N + 1 + T*N)",
        "bytes": _w8a8_matmul_bytes},
    "flash_attention": {
        "formula": "s*(2*B*H*T*hd + 2*B*H*(T/bq)*S*hd)",
        "bytes": _flash_attention_bytes},
    "paged_attention": {
        "formula": "s*(2*B*H*T*hd + runs(kv walk)*2*bs*hd)",
        "bytes": _paged_attention_bytes},
    "paged_kv_scatter": {
        "formula": "s*(2*B*T*r + runs(pool walk)*4*bs*r)",
        "bytes": _paged_kv_scatter_bytes},
}


def cost_model_doc() -> str:
    """The generated docstring table, rendered from :data:`COST_MODEL` —
    ``repro.analysis.hbm`` fails when the module docstring's marker
    section drifts from this (regenerate via
    ``python -m repro.analysis --hbm-table``)."""
    lines = ["--- HBM bytes per call (generated from COST_MODEL; "
             "do not edit) ---"]
    for name in sorted(COST_MODEL):
        lines.append(f"  {name:<20} {COST_MODEL[name]['formula']}")
    lines.append("--- end generated table ---")
    return "\n".join(lines)

"""Pallas TPU kernels for the Amber Pruner hot paths.

  nm_prune         — fused scoring + per-token N:M top-k + mask (1 HBM pass)
  nm_spmm          — tile-consensus compacted matmul (the TPU-native SpMM)
  w8a8_matmul      — int8×int8→int32 GEMM with SmoothQuant dequant
  flash_attention  — causal online-softmax attention, VMEM score tiles
                     (kills the O(T·S) HBM score traffic that dominates the
                     32k-prefill memory roofline term)

``ops``  — jit'd wrappers (batched, padded, interpret-mode switch)
``ref``  — pure-jnp oracles used by the allclose test sweeps
"""
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ops import nm_prune, nm_spmm, w8a8_matmul

__all__ = ["nm_prune", "nm_spmm", "w8a8_matmul", "flash_attention_pallas"]

"""Pallas TPU kernel: fused Amber-Pruner scoring + N:M top-k + mask apply.

The paper's masking pass is bandwidth-bound: naive composition (score,
top_k, one-hot, where) makes 3-4 HBM round-trips over X.  This kernel does
ONE pass: X tiles stream HBM→VMEM, the per-group top-N selection runs on
registers/VMEM, and only the masked tile is written back.

Selection is an iterative first-occurrence argmax (N rounds of max/compare
over the M lanes) — identical tie-breaking to ``lax.top_k`` (lowest index
wins), so the output is bit-equal to the jnp oracle.  ``lax.top_k`` itself
does not lower inside Pallas TPU kernels; the iterative form is
MXU/VPU-friendly and N ≤ 8 keeps it cheap.

Tiling: (block_t × block_d) VMEM tiles, block_d a multiple of both M and
the 128-lane register width; the scale vector rides along as a (block_d,)
tile.  dtype-preserving (bf16 in/out, f32 scoring).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["nm_prune_pallas"]

_NEG = float("-inf")


def _select_topn_mask(scores: jax.Array, n: int, m: int) -> jax.Array:
    """(T, G, m) scores → bool keep-mask, iterative first-occurrence argmax."""
    remaining = scores
    keep = jnp.zeros(scores.shape, dtype=jnp.bool_)
    for _ in range(n):
        cur = remaining.max(axis=-1, keepdims=True)
        eq = remaining == cur
        first = eq & (jnp.cumsum(eq.astype(jnp.int32), axis=-1) == 1)
        keep = keep | first
        remaining = jnp.where(first, _NEG, remaining)
    return keep


def _kernel(x_ref, scale_ref, o_ref, *, n: int, m: int, has_scale: bool):
    x = x_ref[...]                                     # (bt, bd)
    s = jnp.abs(x.astype(jnp.float32))
    if has_scale:
        s = s * scale_ref[...].astype(jnp.float32)[None, :]
    bt, bd = s.shape
    g = s.reshape(bt, bd // m, m)
    keep = _select_topn_mask(g, n, m).reshape(bt, bd)
    o_ref[...] = jnp.where(keep, x, jnp.zeros((), x.dtype))


@functools.partial(jax.jit, static_argnames=("n", "m", "block_t", "block_d",
                                             "interpret"))
def nm_prune_pallas(
    x: jax.Array,                       # (T, D)
    scale: Optional[jax.Array],         # (D,) or None
    n: int,
    m: int,
    block_t: int = 256,
    block_d: int = 512,
    interpret: bool = True,             # CPU container default; False on TPU
) -> jax.Array:
    t, d = x.shape
    bt = min(block_t, t)
    bd = min(block_d, d)
    assert t % bt == 0 and d % bd == 0 and bd % m == 0, (t, d, bt, bd, m)
    grid = (t // bt, d // bd)
    has_scale = scale is not None
    if not has_scale:
        scale = jnp.ones((d,), jnp.float32)

    return pl.pallas_call(
        functools.partial(_kernel, n=n, m=m, has_scale=has_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=interpret,
    )(x, scale)

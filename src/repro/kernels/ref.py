"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["nm_prune_ref", "nm_prune_matmul_ref", "nm_spmm_ref",
           "osparse_matmul_ref", "w8a8_matmul_ref", "flash_attention_ref"]


def flash_attention_ref(
    q: jax.Array,                      # (B, H, T, d)
    k: jax.Array,                      # (B, H, S, d)
    v: jax.Array,                      # (B, H, S, d)
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Dense softmax attention oracle (f32 math; window>0 → SWA band)."""
    d = q.shape[-1]
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * d**-0.5
    t_len, s_len = s.shape[-2:]
    q_pos = jnp.arange(t_len)[:, None] + (s_len - t_len)
    k_pos = jnp.arange(s_len)[None, :]
    mask = jnp.ones((t_len, s_len), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def nm_prune_ref(
    x: jax.Array,                      # (T, D)
    scale: Optional[jax.Array],        # (D,) or None
    n: int,
    m: int,
) -> jax.Array:
    """Fused Amber prune: score → per-token N:M top-k mask → apply."""
    from repro.core import nm, scoring

    scores = scoring.score_activations(x, scale)
    return nm.apply_nm(x, scores, n, m)


def nm_prune_matmul_ref(
    x: jax.Array,                      # (T, D)
    w: jax.Array,                      # (D, N_out)
    scale: Optional[jax.Array],        # (D,) or None
    n: int,
    m: int,
) -> jax.Array:
    """Fused per-token prune + GEMM: score → N:M mask → dense matmul."""
    xp = nm_prune_ref(x, scale, n, m)
    return jnp.dot(xp.astype(jnp.float32), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32
                   ).astype(jnp.result_type(x.dtype, w.dtype))


def osparse_matmul_ref(
    x: jax.Array,                      # (T, D) raw activations
    wq: jax.Array,                     # (D, N_out) int8
    smooth: jax.Array,                 # (D,) SmoothQuant divide factor
    amber: Optional[jax.Array],        # (D,) Amber channel scale or None
    w_scale: jax.Array,                # (N_out,) f32
    n: int,
    m: int,
    act_scale: Optional[jax.Array] = None,
    per_token: bool = False,
) -> jax.Array:
    """Outstanding-sparse chain: smooth → prune → int8 quantize → GEMM →
    dequant — the exact jnp composition ``layers.linear._quantized`` runs."""
    from repro.core import quant

    xs = x.astype(jnp.float32) / smooth
    xp = nm_prune_ref(xs, amber, n, m)
    if per_token:
        xq, ts = quant.quantize_act_per_token(xp)
        return quant.quantized_matmul(xq, wq, ts, w_scale)
    xq = quant.quantize_act_per_tensor(xp, act_scale)
    return quant.quantized_matmul(xq, wq, act_scale, w_scale)


def nm_spmm_ref(
    x: jax.Array,                      # (T, D) — T divisible by tile
    w: jax.Array,                      # (D, N_out)
    scale: Optional[jax.Array],        # (D,) or None
    n: int,
    m: int,
    tile: int,
) -> jax.Array:
    """Tile-consensus N:M compacted matmul (DESIGN.md §2).

    Per token tile: pool scores with an L2 norm over the tile, keep the
    top-N channels of every group of M (shared across the tile), contract
    only the survivors.
    """
    from repro.core import nm, scoring

    t, d = x.shape
    assert t % tile == 0, (t, tile)
    xt = x.reshape(t // tile, tile, d)

    def one(xtile):
        s = scoring.score_activations(xtile, scale)
        chans = nm.tile_consensus_channels(s, n, m)
        xc = nm.compact_columns(xtile, chans)
        wc = jnp.take(w, chans.reshape(-1), axis=0)
        return jnp.dot(xc, wc, preferred_element_type=jnp.float32)

    y = jax.vmap(one)(xt)
    return y.reshape(t, w.shape[-1]).astype(x.dtype)


def w8a8_matmul_ref(
    xq: jax.Array,                     # (T, D) int8
    wq: jax.Array,                     # (D, N_out) int8
    x_scale: jax.Array,                # scalar f32
    w_scale: jax.Array,                # (N_out,) f32
) -> jax.Array:
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * x_scale * w_scale

"""Pallas TPU kernel: paged flash attention — the KV grid axis walks the
per-row block table in-kernel.

Why it exists here: PR 3's paged KV cache made allocation block-granular,
but the serving engine's attention still gathered a dense
``(B, max_blocks * block_size, Hkv, hd)`` logical view into HBM on every
chunked-prefill and decode call (``models/attention.gather_kv_blocks``) —
a full write + re-read of the logical cache view per call, which the
roofline pass shows is the dominant HBM term of paged serving.  This
kernel deletes that view: the innermost grid axis iterates **logical**
block indices, each step resolves ``block_table[b, ki]`` from a
scalar-prefetch argument (``pltpu.PrefetchScalarGridSpec``) and streams
that *physical* block of the shared pool straight through VMEM.  Blocks
that are unallocated (``-1``) or entirely outside the row's valid
``kv_len`` (and, with causal/window masking, outside the query band) are
skipped before their matmuls issue — decode attention is O(pos) per row,
not O(max_blocks * block_size).

Layout: q ``(B, Tq, Hq, hd)``; pools ``(num_blocks, block_size, Hkv, hd)``
shared by every row (GQA is resolved in the index map: query head ``h``
reads KV head ``h // (Hq // Hkv)`` — the pool is never head-repeated in
HBM).  Grid ``(B, Hq, Tq/bq, max_blocks)`` with the block axis innermost;
scratch (m, l, acc) carries the online-softmax state across blocks;
finalization divides on the last block.  Masking is by **absolute**
positions: query row r sits at ``q_offset[b] + qi*bq + r`` and block
``ki`` covers positions ``[ki*bs, (ki+1)*bs)``, so chunked prefill at a
cache offset (scalar ``q_offset`` broadcast per row) and vector-position
decode (per-row ``q_offset``) lower to the same kernel.

MXU alignment: bq and block_size should be multiples of the hardware tile
on real TPUs (any value in interpret mode — decode runs bq=1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import (_NEG, softmax_finalize,
                                           softmax_init, softmax_update)

__all__ = ["paged_attention_pallas", "paged_kv_scatter_pallas",
           "paged_kernel_covers"]


def paged_kernel_covers(t: int) -> bool:
    """Can the kernel serve a ``t``-query call?  The single source of
    truth for the q-tile divisibility rule — the dispatch layer
    (``models/attention.paged_attention``) falls back to the gather oracle
    when this is False, and the serving engine rejects prefill chunk
    buckets that would silently do so while claiming the kernel ran."""
    return t % min(128, t) == 0


def _block_visible(tab_ref, qoff_ref, kvlen_ref, bi, qi, ki, *,
                   bq: int, bs: int, causal: bool, window: int):
    """(physical block id, contributes-anything?) for one grid step.

    The SINGLE definition of the block-level walk: an unallocated (-1)
    table entry, a block entirely past the row's kv_len, or a block
    entirely outside the causal/window band of this q tile contributes
    nothing.  Both the kernel body (to skip the matmuls — on TPU Mosaic
    this prunes the MXU work; decode touches O(pos) rows) and the
    BlockSpec index map (to skip the DMA) consume this predicate; if they
    ever disagreed, the body would accumulate a block the pipeline never
    fetched.
    """
    pb = tab_ref[bi, ki]
    k_lo = ki * bs
    vis = (pb >= 0) & (k_lo < kvlen_ref[bi])
    q_lo = qoff_ref[bi] + qi * bq
    if causal:
        vis = vis & (k_lo <= q_lo + bq - 1)
    if window > 0:
        vis = vis & (k_lo + bs - 1 > q_lo - window)
    return pb, vis


def _kernel(tab_ref, qoff_ref, kvlen_ref,      # scalar prefetch
            q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            mb: int, bq: int, bs: int, causal: bool, window: int,
            scale: float):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        softmax_init(m_ref, l_ref, acc_ref)

    kvl = kvlen_ref[b]
    q_lo = qoff_ref[b] + qi * bq
    k_lo = ki * bs
    _, visible = _block_visible(tab_ref, qoff_ref, kvlen_ref, b, qi, ki,
                                bq=bq, bs=bs, causal=causal, window=window)

    @pl.when(visible)
    def _accumulate():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bs, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bs)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bs), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bs), 1)
        valid = k_pos < kvl
        if causal:
            valid = valid & (k_pos <= q_pos)
        if window > 0:
            valid = valid & (k_pos > q_pos - window)
        s = jnp.where(valid, s, _NEG)
        # rows of a partially-filled physical block past kv_len are
        # unwritten pool memory; zero them so a 0-probability column can
        # never propagate NaN/garbage through the p @ v contraction
        col = jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
        v = jnp.where(k_lo + col < kvl, v, 0.0)

        softmax_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(ki == mb - 1)
    def _finalize():
        o_ref[0, :, 0, :] = softmax_finalize(l_ref, acc_ref, o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "interpret"))
def paged_attention_pallas(
    q: jax.Array,             # (B, Tq, Hq, hd)
    k_pool: jax.Array,        # (num_blocks, block_size, Hkv, hd)
    v_pool: jax.Array,        # (num_blocks, block_size, Hkv, hd)
    block_table: jax.Array,   # (B, max_blocks) int32, -1 = unallocated
    q_offset: jax.Array,      # (B,) int32 absolute position of q[:, 0]
    kv_len: jax.Array,        # (B,) int32 valid KV rows per table row
    causal: bool = True,
    window: int = 0,          # >0 → sliding-window band by absolute pos
    block_q: int = 128,
    interpret: bool = True,   # CPU container default
) -> jax.Array:
    b, t, hq, hd = q.shape
    nb, bs, hkv = k_pool.shape[:3]
    mb = block_table.shape[1]
    g = hq // hkv
    bq = min(block_q, t)
    # non-divisible heads would make the index map read a clamped
    # out-of-range KV head — plausible wrong outputs, so fail fast
    assert hq % hkv == 0 and t % bq == 0, (hq, hkv, t, bq)
    scale = hd**-0.5

    tab = block_table.astype(jnp.int32)
    qoff = q_offset.astype(jnp.int32)
    kvl = kv_len.astype(jnp.int32)

    def k_index(bi, h, qi, ki, tab_ref, qoff_ref, kvlen_ref):
        # physical block for this logical step.  Steps the kernel body will
        # skip (same ``_block_visible`` predicate) resolve to the row's
        # FIRST block instead of their own: consecutive skipped steps then
        # map to an unchanged index, so the pipeline's refetch elision
        # issues no DMA for them and attention traffic is O(kv_len) rows,
        # not O(allocated blocks) (clipped to 0 for fully-empty rows).
        pb, vis = _block_visible(tab_ref, qoff_ref, kvlen_ref, bi, qi, ki,
                                 bq=bq, bs=bs, causal=causal, window=window)
        pb = jnp.where(vis, pb, tab_ref[bi, 0])
        return (jnp.maximum(pb, 0), 0, h // g, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hq, t // bq, mb),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd),
                         lambda bi, h, qi, ki, *_: (bi, qi, h, 0)),
            pl.BlockSpec((1, bs, 1, hd), k_index),
            pl.BlockSpec((1, bs, 1, hd), k_index),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda bi, h, qi, ki, *_: (bi, qi, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, mb=mb, bq=bq, bs=bs, causal=causal,
                          window=window, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, t, hq, hd), q.dtype),
        interpret=interpret,
    )(tab, qoff, kvl, q, k_pool, v_pool)
    return out


def _scatter_visible(tab_ref, pos_ref, len_ref, bi, ci, *, bs: int, mb: int):
    """(physical block id, receives-any-row?) for one scatter grid step.

    Logical block ``pos[bi] // bs + ci`` receives rows iff it overlaps the
    row's write span ``[pos, pos + chunk_len)``, sits inside the table, and
    is actually allocated.  Shared by the kernel body and the pool index
    maps — same contract as ``_block_visible`` above: disagreement would
    mean the body merges into a block the pipeline never fetched.
    """
    lb = pos_ref[bi] // bs + ci
    pb = tab_ref[bi, jnp.clip(lb, 0, mb - 1)]
    lo = lb * bs
    p0 = pos_ref[bi]
    vis = ((lb < mb) & (pb >= 0)
           & (lo < p0 + len_ref[bi]) & (lo + bs > p0))
    return lb, pb, vis


def _scatter_kernel(tab_ref, pos_ref, len_ref,          # scalar prefetch
                    kn_ref, vn_ref, kin_ref, vin_ref, ko_ref, vo_ref, *,
                    bs: int, mb: int, t: int):
    bi = pl.program_id(0)
    ci = pl.program_id(1)
    p0 = pos_ref[bi]
    cl = len_ref[bi]
    lb, _, vis = _scatter_visible(tab_ref, pos_ref, len_ref, bi, ci,
                                  bs=bs, mb=mb)
    lo = lb * bs

    # invisible steps write NOTHING: the pool is aliased in-place, so an
    # unwritten output block keeps its current content.  The pool
    # invariant (no physical block reachable from two slots) means each
    # visible step is the sole writer of its block this call, so the
    # input-side fetch is always the correct merge base.
    @pl.when(vis)
    def _merge():
        # row r of this physical block holds absolute position lo + r; it
        # takes chunk token tk iff lo + r == p0 + tk and tk is within the
        # valid span.  The one-hot selection matrix turns the scatter into
        # an MXU contraction against the whole chunk — no per-row dynamic
        # indexing in-kernel.
        row = jax.lax.broadcasted_iota(jnp.int32, (bs, t), 0)
        tok = jax.lax.broadcasted_iota(jnp.int32, (bs, t), 1)
        sel = ((lo + row == p0 + tok) & (tok < cl)).astype(jnp.float32)
        wr = (lo + row[:, 0] >= p0) & (lo + row[:, 0] < p0 + cl)  # (bs,)
        wr = wr[:, None, None]

        for new_ref, cur_ref, out_ref in ((kn_ref, kin_ref, ko_ref),
                                          (vn_ref, vin_ref, vo_ref)):
            new = new_ref[0].reshape(t, -1).astype(jnp.float32)
            rows = jnp.dot(sel, new, preferred_element_type=jnp.float32)
            cur = cur_ref[0]
            rows = rows.reshape(cur.shape).astype(cur.dtype)
            out_ref[0] = jnp.where(wr, rows, cur)


# VMEM cap for the resident chunk tile of one scatter call, per K/V leaf.
# The scatter kernel keeps the whole (1, T, Hkv, hd) chunk block in VMEM at
# every grid step; at T=256 an MHA-width cache row (e.g. 64 heads x 64 dims,
# 16 KiB/row f32) makes that 2 x 4 MiB double-buffered — past the ~16 MiB
# per-core budget once the pool blocks ride along (surfaced by
# ``repro.analysis.vmem``).  Chunks whose tile would exceed this split into
# bounded sub-chunk calls below; each sub-call writes a disjoint row span,
# so the result is bit-identical to the single-call form.
_MAX_CHUNK_TILE_BYTES = 2 * 1024 * 1024


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_kv_scatter_pallas(
    k_new: jax.Array,         # (B, T, Hkv, hd) chunk K (decode: T == 1)
    v_new: jax.Array,         # (B, T, Hkv, hd)
    k_pool: jax.Array,        # (num_blocks, block_size, Hkv, hd)
    v_pool: jax.Array,        # (num_blocks, block_size, Hkv, hd)
    block_table: jax.Array,   # (B, max_blocks) int32, -1 = unallocated
    pos: jax.Array,           # (B,) int32 absolute position of k_new[:, 0]
    chunk_len: jax.Array,     # (B,) int32 valid rows of k_new per row
    interpret: bool = True,   # CPU container default
) -> tuple[jax.Array, jax.Array]:
    """Write chunk K/V rows into the shared pool through the block table,
    entirely in-kernel: the grid walks the logical blocks the chunk spans,
    resolves each to a physical block via the scalar-prefetched table, and
    merges the chunk rows into that block in VMEM.  The pools are aliased
    input→output (``input_output_aliases``), so nothing pool-shaped is
    gathered or scattered outside the ``pallas_call`` — this replaces the
    host-side flat-index ``.at[].set`` that re-wrote the whole pool view.

    Rows whose target block is unallocated (-1) or out of table range are
    dropped, matching the jnp oracle's ``mode="drop"`` fence.

    Sentinel contract: the LAST pool row (``num_blocks - 1`` of the array,
    i.e. ``serve/paged.device_pool_rows``'s reserved trailing row) is
    where invisible grid steps park their aliased fetch/write-back.  Its
    content is never read for merging and the write-back is the identity,
    but callers must not store live KV there — ``init_paged_cache`` sizes
    device pools with the extra row so allocator block ids never reach it.

    Chunks whose resident tile would blow the static VMEM budget are
    split into sub-chunk calls of at most ``ts`` rows (static Python
    loop, still zero pool-shaped ops outside ``pallas_call``): sub-call
    ``i`` re-bases ``pos``/``chunk_len`` by its row offset and chains the
    aliased pools, so untouched blocks pass through unchanged.
    """
    b, t = k_new.shape[:2]
    nb, bs, hkv, hd = k_pool.shape
    assert v_new.shape == k_new.shape and v_pool.shape == k_pool.shape

    row_bytes = hkv * hd * k_new.dtype.itemsize
    ts = max(1, min(t, _MAX_CHUNK_TILE_BYTES // row_bytes))
    if ts < t:
        posv = pos.astype(jnp.int32)
        cl = chunk_len.astype(jnp.int32)
        for off in range(0, t, ts):
            sl = slice(off, min(off + ts, t))
            k_pool, v_pool = _scatter_call(
                k_new[:, sl], v_new[:, sl], k_pool, v_pool, block_table,
                posv + off, jnp.clip(cl - off, 0, sl.stop - off), interpret)
        return k_pool, v_pool
    return _scatter_call(k_new, v_new, k_pool, v_pool, block_table,
                         pos, chunk_len, interpret)


def _scatter_call(k_new, v_new, k_pool, v_pool, block_table, pos,
                  chunk_len, interpret):
    """One bounded-tile scatter ``pallas_call`` (see the public wrapper)."""
    b, t = k_new.shape[:2]
    nb, bs, hkv, hd = k_pool.shape
    mb = block_table.shape[1]
    # an unaligned T-row chunk spans at most this many logical blocks
    n_lb = min((t - 1) // bs + 2, t)

    tab = block_table.astype(jnp.int32)
    posv = pos.astype(jnp.int32)
    cl = chunk_len.astype(jnp.int32)

    def pool_index(bi, ci, tab_ref, pos_ref, len_ref):
        _, pb, vis = _scatter_visible(tab_ref, pos_ref, len_ref, bi, ci,
                                      bs=bs, mb=mb)
        # invisible steps park on the SENTINEL block — the pool's reserved
        # trailing row (``serve/paged.device_pool_rows``), never handed out
        # by the allocator and never in any block table.  Consecutive
        # skipped steps keep the index unchanged so refetch elision drops
        # their DMA; the identity write-back lands on a block no other
        # grid step fetches for content.  Parking on a *live* block (the
        # old ``tab[bi, 0]`` remap) is a pipelining RAW hazard: a chunk
        # whose trailing invisible step remapped to its own first block
        # would refetch that block while the earlier step's aliased
        # write-back may still be in flight — surfaced by the ``races``
        # analyzer family (grid_eval checks aliased refetch-after-write).
        pb = jnp.where(vis, pb, nb - 1)
        return (jnp.maximum(pb, 0), 0, 0, 0)

    def new_index(bi, ci, *_):
        return (bi, 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, n_lb),
        in_specs=[
            pl.BlockSpec((1, t, hkv, hd), new_index),   # k_new
            pl.BlockSpec((1, t, hkv, hd), new_index),   # v_new
            pl.BlockSpec((1, bs, hkv, hd), pool_index),  # k_pool (in)
            pl.BlockSpec((1, bs, hkv, hd), pool_index),  # v_pool (in)
        ],
        out_specs=[
            pl.BlockSpec((1, bs, hkv, hd), pool_index),  # k_pool (out)
            pl.BlockSpec((1, bs, hkv, hd), pool_index),  # v_pool (out)
        ],
    )
    return pl.pallas_call(
        functools.partial(_scatter_kernel, bs=bs, mb=mb, t=t),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
                   jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype)],
        # operand indices count the scalar-prefetch args: k_pool is
        # operand 5, v_pool operand 6 → outputs 0, 1 (updated in place)
        input_output_aliases={5: 0, 6: 1},
        interpret=interpret,
    )(tab, posv, cl, k_new, v_new, k_pool, v_pool)

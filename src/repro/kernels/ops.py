"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU deployment set ``REPRO_PALLAS_INTERPRET=0`` (or pass
``interpret=False``) and the same BlockSpecs compile to Mosaic.

Wrappers handle leading-batch flattening and shape padding so callers can
use them as drop-in linear ops: when no well-sized block evenly divides an
axis, the axis is zero-padded up to the next block multiple (mirroring
``pruner.sparse_matmul``'s token padding) and the output is sliced back.
Zero padding is exact for every kernel here — padded tokens score zero in
the consensus pool, padded channels form all-zero N:M groups against
zero weight rows, and padded output columns are sliced away.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import tp as tp_mod
from repro.kernels.nm_prune import nm_prune_pallas
from repro.kernels.nm_prune_matmul import nm_prune_matmul_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas
from repro.kernels.osparse_matmul import osparse_matmul_pallas
from repro.kernels.w8a8_matmul import w8a8_matmul_pallas

__all__ = [
    "nm_prune",
    "nm_prune_matmul",
    "nm_spmm",
    "osparse_matmul",
    "w8a8_matmul",
    "default_interpret",
]


def default_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _flatten(x: jax.Array):
    lead = x.shape[:-1]
    t = 1
    for s in lead:
        t *= s
    return x.reshape(t, x.shape[-1]), lead


def _largest_divisor(total: int, target: int,
                     multiple_of: int = 1) -> Optional[int]:
    """Largest divisor of ``total`` that is ≤ target and a multiple of
    ``multiple_of``, or None when no such divisor exists."""
    for cand in range(min(target, total), 0, -1):
        if total % cand == 0 and cand % multiple_of == 0:
            return cand
    return None


def _block_and_pad(total: int, target: int, multiple_of: int = 1):
    """Pick a block size ≤ target (multiple of ``multiple_of``) and the
    padded axis length it divides.

    Prefers an exact divisor of ``total`` (zero padding, full occupancy);
    when only degenerately small divisors exist (e.g. prime token counts)
    or none is a multiple of ``multiple_of``, falls back to a full-size
    block with zero padding up to the next block multiple.
    """
    div = _largest_divisor(total, target, multiple_of)
    lim = min(total, target)
    if div is not None and 2 * div >= lim:
        return div, total
    block = max(lim - lim % multiple_of, multiple_of)
    return block, total + (-total) % block


def _pad_to(a: jax.Array, axis: int, new_size: int, value: float = 0.0):
    if a.shape[axis] == new_size:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, new_size - a.shape[axis])
    return jnp.pad(a, widths, constant_values=value)


def _check_groups(d: int, m: int) -> None:
    if d % m != 0:
        raise ValueError(f"last dim {d} not divisible by group size {m}")


def nm_prune(
    x: jax.Array,
    scale: Optional[jax.Array],
    n: int,
    m: int,
    block_t: int = 256,
    block_d: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused Amber prune over any (..., D) tensor."""
    interpret = default_interpret() if interpret is None else interpret
    xf, lead = _flatten(x)
    t, d = xf.shape
    _check_groups(d, m)
    bt, tp = _block_and_pad(t, block_t)
    bd, dp = _block_and_pad(d, block_d, multiple_of=m)
    xf = _pad_to(_pad_to(xf, 0, tp), 1, dp)
    if scale is not None:
        scale = _pad_to(scale, 0, dp)
    y = nm_prune_pallas(xf, scale, n, m, block_t=bt, block_d=bd,
                        interpret=interpret)
    return y[:t, :d].reshape(*lead, d)


def nm_prune_matmul(
    x: jax.Array,
    w: jax.Array,
    scale: Optional[jax.Array],
    n: int,
    m: int,
    bias: Optional[jax.Array] = None,
    block_t: int = 256,
    block_o: int = 256,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused per-token prune + GEMM over any (..., D) input (one X pass).

    ``bias`` (``(N_out,)``) is folded into the kernel epilogue — the add
    happens on the hot f32 accumulator instead of a separate HBM pass."""
    interpret = default_interpret() if interpret is None else interpret
    # tensor parallelism (distributed/tp.py): under an active TP scope the
    # call re-enters itself column-parallel — each device runs this same
    # wrapper on its N_out slice (scope suspended inside the shard body),
    # and the gathered result is bit-identical to the unsharded call
    y = tp_mod.column_parallel(
        lambda w_, b_: nm_prune_matmul(x, w_, scale, n, m, bias=b_,
                                       block_t=block_t, block_o=block_o,
                                       block_k=block_k, interpret=interpret),
        (w, bias))
    if y is not None:
        return y
    xf, lead = _flatten(x)
    t, d = xf.shape
    n_out = w.shape[-1]
    _check_groups(d, m)
    bt, tp = _block_and_pad(t, block_t)
    bo, op = _block_and_pad(n_out, block_o)
    bk, dp = _block_and_pad(d, block_k, multiple_of=m)
    xf = _pad_to(_pad_to(xf, 0, tp), 1, dp)
    w = _pad_to(_pad_to(w, 0, dp), 1, op)
    if scale is not None:
        scale = _pad_to(scale, 0, dp)
    if bias is not None:
        bias = _pad_to(bias, 0, op)
    y = nm_prune_matmul_pallas(xf, w, scale, n, m, bias=bias, block_t=bt,
                               block_o=bo, block_k=bk, interpret=interpret)
    return y[:t, :n_out].reshape(*lead, n_out)


def nm_spmm(
    x: jax.Array,
    w: jax.Array,
    scale: Optional[jax.Array],
    n: int,
    m: int,
    tile: int = 256,
    block_o: int = 256,
    block_k: int = 2048,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Tile-consensus compacted matmul over any (..., D) input.

    The token block IS the consensus tile (one shared channel set per bt
    tokens), so ``tile`` is semantic, not a free tiling parameter: the
    block is always ``min(tile, t)`` with zero-padding up to a tile
    multiple — exactly ``pruner.sparse_matmul``'s tiling, never a smaller
    divisor (which would change which tokens vote in each pool).
    """
    interpret = default_interpret() if interpret is None else interpret
    # column-parallel TP: the consensus vote runs over the full (replicated)
    # activations/K axis on every device, so sharding N_out cannot change
    # which channels win — outputs stay bit-identical
    y = tp_mod.column_parallel(
        lambda w_: nm_spmm(x, w_, scale, n, m, tile=tile, block_o=block_o,
                           block_k=block_k, interpret=interpret),
        (w,))
    if y is not None:
        return y
    xf, lead = _flatten(x)
    t, d = xf.shape
    n_out = w.shape[-1]
    _check_groups(d, m)
    bt = min(tile, t)
    tp = t + (-t) % bt
    bo, op = _block_and_pad(n_out, block_o)
    bk, dp = _block_and_pad(d, block_k, multiple_of=m)
    xf = _pad_to(_pad_to(xf, 0, tp), 1, dp)
    w = _pad_to(_pad_to(w, 0, dp), 1, op)
    if scale is not None:
        scale = _pad_to(scale, 0, dp)
    y = nm_spmm_pallas(xf, w, scale, n, m, block_t=bt, block_o=bo,
                       block_k=bk, interpret=interpret)
    return y[:t, :n_out].reshape(*lead, n_out)


def osparse_matmul(
    x: jax.Array,
    wq: jax.Array,
    smooth: jax.Array,
    amber: Optional[jax.Array],
    w_scale: jax.Array,
    n: int,
    m: int,
    act_scale: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    prune: bool = True,
    per_token: bool = False,
    block_t: int = 256,
    block_o: int = 256,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused Outstanding-sparse projection over any (..., D) input.

    Returns float32 (dequantized) — callers cast back to the model dtype,
    matching ``quant.quantized_matmul``.  ``bias`` is folded into the
    dequant epilogue; ``prune=False`` skips the N:M selection statically,
    turning the same kernel into the decode-phase smoothed W8A8 GEMM.
    """
    interpret = default_interpret() if interpret is None else interpret
    if not prune:
        n = m = 1  # no selection → no channel-group divisibility constraint
    # column-parallel TP: wq/w_scale/bias are N_out-aligned and shard;
    # smooth/amber/act_scale are K- or token-aligned and replicate
    y = tp_mod.column_parallel(
        lambda wq_, ws_, b_: osparse_matmul(
            x, wq_, smooth, amber, ws_, n, m, act_scale=act_scale, bias=b_,
            prune=prune, per_token=per_token, block_t=block_t,
            block_o=block_o, block_k=block_k, interpret=interpret),
        (wq, w_scale, bias))
    if y is not None:
        return y
    xf, lead = _flatten(x)
    t, d = xf.shape
    n_out = wq.shape[-1]
    _check_groups(d, m)
    bt, tp = _block_and_pad(t, block_t)
    bo, op = _block_and_pad(n_out, block_o)
    bk, dp = _block_and_pad(d, block_k, multiple_of=m)
    xf = _pad_to(_pad_to(xf, 0, tp), 1, dp)
    wq = _pad_to(_pad_to(wq, 0, dp), 1, op)
    smooth = _pad_to(smooth, 0, dp, value=1.0)  # padded channels: 0/1 = 0
    w_scale = _pad_to(w_scale, 0, op)
    if amber is not None:
        amber = _pad_to(amber, 0, dp)
    if bias is not None:
        bias = _pad_to(bias, 0, op)
    y = osparse_matmul_pallas(xf, wq, smooth, amber, w_scale, act_scale,
                              n, m, bias=bias, prune=prune,
                              per_token=per_token, block_t=bt,
                              block_o=bo, block_k=bk, interpret=interpret)
    return y[:t, :n_out].reshape(*lead, n_out)


def w8a8_matmul(
    xq: jax.Array,
    wq: jax.Array,
    x_scale: jax.Array,
    w_scale: jax.Array,
    block_t: int = 256,
    block_o: int = 256,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    y = tp_mod.column_parallel(
        lambda wq_, ws_: w8a8_matmul(xq, wq_, x_scale, ws_, block_t=block_t,
                                     block_o=block_o, block_k=block_k,
                                     interpret=interpret),
        (wq, w_scale))
    if y is not None:
        return y
    xf, lead = _flatten(xq)
    t, d = xf.shape
    n_out = wq.shape[-1]
    bt, tp = _block_and_pad(t, block_t)
    bo, op = _block_and_pad(n_out, block_o)
    bk, dp = _block_and_pad(d, block_k)
    xf = _pad_to(_pad_to(xf, 0, tp), 1, dp)
    wq = _pad_to(_pad_to(wq, 0, dp), 1, op)
    w_scale = _pad_to(w_scale, 0, op)
    y = w8a8_matmul_pallas(xf, wq, x_scale, w_scale, block_t=bt, block_o=bo,
                           block_k=bk, interpret=interpret)
    return y[:t, :n_out].reshape(*lead, n_out)

"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU deployment set ``REPRO_PALLAS_INTERPRET=0`` (or pass
``interpret=False``) and the same BlockSpecs compile to Mosaic.

Wrappers handle leading-batch flattening and shape padding so callers can
use them as drop-in linear ops.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.nm_prune import nm_prune_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas
from repro.kernels.w8a8_matmul import w8a8_matmul_pallas

__all__ = ["nm_prune", "nm_spmm", "w8a8_matmul", "default_interpret"]


def default_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _flatten(x: jax.Array):
    lead = x.shape[:-1]
    t = 1
    for s in lead:
        t *= s
    return x.reshape(t, x.shape[-1]), lead


def nm_prune(
    x: jax.Array,
    scale: Optional[jax.Array],
    n: int,
    m: int,
    block_t: int = 256,
    block_d: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused Amber prune over any (..., D) tensor."""
    interpret = default_interpret() if interpret is None else interpret
    xf, lead = _flatten(x)
    t, d = xf.shape
    bt = _largest_divisor(t, block_t)
    bd = _largest_divisor(d, block_d, multiple_of=m)
    y = nm_prune_pallas(xf, scale, n, m, block_t=bt, block_d=bd,
                        interpret=interpret)
    return y.reshape(*lead, d)


def nm_spmm(
    x: jax.Array,
    w: jax.Array,
    scale: Optional[jax.Array],
    n: int,
    m: int,
    tile: int = 256,
    block_o: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Tile-consensus compacted matmul over any (..., D) input."""
    interpret = default_interpret() if interpret is None else interpret
    xf, lead = _flatten(x)
    t, d = xf.shape
    n_out = w.shape[-1]
    bt = _largest_divisor(t, tile)
    bo = _largest_divisor(n_out, block_o)
    y = nm_spmm_pallas(xf, w, scale, n, m, block_t=bt, block_o=bo,
                       interpret=interpret)
    return y.reshape(*lead, n_out)


def w8a8_matmul(
    xq: jax.Array,
    wq: jax.Array,
    x_scale: jax.Array,
    w_scale: jax.Array,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    xf, lead = _flatten(xq)
    t, d = xf.shape
    n_out = wq.shape[-1]
    bt = _largest_divisor(t, 256)
    bo = _largest_divisor(n_out, 256)
    bk = _largest_divisor(d, 512)
    y = w8a8_matmul_pallas(xf, wq, x_scale, w_scale, block_t=bt, block_o=bo,
                           block_k=bk, interpret=interpret)
    return y.reshape(*lead, n_out)


def _largest_divisor(total: int, target: int, multiple_of: int = 1) -> int:
    """Largest divisor of ``total`` that is ≤ target and a multiple of
    ``multiple_of`` (falls back to ``multiple_of`` blocks)."""
    best = multiple_of
    for cand in range(min(target, total), multiple_of - 1, -1):
        if total % cand == 0 and cand % multiple_of == 0:
            best = cand
            break
    return max(best, 1)

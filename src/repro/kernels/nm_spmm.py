"""Pallas TPU kernel: tile-consensus N:M compacted matmul (the TPU SpMM).

This is the TPU-native analogue of the sparse-tensor-core SpMM the paper
targets (DESIGN.md §2).  Per token tile, one shared N:M channel pattern is
chosen (L2-pooled Amber scores), and the contraction runs over only the
surviving D·N/M channels — a real (M/N)× MXU FLOP reduction, unlike
per-token masking which the MXU cannot exploit.

In-kernel compaction uses **one-hot selection matmuls** (block-diagonal,
(m × n) per group): gathers with traced indices don't vectorize on the TPU
VPU, but tiny matmuls run on the MXU at full utilization.  Cost per tile:
  selection:  bt·D·n + D·n·bo     (≈ n/m · bo⁻¹ relative overhead)
  main GEMM:  bt·(D·n/m)·bo       (the (M/N)× win)

Grid: (T/bt, N_out/bo); each kernel instance sees the full reduction depth
D (VMEM: bt·D + D·bo + compacted operands — fits comfortably for
D ≤ 8192 at bf16 with bt = bo = 256).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.nm_prune import _select_topn_mask

__all__ = ["nm_spmm_pallas"]


def _selection_onehot(scores_g: jax.Array, n: int, m: int) -> jax.Array:
    """(G, m) pooled scores → (G, m, n) one-hot selection (rank order)."""
    remaining = scores_g
    cols = []
    for _ in range(n):
        cur = remaining.max(axis=-1, keepdims=True)
        eq = remaining == cur
        first = eq & (jnp.cumsum(eq.astype(jnp.int32), axis=-1) == 1)
        cols.append(first.astype(jnp.float32))
        remaining = jnp.where(first, float("-inf"), remaining)
    return jnp.stack(cols, axis=-1)                     # (G, m, n)


def _kernel(x_ref, w_ref, scale_ref, o_ref, *, n: int, m: int,
            has_scale: bool):
    x = x_ref[...]                                      # (bt, D)
    w = w_ref[...]                                      # (D, bo)
    bt, d = x.shape
    bo = w.shape[-1]
    g = d // m

    s = jnp.abs(x.astype(jnp.float32))
    if has_scale:
        s = s * scale_ref[...].astype(jnp.float32)[None, :]
    pooled = jnp.sqrt((s * s).sum(axis=0))              # (D,) tile-L2 pool
    sel = _selection_onehot(pooled.reshape(g, m), n, m) # (G, m, n)

    # compact activations and weights via block-diagonal one-hot matmuls
    xg = x.reshape(bt, g, m).astype(jnp.float32)
    xc = jnp.einsum("tgm,gmn->tgn", xg, sel).reshape(bt, g * n)
    wg = w.reshape(g, m, bo).astype(jnp.float32)
    wc = jnp.einsum("gmo,gmn->gno", wg, sel).reshape(g * n, bo)

    o_ref[...] = jnp.dot(
        xc, wc, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "m", "block_t", "block_o",
                                             "interpret"))
def nm_spmm_pallas(
    x: jax.Array,                       # (T, D)
    w: jax.Array,                       # (D, N_out)
    scale: Optional[jax.Array],         # (D,) or None
    n: int,
    m: int,
    block_t: int = 256,                 # = consensus tile size
    block_o: int = 256,
    interpret: bool = True,
) -> jax.Array:
    t, d = x.shape
    n_out = w.shape[-1]
    bt = min(block_t, t)
    bo = min(block_o, n_out)
    assert t % bt == 0 and n_out % bo == 0 and d % m == 0, (t, d, n_out, m)
    has_scale = scale is not None
    if not has_scale:
        scale = jnp.ones((d,), jnp.float32)

    return pl.pallas_call(
        functools.partial(_kernel, n=n, m=m, has_scale=has_scale),
        grid=(t // bt, n_out // bo),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bo), lambda i, j: (0, j)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n_out), x.dtype),
        interpret=interpret,
    )(x, w, scale)

"""Pallas TPU kernel: tile-consensus N:M compacted matmul (the TPU SpMM).

This is the TPU-native analogue of the sparse-tensor-core SpMM the paper
targets (DESIGN.md §2).  Per token tile, one shared N:M channel pattern is
chosen (L2-pooled Amber scores), and the contraction runs over only the
surviving D·N/M channels — a real (M/N)× MXU FLOP reduction, unlike
per-token masking which the MXU cannot exploit.

In-kernel compaction uses **one-hot selection matmuls** (block-diagonal,
(m × n) per group): gathers with traced indices don't vectorize on the TPU
VPU, but tiny matmuls run on the MXU at full utilization.  Cost per tile:
  selection:  bt·D·n + D·n·bo     (≈ n/m · bo⁻¹ relative overhead)
  main GEMM:  bt·(D·n/m)·bo       (the (M/N)× win)

Grid: (T/bt, N_out/bo, D/bk) with a float32 accumulator scratch.  The
consensus selection is *local to each group of M channels* (the tile-L2
pool is per-channel), so k-blocking the reduction depth is exact: each
k-step selects inside its own groups and accumulates its partial product.
VMEM residency per instance is bt·bk + bk·bo + compacted operands —
independent of D, so D = 16k+ models tile fine (the previous full-D
BlockSpec capped out near D ≤ 8192 at bf16 and wasted VMEM below that).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.nm_prune import _select_topn_mask

__all__ = ["nm_spmm_pallas"]


def _selection_onehot(scores_g: jax.Array, n: int, m: int) -> jax.Array:
    """(G, m) pooled scores → (G, m, n) one-hot selection (rank order)."""
    remaining = scores_g
    cols = []
    for _ in range(n):
        cur = remaining.max(axis=-1, keepdims=True)
        eq = remaining == cur
        first = eq & (jnp.cumsum(eq.astype(jnp.int32), axis=-1) == 1)
        cols.append(first.astype(jnp.float32))
        remaining = jnp.where(first, float("-inf"), remaining)
    return jnp.stack(cols, axis=-1)                     # (G, m, n)


def _kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *, n: int, m: int,
            has_scale: bool, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                      # (bt, bk)
    w = w_ref[...]                                      # (bk, bo)
    bt, bk = x.shape
    bo = w.shape[-1]
    g = bk // m

    s = jnp.abs(x.astype(jnp.float32))
    if has_scale:
        s = s * scale_ref[...].astype(jnp.float32)[None, :]
    pooled = jnp.sqrt((s * s).sum(axis=0))              # (bk,) tile-L2 pool
    sel = _selection_onehot(pooled.reshape(g, m), n, m) # (G, m, n)

    # compact activations and weights via block-diagonal one-hot matmuls
    xg = x.reshape(bt, g, m).astype(jnp.float32)
    xc = jnp.einsum("tgm,gmn->tgn", xg, sel).reshape(bt, g * n)
    wg = w.reshape(g, m, bo).astype(jnp.float32)
    wc = jnp.einsum("gmo,gmn->gno", wg, sel).reshape(g * n, bo)

    acc_ref[...] += jnp.dot(xc, wc, preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "m", "block_t", "block_o",
                                             "block_k", "interpret"))
def nm_spmm_pallas(
    x: jax.Array,                       # (T, D)
    w: jax.Array,                       # (D, N_out)
    scale: Optional[jax.Array],         # (D,) or None
    n: int,
    m: int,
    block_t: int = 256,                 # = consensus tile size
    block_o: int = 256,
    block_k: int = 2048,
    interpret: bool = True,
) -> jax.Array:
    t, d = x.shape
    n_out = w.shape[-1]
    bt = min(block_t, t)
    bo = min(block_o, n_out)
    bk = min(block_k, d)
    assert t % bt == 0 and n_out % bo == 0 and d % bk == 0 and bk % m == 0, (
        t, d, n_out, bt, bo, bk, m)
    k_steps = d // bk
    has_scale = scale is not None
    if not has_scale:
        scale = jnp.ones((d,), jnp.float32)

    return pl.pallas_call(
        functools.partial(_kernel, n=n, m=m, has_scale=has_scale,
                          k_steps=k_steps),
        grid=(t // bt, n_out // bo, k_steps),
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bo), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bt, bo), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n_out), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, bo), jnp.float32)],
        interpret=interpret,
    )(x, w, scale)

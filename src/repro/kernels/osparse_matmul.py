"""Pallas TPU kernel: fused Outstanding-sparse projection.

The Outstanding-sparse runtime chain (paper §Outstanding-sparse) is

    smooth-divide → N:M prune → int8 quantize → int8 GEMM → dequant

which the jnp path executes as 4-5 separate XLA ops, each a full HBM pass
over a T×D activation tensor (smoothed copy, masked copy, quantized copy,
GEMM read).  This kernel runs the whole chain inside one ``pallas_call``:
every intermediate (smoothed / masked / quantized tile) lives in
registers, and the only HBM write is the T×N_out output.  The GEMM's own
block streaming is the same as a dense tiled matmul's; what the fusion
removes is the three intermediate copies' write+read traffic.

Two quantization modes (matching ``repro.core.quant``):

  * **per-tensor** (static ``act_scale``): classic k-blocked int8 GEMM grid
    (T/bt, N_out/bo, D/bk) with an int32 accumulator scratch; int32 partial
    sums commute, so the result is bit-equal to the jnp oracle.
  * **per-token** (dynamic scales): the row absmax of the *pruned smoothed*
    activations must be known before quantizing, so the k axis runs two
    sweeps — sweep 1 (executed only at the first output block; the scratch
    persists across the sequential j steps) reduces the per-token absmax,
    sweep 2 quantizes with the finished scale and accumulates the int8
    GEMM.  Cost: one extra streaming pass over X and zero intermediate
    writes, vs the jnp path's ~4 reads + 3 writes.

Scoring uses the Amber channel scale on the *smoothed* activations, exactly
as ``layers.linear._quantized`` does; selection is the shared iterative
first-occurrence argmax, so masks match ``nm.apply_nm`` bit-for-bit.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.nm_prune import _select_topn_mask

__all__ = ["osparse_matmul_pallas"]

_EPS = 1e-8  # matches repro.core.quant._EPS


def _pruned_smoothed(x, smooth, amber, *, n, m, has_amber, prune=True):
    """smooth-divide + score + N:M mask, all in registers. (bt, bk) f32.

    ``prune=False`` (static) skips scoring/selection entirely — the same
    kernel then runs the plain smoothed W8A8 chain, which is what the
    decode phase uses (the policy gates pruning to prefill)."""
    xs = x.astype(jnp.float32) / smooth.astype(jnp.float32)[None, :]
    if not prune:
        return xs
    s = jnp.abs(xs)
    if has_amber:
        s = s * amber.astype(jnp.float32)[None, :]
    bt, bk = s.shape
    keep = _select_topn_mask(s.reshape(bt, bk // m, m), n, m).reshape(bt, bk)
    return jnp.where(keep, xs, 0.0)


def _quantize(xp, scale):
    return jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)


def _kernel(x_ref, wq_ref, smooth_ref, amber_ref, ws_ref, as_ref, bias_ref,
            o_ref, acc_ref, amax_ref, *, n: int, m: int, has_amber: bool,
            has_bias: bool, prune: bool, per_token: bool, k_steps: int):
    j = pl.program_id(1)
    k = pl.program_id(2)

    def xp():
        return _pruned_smoothed(x_ref[...], smooth_ref[...], amber_ref[...],
                                n=n, m=m, has_amber=has_amber, prune=prune)

    def epilogue(o):  # (bt, bo) f32 dequantized — fold the bias-add in
        if has_bias:
            o = o + bias_ref[...].astype(jnp.float32)[None, :]
        return o

    if per_token:
        # ---- sweep 1: reduce the per-token absmax of the pruned rows.
        # The scale is independent of the output block, and the grid runs
        # sequentially with j outer / k inner, so the scratch filled at
        # j == 0 stays valid for every later j of the same token block —
        # the sweep (and its smooth+select work) runs once per i, not per j.
        @pl.when((j == 0) & (k == 0))
        def _init_amax():
            amax_ref[...] = jnp.zeros_like(amax_ref)

        @pl.when((j == 0) & (k < k_steps))
        def _scan_amax():
            amax_ref[...] = jnp.maximum(
                amax_ref[...], jnp.abs(xp()).max(axis=-1, keepdims=True))

        # ---- sweep 2: quantize with the finished scale, int8 GEMM ----
        @pl.when(k == k_steps)
        def _init_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        @pl.when(k >= k_steps)
        def _accumulate():
            scale = jnp.maximum(amax_ref[...], _EPS) / 127.0    # (bt, 1)
            acc_ref[...] += jax.lax.dot_general(
                _quantize(xp(), scale), wq_ref[...],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )

        @pl.when(k == 2 * k_steps - 1)
        def _finish():
            scale = jnp.maximum(amax_ref[...], _EPS) / 127.0
            w_scale = ws_ref[...].astype(jnp.float32)
            o_ref[...] = epilogue(acc_ref[...].astype(jnp.float32) * scale
                                  * w_scale[None, :]).astype(o_ref.dtype)
    else:
        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        act_scale = as_ref[0]
        acc_ref[...] += jax.lax.dot_general(
            _quantize(xp(), act_scale), wq_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

        @pl.when(k == k_steps - 1)
        def _finish():
            w_scale = ws_ref[...].astype(jnp.float32)
            o_ref[...] = epilogue(acc_ref[...].astype(jnp.float32) * act_scale
                                  * w_scale[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "m", "prune", "per_token",
                                             "block_t", "block_o", "block_k",
                                             "interpret"))
def osparse_matmul_pallas(
    x: jax.Array,                       # (T, D) raw (unsmoothed) activations
    wq: jax.Array,                      # (D, N_out) int8
    smooth: jax.Array,                  # (D,) SmoothQuant divide factor
    amber: Optional[jax.Array],         # (D,) Amber channel scale or None
    w_scale: jax.Array,                 # (N_out,) f32 per-channel dequant
    act_scale: Optional[jax.Array],     # scalar f32, required unless per_token
    n: int,
    m: int,
    bias: Optional[jax.Array] = None,   # (N_out,) or None — epilogue add
    prune: bool = True,                 # False → plain smoothed W8A8 (decode)
    per_token: bool = False,
    block_t: int = 256,
    block_o: int = 256,
    block_k: int = 512,
    interpret: bool = True,             # CPU container default; False on TPU
) -> jax.Array:
    t, d = x.shape
    n_out = wq.shape[-1]
    if not prune:
        n = m = 1  # selection is skipped; neutralize the bk % m constraint
    bt = min(block_t, t)
    bo = min(block_o, n_out)
    bk = min(block_k, d)
    assert t % bt == 0 and n_out % bo == 0 and d % bk == 0 and bk % m == 0, (
        t, d, n_out, bt, bo, bk, m)
    k_steps = d // bk
    has_amber = amber is not None
    if not has_amber:
        amber = jnp.ones((d,), jnp.float32)
    has_bias = bias is not None
    if not has_bias:
        bias = jnp.zeros((n_out,), jnp.float32)
    if act_scale is None:
        if not per_token:
            raise ValueError("act_scale is required for per-tensor mode")
        act_scale = jnp.ones((), jnp.float32)  # unused placeholder

    # per-token mode runs the k axis twice: absmax sweep, then GEMM sweep
    k_grid = (2 * k_steps) if per_token else k_steps
    x_block = lambda i, j, k: (i, k % k_steps)
    d_block = lambda i, j, k: (k % k_steps,)

    return pl.pallas_call(
        functools.partial(_kernel, n=n, m=m, has_amber=has_amber,
                          has_bias=has_bias, prune=prune,
                          per_token=per_token, k_steps=k_steps),
        grid=(t // bt, n_out // bo, k_grid),
        in_specs=[
            pl.BlockSpec((bt, bk), x_block),
            pl.BlockSpec((bk, bo), lambda i, j, k: (k % k_steps, j)),
            pl.BlockSpec((bk,), d_block),
            pl.BlockSpec((bk,), d_block),
            pl.BlockSpec((bo,), lambda i, j, k: (j,)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
            pl.BlockSpec((bo,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, bo), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n_out), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bt, bo), jnp.int32),
            pltpu.VMEM((bt, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, wq, smooth, amber, w_scale,
      jnp.asarray(act_scale, jnp.float32).reshape(1), bias)

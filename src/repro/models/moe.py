"""Routed mixture-of-experts with a sort-based grouped matmul.

Dispatch is dropless: tokens are argsorted by expert id and contracted with
``jax.lax.ragged_dot`` (grouped matmul — FLOPs ∝ top_k, not n_experts).
An einsum-based dense fallback (``moe_impl='dense'``) exists for platforms
where ragged_dot does not lower.

Amber Pruner inside experts: the paper disables Robust-Norm scoring for MoE
(tokens are dynamically routed → per-expert weight statistics are unstable),
so expert-FFN inputs are pruned with plain |X| scores (``moe_plain_score``);
the per-token N:M mode is used even under tile-consensus because expert
groups don't align with token tiles.  Router projections stay dense (tiny).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import pruner
from repro.core.policy import SparsityPolicy
from repro.layers.linear import init_linear
from repro.models.mlp import _act, init_mlp, mlp

__all__ = ["init_moe", "moe"]


def init_moe(
    rng: jax.Array,
    d_model: int,
    moe_d_ff: int,
    n_experts: int,
    shared_expert: bool,
    dtype=jnp.float32,
) -> Dict:
    r = jax.random.split(rng, 5)
    std = d_model**-0.5
    fstd = moe_d_ff**-0.5
    p = {
        "router": init_linear(r[0], d_model, n_experts, dtype=jnp.float32),
        "experts": {
            "gate_proj": {"w": (jax.random.normal(r[1], (n_experts, d_model, moe_d_ff)) * std).astype(dtype)},
            "up_proj": {"w": (jax.random.normal(r[2], (n_experts, d_model, moe_d_ff)) * std).astype(dtype)},
            "down_proj": {"w": (jax.random.normal(r[3], (n_experts, moe_d_ff, d_model)) * fstd).astype(dtype)},
        },
    }
    if shared_expert:
        p["shared"] = init_mlp(r[4], d_model, moe_d_ff, dtype)
    return p


def _maybe_prune(x: jax.Array, module: str, policy: SparsityPolicy,
                 phase: str) -> jax.Array:
    if policy.active(phase) and policy.should_prune(module, None):
        return pruner.prune_input(x, None, policy)  # naive |X| inside experts
    return x


def moe(
    x: jax.Array,                      # (..., T, D) — flattened internally
    p: Dict,
    policy: SparsityPolicy,
    phase: str,
    top_k: int,
    act_fn: str = "silu",
    impl: str = "ragged",
    flags: Optional[Dict[str, jax.Array]] = None,
) -> jax.Array:
    # Under a multi-device mesh, GSPMD partitions ragged_dot by expanding
    # the expert dim into dense masked ops over the GLOBAL token axis
    # (O(E·T·d) buffers).  Dispatch must be token-local: shard_map keeps the
    # sort/bincount/ragged_dot per data shard, with TP over d_ff and one
    # explicit psum for the row-parallel down projection.
    from repro.distributed.sharding import _context_mesh, data_axes

    mesh = _context_mesh()
    if (impl == "ragged" and mesh is not None and mesh.size > 1
            and "model" in mesh.axis_names and x.ndim == 3):
        dp_size = 1
        for a in data_axes(mesh):
            dp_size *= mesh.shape[a]
        # shard_map needs the batch divisible by DP; tiny batches (e.g. the
        # long-context decode cell, B=1) go through the local path — the
        # token count there is trivial so the portable ragged decomposition
        # is harmless
        if x.shape[0] % dp_size == 0 and x.shape[0] >= dp_size:
            return _moe_shard_map(mesh, x, p, policy, phase, top_k, act_fn,
                                  flags)
    return _moe_local(x, p, policy, phase, top_k, act_fn, impl, flags)


def _moe_local(
    x: jax.Array,
    p: Dict,
    policy: SparsityPolicy,
    phase: str,
    top_k: int,
    act_fn: str = "silu",
    impl: str = "ragged",
    flags: Optional[Dict[str, jax.Array]] = None,
) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    n_experts = p["router"]["w"].shape[-1]

    logits = (xt.astype(jnp.float32) @ p["router"]["w"])        # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(logits, top_k)        # (T, k)
    gates = jax.nn.softmax(gate_vals, axis=-1)                  # renorm over top-k

    wg = p["experts"]["gate_proj"]["w"]
    wu = p["experts"]["up_proj"]["w"]
    wd = p["experts"]["down_proj"]["w"]

    if impl == "dense":
        # weighted all-expert compute (compile-anywhere fallback)
        combine = jnp.zeros((t, n_experts), jnp.float32)
        combine = jax.vmap(lambda c, i, g: c.at[i].add(g))(combine, expert_ids, gates)
        xin = _maybe_prune(xt, "gate_proj", policy, phase)
        xup = _maybe_prune(xt, "up_proj", policy, phase)
        h = _act(jnp.einsum("td,edf->tef", xin, wg), act_fn)
        h = h * jnp.einsum("td,edf->tef", xup, wu)
        h = _maybe_prune(h.reshape(t * n_experts, -1), "down_proj", policy, phase
                         ).reshape(t, n_experts, -1)
        y_e = jnp.einsum("tef,efd->ted", h, wd)
        y = jnp.einsum("ted,te->td", y_e, combine.astype(y_e.dtype))
    else:
        flat_e = expert_ids.reshape(-1)                         # (T*k,)
        flat_t = jnp.repeat(jnp.arange(t), top_k)               # (T*k,)
        order = jnp.argsort(flat_e, stable=True)
        inv = jnp.argsort(order)
        xs = jnp.take(xt, jnp.take(flat_t, order), axis=0)      # (T*k, D)
        group_sizes = jnp.bincount(flat_e, length=n_experts).astype(jnp.int32)

        xg = _maybe_prune(xs, "gate_proj", policy, phase)
        xu = _maybe_prune(xs, "up_proj", policy, phase)
        hg = jax.lax.ragged_dot(xg, wg, group_sizes)
        hu = jax.lax.ragged_dot(xu, wu, group_sizes)
        h = _act(hg, act_fn) * hu
        h = _maybe_prune(h, "down_proj", policy, phase)
        ys = jax.lax.ragged_dot(h, wd, group_sizes)             # (T*k, D)
        y_flat = jnp.take(ys, inv, axis=0).reshape(t, top_k, d)
        y = jnp.einsum("tkd,tk->td", y_flat, gates.astype(y_flat.dtype))

    y = y.astype(x.dtype)
    if "shared" in p:
        y = y + mlp(xt, p["shared"], policy, phase, act_fn, None, flags)
    return y.reshape(orig_shape)


def _moe_shard_map(
    mesh,
    x: jax.Array,                      # (B, T, D)
    p: Dict,
    policy: SparsityPolicy,
    phase: str,
    top_k: int,
    act_fn: str,
    flags: Optional[Dict[str, jax.Array]],
) -> jax.Array:
    """Token-local routed experts under shard_map.

    Layout: batch over the DP axes, expert weights TP-sharded on d_ff over
    "model" (column-parallel gate/up, row-parallel down + psum).  Routing,
    argsort, bincount and both ragged_dots see only LOCAL shapes — the
    collective footprint is exactly one psum of the (local tokens, d_model)
    output, matching a Megatron MLP.

    N:M note: inside the experts the groups-of-M run over each device's
    contiguous d_ff shard — identical semantics to the unsharded op for
    gate/up (d_model unsharded); for the down projection the group
    boundaries align with the weight shard, which is also how a sparse
    tensor core would see the operand.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    dp = data_axes_tuple(mesh)
    wg, wu, wd = (p["experts"]["gate_proj"]["w"], p["experts"]["up_proj"]["w"],
                  p["experts"]["down_proj"]["w"])
    router = p["router"]["w"]

    def body(xb, router_l, wg_l, wu_l, wd_l):
        b, t, d = xb.shape
        n_exp = router_l.shape[-1]
        xt = xb.reshape(b * t, d)
        logits = xt.astype(jnp.float32) @ router_l
        gate_vals, expert_ids = jax.lax.top_k(logits, top_k)
        gates = jax.nn.softmax(gate_vals, axis=-1)

        # sort-by-expert, then FIXED-CAPACITY batched matmuls.  ragged_dot
        # would be the native TPU op, but its portable decomposition dense-
        # expands the expert dim (O(E·T·d)); capacity slots keep every
        # shape static and partitioner-friendly at topk·cf× dense FLOPs.
        flat_e = expert_ids.reshape(-1)                      # (t*k,)
        flat_t = jnp.repeat(jnp.arange(b * t), top_k)
        order = jnp.argsort(flat_e, stable=True)
        tok_sorted = jnp.take(flat_t, order)
        xs = jnp.take(xt, tok_sorted, axis=0)                # (t*k, D)
        counts = jnp.bincount(flat_e, length=n_exp)
        offsets = jnp.cumsum(counts) - counts

        cap = int(-(-(b * t * top_k) // n_exp) * 1.25)
        cap = max(8, -(-cap // 8) * 8)
        slot = jnp.arange(cap)
        idx = offsets[:, None] + slot[None, :]               # (E, C)
        valid = slot[None, :] < counts[:, None]
        idx_c = jnp.clip(idx, 0, b * t * top_k - 1)
        xe = jnp.take(xs, idx_c.reshape(-1), axis=0).reshape(
            n_exp, cap, d)                                   # (E, C, D)

        xg = _maybe_prune(xe, "gate_proj", policy, phase)
        xu = _maybe_prune(xe, "up_proj", policy, phase)
        hg = jnp.einsum("ecd,edf->ecf", xg, wg_l)
        hu = jnp.einsum("ecd,edf->ecf", xu, wu_l)
        h = _act(hg, act_fn) * hu
        h = _maybe_prune(h, "down_proj", policy, phase)
        ye = jnp.einsum("ecf,efd->ecd", h, wd_l)             # partial over F
        ye = ye * valid[..., None]

        ys = jnp.zeros((b * t * top_k, d), ye.dtype).at[
            idx_c.reshape(-1)].add(ye.reshape(-1, d))
        y = jnp.take(ys, jnp.argsort(order), axis=0).reshape(
            b * t, top_k, d)
        y = jnp.einsum("tkd,tk->td", y, gates.astype(y.dtype))
        y = jax.lax.psum(y, "model")                         # row-parallel sum
        return y.reshape(b, t, d).astype(xb.dtype)

    dp_entry = dp if len(dp) > 1 else dp[0]
    y = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(dp_entry, None, None),          # x: batch over DP
            P(None, None),                    # router replicated
            P(None, None, "model"),           # gate (E, D, F/model)
            P(None, None, "model"),           # up
            P(None, "model", None),           # down (E, F/model, D)
        ),
        out_specs=P(dp_entry, None, None),
        check_rep=False,
    )(x, router, wg, wu, wd)

    if "shared" in p:
        y = y + mlp(x, p["shared"], policy, phase, act_fn, None, flags)
    return y


def data_axes_tuple(mesh):
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))

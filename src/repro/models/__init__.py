from repro.models.model import build_model

__all__ = ["build_model"]

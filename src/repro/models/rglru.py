"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Recurrence (per channel):
    r_t = σ(x_t W_a),  i_t = σ(x_t W_x)
    a_t = exp(-c · softplus(Λ) · r_t)                (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

The block wraps the recurrence Griffin-style:
    y = W_out( RG-LRU(conv4(W_in x)) ⊙ gelu(W_gate x) )
with a causal width-4 temporal conv.  The linear recurrence is evaluated
with ``jax.lax.associative_scan`` for prefill (log-depth on TPU) and as a
single step for decode.

Amber mapping: W_in → 'q_proj' (selective), W_gate → 'gate_proj'
(selective), W_out → 'o_proj' (skipped); the small recurrence gates
W_a / W_x and Λ stay dense (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import SparsityPolicy
from repro.layers.linear import init_linear, sparse_linear

__all__ = ["init_rglru_block", "rglru_block", "init_rglru_state"]

_C = 8.0


def init_rglru_block(rng: jax.Array, d: int, rnn_w: int, conv_width: int,
                     dtype=jnp.float32) -> Dict:
    r = jax.random.split(rng, 7)
    return {
        "q_proj": init_linear(r[0], d, rnn_w, dtype=dtype),      # W_in
        "gate_proj": init_linear(r[1], d, rnn_w, dtype=dtype),   # W_gate
        "o_proj": init_linear(r[2], rnn_w, d, dtype=dtype),      # W_out
        "conv_w": (jax.random.normal(r[3], (conv_width, rnn_w)) *
                   (conv_width * rnn_w) ** -0.25).astype(dtype),
        "conv_b": jnp.zeros((rnn_w,), dtype),
        "gate_a": init_linear(r[4], rnn_w, rnn_w, dtype=dtype),  # W_a (dense)
        "gate_x": init_linear(r[5], rnn_w, rnn_w, dtype=dtype),  # W_x (dense)
        "lam": (jax.random.uniform(r[6], (rnn_w,)) * 3 + 2).astype(jnp.float32),
    }


def init_rglru_state(batch: int, rnn_w: int, conv_width: int,
                     dtype=jnp.float32) -> Dict:
    return {
        "h": jnp.zeros((batch, rnn_w), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, rnn_w), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 hist: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv via shifted adds.  x: (B,T,W), hist: (B,cw-1,W)."""
    cw = w.shape[0]
    xp = jnp.concatenate([hist, x], axis=1)              # (B, T+cw-1, W)
    t = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(cw):
        y = y + xp[:, i : i + t].astype(jnp.float32) * w[cw - 1 - i].astype(jnp.float32)
    new_hist = xp[:, -(cw - 1):] if cw > 1 else hist
    return (y + b.astype(jnp.float32)).astype(x.dtype), new_hist


def _rglru_scan(a: jax.Array, bx: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t h_{t-1} + bx_t via associative scan.  a,bx: (B,T,W) f32."""
    # fold h0 into the first step: bx_0 += a_0 * h0
    bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return hh


def rglru_block(
    x: jax.Array,                      # (B, T, d)
    p: Dict,
    policy: SparsityPolicy,
    phase: str,
    state: Optional[Dict] = None,
    flags: Optional[Dict[str, jax.Array]] = None,
):
    """Returns (y, new_state)."""
    b, t, d = x.shape
    rnn_w = p["conv_b"].shape[0]
    cw = p["conv_w"].shape[0]
    if state is None:
        state = init_rglru_state(b, rnn_w, cw, x.dtype)
    fl = flags or {}

    xi = sparse_linear(x, p["q_proj"], "q_proj", policy, phase, None,
                       fl.get("q_proj"))
    gate = jax.nn.gelu(
        sparse_linear(x, p["gate_proj"], "gate_proj", policy, phase, None,
                      fl.get("gate_proj"))
    )
    xc, new_hist = _causal_conv(xi, p["conv_w"], p["conv_b"], state["conv"])

    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["gate_a"]["w"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["gate_x"]["w"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    bx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)

    if t == 1:
        h = a[:, 0] * state["h"] + bx[:, 0]
        hs = h[:, None]
        h_last = h
    else:
        hs = _rglru_scan(a, bx, state["h"])
        h_last = hs[:, -1]

    y = (hs.astype(x.dtype) * gate)
    y = sparse_linear(y, p["o_proj"], "o_proj", policy, phase, None,
                      fl.get("o_proj"))
    return y, {"h": h_last, "conv": new_hist}

"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

The audio frontend is a STUB per the task spec: ``batch["frame_embeds"]``
carries precomputed (B, encoder_seq, d_model) frame embeddings (what the
two conv layers would produce).  Encoder = non-causal attention stack;
decoder = causal self-attention + cross-attention + MLP, scan-stacked.

Whisper's MLP is non-gated (fc1 → GELU → fc2); for the Amber policy we map
fc1 → 'gate_proj' (selectively pruned) and fc2 → 'down_proj' (always
pruned).  Cross-attention K/V projections run once per request over the
encoder states and are cached — they map to 'k_proj'/'v_proj' (skipped).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import SparsityPolicy
from repro.layers.linear import init_linear, sparse_linear
from repro.models import common
from repro.models.attention import attention

__all__ = ["init_params", "forward", "init_cache", "prefill", "prefill_chunk",
           "decode_step"]


def _init_ff(cfg, rng, dtype):
    r1, r2 = jax.random.split(rng)
    return {
        "gate_proj": init_linear(r1, cfg.d_model, cfg.d_ff, bias=True, dtype=dtype),
        "down_proj": init_linear(r2, cfg.d_ff, cfg.d_model, bias=True, dtype=dtype),
    }


def _ff(x, p, policy, phase):
    h = sparse_linear(x, p["gate_proj"], "gate_proj", policy, phase)
    h = jax.nn.gelu(h)
    return sparse_linear(h, p["down_proj"], "down_proj", policy, phase)


def _init_attn(cfg, rng, dtype):
    r = jax.random.split(rng, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "q_proj": init_linear(r[0], d, qd, bias=True, dtype=dtype),
        "k_proj": init_linear(r[1], d, kvd, dtype=dtype),
        "v_proj": init_linear(r[2], d, kvd, bias=True, dtype=dtype),
        "o_proj": init_linear(r[3], qd, d, bias=True, dtype=dtype),
    }


def _init_enc_block(cfg, rng, dtype):
    r1, r2 = jax.random.split(rng)
    return {
        "ln1": common.init_norm(cfg.d_model, cfg.norm, dtype),
        "attn": _init_attn(cfg, r1, dtype),
        "ln2": common.init_norm(cfg.d_model, cfg.norm, dtype),
        "ff": _init_ff(cfg, r2, dtype),
    }


def _init_dec_block(cfg, rng, dtype):
    r1, r2, r3 = jax.random.split(rng, 3)
    return {
        "ln1": common.init_norm(cfg.d_model, cfg.norm, dtype),
        "self_attn": _init_attn(cfg, r1, dtype),
        "ln_x": common.init_norm(cfg.d_model, cfg.norm, dtype),
        "cross_attn": _init_attn(cfg, r2, dtype),
        "ln2": common.init_norm(cfg.d_model, cfg.norm, dtype),
        "ff": _init_ff(cfg, r3, dtype),
    }


def init_params(cfg: ModelConfig, rng: jax.Array) -> Dict:
    dtype = common.dtype_of(cfg)
    r = jax.random.split(rng, 5)
    return {
        "embed": common.init_embedding(r[0], cfg.vocab_size, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(cfg, k, dtype))(
            jax.random.split(r[1], cfg.n_encoder_layers)),
        "enc_norm": common.init_norm(cfg.d_model, cfg.norm, dtype),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(cfg, k, dtype))(
            jax.random.split(r[2], cfg.n_layers)),
        "dec_norm": common.init_norm(cfg.d_model, cfg.norm, dtype),
        "lm_head": init_linear(r[3], cfg.d_model, cfg.vocab_size, dtype=dtype),
    }


def _qkv(x, p, cfg, policy, phase, kv_x=None):
    b, t, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    s = kv_x.shape[1]
    q = sparse_linear(x, p["q_proj"], "q_proj", policy, phase)
    k = sparse_linear(kv_x, p["k_proj"], "k_proj", policy, phase)
    v = sparse_linear(kv_x, p["v_proj"], "v_proj", policy, phase)
    return (q.reshape(b, t, cfg.n_heads, cfg.head_dim),
            k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim),
            v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim))


def _encode(cfg, params, frame_embeds, policy, phase):
    frame_embeds = frame_embeds.astype(params["enc_norm"]["w"].dtype)
    b, s, d = frame_embeds.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = frame_embeds + common.sinusoidal_positions(pos, d).astype(frame_embeds.dtype)

    def body(h_c, pp):
        x = common.norm_apply(h_c, pp["ln1"], cfg.norm)
        q, k, v = _qkv(x, pp["attn"], cfg, policy, phase)
        o = attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
        o = sparse_linear(o.reshape(b, s, cfg.q_dim), pp["attn"]["o_proj"],
                          "o_proj", policy, phase)
        h_c = h_c + o
        x2 = common.norm_apply(h_c, pp["ln2"], cfg.norm)
        return h_c + _ff(x2, pp["ff"], policy, phase), None

    if not cfg.scan_layers:  # analysis mode: exact per-layer cost accounting
        for i in range(cfg.n_encoder_layers):
            pp = jax.tree_util.tree_map(lambda x: x[i], params["enc_blocks"])
            h, _ = body(h, pp)
    else:
        h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return common.norm_apply(h, params["enc_norm"], cfg.norm)


def _decode_blocks(cfg, params, h, enc_out, policy, phase, cache, pos,
                   chunk_len=None):
    """Runs the decoder stack.  cache None → training path (full seq).

    ``chunk_len`` (traced, prefill-with-cache only) enables offset writes:
    the chunk's first ``chunk_len`` tokens land at cache rows
    ``pos .. pos+chunk_len`` and attend over the whole cached prefix.  With
    ``enc_out`` None the cached cross-KV is reused (chunks after the first).
    ``pos`` may be a (B,) vector in single-token decode (slot batching).
    """
    b, t, _ = h.shape

    def body(h_c, xs):
        pp, cc = xs if cache is not None else (xs, None)
        x = common.norm_apply(h_c, pp["ln1"], cfg.norm)
        q, k, v = _qkv(x, pp["self_attn"], cfg, policy, phase)
        new_cc = {}
        if cache is None:
            o = attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        elif t == 1:
            s_c = cc["self_k"].shape[1]
            if jnp.ndim(pos) == 1:
                bidx = jnp.arange(b)
                ck = cc["self_k"].at[bidx, pos].set(k[:, 0], mode="drop")
                cv = cc["self_v"].at[bidx, pos].set(v[:, 0], mode="drop")
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(cc["self_k"], k, pos,
                                                         axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(cc["self_v"], v, pos,
                                                         axis=1)
            o = attention(q, ck, cv, causal=False, q_offset=pos,
                          kv_len=jnp.minimum(pos + 1, s_c),
                          chunk=cfg.attn_chunk)
            new_cc.update(self_k=ck, self_v=cv)
        elif chunk_len is not None:  # chunked prefill at offset pos
            s_c = cc["self_k"].shape[1]
            i = jnp.arange(t)
            idx = jnp.where(i < chunk_len, pos + i, s_c)   # pad rows dropped
            ck = cc["self_k"].at[:, idx].set(k, mode="drop")
            cv = cc["self_v"].at[:, idx].set(v, mode="drop")
            o = attention(q, ck, cv, causal=True, q_offset=pos,
                          kv_len=pos + chunk_len, chunk=cfg.attn_chunk)
            new_cc.update(self_k=ck, self_v=cv)
        else:  # prefill
            o = attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
            ck = jax.lax.dynamic_update_slice_in_dim(cc["self_k"], k, 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cc["self_v"], v, 0, axis=1)
            new_cc.update(self_k=ck, self_v=cv)
        o = sparse_linear(o.reshape(b, t, cfg.q_dim), pp["self_attn"]["o_proj"],
                          "o_proj", policy, phase)
        h_c = h_c + o

        # cross attention: reuse the cached encoder KV whenever no fresh
        # encoder output is supplied (decode steps and prefill chunks > 0)
        xx = common.norm_apply(h_c, pp["ln_x"], cfg.norm)
        if cache is not None and enc_out is None:
            qx = sparse_linear(xx, pp["cross_attn"]["q_proj"], "q_proj",
                               policy, phase)
            qx = qx.reshape(b, t, cfg.n_heads, cfg.head_dim)
            kx, vx = cc["cross_k"], cc["cross_v"]
            new_cc.update(cross_k=kx, cross_v=vx)
        else:
            qx, kx, vx = _qkv(xx, pp["cross_attn"], cfg, policy, phase,
                              kv_x=enc_out)
            if cache is not None:
                new_cc.update(cross_k=kx, cross_v=vx)
        ox = attention(qx, kx, vx, causal=False, chunk=cfg.attn_chunk)
        ox = sparse_linear(ox.reshape(b, t, cfg.q_dim),
                           pp["cross_attn"]["o_proj"], "o_proj", policy, phase)
        h_c = h_c + ox

        x2 = common.norm_apply(h_c, pp["ln2"], cfg.norm)
        h_c = h_c + _ff(x2, pp["ff"], policy, phase)
        return h_c, (new_cc if cache is not None else None)

    if cache is None:
        if not cfg.scan_layers:
            for i in range(cfg.n_layers):
                pp = jax.tree_util.tree_map(lambda x: x[i],
                                            params["dec_blocks"])
                h, _ = body(h, pp)
            return h, None

        def body2(h_c, pp):
            h_c, _ = body(h_c, pp)
            return h_c, None
        h, _ = jax.lax.scan(body2, h, params["dec_blocks"])
        return h, None

    if not cfg.scan_layers:
        new_stack = cache["blocks"]
        for i in range(cfg.n_layers):
            pp = jax.tree_util.tree_map(lambda x: x[i], params["dec_blocks"])
            cc = jax.tree_util.tree_map(lambda x: x[i], cache["blocks"])
            h, cc_new = body(h, (pp, cc))
            new_stack = jax.tree_util.tree_map(
                lambda c, u: c.at[i].set(u.astype(c.dtype)), new_stack,
                cc_new)
        return h, new_stack

    # cache rides in the carry (see models/transformer.py — avoids XLA-CPU
    # hoisting a full f32 copy of an xs cache out of the layer loop)
    def body3(carry, xs):
        h_c, cs = carry
        pp, idx = xs
        cc = jax.tree_util.tree_map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0, keepdims=False),
            cs)
        h_c, cc_new = body(h_c, (pp, cc))
        cs = jax.tree_util.tree_map(
            lambda c, u: jax.lax.dynamic_update_index_in_dim(
                c, u.astype(c.dtype), idx, 0), cs, cc_new)
        return (h_c, cs), None

    (h, new_blocks), _ = jax.lax.scan(
        body3, (h, cache["blocks"]),
        (params["dec_blocks"], jnp.arange(params["dec_blocks"]["ln1"]["w"].shape[0])))
    return h, new_blocks


def _embed_dec(cfg, params, tokens, pos0):
    b, t = tokens.shape
    h = common.embed(tokens, params["embed"])
    if jnp.ndim(pos0) == 1:                  # per-slot positions (B,)
        pos0 = pos0[:, None]
    pos = pos0 + jnp.broadcast_to(jnp.arange(t), (b, t))
    return h + common.sinusoidal_positions(pos, cfg.d_model).astype(h.dtype)


def forward(cfg: ModelConfig, params, batch, *, policy: SparsityPolicy,
            phase: str = "train") -> jax.Array:
    enc_out = _encode(cfg, params, batch["frame_embeds"], policy, phase)
    h = _embed_dec(cfg, params, batch["tokens"], 0)
    h, _ = _decode_blocks(cfg, params, h, enc_out, policy, phase, None, 0)
    h = common.norm_apply(h, params["dec_norm"], cfg.norm)
    return h @ params["lm_head"]["w"]


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> Dict:
    dtype = dtype or common.dtype_of(cfg)
    kv = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    xkv = (batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim)

    def one(_):
        return {
            "self_k": jnp.zeros(kv, dtype), "self_v": jnp.zeros(kv, dtype),
            "cross_k": jnp.zeros(xkv, dtype), "cross_v": jnp.zeros(xkv, dtype),
        }

    return {"blocks": jax.vmap(one)(jnp.arange(cfg.n_layers)),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(cfg: ModelConfig, params, batch, cache, *, policy):
    enc_out = _encode(cfg, params, batch["frame_embeds"], policy, "prefill")
    tokens = batch["tokens"]
    h = _embed_dec(cfg, params, tokens, 0)
    h, new_blocks = _decode_blocks(cfg, params, h, enc_out, policy, "prefill",
                                   cache, cache["pos"])
    h = common.norm_apply(h[:, -1:], params["dec_norm"], cfg.norm)
    logits = (h @ params["lm_head"]["w"])[:, 0]
    return logits, {"blocks": new_blocks, "pos": cache["pos"] + tokens.shape[1]}


def prefill_chunk(cfg: ModelConfig, params, batch, cache, *, policy):
    """Chunked prefill at the cache offset (see transformer.prefill_chunk).

    The encoder runs only when ``batch`` carries ``frame_embeds`` — the
    serving engine sends them with the first chunk of a request, which
    populates the cross-attention KV cache; later chunks (no frame_embeds →
    a different jit signature, hence their own trace bucket) reuse it.
    """
    tokens = batch["tokens"]
    b, t = tokens.shape
    pos = cache["pos"]
    chunk_len = batch.get("chunk_len")
    if chunk_len is None:
        chunk_len = jnp.asarray(t, jnp.int32)
    enc_out = (_encode(cfg, params, batch["frame_embeds"], policy, "prefill")
               if "frame_embeds" in batch else None)
    h = _embed_dec(cfg, params, tokens, pos)
    h, new_blocks = _decode_blocks(cfg, params, h, enc_out, policy, "prefill",
                                   cache, pos, chunk_len=chunk_len)
    h_last = jax.lax.dynamic_slice_in_dim(h, chunk_len - 1, 1, axis=1)
    h_last = common.norm_apply(h_last, params["dec_norm"], cfg.norm)
    logits = (h_last @ params["lm_head"]["w"])[:, 0]
    return logits, {"blocks": new_blocks, "pos": pos + chunk_len}


def decode_step(cfg: ModelConfig, params, tokens, cache, *, policy):
    pos = cache["pos"]
    h = _embed_dec(cfg, params, tokens, pos)
    h, new_blocks = _decode_blocks(cfg, params, h, None, policy, "decode",
                                   cache, pos)
    h = common.norm_apply(h, params["dec_norm"], cfg.norm)
    logits = (h @ params["lm_head"]["w"])[:, 0]
    return logits, {"blocks": new_blocks, "pos": pos + 1}

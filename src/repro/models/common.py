"""Shared model-zoo pieces: norms, embeddings, positional encodings."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "layer_norm",
    "norm_apply",
    "init_norm",
    "init_embedding",
    "embed",
    "sinusoidal_positions",
    "rope_freqs",
    "apply_rope",
    "apply_rope_2d",
    "apply_mrope",
    "dtype_of",
    "opt_barrier",
]


def dtype_of(cfg) -> Any:
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ---------------------------------------------------------------- barrier

@jax.custom_vjp
def opt_barrier(x):
    """``lax.optimization_barrier`` with an identity gradient.

    The primitive has no differentiation rule on the pinned jax version,
    which breaks training through any scan body that uses the barrier to
    fence LICM; the barrier is the identity, so the cotangent routes
    straight through.
    """
    return jax.lax.optimization_barrier(x)


def _opt_barrier_fwd(x):
    return opt_barrier(x), None


def _opt_barrier_bwd(_, g):
    return (g,)


opt_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


# --------------------------------------------------------------------- norms

def init_norm(d: int, kind: str, dtype=jnp.float32) -> Dict[str, jax.Array]:
    p = {"w": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["b"] = jnp.zeros((d,), dtype)
    return p


def rms_norm(x: jax.Array, p: Dict[str, jax.Array], eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["w"].astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, p: Dict[str, jax.Array], eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["w"].astype(jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm_apply(x: jax.Array, p: Dict[str, jax.Array], kind: str) -> jax.Array:
    return layer_norm(x, p) if kind == "layernorm" else rms_norm(x, p)


# ---------------------------------------------------------------- embeddings

def init_embedding(rng: jax.Array, vocab: int, d: int, dtype=jnp.float32):
    return {"w": (jax.random.normal(rng, (vocab, d)) * 0.02).astype(dtype)}


def embed(tokens: jax.Array, p: Dict[str, jax.Array]) -> jax.Array:
    return jnp.take(p["w"], tokens, axis=0)


def sinusoidal_positions(positions: jax.Array, d: int) -> jax.Array:
    """Classic transformer sinusoidal embedding for arbitrary positions."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float, rotary_dim: int | None = None):
    rd = rotary_dim or head_dim
    return 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs laid out as [x0..x_{d/2-1} | x_{d/2}..] (neox style)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard RoPE.  x: (B, T, H, hd); positions: (B, T) absolute."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                                # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv        # (B, T, hd/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    return _rotate(x, cos, sin)


def apply_rope_2d(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """ChatGLM-style: rotary on the first half of head_dim only."""
    hd = x.shape[-1]
    rd = hd // 2
    xr, xp = x[..., :rd], x[..., rd:]
    inv = rope_freqs(hd, theta, rotary_dim=rd)
    ang = positions.astype(jnp.float32)[..., None] * inv
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([_rotate(xr, cos, sin), xp], axis=-1)


def apply_mrope(
    x: jax.Array, positions_3d: jax.Array, theta: float,
    sections=(0.25, 0.375, 0.375),
) -> jax.Array:
    """Qwen2-VL M-RoPE: head_dim frequency bands split across (t, h, w)
    position streams.  positions_3d: (3, B, T)."""
    hd = x.shape[-1]
    half = hd // 2
    inv = rope_freqs(hd, theta)                                 # (half,)
    n_t = int(half * sections[0])
    n_h = int(half * sections[1])
    sel = jnp.zeros((half,), jnp.int32)
    sel = sel.at[n_t : n_t + n_h].set(1).at[n_t + n_h :].set(2)
    pos = positions_3d.astype(jnp.float32)                      # (3, B, T)
    ang_all = pos[..., None] * inv                              # (3, B, T, half)
    # per-frequency selection of the (t|h|w) position stream
    onehot = jax.nn.one_hot(sel, 3, dtype=jnp.float32)          # (half, 3)
    ang = jnp.einsum("sbth,hs->bth", ang_all, onehot)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    return _rotate(x, cos, sin)

"""Memory-efficient attention for the model zoo.

Online-softmax (flash-style) attention in pure jnp + ``lax.scan`` so that
32k-token prefill lowers with activation memory linear in sequence length:

  * ``full`` causal / non-causal: scan over KV chunks with running
    (max, denom, acc) statistics — peak live buffer is one (Tq × chunk)
    score tile per head group.
  * ``swa`` / ``local`` prefill: scan over **Q chunks**, each attending a
    static ``window + chunk`` KV slab via ``dynamic_slice`` — HLO FLOPs are
    O(T·window), making sliding-window archs genuinely sub-quadratic in the
    lowered module (this is what long-context roofline cells measure).
  * decode: single-token query against a (possibly ring-buffered) cache
    with a validity length.

GQA is computed in grouped layout (B, T, Hkv, G, hd) — KV is never
materialized repeated.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import opt_barrier

__all__ = ["attention", "gather_kv_blocks", "paged_attention"]

_NEG = -1e30


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _kv_chunk_attention(
    q: jax.Array,          # (B, T, Hkv, G, hd) pre-scaled
    k: jax.Array,          # (B, S, Hkv, hd)
    v: jax.Array,          # (B, S, Hkv, hd)
    q_pos: jax.Array,      # (T,) or (B, T) absolute positions of queries
    causal: bool,
    window: Optional[int],
    kv_len: Optional[jax.Array],  # scalar or (B,) valid-slot counts
    kv_pos_base: jax.Array,  # (S,) absolute positions of cache slots
    chunk: int,
) -> jax.Array:
    B, T, Hkv, G, hd = q.shape
    S = k.shape[1]
    c = min(chunk, S)
    Sp = _ceil_to(S, c)
    if Sp != S:
        pad = Sp - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos_base = jnp.pad(kv_pos_base, (0, pad), constant_values=-1)
    n_chunks = Sp // c

    # chunk-level remat = flash-attention backward: scores/probabilities of
    # a chunk are recomputed in its own backward instead of being stacked
    # across the whole scan (which would be O(T·S) live memory in training)
    @jax.checkpoint
    def body(carry, ci):
        # index-based dynamic slices keep the (possibly huge) cache in
        # place — no transposed copy of K/V is ever materialized
        m, l, acc = carry
        start = ci * c
        # the barrier stops XLA commuting convert(f32) past the slice and
        # hoisting a full-cache f32 copy out of the loop (CPU dot lowering)
        kci, vci = opt_barrier((
            jax.lax.dynamic_slice_in_dim(k, start, c, axis=1),
            jax.lax.dynamic_slice_in_dim(v, start, c, axis=1),
        ))
        pci = jax.lax.dynamic_slice_in_dim(kv_pos_base, start, c, axis=0)
        sloti = start + jnp.arange(c)
        s = jnp.einsum(
            "bthgd,bchd->bthgc", q, kci, preferred_element_type=jnp.float32
        )                                                   # (B,T,Hkv,G,c)
        # mask is built in (B', T', c) layout with B'/T' ∈ {1, full} so both
        # scalar (shared) and per-row (slot-batched decode) kv_len / q_pos
        # broadcast against the (B, T, Hkv, G, c) score tile
        qp = q_pos if q_pos.ndim == 2 else q_pos[None, :]     # (B'|1, T)
        mask = (pci >= 0)[None, None, :]                      # (1, 1, c)
        if kv_len is not None:
            kvl = jnp.asarray(kv_len).reshape(-1, 1, 1)       # (B'|1, 1, 1)
            mask = mask & (sloti[None, None, :] < kvl)
        if causal:
            mask = mask & (pci[None, None, :] <= qp[:, :, None])
        if window is not None:
            mask = mask & (pci[None, None, :] > (qp[:, :, None] - window))
        mask = mask[:, :, None, None, :]
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bthgc,bchd->bthgd", p.astype(vci.dtype), vci,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, T, Hkv, G), _NEG, jnp.float32)
    l0 = jnp.zeros((B, T, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, T, Hkv, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out


def _banded_attention(
    q: jax.Array,          # (B, T, Hkv, G, hd) pre-scaled; T == S
    k: jax.Array,
    v: jax.Array,
    window: int,
    chunk: int,
) -> jax.Array:
    """Sliding-window causal prefill: Q-chunk scan over a static KV slab."""
    B, T, Hkv, G, hd = q.shape
    cq = min(chunk, T)
    Tp = _ceil_to(T, cq)
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0), (0, 0)))
    nq = Tp // cq
    # front-pad KV by window (and end-pad to Tp) so every slab is in bounds
    end_pad = Tp - k.shape[1]
    kp = jnp.pad(k, ((0, 0), (window, end_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, end_pad), (0, 0), (0, 0)))
    slab = window + cq

    @jax.checkpoint
    def one_chunk(ci):
        s0 = ci * cq
        qc = jax.lax.dynamic_slice_in_dim(q, s0, cq, axis=1)
        kc = jax.lax.dynamic_slice_in_dim(kp, s0, slab, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vp, s0, slab, axis=1)
        q_pos = s0 + jnp.arange(cq)                       # absolute
        kv_pos = s0 - window + jnp.arange(slab)           # absolute (may be <0 = pad)
        s = jnp.einsum("bthgd,bchd->bthgc", qc, kc,
                       preferred_element_type=jnp.float32)
        mask = (
            (kv_pos[None, :] >= 0)
            & (kv_pos[None, :] <= q_pos[:, None])
            & (kv_pos[None, :] > q_pos[:, None] - window)
            & (q_pos[:, None] < T)
        )[None, :, None, None, :]
        s = jnp.where(mask, s, _NEG)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        p = jnp.where(mask, p, 0.0)
        l = p.sum(axis=-1, keepdims=True)
        o = jnp.einsum("bthgc,bchd->bthgd", p.astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32)
        return o / jnp.maximum(l[..., 0], 1e-20)[..., None]

    outs = jax.lax.map(one_chunk, jnp.arange(nq))          # (nq,B,cq,Hkv,G,hd)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Tp, Hkv, G, hd)
    return out[:, :T]


def attention(
    q: jax.Array,              # (B, T, Hq, hd)
    k: jax.Array,              # (B, S, Hkv, hd)
    v: jax.Array,              # (B, S, Hkv, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: jax.Array | int = 0,
    kv_len: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    chunk: int = 1024,
    impl: str = "chunked",
) -> jax.Array:
    """Grouped-query online-softmax attention.  Returns (B, T, Hq, hd).

    Args:
      q_offset:     absolute position of q[0] (decode: current cache length).
                    May be a (B,) vector when every batch row sits at its own
                    position (slot-batched continuous decode).
      kv_len:       number of valid cache slots (decode against padded cache).
                    Scalar or (B,) per-row vector.
      kv_positions: absolute position of every cache slot (ring buffers);
                    defaults to arange(S).
      window:       sliding-window size (swa/local); None = full.
      impl:         "chunked" (jnp scans) or "flash" (Pallas kernel) — the
                    kernel path covers the self-attention prefill/train case
                    (T == S, no kv_len), full **and** sliding-window: the
                    kernel masks the band in-block and skips off-band KV
                    blocks entirely.  Everything else falls back to the jnp
                    scans.
    """
    B, T, Hq, hd = q.shape
    S = k.shape[1]
    Hkv = k.shape[2]
    G = Hq // Hkv

    # static "whole-sequence from position 0" check: offset-prefill /
    # slot-batched callers pass traced (or vector) offsets and must take the
    # masked chunked path
    from_zero = isinstance(q_offset, int) and q_offset == 0

    bqk = min(128, T)
    if (impl == "flash" and T == S and T > 1 and kv_len is None
            and kv_positions is None and from_zero and T % bqk == 0
            and (window is None or causal)):
        from repro.kernels.flash_attention import flash_attention_pallas
        from repro.kernels.ops import default_interpret

        # KV stays in grouped (B, Hkv, S, hd) layout — the kernel's index
        # map resolves each query head to its KV head, so GQA is never
        # head-repeated in HBM (this path is memory-bound; see kernel doc)
        kh = k.transpose(0, 2, 1, 3)                         # (B,Hkv,S,hd)
        vh = v.transpose(0, 2, 1, 3)
        qh = q.transpose(0, 2, 1, 3)
        o = flash_attention_pallas(qh, kh, vh, causal=causal,
                                   window=0 if window is None
                                   else min(window, S),
                                   block_q=bqk, block_k=bqk,
                                   interpret=default_interpret())
        return o.transpose(0, 2, 1, 3).astype(q.dtype)

    qg = (q * hd**-0.5).reshape(B, T, Hkv, G, hd)
    qo = jnp.asarray(q_offset)
    q_pos = (qo[:, None] + jnp.arange(T)[None, :] if qo.ndim == 1
             else qo + jnp.arange(T))

    if (window is not None and T == S and T > 1 and causal and kv_len is None
            and kv_positions is None and from_zero):
        w = min(window, S)
        out = _banded_attention(qg, k, v, w, chunk)
    else:
        kv_pos = kv_positions if kv_positions is not None else jnp.arange(S)
        out = _kv_chunk_attention(
            qg, k, v, q_pos, causal, window, kv_len, kv_pos, chunk
        )
    return out.reshape(B, T, Hq, hd).astype(q.dtype)


# ------------------------------------------------------------ paged caches

def gather_kv_blocks(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Contiguous logical KV view gathered from a pooled cache.

    ``pool`` is ``(num_blocks, block_size, Hkv, hd)`` shared by every slot;
    ``block_table`` is ``(B, max_blocks)`` int32 mapping each row's logical
    block index to its physical block (``-1`` = unallocated).  Returns
    ``(B, max_blocks * block_size, Hkv, hd)``.  Unallocated entries clip to
    block 0 for the gather and their rows are then **zeroed**: those
    logical positions are ≥ the row's ``pos`` and callers fence them with
    ``kv_len``, but the softmax fence multiplies by probability 0 — which
    is only a fence for *finite* garbage (0·NaN = NaN), so whatever block 0
    happens to hold must never reach the contraction
    (``tests/test_paged_kv.py`` poisons it to pin this).
    """
    nb, bs = pool.shape[:2]
    idx = jnp.clip(block_table, 0, nb - 1)
    g = jnp.take(pool, idx, axis=0)            # (B, max_blocks, bs, Hkv, hd)
    g = jnp.where((block_table >= 0)[:, :, None, None, None], g, 0)
    b, mb = block_table.shape
    return g.reshape(b, mb * bs, *pool.shape[2:])


def paged_kv_update(
    k_pool: jax.Array,             # (num_blocks, block_size, Hkv, hd)
    v_pool: jax.Array,
    k_new: jax.Array,              # (B, T, Hkv, hd) — decode: T == 1
    v_new: jax.Array,
    block_table: jax.Array,        # (B, max_blocks) int32, -1 = unallocated
    pos: jax.Array | int,          # scalar or (B,) absolute pos of row 0
    chunk_len: Optional[jax.Array | int] = None,  # valid rows (default T)
    *,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Write new K/V rows into the pooled cache through the block table.

    Same dispatch ladder as :func:`paged_attention`: ``use_kernel`` routes
    to :func:`repro.kernels.paged_attention.paged_kv_scatter_pallas`
    (pools aliased in-place, nothing pool-shaped touched outside the
    ``pallas_call``); the jnp flat-index scatter below is the bit-exact
    oracle and the fallback.  Rows landing on an unallocated (-1) or
    out-of-range block are dropped — the same fence either way.

    Chunked prefill (``B == 1``, scalar ``pos``, partial ``chunk_len``)
    and slot-batched decode (``T == 1``, vector ``pos``) are the same op.
    """
    b, t = k_new.shape[:2]
    nb, bs = k_pool.shape[:2]
    mb = block_table.shape[1]
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    cl = (jnp.full((b,), t, jnp.int32) if chunk_len is None else
          jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32).reshape(-1),
                           (b,)))
    if use_kernel:
        # chaos-harness injection site — see paged_attention below for the
        # trace-time compile_error / fallback semantics
        from repro.serve.faults import KernelFault, fire as _fire_fault

        kind = _fire_fault("kernel.paged_scatter")
        if kind == "compile_error":
            raise KernelFault(
                "injected paged KV scatter kernel compile failure")
        if kind != "fallback":
            from repro.kernels.ops import default_interpret
            from repro.kernels.paged_attention import paged_kv_scatter_pallas

            interp = default_interpret() if interpret is None else interpret

            def _scatter(kn_, vn_, kp_, vp_, bt_, pos_, cl_):
                return paged_kv_scatter_pallas(kn_, vn_, kp_, vp_, bt_,
                                               pos_, cl_, interpret=interp)

            # tensor parallelism (distributed/tp.py): under an active TP
            # scope the scatter shards over KV heads; the pools come back
            # gathered so the cache pytree stays replicated between steps
            from repro.distributed import tp as tp_mod
            out = tp_mod.head_sharded_scatter(
                _scatter, k_new, v_new, k_pool, v_pool,
                (block_table, posv, cl))
            if out is not None:
                return out
            return paged_kv_scatter_pallas(k_new, v_new, k_pool, v_pool,
                                           block_table, posv, cl,
                                           interpret=interp)
    # jnp oracle: flat-index scatter over the (nb*bs, ...) pool view
    i = jnp.arange(t)
    wpos = posv[:, None] + i[None, :]                       # (B, T) abs pos
    blk = block_table[jnp.arange(b)[:, None],
                      jnp.clip(wpos // bs, 0, mb - 1)]
    flat = jnp.where((i[None, :] < cl[:, None]) & (blk >= 0)
                     & (wpos // bs < mb),
                     blk * bs + wpos % bs, nb * bs)         # OOB → dropped
    fk = k_pool.reshape(nb * bs, *k_pool.shape[2:]).at[flat.reshape(-1)].set(
        k_new.reshape(b * t, *k_new.shape[2:]), mode="drop")
    fv = v_pool.reshape(nb * bs, *v_pool.shape[2:]).at[flat.reshape(-1)].set(
        v_new.reshape(b * t, *v_new.shape[2:]), mode="drop")
    return fk.reshape(k_pool.shape), fv.reshape(v_pool.shape)


def paged_attention(
    q: jax.Array,                  # (B, T, Hq, hd)
    k_pool: jax.Array,             # (num_blocks, block_size, Hkv, hd)
    v_pool: jax.Array,
    block_table: jax.Array,        # (B, max_blocks) int32, -1 = unallocated
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: jax.Array | int = 0,
    kv_len: Optional[jax.Array] = None,
    chunk: int = 1024,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Attention over non-contiguous physical KV blocks.

    Dispatch ladder (the one PR 1 established for the projections):

      1. ``use_kernel`` — the policy flag (``SparsityPolicy
         .use_pallas_kernels``, threaded down by ``models/transformer``)
         routes the call onto :func:`repro.kernels.paged_attention
         .paged_attention_pallas`, which walks the block table in-kernel
         and never materializes the gathered logical view;
      2. ``REPRO_PALLAS_INTERPRET`` — ``1`` (CPU container default) runs
         the kernel interpreted, ``0`` compiles it to Mosaic on a TPU;
      3. the jnp gather-then-attend path below stays the bit-exact oracle
         and the fallback for shapes the kernel does not cover (sliding
         windows over paged pools, non-tile-divisible query counts).

    Chunked sparse prefill at cache offsets (``q_offset`` scalar) and
    vector-pos decode (``q_offset`` (B,)) both lower to the same kernel:
    masking is by absolute positions either way.
    """
    from repro.kernels.paged_attention import (paged_attention_pallas,
                                               paged_kernel_covers)
    B, T = q.shape[:2]
    if (use_kernel and window is None and kv_len is not None
            and paged_kernel_covers(T)):
        # chaos-harness injection site (serve/faults.py): this dispatch
        # runs at trace time, so a "compile_error" KernelFault aborts the
        # trace cleanly (nothing cached, engine degrades to the oracle jit)
        # and "fallback" silently takes the gather-oracle branch below —
        # either fires only while a trace is actually being built
        from repro.serve.faults import KernelFault, fire as _fire_fault

        kind = _fire_fault("kernel.paged_attention")
        if kind == "compile_error":
            raise KernelFault(
                "injected paged-attention kernel compile failure")
        if kind != "fallback":
            from repro.kernels.ops import default_interpret

            interp = default_interpret() if interpret is None else interpret
            qo = jnp.broadcast_to(
                jnp.asarray(q_offset, jnp.int32).reshape(-1), (B,))
            kvl = jnp.broadcast_to(
                jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))

            def _kern(q_, kp_, vp_, bt_, qo_, kvl_):
                return paged_attention_pallas(q_, kp_, vp_, bt_, qo_, kvl_,
                                              causal=causal,
                                              block_q=min(128, T),
                                              interpret=interp)

            # tensor parallelism (distributed/tp.py): under an active TP
            # scope the kernel shards over KV heads (heads are independent
            # and the GQA ratio is preserved) — bit-identical outputs
            from repro.distributed import tp as tp_mod
            out = tp_mod.head_sharded_attention(
                _kern, q, k_pool, v_pool, (block_table, qo, kvl))
            if out is not None:
                return out
            return paged_attention_pallas(q, k_pool, v_pool, block_table,
                                          qo, kvl, causal=causal,
                                          block_q=min(128, T),
                                          interpret=interp)
    k = gather_kv_blocks(k_pool, block_table)
    v = gather_kv_blocks(v_pool, block_table)
    return attention(q, k, v, causal=causal, window=window,
                     q_offset=q_offset, kv_len=kv_len, chunk=chunk)

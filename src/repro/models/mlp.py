"""Gated MLP (SwiGLU / GeGLU) with Amber-prunable projections."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import SparsityPolicy
from repro.layers.linear import init_linear, sparse_linear

__all__ = ["init_mlp", "mlp"]


def _act(x: jax.Array, kind: str) -> jax.Array:
    return jax.nn.gelu(x) if kind == "gelu" else jax.nn.silu(x)


def init_mlp(rng: jax.Array, d_model: int, d_ff: int, dtype=jnp.float32) -> Dict:
    r1, r2, r3 = jax.random.split(rng, 3)
    return {
        "gate_proj": init_linear(r1, d_model, d_ff, dtype=dtype),
        "up_proj": init_linear(r2, d_model, d_ff, dtype=dtype),
        "down_proj": init_linear(r3, d_ff, d_model, dtype=dtype),
    }


def mlp(
    x: jax.Array,
    p: Dict,
    policy: SparsityPolicy,
    phase: str,
    act_fn: str = "silu",
    layer_idx: Optional[int] = None,
    flags: Optional[Dict[str, jax.Array]] = None,
) -> jax.Array:
    """SwiGLU: down( act(gate(x)) * up(x) ).

    The paper's policy: ``up_proj`` is skipped (sensitive), ``down_proj`` is
    always pruned (lowest sensitivity), ``gate_proj`` selectively pruned.
    """
    fl = flags or {}
    g = sparse_linear(x, p["gate_proj"], "gate_proj", policy, phase,
                      layer_idx, fl.get("gate_proj"))
    u = sparse_linear(x, p["up_proj"], "up_proj", policy, phase,
                      layer_idx, fl.get("up_proj"))
    h = _act(g, act_fn) * u
    return sparse_linear(h, p["down_proj"], "down_proj", policy, phase,
                         layer_idx, fl.get("down_proj"))

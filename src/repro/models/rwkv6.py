"""RWKV6 "Finch" block — attention-free token mixing with data-dependent decay.

Time-mix (per head h, head size N):
    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
with the decay w_t = exp(-exp(w0 + tanh(x_w A) B)) data-dependent (the
Finch novelty), and token-shift interpolation feeding every projection.

Channel-mix: k = relu(x_k W_k)²;  y = σ(x_r W_r) ⊙ (k W_v).

Amber mapping (DESIGN.md §5): r/k/v/g projections → 'q_proj' category
(selective), output projection → 'o_proj' (skipped), channel-mix W_k →
'gate_proj', W_v → 'down_proj' (always pruned), W_r → 'up_proj' (skipped).
The tiny decay LoRA stays dense (sensitive).

Prefill/train use a sequential ``lax.scan`` over time (state is O(H·N²) —
the chunked-parallel TPU kernel is future work, noted in DESIGN.md);
decode is the single-step recurrence against a carried state.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import SparsityPolicy
from repro.layers.linear import init_linear, sparse_linear

__all__ = ["init_rwkv6_block", "rwkv6_block", "init_rwkv6_state"]

_LORA = 64


def init_rwkv6_block(rng: jax.Array, d: int, d_ff: int, n_heads: int,
                     dtype=jnp.float32) -> Dict:
    r = jax.random.split(rng, 12)
    hd = d // n_heads
    mix = lambda i: (jax.random.uniform(r[i], (d,)) * 0.1 + 0.45).astype(dtype)
    return {
        "tm": {
            "mix_r": mix(0), "mix_k": mix(1), "mix_v": mix(2),
            "mix_w": mix(3), "mix_g": mix(4),
            "r_proj": init_linear(r[5], d, d, dtype=dtype),
            "k_proj_tm": init_linear(r[6], d, d, dtype=dtype),
            "v_proj_tm": init_linear(r[7], d, d, dtype=dtype),
            "g_proj": init_linear(r[8], d, d, dtype=dtype),
            "o_proj": init_linear(r[9], d, d, dtype=dtype),
            "w0": (jnp.zeros((d,)) - 4.0).astype(jnp.float32),
            "w_A": (jax.random.normal(r[10], (d, _LORA)) * 0.01).astype(dtype),
            "w_B": (jax.random.normal(r[11], (_LORA, d)) * 0.01).astype(dtype),
            "u": jnp.zeros((n_heads, hd), jnp.float32),
            "ln_x": {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
        },
        "cm": {
            "mix_k": mix(0), "mix_r": mix(1),
            "gate_proj": init_linear(r[6], d, d_ff, dtype=dtype),   # W_k
            "down_proj": init_linear(r[7], d_ff, d, dtype=dtype),   # W_v
            "up_proj": init_linear(r[8], d, d, dtype=dtype),        # W_r
        },
    }


def init_rwkv6_state(batch: int, d: int, n_heads: int, dtype=jnp.float32) -> Dict:
    hd = d // n_heads
    return {
        "tm_shift": jnp.zeros((batch, d), dtype),
        "cm_shift": jnp.zeros((batch, d), dtype),
        "S": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
    }


def _group_norm(x: jax.Array, p: Dict, n_heads: int, eps=1e-5) -> jax.Array:
    b, t, d = x.shape
    xh = x.reshape(b, t, n_heads, d // n_heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b, t, d) * p["w"] + p["b"]).astype(x.dtype)


def _time_mix_step(
    carry: Tuple[jax.Array, jax.Array],
    rkvwg: Tuple[jax.Array, ...],
    u: jax.Array,
    n_heads: int,
):
    """One recurrence step.  carry = S (B,H,N,N); inputs are (B,d)."""
    S = carry
    r, k, v, w = rkvwg
    b, d = r.shape
    hd = d // n_heads
    rh = r.reshape(b, n_heads, hd).astype(jnp.float32)
    kh = k.reshape(b, n_heads, hd).astype(jnp.float32)
    vh = v.reshape(b, n_heads, hd).astype(jnp.float32)
    wh = w.reshape(b, n_heads, hd)
    kv = kh[..., :, None] * vh[..., None, :]                 # (B,H,N,N)
    y = jnp.einsum("bhk,bhkn->bhn", rh, S + u[None, :, :, None] * kv)
    S_new = wh[..., :, None] * S + kv
    return S_new, y.reshape(b, d)


def rwkv6_block(
    x: jax.Array,                       # (B, T, d)
    p: Dict,
    policy: SparsityPolicy,
    phase: str,
    n_heads: int,
    state: Optional[Dict] = None,
    flags: Optional[Dict[str, jax.Array]] = None,
):
    """Returns (y, new_state).  state=None → fresh zeros (prefill/train)."""
    b, t, d = x.shape
    if state is None:
        state = init_rwkv6_state(b, d, n_heads, x.dtype)
    fl = flags or {}
    tm, cm = p["tm"], p["cm"]

    # ---- time mix ----
    prev = jnp.concatenate([state["tm_shift"][:, None], x[:, :-1]], axis=1)
    dx = prev - x
    xr = x + dx * tm["mix_r"]
    xk = x + dx * tm["mix_k"]
    xv = x + dx * tm["mix_v"]
    xw = x + dx * tm["mix_w"]
    xg = x + dx * tm["mix_g"]

    qflag = fl.get("q_proj")
    r = sparse_linear(xr, tm["r_proj"], "q_proj", policy, phase, None, qflag)
    k = sparse_linear(xk, tm["k_proj_tm"], "q_proj", policy, phase, None, qflag)
    v = sparse_linear(xv, tm["v_proj_tm"], "q_proj", policy, phase, None, qflag)
    g = jax.nn.silu(
        sparse_linear(xg, tm["g_proj"], "q_proj", policy, phase, None, qflag)
    )
    w = jnp.exp(-jnp.exp(
        tm["w0"]
        + jnp.tanh(xw.astype(jnp.float32) @ tm["w_A"].astype(jnp.float32))
        @ tm["w_B"].astype(jnp.float32)
    ))                                                        # (B,T,d) f32

    u = tm["u"]
    if t == 1:
        S_new, y = _time_mix_step(
            state["S"], (r[:, 0], k[:, 0], v[:, 0], w[:, 0]), u, n_heads
        )
        y = y[:, None]
    else:
        def body(S, xs):
            return _time_mix_step(S, xs, u, n_heads)
        xs = (r.transpose(1, 0, 2), k.transpose(1, 0, 2),
              v.transpose(1, 0, 2), w.transpose(1, 0, 2))
        S_new, ys = jax.lax.scan(body, state["S"], xs)
        y = ys.transpose(1, 0, 2)

    y = _group_norm(y.astype(x.dtype), tm["ln_x"], n_heads) * g
    y = sparse_linear(y, tm["o_proj"], "o_proj", policy, phase, None,
                      fl.get("o_proj"))
    h = x + y

    # ---- channel mix ----
    prev_c = jnp.concatenate([state["cm_shift"][:, None], h[:, :-1]], axis=1)
    dxc = prev_c - h
    xkc = h + dxc * cm["mix_k"]
    xrc = h + dxc * cm["mix_r"]
    kk = sparse_linear(xkc, cm["gate_proj"], "gate_proj", policy, phase, None,
                       fl.get("gate_proj"))
    kk = jnp.square(jax.nn.relu(kk))
    kv = sparse_linear(kk, cm["down_proj"], "down_proj", policy, phase, None,
                       fl.get("down_proj"))
    rr = jax.nn.sigmoid(
        sparse_linear(xrc, cm["up_proj"], "up_proj", policy, phase, None,
                      fl.get("up_proj"))
    )
    out = h + rr * kv

    new_state = {"tm_shift": x[:, -1], "cm_shift": h[:, -1], "S": S_new}
    return out, new_state

"""Unified decoder-only LM covering dense / GQA / MoE / SSM / hybrid archs.

A model is a sequence of blocks whose kinds follow ``cfg.block_pattern``
(period-tiled), e.g. ``("attn",)`` for LLaMA-likes, ``("rwkv6",)`` for
RWKV6, ``("rglru", "rglru", "attn")`` for RecurrentGemma.  Homogeneous
periods are **scan-stacked** (params carry a leading ``n_periods`` axis and
the forward runs ``lax.scan`` over them) so 88-layer models compile in
bounded time; leftover layers (n_layers % period) live in an unrolled tail.

Three execution phases share one block implementation:
  * ``train`` / ``prefill`` without cache — full-sequence causal pass;
  * ``prefill`` with cache — same pass + cache population (serving);
  * ``decode`` — single-token step against the cache.

Sliding-window attention layers keep a **ring-buffer** cache of exactly
``window`` slots, which is what makes the ``long_500k`` decode cells cheap
for SWA archs.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.policy import SparsityPolicy
from repro.layers.linear import init_linear, sparse_linear
from repro.models import common
from repro.models.attention import (attention, paged_attention,
                                    paged_kv_update)
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe
from repro.models.rglru import init_rglru_block, init_rglru_state, rglru_block
from repro.models.rwkv6 import init_rwkv6_block, init_rwkv6_state, rwkv6_block

__all__ = [
    "init_params",
    "forward",
    "init_cache",
    "prefill",
    "prefill_chunk",
    "decode_step",
    "layer_kinds",
    "paged_kv_spec",
]


# --------------------------------------------------------------------- utils

def layer_kinds(cfg: ModelConfig):
    return [cfg.block_pattern[i % len(cfg.block_pattern)] for i in range(cfg.n_layers)]


def _n_periods(cfg: ModelConfig) -> Tuple[int, int]:
    p = len(cfg.block_pattern)
    return cfg.n_layers // p, cfg.n_layers % p


def _apply_rope(cfg: ModelConfig, x: jax.Array, positions, positions_3d):
    if cfg.rope_variant == "default":
        return common.apply_rope(x, positions, cfg.rope_theta)
    if cfg.rope_variant == "2d":
        return common.apply_rope_2d(x, positions, cfg.rope_theta)
    if cfg.rope_variant == "mrope":
        return common.apply_mrope(x, positions_3d, cfg.rope_theta)
    return x  # none | sinusoidal (added at embedding)


# --------------------------------------------------------------- block init

def _init_attn_block(cfg: ModelConfig, rng: jax.Array, dtype) -> Dict:
    r = jax.random.split(rng, 6)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "ln1": common.init_norm(d, cfg.norm, dtype),
        "q_proj": init_linear(r[0], d, qd, bias=cfg.qkv_bias, dtype=dtype),
        "k_proj": init_linear(r[1], d, kvd, bias=cfg.qkv_bias, dtype=dtype),
        "v_proj": init_linear(r[2], d, kvd, bias=cfg.qkv_bias, dtype=dtype),
        "o_proj": init_linear(r[3], qd, d, dtype=dtype),
        "ln2": common.init_norm(d, cfg.norm, dtype),
    }
    if cfg.n_experts:
        p["moe"] = init_moe(r[4], d, cfg.moe_d_ff, cfg.n_experts,
                            cfg.shared_expert, dtype)
    else:
        p["mlp"] = init_mlp(r[4], d, cfg.d_ff, dtype)
    return p


def _init_block(cfg: ModelConfig, kind: str, rng: jax.Array, dtype) -> Dict:
    if kind == "attn":
        return _init_attn_block(cfg, rng, dtype)
    if kind == "rwkv6":
        return {"rwkv": init_rwkv6_block(rng, cfg.d_model, cfg.d_ff,
                                         cfg.n_heads, dtype)}
    if kind == "rglru":
        r1, r2 = jax.random.split(rng)
        return {
            "ln1": common.init_norm(cfg.d_model, cfg.norm, dtype),
            "rglru": init_rglru_block(r1, cfg.d_model,
                                      cfg.rnn_width or cfg.d_model,
                                      cfg.conv_width, dtype),
            "ln2": common.init_norm(cfg.d_model, cfg.norm, dtype),
            "mlp": init_mlp(r2, cfg.d_model, cfg.d_ff, dtype),
        }
    raise ValueError(f"unknown block kind {kind}")


def init_params(cfg: ModelConfig, rng: jax.Array) -> Dict:
    dtype = common.dtype_of(cfg)
    n_per, tail = _n_periods(cfg)
    r_embed, r_blocks, r_tail, r_head = jax.random.split(rng, 4)
    params: Dict[str, Any] = {
        "embed": common.init_embedding(r_embed, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": common.init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(r_head, cfg.d_model, cfg.vocab_size,
                                        dtype=dtype)

    def period_init(rng_i):
        keys = jax.random.split(rng_i, len(cfg.block_pattern))
        return {f"b{j}": _init_block(cfg, kind, keys[j], dtype)
                for j, kind in enumerate(cfg.block_pattern)}

    if n_per:
        params["periods"] = jax.vmap(period_init)(jax.random.split(r_blocks, n_per))
    if tail:
        keys = jax.random.split(r_tail, tail)
        params["tail"] = {
            f"t{j}": _init_block(cfg, cfg.block_pattern[j], keys[j], dtype)
            for j in range(tail)
        }
    return params


# ------------------------------------------------------------------- caches

def _attn_cache_len(cfg: ModelConfig, max_seq: int) -> int:
    if cfg.attn_type in ("swa", "local"):
        return min(cfg.window, max_seq)
    return max_seq


def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                      dtype) -> Dict:
    if kind == "attn":
        s = _attn_cache_len(cfg, max_seq)
        return {
            "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
    if kind == "rwkv6":
        return init_rwkv6_state(batch, cfg.d_model, cfg.n_heads, dtype)
    if kind == "rglru":
        return init_rglru_state(batch, cfg.rnn_width or cfg.d_model,
                                cfg.conv_width, dtype)
    raise ValueError(kind)


def paged_kv_spec(cfg: ModelConfig) -> Dict:
    """Bool pytree over ``init_cache``'s layer subtrees: True marks the
    attention K/V leaves that move into the global block pool under paged
    serving (``serve/paged.py``).

    Sliding-window rings are excluded — they are already bounded by
    ``window`` and their in-ring wraparound does not compose with block
    tables; recurrent states (rwkv6 / rglru) are position-independent
    per-slot state.  Callers check ``any(leaves)`` to decide whether
    paging buys anything for the arch.
    """
    n_per, tail = _n_periods(cfg)

    def block_spec(kind):
        tmpl = _init_block_cache(cfg, kind, 1, 1, jnp.float32)
        paged = kind == "attn" and cfg.attn_type not in ("swa", "local")
        return jax.tree_util.tree_map(lambda _: paged, tmpl)

    spec: Dict[str, Any] = {}
    if n_per:
        spec["periods"] = {f"b{j}": block_spec(kind)
                           for j, kind in enumerate(cfg.block_pattern)}
    if tail:
        spec["tail"] = {f"t{j}": block_spec(cfg.block_pattern[j])
                        for j in range(tail)}
    return spec


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> Dict:
    dtype = dtype or common.dtype_of(cfg)
    n_per, tail = _n_periods(cfg)
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}

    def one_period(_):
        return {f"b{j}": _init_block_cache(cfg, kind, batch, max_seq, dtype)
                for j, kind in enumerate(cfg.block_pattern)}

    if n_per:
        cache["periods"] = jax.vmap(one_period)(jnp.arange(n_per))
    if tail:
        cache["tail"] = {
            f"t{j}": _init_block_cache(cfg, cfg.block_pattern[j], batch,
                                       max_seq, dtype)
            for j in range(tail)
        }
    return cache


# -------------------------------------------------------------- block apply

def _attn_block_apply(
    cfg: ModelConfig,
    h: jax.Array,
    p: Dict,
    policy: SparsityPolicy,
    phase: str,
    cache: Optional[Dict],
    pos,
    positions,
    positions_3d,
    flags,
    chunk_len=None,
    block_table=None,
) -> Tuple[jax.Array, Optional[Dict]]:
    b, t, d = h.shape
    fl = flags or {}
    x = common.norm_apply(h, p["ln1"], cfg.norm)
    q = sparse_linear(x, p["q_proj"], "q_proj", policy, phase, None,
                      fl.get("q_proj"))
    k = sparse_linear(x, p["k_proj"], "k_proj", policy, phase, None,
                      fl.get("k_proj"))
    v = sparse_linear(x, p["v_proj"], "v_proj", policy, phase, None,
                      fl.get("v_proj"))
    q = q.reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    q = _apply_rope(cfg, q, positions, positions_3d)
    k = _apply_rope(cfg, k, positions, positions_3d)
    # pin attention sharding: heads on "model" when divisible, otherwise
    # replicated head compute — NEVER a head_dim-split contraction, which
    # would all-reduce the O(T·S) score tensor (measured on qwen2.5's 40
    # heads @ 16-way TP; EXPERIMENTS.md §Perf iteration 2)
    from repro.distributed.sharding import maybe_shard
    q = maybe_shard(q, "dp", None, "model", None)
    k = maybe_shard(k, "dp", None, "model", None)
    v = maybe_shard(v, "dp", None, "model", None)

    window = cfg.window if cfg.attn_type in ("swa", "local") else None
    new_cache = None

    if cache is None:
        o = attention(q, k, v, causal=True, window=window, q_offset=0,
                      chunk=cfg.attn_chunk, impl=cfg.attn_impl)
    elif block_table is not None:
        # paged cache: K/V live in a pooled (num_blocks, block_size, Hkv,
        # hd) array shared by every slot; logical row p of a slot maps to
        # physical row (table[p // bs], p % bs).  Writes scatter through
        # the table (unallocated / pad rows drop) and reads fence stale or
        # unallocated positions with kv_len; both dispatch through the
        # kernel ladder in models/attention — with kernels on, neither
        # direction touches a pool-shaped array outside a pallas_call.
        assert window is None, "paged KV does not cover sliding-window rings"
        bs_ = cache["k"].shape[1]
        mb = block_table.shape[1]
        # same policy flag that routes projections onto the fused kernels
        # sends the KV scatter AND the attention through the in-kernel
        # block-table walk (pool aliased in-place, no gathered logical
        # view); the jnp flat-index scatter / gather stay the oracles
        use_kernel = bool(policy.use_pallas_kernels)
        if t == 1:  # vector-pos decode: every row writes at its own depth
            posv = pos if jnp.ndim(pos) == 1 else jnp.broadcast_to(pos, (b,))
            ck, cv = paged_kv_update(cache["k"], cache["v"], k, v,
                                     block_table, posv,
                                     use_kernel=use_kernel)
            o = paged_attention(q, ck, cv, block_table, causal=False,
                                q_offset=posv,
                                kv_len=jnp.minimum(posv + 1, mb * bs_),
                                chunk=cfg.attn_chunk,
                                use_kernel=use_kernel)
        else:  # chunked prefill at offset ``pos`` (batch-1 slot path)
            # ``pos`` may be nonzero on a request's FIRST chunk: with
            # block-level prefix caching (serve/paged.py) admission maps
            # already-computed blocks into the table and starts the slot at
            # the first non-cached token.  Writes only ever target
            # wpos >= pos, and a cached prefix is always a whole number of
            # blocks, so shared (refcount > 1) blocks — table indices
            # < pos // bs — are read-only here by construction.
            assert b == 1, "paged chunked prefill is per-slot (batch 1)"
            cl = (chunk_len if chunk_len is not None
                  else jnp.asarray(t, jnp.int32))
            ck, cv = paged_kv_update(cache["k"], cache["v"], k, v,
                                     block_table, pos, cl,
                                     use_kernel=use_kernel)
            o = paged_attention(q, ck, cv, block_table, causal=True,
                                q_offset=pos, kv_len=pos + cl,
                                chunk=cfg.attn_chunk,
                                use_kernel=use_kernel)
        new_cache = {"k": ck, "v": cv}
    else:
        s_c = cache["k"].shape[1]
        if t == 1:  # decode step: write slot, then attend over valid slots
            if jnp.ndim(pos) == 1:  # slot-batched: every row at its own pos
                slot = pos % s_c if window is not None else pos
                bidx = jnp.arange(b)
                ck = cache["k"].at[bidx, slot].set(k[:, 0], mode="drop")
                cv = cache["v"].at[bidx, slot].set(v[:, 0], mode="drop")
            else:
                slot = pos % s_c if window is not None else pos
                ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot,
                                                         axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot,
                                                         axis=1)
            kv_len = jnp.minimum(pos + 1, s_c)
            o = attention(q, ck, cv, causal=False, window=None,
                          q_offset=pos, kv_len=kv_len, chunk=cfg.attn_chunk)
            new_cache = {"k": ck, "v": cv}
        elif chunk_len is not None:
            # chunked prefill at offset ``pos``: write the chunk's valid rows
            # into the cache first, then attend the chunk queries over the
            # whole cached history (earlier chunks + this one).  Rows past
            # ``chunk_len`` (padding) scatter out of bounds and are dropped;
            # stale rows from a previous slot occupant are excluded by the
            # kv_len / kv_positions masks.
            i = jnp.arange(t)
            valid_i = i < chunk_len
            wpos = pos + i                      # absolute token positions
            if window is not None:
                # Ring buffer: writing the chunk first would evict older
                # rows still inside the window of this chunk's early queries
                # (ring size == window), so attend over [old ring ∥ fresh
                # chunk] with explicit absolute positions, then scatter the
                # chunk into the ring for later chunks / decode.  Row r of
                # the old ring holds the latest position ≤ pos-1 congruent
                # to r mod s_c (-1 = never written).
                r_ = jnp.arange(s_c)
                p_old = pos - 1 - ((pos - 1 - r_) % s_c)
                kv_pos = jnp.concatenate(
                    [jnp.where(p_old >= 0, p_old, -1),
                     jnp.where(valid_i, wpos, -1)])
                k_att = jnp.concatenate([cache["k"], k], axis=1)
                v_att = jnp.concatenate([cache["v"], v], axis=1)
                o = attention(q, k_att, v_att, causal=True, window=window,
                              q_offset=pos, kv_positions=kv_pos,
                              chunk=cfg.attn_chunk)
                idx = jnp.where(valid_i, wpos % s_c, s_c)
                ck = cache["k"].at[:, idx].set(k, mode="drop")
                cv = cache["v"].at[:, idx].set(v, mode="drop")
            else:
                # full cache: write the valid rows at their absolute offsets
                # (pad rows scatter out of bounds → dropped), then attend the
                # chunk queries over the whole cached prefix + chunk
                idx = jnp.where(valid_i, wpos, s_c)
                ck = cache["k"].at[:, idx].set(k, mode="drop")
                cv = cache["v"].at[:, idx].set(v, mode="drop")
                o = attention(q, ck, cv, causal=True, window=None,
                              q_offset=pos, kv_len=pos + chunk_len,
                              chunk=cfg.attn_chunk)
            new_cache = {"k": ck, "v": cv}
        else:  # prefill: full attention, then populate the cache
            o = attention(q, k, v, causal=True, window=window, q_offset=0,
                          chunk=cfg.attn_chunk, impl=cfg.attn_impl)
            if s_c >= t:
                ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
            else:  # ring buffer smaller than the prompt: keep last s_c
                tail_k = k[:, t - s_c:]
                tail_v = v[:, t - s_c:]
                idx = (jnp.arange(t - s_c, t) % s_c)
                ck = cache["k"].at[:, idx].set(tail_k)
                cv = cache["v"].at[:, idx].set(tail_v)
            new_cache = {"k": ck, "v": cv}

    o = o.reshape(b, t, cfg.q_dim)
    o = sparse_linear(o, p["o_proj"], "o_proj", policy, phase, None,
                      fl.get("o_proj"))
    h = h + o
    x2 = common.norm_apply(h, p["ln2"], cfg.norm)
    if cfg.n_experts:
        ff = moe(x2, p["moe"], policy, phase, cfg.top_k, cfg.act_fn,
                 cfg.moe_impl, fl)
    else:
        ff = mlp(x2, p["mlp"], policy, phase, cfg.act_fn, None, fl)
    return h + ff, new_cache


def _block_apply(cfg, kind, h, p, policy, phase, cache, pos, positions,
                 positions_3d, flags, chunk_len=None, block_table=None):
    if kind == "attn":
        return _attn_block_apply(cfg, h, p, policy, phase, cache, pos,
                                 positions, positions_3d, flags, chunk_len,
                                 block_table)
    if kind == "rwkv6":
        y, st = rwkv6_block(h, p["rwkv"], policy, phase, cfg.n_heads,
                            cache, flags)
        return y, st
    if kind == "rglru":
        x = common.norm_apply(h, p["ln1"], cfg.norm)
        y, st = rglru_block(x, p["rglru"], policy, phase, cache, flags)
        h = h + y
        x2 = common.norm_apply(h, p["ln2"], cfg.norm)
        h = h + mlp(x2, p["mlp"], policy, phase, cfg.act_fn, None, flags)
        return h, st
    raise ValueError(kind)


# ------------------------------------------------------------ layer skipping

def _build_flags(cfg: ModelConfig, policy: SparsityPolicy):
    """Per-period boolean prune-flags for modules with layer-dependent skips.

    Returns (period_flags, tail_flags):
      period_flags: {"b{j}": {module: (n_periods,) bool}} scanned as xs;
      tail_flags:   {"t{j}": {module: bool scalar}}.
    None / missing module ⇒ no layer dependence (prune whenever the module
    is prunable).
    """
    if not policy.enabled or not policy.skip_layers:
        return None, None
    has_any = any(len(idxs) for _, idxs in policy.skip_layers)  # type: ignore
    if not has_any:
        return None, None
    n_per, tail = _n_periods(cfg)
    plen = len(cfg.block_pattern)
    modules = [name for name, idxs in policy.skip_layers if len(idxs)]  # type: ignore

    period_flags = {}
    for j in range(plen):
        fl = {}
        for mname in modules:
            vec = np.array(
                [policy.should_prune(mname, i * plen + j) for i in range(n_per)],
                dtype=bool,
            )
            fl[mname] = jnp.asarray(vec)
        period_flags[f"b{j}"] = fl
    tail_flags = {}
    for j in range(tail):
        li = n_per * plen + j
        tail_flags[f"t{j}"] = {
            m: jnp.asarray(bool(policy.should_prune(m, li))) for m in modules
        }
    return (period_flags if n_per else None), (tail_flags if tail else None)


# ------------------------------------------------------------------ forward

def _embed_inputs(cfg: ModelConfig, params, batch) -> jax.Array:
    from repro.distributed.sharding import maybe_shard

    tokens = batch["tokens"]
    h = common.embed(tokens, params["embed"])
    h = maybe_shard(h, "dp", None, None)
    if cfg.vision_stub and "pixel_embeds" in batch:
        pe = batch["pixel_embeds"].astype(h.dtype)
        h = jax.lax.dynamic_update_slice(h, pe, (0, 0, 0))
    if cfg.rope_variant == "sinusoidal":
        pos = batch.get("positions", jnp.arange(tokens.shape[1])[None, :])
        h = h + common.sinusoidal_positions(pos, cfg.d_model).astype(h.dtype)
    return h


def _run_blocks(cfg, params, h, policy, phase, cache, positions, positions_3d,
                chunk_len=None):
    n_per, tail = _n_periods(cfg)
    pos = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    btab = cache.get("block_table") if cache is not None else None
    period_flags, tail_flags = _build_flags(cfg, policy)
    new_cache: Dict[str, Any] = {} if cache is not None else None

    if n_per:
        def run_period(h_c, pp, cc, fl):
            cc_new = {}
            hh = h_c
            for j, kind in enumerate(cfg.block_pattern):
                blk_cache = cc[f"b{j}"] if cc is not None else None
                blk_flags = fl[f"b{j}"] if fl is not None else None
                hh, c_out = _block_apply(cfg, kind, hh, pp[f"b{j}"], policy,
                                         phase, blk_cache, pos, positions,
                                         positions_3d, blk_flags, chunk_len,
                                         btab)
                if cc is not None:
                    cc_new[f"b{j}"] = c_out
            return hh, cc_new

        if cache is None and not cfg.scan_layers:
            # unrolled layers: FSDP param gathers sit at their natural use
            # sites (a lax.scan would let LICM hoist one whole-stack gather
            # of the loop-invariant xs out of the loop — n_layers× the
            # per-layer working set)
            from repro.distributed.sharding import maybe_shard

            body_fn = run_period
            if cfg.remat and phase == "train":
                body_fn = jax.checkpoint(
                    lambda h_c, pp, fl: run_period(h_c, pp, None, fl)[0],
                    static_argnums=())
            for i in range(n_per):
                pp = jax.tree_util.tree_map(lambda x: x[i],
                                            params["periods"])
                fl = (jax.tree_util.tree_map(lambda x: x[i], period_flags)
                      if period_flags is not None else None)
                if cfg.remat and phase == "train":
                    h = body_fn(h, pp, fl)
                else:
                    h, _ = run_period(h, pp, None, fl)
                h = maybe_shard(h, "dp", None, None)
        elif cache is None:
            # stateless pass: params (and optional flags) ride as scan xs
            from repro.distributed.sharding import maybe_shard

            def body(h_c, xs):
                pp, fl = xs if period_flags is not None else (xs, None)
                # barrier pins the FSDP param all-gather INSIDE the loop:
                # without it LICM hoists a whole-stack (n_layers×) gather of
                # the loop-invariant xs out of the scan
                pp = common.opt_barrier(pp)
                hh, _ = run_period(h_c, pp, None, fl)
                # keep the residual carry batch-sharded (GSPMD propagation
                # through the recurrent scan sometimes drops it)
                hh = maybe_shard(hh, "dp", None, None)
                return hh, None

            if cfg.remat and phase == "train":
                body = jax.checkpoint(body)
            xs = (params["periods"], period_flags) \
                if period_flags is not None else params["periods"]
            h, _ = jax.lax.scan(body, h, xs)
        elif not cfg.scan_layers:
            # unrolled cached path (analysis mode: exact per-layer cost
            # accounting — while bodies are counted once by HLO cost
            # analysis, so roofline extraction unrolls)
            cstack = cache["periods"]
            new_stack = cstack
            for i in range(n_per):
                pp = jax.tree_util.tree_map(lambda x: x[i], params["periods"])
                fl = (jax.tree_util.tree_map(lambda x: x[i], period_flags)
                      if period_flags is not None else None)
                cc = jax.tree_util.tree_map(lambda x: x[i], cstack)
                h, cc_new = run_period(h, pp, cc, fl)
                new_stack = jax.tree_util.tree_map(
                    lambda c, u: c.at[i].set(u.astype(c.dtype)),
                    new_stack, cc_new)
            new_cache["periods"] = new_stack
        else:
            # cache rides in the CARRY (not xs): scan xs are loop-invariant,
            # and XLA's float-normalization + LICM on CPU would hoist a full
            # f32 copy of an xs cache out of the loop — as loop-varying
            # state it is sliced/updated in place per period
            cstack = cache["periods"]

            def body(carry, xs):
                h_c, cs = carry
                if period_flags is not None:
                    pp, fl, idx = xs
                else:
                    (pp, idx), fl = xs, None
                cc = jax.tree_util.tree_map(
                    lambda c: jax.lax.dynamic_index_in_dim(
                        c, idx, 0, keepdims=False), cs)
                hh, cc_new = run_period(h_c, pp, cc, fl)
                cs = jax.tree_util.tree_map(
                    lambda c, u: jax.lax.dynamic_update_index_in_dim(
                        c, u.astype(c.dtype), idx, 0), cs, cc_new)
                return (hh, cs), None

            idxs = jnp.arange(n_per)
            xs = (params["periods"], period_flags, idxs) \
                if period_flags is not None else (params["periods"], idxs)
            (h, cstack), _ = jax.lax.scan(body, (h, cstack), xs)
            new_cache["periods"] = cstack

    if tail:
        base = n_per * len(cfg.block_pattern)
        for j in range(tail):
            kind = cfg.block_pattern[j]
            blk_cache = cache["tail"][f"t{j}"] if cache is not None else None
            blk_flags = tail_flags[f"t{j}"] if tail_flags is not None else None
            h, c_out = _block_apply(cfg, kind, h, params["tail"][f"t{j}"],
                                    policy, phase, blk_cache, pos, positions,
                                    positions_3d, blk_flags, chunk_len, btab)
            if cache is not None:
                new_cache.setdefault("tail", {})[f"t{j}"] = c_out

    return h, new_cache


def _lm_logits(cfg, params, h):
    from repro.distributed.sharding import maybe_shard

    h = common.norm_apply(h, params["final_norm"], cfg.norm)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["w"].T
    else:
        logits = h @ params["lm_head"]["w"]
    # keep the vocab dim model-sharded: (B, T, V) or (B, V)
    if logits.ndim == 3:
        return maybe_shard(logits, "dp", None, "model")
    return maybe_shard(logits, "dp", "model")


def forward(
    cfg: ModelConfig,
    params: Dict,
    batch: Dict,
    *,
    policy: SparsityPolicy,
    phase: str = "train",
) -> jax.Array:
    """Full-sequence pass (train / prefill-without-cache).  → (B, T, V)."""
    tokens = batch["tokens"]
    b, t = tokens.shape
    positions = batch.get("positions", jnp.broadcast_to(jnp.arange(t), (b, t)))
    positions_3d = batch.get(
        "positions_3d",
        jnp.broadcast_to(jnp.arange(t), (3, b, t)) if cfg.rope_variant == "mrope"
        else None,
    )
    h = _embed_inputs(cfg, params, batch)
    h, _ = _run_blocks(cfg, params, h, policy, phase, None, positions,
                       positions_3d)
    return _lm_logits(cfg, params, h)


def prefill(
    cfg: ModelConfig,
    params: Dict,
    batch: Dict,
    cache: Dict,
    *,
    policy: SparsityPolicy,
) -> Tuple[jax.Array, Dict]:
    """Prompt ingestion: returns (last-token logits (B, V), filled cache)."""
    tokens = batch["tokens"]
    b, t = tokens.shape
    positions = batch.get("positions", jnp.broadcast_to(jnp.arange(t), (b, t)))
    positions_3d = batch.get(
        "positions_3d",
        jnp.broadcast_to(jnp.arange(t), (3, b, t)) if cfg.rope_variant == "mrope"
        else None,
    )
    h = _embed_inputs(cfg, params, batch)
    h, new_cache = _run_blocks(cfg, params, h, policy, "prefill", cache,
                               positions, positions_3d)
    new_cache["pos"] = cache["pos"] + t
    logits = _lm_logits(cfg, params, h[:, -1:])[:, 0]
    return logits, new_cache


def prefill_chunk(
    cfg: ModelConfig,
    params: Dict,
    batch: Dict,
    cache: Dict,
    *,
    policy: SparsityPolicy,
) -> Tuple[jax.Array, Dict]:
    """One fixed-shape prefill chunk written at the cache offset ``pos``.

    ``batch["tokens"]`` is ``(B, C)``; ``batch["chunk_len"]`` (traced scalar,
    default C) marks how many leading tokens are valid — the padded tail is
    masked out of both the KV writes and the attention.  The chunk attends
    causally over everything the cache already holds — earlier chunks of
    the same request, or (paged caches) prefix blocks another request
    computed that admission mapped into this slot's table with ``pos``
    advanced past them — so feeding a prompt through in C-token chunks,
    from any starting offset with valid cached KV below it, reproduces the
    one-shot prefill.  Recurrent blocks (rwkv6 / rglru) carry their state
    through the cache but cannot mask padded tokens out of their scans — for
    those archs the caller must send fully-valid chunks (chunk_len == C; the
    serving engine decomposes prompts dyadically to guarantee it).

    Returns (logits of the last *valid* token (B, V), cache with
    ``pos += chunk_len``).
    """
    tokens = batch["tokens"]
    b, t = tokens.shape
    pos = cache["pos"]
    chunk_len = batch.get("chunk_len")
    if chunk_len is None:
        chunk_len = jnp.asarray(t, jnp.int32)
    positions = pos + jnp.broadcast_to(jnp.arange(t), (b, t))
    positions_3d = (
        pos + jnp.broadcast_to(jnp.arange(t), (3, b, t))
        if cfg.rope_variant == "mrope" else None
    )
    if cfg.rope_variant == "sinusoidal":
        batch = dict(batch, positions=positions)
    h = _embed_inputs(cfg, params, batch)
    h, new_cache = _run_blocks(cfg, params, h, policy, "prefill", cache,
                               positions, positions_3d, chunk_len=chunk_len)
    new_cache["pos"] = pos + chunk_len
    if "block_table" in cache:
        new_cache["block_table"] = cache["block_table"]
    h_last = jax.lax.dynamic_slice_in_dim(h, chunk_len - 1, 1, axis=1)
    logits = _lm_logits(cfg, params, h_last)[:, 0]
    return logits, new_cache


def decode_step(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,        # (B, 1)
    cache: Dict,
    *,
    policy: SparsityPolicy,
) -> Tuple[jax.Array, Dict]:
    """One decode step.  → ((B, V) logits, updated cache).

    ``cache["pos"]`` may be a scalar (whole batch in lockstep, legacy
    one-shot engine) or a (B,) vector of per-slot positions (continuous
    batching: every slot decodes at its own depth).
    """
    b, t = tokens.shape
    pos = cache["pos"]
    if jnp.ndim(pos) == 1:
        positions = jnp.broadcast_to(pos[:, None], (b, t))
        positions_3d = (
            jnp.broadcast_to(pos[None, :, None], (3, b, t))
            if cfg.rope_variant == "mrope" else None
        )
    else:
        positions = jnp.broadcast_to(pos, (b, t))
        positions_3d = (
            jnp.broadcast_to(pos, (3, b, t)) if cfg.rope_variant == "mrope"
            else None
        )
    batch = {"tokens": tokens, "positions": positions}
    h = _embed_inputs(cfg, params, batch)
    h, new_cache = _run_blocks(cfg, params, h, policy, "decode", cache,
                               positions, positions_3d)
    new_cache["pos"] = pos + 1
    if "block_table" in cache:
        new_cache["block_table"] = cache["block_table"]
    logits = _lm_logits(cfg, params, h)[:, 0]
    return logits, new_cache

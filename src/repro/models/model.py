"""Model dispatcher: one uniform API over the whole zoo.

    model = build_model(cfg)
    params = model.init(rng)
    logits = model.forward(params, batch, policy=..., phase="train")
    cache  = model.init_cache(batch_size, max_seq)
    logits, cache = model.prefill(params, batch, cache, policy=...)
    logits, cache = model.decode_step(params, tokens, cache, policy=...)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax

from repro.configs.base import ModelConfig
from repro.core.policy import DENSE, SparsityPolicy
from repro.models import encdec, transformer

__all__ = ["Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    _mod: Any

    def init(self, rng: jax.Array) -> Dict:
        return self._mod.init_params(self.cfg, rng)

    def forward(self, params, batch, *, policy: SparsityPolicy = DENSE,
                phase: str = "train"):
        return self._mod.forward(self.cfg, params, batch, policy=policy,
                                 phase=phase)

    def init_cache(self, batch_size: int, max_seq: int, dtype=None):
        return self._mod.init_cache(self.cfg, batch_size, max_seq, dtype)

    def paged_kv_spec(self):
        """Bool pytree marking the cache leaves that can live in a global
        block pool (paged serving), or None when the arch has no paged
        layout (encoder-decoder caches are request-shaped, not
        sequence-growing)."""
        fn = getattr(self._mod, "paged_kv_spec", None)
        return fn(self.cfg) if fn is not None else None

    def prefill(self, params, batch, cache, *, policy: SparsityPolicy = DENSE):
        return self._mod.prefill(self.cfg, params, batch, cache, policy=policy)

    def prefill_chunk(self, params, batch, cache, *,
                      policy: SparsityPolicy = DENSE):
        """Fixed-shape prefill chunk at the cache offset (continuous
        batching); ``batch["chunk_len"]`` masks the padded tail."""
        return self._mod.prefill_chunk(self.cfg, params, batch, cache,
                                       policy=policy)

    def decode_step(self, params, tokens, cache, *,
                    policy: SparsityPolicy = DENSE):
        return self._mod.decode_step(self.cfg, params, tokens, cache,
                                     policy=policy)


def build_model(cfg: ModelConfig) -> Model:
    mod = encdec if cfg.is_encdec else transformer
    return Model(cfg=cfg, _mod=mod)

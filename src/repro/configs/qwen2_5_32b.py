"""Qwen2.5-32B — dense GQA with QKV bias.

[hf:Qwen/Qwen2.5-0.5B; hf] 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064.  Full attention → long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    sub_quadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        qkv_bias=True,
        attn_chunk=8,
    )

"""RecurrentGemma-2B (Griffin) — RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427; hf] 26L d_model=2560 10H (GQA kv=1, MQA) d_ff=7680
vocab=256000, local-attn window 2048.  Period (rglru, rglru, attn);
26 = 8 full periods + 2 leftover recurrent blocks.  Sub-quadratic →
long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "attn"),
    rnn_width=2560,
    attn_type="local",
    window=2048,
    act_fn="gelu",
    sub_quadratic=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        n_layers=5,          # 1 full period + 2 leftover
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        block_pattern=("rglru", "rglru", "attn"),
        rnn_width=64,
        attn_type="local",
        window=8,
        act_fn="gelu",
        sub_quadratic=True,
        attn_chunk=8,
    )

"""Qwen2-7B — one of the paper's own evaluation models.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
Published Amber-P skip list: q_proj/gate_proj skipped in layers
0, 6, 23, 26, 27 → 57.6% of linear FLOPs accelerated (paper §Setup).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    qgate_skip_layers=(0, 6, 23, 26, 27),
    sub_quadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b-smoke",
        family="dense",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        qkv_bias=True,
        qgate_skip_layers=(0, 3),
        attn_chunk=8,
    )

"""Qwen2-VL-2B — VLM backbone with M-RoPE; vision frontend stubbed.

[arXiv:2409.12191; hf] 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936.  ``input_specs`` provides precomputed patch embeddings
(B, n_patches, d_model) that replace the first ``n_patches`` token slots;
M-RoPE position ids (3, B, S) are a stub input.  Full attention →
long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    rope_variant="mrope",
    rope_theta=1e6,
    qkv_bias=True,
    vision_stub=True,
    n_patches=64,
    sub_quadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke",
        family="vlm",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        rope_variant="mrope",
        qkv_bias=True,
        vision_stub=True,
        n_patches=4,
        attn_chunk=8,
    )

"""Qwen3-30B-A3B — the paper's MoE evaluation model.

48L d_model=2048 32H (GQA kv=4) 128 experts top-8, moe_d_ff=768,
vocab=151936.  Published Amber-P skip list: q_proj/gate_proj skipped in
layers 41, 46, 47 → 56.9% coverage.  Robust-Norm scoring disabled inside
routed experts (paper: dynamic routing → per-expert stats unstable).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    n_experts=128,
    top_k=8,
    moe_d_ff=768,
    rope_theta=1e6,
    qgate_skip_layers=(41, 46, 47),
    sub_quadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        n_experts=8,
        top_k=2,
        moe_d_ff=32,
        qgate_skip_layers=(1,),
        attn_chunk=8,
    )

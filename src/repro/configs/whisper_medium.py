"""Whisper-medium — encoder-decoder with a stubbed conv frontend.

[arXiv:2212.04356; unverified] 24L d_model=1024 16H (MHA kv=16) d_ff=4096
vocab=51865; encoder consumes 1500 precomputed frame embeddings
(conv frontend stub per the task spec).  Sinusoidal positions so assigned
decoder lengths beyond Whisper's 448 are well-defined.  Full attention →
long_500k skipped; decode shapes run (decoder + cross-attention cache).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    is_encdec=True,
    n_encoder_layers=24,
    encoder_seq=1500,
    rope_variant="sinusoidal",
    act_fn="gelu",
    norm="layernorm",
    qkv_bias=True,
    sub_quadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="encdec",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        is_encdec=True,
        n_encoder_layers=2,
        encoder_seq=16,
        rope_variant="sinusoidal",
        act_fn="gelu",
        norm="layernorm",
        qkv_bias=True,
        attn_chunk=8,
    )

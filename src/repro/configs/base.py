"""Model / run configuration system.

``ModelConfig`` is a frozen dataclass — hashable, so jitted step functions
can close over it statically.  One module per assigned architecture lives in
this package (``repro/configs/<id>.py``), each exporting ``CONFIG`` plus a
``smoke()`` reduced config of the same family for CPU tests.

Input-shape cells (assigned per the task):
    train_4k     seq 4096,   global_batch 256   (training      → train_step)
    prefill_32k  seq 32768,  global_batch 32    (prefill       → prefill_step)
    decode_32k   seq 32768,  global_batch 128   (decode        → serve_step)
    long_500k    seq 524288, global_batch 1     (long decode   → serve_step;
                                                 sub-quadratic archs only)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeCell", "SHAPE_CELLS", "ARCH_IDS", "get_config"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // n_heads

    # --- attention ---
    attn_type: str = "full"         # full | swa | local
    window: int = 4096
    rope_variant: str = "default"   # default | 2d | mrope | sinusoidal | none
    rope_theta: float = 1e4
    qkv_bias: bool = False
    attn_chunk: int = 1024          # online-softmax KV/Q chunk
    attn_impl: str = "chunked"      # chunked (jnp) | flash (Pallas kernel;
                                    # interpret-mode on CPU, Mosaic on TPU)

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert FFN width (0 → d_ff)
    shared_expert: bool = False
    moe_impl: str = "ragged"        # ragged | dense (dense = weighted all-expert)

    # --- recurrent / hybrid ---
    block_pattern: Tuple[str, ...] = ("attn",)   # kinds per period: attn|rwkv6|rglru
    rnn_width: int = 0              # RG-LRU recurrent width (0 → d_model)
    conv_width: int = 4             # RG-LRU temporal conv

    # --- encoder-decoder ---
    is_encdec: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500         # whisper 30s @ 50Hz after conv stub

    # --- VLM stub ---
    vision_stub: bool = False
    n_patches: int = 64             # stub patch embeddings prepended

    # --- misc ---
    act_fn: str = "silu"            # silu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    sub_quadratic: bool = False     # eligible for long_500k
    remat: bool = True              # activation checkpoint per block (training)
    scan_layers: bool = True        # lax.scan over layer stack (False=unroll)

    # paper-policy metadata: published q/gate skip lists where known
    qgate_skip_layers: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.n_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.n_experts:
            per_ff = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
            if self.shared_expert:
                per_ff += 3 * d * self.moe_d_ff
        else:
            per_ff = 3 * d * f
        per_rnn = 0
        kinds = [self.block_pattern[i % len(self.block_pattern)] for i in range(self.n_layers)]
        n_attn = sum(k == "attn" for k in kinds)
        n_rwkv = sum(k == "rwkv6" for k in kinds)
        n_rglru = sum(k == "rglru" for k in kinds)
        rnn_w = self.rnn_width or d
        per_rwkv = 5 * d * d + 3 * d * f  # r,k,v,g,o + channel-mix
        per_rglru = 2 * d * rnn_w + rnn_w * d + 2 * rnn_w * rnn_w // 64  # in/gate/out + gates(diag-ish)
        total = v * d * (1 if self.tie_embeddings else 2)
        total += n_attn * (per_attn + per_ff) + n_rwkv * per_rwkv + n_rglru * (per_rglru + per_ff)
        if self.is_encdec:
            total += self.n_encoder_layers * (2 * per_attn + per_ff)  # self+cross approx
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.n_experts:
            return self.n_params()
        d = self.d_model
        dense_ff_total = self.n_params() - self.n_layers * (
            self.n_experts * 3 * d * self.moe_d_ff
        )
        active_ff = self.n_layers * (self.top_k * 3 * d * self.moe_d_ff)
        return int(dense_ff_total + active_ff)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPE_CELLS = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

ARCH_IDS = (
    "mixtral_8x7b",
    "llama4_scout_17b_a16e",
    "qwen2_vl_2b",
    "rwkv6_7b",
    "whisper_medium",
    "recurrentgemma_2b",
    "qwen2_5_32b",
    "stablelm_3b",
    "granite_34b",
    "chatglm3_6b",
)

# the paper's own evaluation models (small-scale stand-ins live in smoke())
PAPER_ARCH_IDS = ("llama31_8b", "qwen2_7b", "qwen3_30b_a3b")


def get_config(arch: str) -> ModelConfig:
    """Load ``repro/configs/<arch>.py`` and return its CONFIG."""
    import importlib

    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    import importlib

    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke()

"""ChatGLM3-6B — dense GQA with 2D (half-dim) RoPE and QKV bias.

[arXiv:2406.12793; hf] 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024.  Full attention → long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_variant="2d",
    qkv_bias=True,
    sub_quadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        rope_variant="2d",
        qkv_bias=True,
        attn_chunk=8,
    )

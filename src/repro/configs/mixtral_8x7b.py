"""Mixtral-8x7B — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, SWA window 4096.  Sub-quadratic (SWA) → long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    top_k=2,
    moe_d_ff=14336,
    attn_type="swa",
    window=4096,
    rope_theta=1e6,
    sub_quadratic=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        n_experts=4,
        top_k=2,
        moe_d_ff=128,
        attn_type="swa",
        window=16,
        sub_quadratic=True,
        attn_chunk=8,
    )

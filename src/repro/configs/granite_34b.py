"""Granite-34B-Code — deep dense LLaMA-arch with MQA (kv=1).

[arXiv:2405.04324; hf] 88L d_model=6144 48H (GQA kv=1) d_ff=24576
vocab=49152.  Full attention → long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    sub_quadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=256,
        attn_chunk=8,
    )

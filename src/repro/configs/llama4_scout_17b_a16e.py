"""Llama-4-Scout-17B-16E — 16-expert top-1 MoE with a shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1, early fusion (multimodal
frontend stubbed — text backbone only here).  Full attention → long_500k
skipped (see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    n_experts=16,
    top_k=1,
    moe_d_ff=8192,
    shared_expert=True,
    rope_theta=5e5,
    sub_quadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        n_experts=4,
        top_k=1,
        moe_d_ff=96,
        shared_expert=True,
        attn_chunk=8,
    )

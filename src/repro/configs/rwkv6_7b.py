"""RWKV6-7B (Finch) — attention-free, data-dependent decay.

[arXiv:2404.05892; hf] 32L d_model=4096 d_ff=14336 vocab=65536.
Head size 64 → 64 heads.  Linear-time → long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,            # head_size 64
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    head_dim=64,
    block_pattern=("rwkv6",),
    rope_variant="none",
    sub_quadratic=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        head_dim=32,
        block_pattern=("rwkv6",),
        rope_variant="none",
        sub_quadratic=True,
    )

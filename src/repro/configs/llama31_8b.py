"""LLaMA3.1-8B — one of the paper's own evaluation models.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
Published Amber-P skip list: q_proj/gate_proj skipped in layers
19, 21, 28, 30, 31 → 56.1% of linear FLOPs accelerated (paper §Setup).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=5e5,
    qgate_skip_layers=(19, 21, 28, 30, 31),
    sub_quadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama31-smoke",
        family="dense",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        qgate_skip_layers=(3,),
        attn_chunk=8,
    )

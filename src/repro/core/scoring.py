"""Activation-importance scoring for Amber Pruner.

Three scoring modes, in increasing fidelity (paper §Methodology):

  * ``naive``  — ``S_ij = |X_ij|``  (the Naïve top-k baseline).
  * ``wanda``  — ``S_ij = |X_ij| · ‖W_:,j‖₂ / min_k ‖W_:,k‖₂``  (Eq. 2;
                 min-normalized so low-dynamic-range channels cannot
                 underflow in low-precision inference).
  * ``robust`` — Robust-Norm Scoring (Eqs. 3-5): winsorize weights to the
                 [0.5%, 99.5%] percentile band, standardize by the global
                 mean/variance of the surviving weights, then take channel
                 L2 norms (min-normalized like ``wanda``).

Weight convention throughout the code base: ``W`` has shape
``(d_in, d_out)`` so an input channel j is the **row** ``W[j, :]`` — this is
the transpose of the paper's ``(d_out, d_in)`` layout; the channel norms are
identical.

Scales depend only on the weights, so they are precomputed offline
(:func:`precompute_scale`) and stored as auxiliary parameters (<0.05% of
model size).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "channel_norm_scale",
    "robust_norm_scale",
    "precompute_scale",
    "score_activations",
    "SCORE_MODES",
]

SCORE_MODES = ("naive", "wanda", "robust")

_EPS = 1e-12


def _min_normalize(norms: jax.Array) -> jax.Array:
    """``f(W_:,j) = ‖W_:,j‖ / min_k ‖W_:,k‖`` (Eq. 2 / Appendix B Eq. 5)."""
    return norms / (jnp.min(norms) + _EPS)


def channel_norm_scale(w: jax.Array) -> jax.Array:
    """Wanda-like per-input-channel scale from raw weight column norms.

    Args:
      w: ``(d_in, d_out)`` weight matrix.
    Returns:
      ``(d_in,)`` float32 scale.
    """
    norms = jnp.linalg.norm(w.astype(jnp.float32), axis=-1)
    return _min_normalize(norms)


def robust_norm_scale(
    w: jax.Array, q_low: float = 0.005, q_high: float = 0.995
) -> jax.Array:
    """Robust-Norm Scoring scale (paper Eqs. 3-5).

    1. Outlier removal: weights outside the [q_low, q_high] percentile band
       are winsorized to the band edge (the paper "discards" them; clamping
       keeps per-channel element counts equal, which the channel norm in
       step 3 requires — the contribution of a clamped outlier saturates at
       the band edge either way).
    2. Standardize by the global mean/std of the winsorized tensor.
    3. Channel-wise L2 norm, min-normalized.

    Args:
      w: ``(d_in, d_out)`` weight matrix.
    Returns:
      ``(d_in,)`` float32 scale.
    """
    wf = w.astype(jnp.float32)
    lo = jnp.quantile(wf, q_low)
    hi = jnp.quantile(wf, q_high)
    wc = jnp.clip(wf, lo, hi)
    mu = jnp.mean(wc)
    sd = jnp.sqrt(jnp.var(wc) + _EPS)
    wn = (wc - mu) / sd
    norms = jnp.linalg.norm(wn, axis=-1)
    return _min_normalize(norms)


def precompute_scale(w: jax.Array, mode: str) -> jax.Array | None:
    """Offline per-channel scale for a linear's weight, or None for naive."""
    if mode == "naive":
        return None
    if mode == "wanda":
        return channel_norm_scale(w)
    if mode == "robust":
        return robust_norm_scale(w)
    raise ValueError(f"unknown score mode {mode!r}; expected one of {SCORE_MODES}")


def score_activations(x: jax.Array, scale: jax.Array | None) -> jax.Array:
    """``S_ij = |X_ij| · scale_j`` (scale None → naive |X|). float32 output."""
    s = jnp.abs(x.astype(jnp.float32))
    if scale is not None:
        s = s * scale.astype(jnp.float32)
    return s

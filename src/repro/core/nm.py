"""N:M structured sparsity primitives.

An N:M pattern keeps the N largest-scoring elements inside every contiguous
group of M elements along the *input-channel* (last) axis.  These are the
low-level building blocks used by the Amber Pruner (per-token masks) and the
TPU-native tile-consensus variant (per-tile shared masks).

All functions are pure jnp and jit-safe; scores are computed in float32 for
stable tie-breaking regardless of the activation dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "nm_topk_mask",
    "apply_nm",
    "nm_group_view",
    "sparsity_fraction",
    "validate_nm",
    "tile_consensus_channels",
    "compact_columns",
]


def nm_group_view(x: jax.Array, m: int) -> jax.Array:
    """Reshape ``(..., D)`` to ``(..., D // m, m)`` groups of M channels."""
    d = x.shape[-1]
    if d % m != 0:
        raise ValueError(f"last dim {d} not divisible by group size {m}")
    return x.reshape(*x.shape[:-1], d // m, m)


def nm_topk_mask(scores: jax.Array, n: int, m: int) -> jax.Array:
    """Boolean keep-mask with exactly N True per contiguous group of M.

    Ties break toward the lower channel index (``lax.top_k`` semantics),
    making the mask deterministic.

    Implementation note: N rounds of first-occurrence argmax (max + compare
    + cumsum over the M lanes) instead of ``top_k``+``one_hot``.  Identical
    output, but every op is an element-wise/last-dim reduction, so GSPMD
    keeps the token axes sharded — ``top_k``'s variadic sort partitioning
    forced a full batch all-gather of the scores in the 32k-prefill cells
    (measured: 108 GiB of gathered scores per qwen2.5 layer, EXPERIMENTS.md
    §Perf iteration 1).  It is also the exact construction the Pallas
    kernel uses, so kernel↔reference equality is structural.

    Args:
      scores: ``(..., D)`` non-negative importance scores, D % m == 0.
      n, m:   the N:M pattern (0 < n <= m).
    Returns:
      bool mask of ``scores.shape`` with per-group popcount == n.
    """
    if not (0 < n <= m):
        raise ValueError(f"invalid N:M pattern {n}:{m}")
    if n == m:  # dense — nothing to do
        return jnp.ones(scores.shape, dtype=bool)
    g = nm_group_view(scores.astype(jnp.float32), m)        # (..., G, m)
    remaining = g
    keep = jnp.zeros(g.shape, dtype=jnp.bool_)
    for _ in range(n):
        cur = remaining.max(axis=-1, keepdims=True)
        eq = remaining == cur
        first = eq & (jnp.cumsum(eq.astype(jnp.int32), axis=-1) == 1)
        keep = keep | first
        remaining = jnp.where(first, -jnp.inf, remaining)
    return keep.reshape(scores.shape)


def apply_nm(x: jax.Array, scores: jax.Array, n: int, m: int) -> jax.Array:
    """Zero out everything but the per-group top-N scored entries of ``x``."""
    mask = nm_topk_mask(scores, n, m)
    return jnp.where(mask, x, jnp.zeros((), dtype=x.dtype))


def sparsity_fraction(x: jax.Array) -> jax.Array:
    """Fraction of exactly-zero entries (diagnostic)."""
    return jnp.mean((x == 0).astype(jnp.float32))


def validate_nm(mask: jax.Array, n: int, m: int) -> jax.Array:
    """True iff every group of M has at most N kept entries (bool scalar)."""
    g = nm_group_view(mask.astype(jnp.int32), m)
    return jnp.all(g.sum(-1) <= n)


# ---------------------------------------------------------------------------
# Tile-consensus mode (TPU-native compacted matmul support, see DESIGN.md §2)
# ---------------------------------------------------------------------------

def tile_consensus_channels(scores: jax.Array, n: int, m: int) -> jax.Array:
    """Pick one shared N:M channel set for a whole token tile.

    Aggregates per-token scores over the token axes with an L2 norm (the
    Wanda ``‖X_:,j‖₂`` statistic restricted to the tile) and returns the
    *channel indices* kept, shaped ``(G, n)`` sorted ascending inside each
    group so the gather below is monotonic.

    Args:
      scores: ``(T, D)`` (or ``(..., T, D)`` — leading axes are pooled too).
    """
    s2 = scores.astype(jnp.float32) ** 2
    pooled = jnp.sqrt(s2.reshape(-1, scores.shape[-1]).sum(axis=0))  # (D,)
    g = nm_group_view(pooled, m)                                     # (G, m)
    _, idx = jax.lax.top_k(g, n)                                     # (G, n)
    idx = jnp.sort(idx, axis=-1)
    base = (jnp.arange(g.shape[0]) * m)[:, None]
    return idx + base                                                # absolute channel ids


def compact_columns(x: jax.Array, channels: jax.Array) -> jax.Array:
    """Gather the kept channels: ``(..., D) -> (..., G*n)``.

    ``channels`` is the absolute-index output of
    :func:`tile_consensus_channels` (shape ``(G, n)``).
    """
    flat = channels.reshape(-1)
    return jnp.take(x, flat, axis=-1)

"""Amber Pruner: the functional pruning path + offline scale precomputation.

``prune_input`` is the single entry point the model zoo's ``SparseLinear``
calls on a projection input.  It dispatches between:

  * **per-token** N:M masking (paper-faithful; mathematically identical to
    the SpMM the paper runs on sparse tensor cores), and
  * **tile-consensus** N:M (TPU-native compacted-matmul mode, DESIGN.md §2).

``precompute_scales`` walks a parameter pytree offline and attaches the
Robust-Norm / Wanda channel scales next to every prunable weight — the
paper's "auxiliary weights" (<0.05% of model size).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import nm, scoring
from repro.core.policy import SparsityPolicy

__all__ = [
    "prune_input",
    "sparse_matmul",
    "precompute_scales",
    "SCALE_KEY",
]

SCALE_KEY = "amber_scale"  # aux-param key stored alongside "w"/"b"


def prune_input(
    x: jax.Array,
    scale: jax.Array | None,
    policy: SparsityPolicy,
) -> jax.Array:
    """Apply per-token N:M sparsity to a projection input.

    Args:
      x:      ``(..., d_in)`` activations.
      scale:  ``(d_in,)`` precomputed channel scale, or None for naive |X|.
      policy: static sparsity policy (already filtered for module/layer).
    Returns:
      x with exactly N of every M contiguous channels kept per token.
    """
    scores = scoring.score_activations(x, scale)
    return nm.apply_nm(x, scores, policy.n, policy.m)


def sparse_matmul(
    x: jax.Array,
    w: jax.Array,
    scale: jax.Array | None,
    policy: SparsityPolicy,
    bias: jax.Array | None = None,
) -> jax.Array:
    """N:M-sparsified ``x @ w`` (+ optional fused ``bias``) with the
    policy's mode.

    per-token mode: mask then dense matmul (functional reproduction — on TPU
    the MXU cannot skip per-row patterns; see DESIGN.md §2).

    tile-consensus mode: one shared channel set per token tile → compacted
    dense matmul at (n/m) of the FLOPs.  Token axes are flattened, tiled by
    ``policy.tile_size`` (padded if needed), and each tile contracts only its
    surviving channels against the gathered weight rows.

    ``policy.use_pallas_kernels`` reroutes both modes onto the fused Pallas
    kernels (one ``pallas_call``, X streamed through VMEM once — no masked
    copy materialized in HBM); the jnp code below stays the bit-exact
    oracle and the fallback for callers that need the mask itself.
    """
    if policy.use_pallas_kernels:
        # chaos-harness injection site (serve/faults.py, lazily imported to
        # keep repro.core free of serving deps): dispatch happens at trace
        # time, so "compile_error" aborts the trace with a KernelFault
        # (nothing cached; the serving engine re-runs on its oracle jit)
        # and "fallback" silently takes the jnp oracle path below
        from repro.serve.faults import KernelFault, fire as _fire_fault

        kind = _fire_fault("kernel.projection")
        if kind == "compile_error":
            raise KernelFault(
                "injected N:M projection kernel compile failure")
        if kind != "fallback":
            from repro.kernels import ops

            if policy.tile_consensus:
                y = ops.nm_spmm(x, w, scale, policy.n, policy.m,
                                tile=policy.tile_size)
                return y if bias is None else y + bias
            return ops.nm_prune_matmul(x, w, scale, policy.n, policy.m,
                                       bias=bias)

    if not policy.tile_consensus:
        xp = prune_input(x, scale, policy)
        y = xp @ w
        return y if bias is None else y + bias

    *lead, d_in = x.shape
    t = 1
    for s in lead:
        t *= s
    xf = x.reshape(t, d_in)
    ts = min(policy.tile_size, t)
    pad = (-t) % ts
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, d_in), xf.dtype)], axis=0)
    n_tiles = xf.shape[0] // ts
    xt = xf.reshape(n_tiles, ts, d_in)

    def one_tile(xtile: jax.Array) -> jax.Array:
        scores = scoring.score_activations(xtile, scale)
        chans = nm.tile_consensus_channels(scores, policy.n, policy.m)  # (G, n)
        xc = nm.compact_columns(xtile, chans)           # (ts, G*n)
        wc = jnp.take(w, chans.reshape(-1), axis=0)      # (G*n, d_out)
        return xc @ wc

    yt = jax.vmap(one_tile)(xt)                          # (n_tiles, ts, d_out)
    y = yt.reshape(n_tiles * ts, -1)[:t]
    y = y.reshape(*lead, w.shape[-1])
    return y if bias is None else y + bias


def precompute_scales(params: Any, policy: SparsityPolicy) -> Any:
    """Offline pass: attach Amber channel scales to every prunable linear.

    Walks the (nested-dict) parameter pytree; every sub-dict that looks like
    a linear (has a 2D ``w``) and whose name is prunable under the policy
    gets an ``amber_scale`` entry.  MoE expert weights (3D, leading expert
    axis) get per-expert scales unless ``policy.moe_plain_score`` (the
    paper's rule: Robust-Norm is N/A under dynamic routing).

    Layer-stacked weights (3D with leading layer axis, from ``lax.scan``
    stacking) get per-layer scales via vmap.
    """
    if policy.score_mode == "naive" or not policy.enabled:
        return params

    def visit(d: Any, path: tuple) -> Any:
        if not isinstance(d, dict):
            return d
        out: Dict[str, Any] = {}
        for k, v in d.items():
            if isinstance(v, dict) and "w" in v and not isinstance(v["w"], dict):
                w = v["w"]
                module = k
                is_expert = "expert" in "/".join(path + (k,))
                prunable = policy.should_prune(module, None)
                new_v = dict(v)
                if prunable and hasattr(w, "ndim"):
                    if is_expert and policy.moe_plain_score:
                        pass  # naive |X| scoring inside routed experts
                    elif w.ndim == 2:
                        new_v[SCALE_KEY] = scoring.precompute_scale(w, policy.score_mode)
                    elif w.ndim == 3:  # (layers, d_in, d_out) scan-stacked
                        fn = lambda wi: scoring.precompute_scale(wi, policy.score_mode)
                        new_v[SCALE_KEY] = jax.vmap(fn)(w)
                    elif w.ndim == 4:  # (layers, experts, d_in, d_out)
                        if not policy.moe_plain_score:
                            fn = lambda wi: scoring.precompute_scale(wi, policy.score_mode)
                            new_v[SCALE_KEY] = jax.vmap(jax.vmap(fn))(w)
                out[k] = {kk: visit(vv, path + (k, kk)) if isinstance(vv, dict) else vv
                          for kk, vv in new_v.items()}
            else:
                out[k] = visit(v, path + (k,)) if isinstance(v, dict) else v
        return out

    return visit(params, ())

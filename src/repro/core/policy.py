"""Sparsity policy: which linear projections get N:M-pruned, and how.

The paper's deployment policy (Experiments §Setup):

  * sparsity is confined to the **prefill** phase;
  * ``k_proj`` / ``v_proj`` are never pruned (GQA ⇒ negligible FLOP share);
  * ``o_proj`` / ``up_proj`` are never pruned (highest sensitivity, App. D);
  * ``down_proj`` is pruned in **all** layers (lowest sensitivity);
  * ``q_proj`` / ``gate_proj`` are pruned except in a small per-model skip
    list chosen by sensitivity analysis (e.g. layers 19/21/28/30/31 for
    LLaMA3.1-8B).

A :class:`SparsityPolicy` is a hashable static dataclass so it can be closed
over by jitted step functions without retracing churn.
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet, Mapping, Tuple

__all__ = [
    "SparsityPolicy",
    "DENSE",
    "paper_policy",
    "naive_policy",
]

# canonical projection names used across the model zoo
ATTN_PROJS = ("q_proj", "k_proj", "v_proj", "o_proj")
MLP_PROJS = ("gate_proj", "up_proj", "down_proj")
ALL_PROJS = ATTN_PROJS + MLP_PROJS


@dataclasses.dataclass(frozen=True)
class SparsityPolicy:
    """Static description of the Amber Pruner deployment.

    Attributes:
      enabled:       master switch.
      n, m:          the N:M pattern (2:4, 4:8, 8:16).
      score_mode:    'naive' | 'wanda' | 'robust'.
      skip_modules:  projection names never pruned (any layer).
      skip_layers:   mapping module -> layer indices additionally skipped.
      phases:        phases in which sparsity is active ('prefill' only per
                     the paper; 'train'/'decode' may be added for ablations).
      moe_plain_score: Robust-Norm scoring is N/A inside routed experts
                     (tokens routed dynamically → per-expert statistics are
                     not stable); fall back to |X| there when True.
      tile_consensus: TPU-native mode — one shared N:M pattern per token
                     tile (see DESIGN.md §2); tile size in tokens.
      use_pallas_kernels: route prunable projections through the fused
                     Pallas kernels (``repro.kernels.ops``): per-token mode
                     lowers to one ``nm_prune_matmul`` call, tile-consensus
                     to the k-blocked ``nm_spmm``, and the Outstanding-
                     sparse W8A8 chain to ``osparse_matmul``.  The pure-jnp
                     path stays the bit-exact oracle/fallback and is always
                     used for scan-stacked ``layer_flag`` models (which
                     need the mask-select form, not a fused GEMM).  The
                     ``REPRO_PALLAS_INTERPRET`` env switch controls whether
                     the kernels run interpreted (CPU) or compiled (TPU).
    """

    enabled: bool = True
    n: int = 8
    m: int = 16
    score_mode: str = "robust"
    skip_modules: Tuple[str, ...] = ("k_proj", "v_proj", "o_proj", "up_proj")
    skip_layers: Mapping[str, FrozenSet[int]] = dataclasses.field(
        default_factory=dict
    )
    phases: Tuple[str, ...] = ("prefill",)
    moe_plain_score: bool = True
    tile_consensus: bool = False
    tile_size: int = 256
    use_pallas_kernels: bool = False

    def __post_init__(self):
        # N with N not dividing M is legal (e.g. 3:8) — the only structural
        # requirements are integer 0 < N ≤ M, checked even when disabled so
        # a bad policy cannot lie dormant behind ``enabled=False``
        import numbers
        if not (isinstance(self.n, numbers.Integral)
                and isinstance(self.m, numbers.Integral)
                and 0 < self.n <= self.m):
            raise ValueError(f"bad N:M {self.n}:{self.m}")
        from repro.core.scoring import SCORE_MODES
        if self.score_mode not in SCORE_MODES:
            raise ValueError(f"unknown score_mode {self.score_mode!r}; "
                             f"expected one of {SCORE_MODES}")
        if self.tile_size < 1:
            raise ValueError(f"tile_size must be >= 1, got {self.tile_size}")
        # freeze the mapping for hashability
        object.__setattr__(
            self,
            "skip_layers",
            tuple(sorted((k, tuple(sorted(v))) for k, v in dict(self.skip_layers).items())),
        )

    # skip_layers is stored as a tuple of (name, (idx...)) pairs post-init
    def _skips_for(self, module: str) -> Tuple[int, ...]:
        for name, idxs in self.skip_layers:  # type: ignore[attr-defined]
            if name == module:
                return idxs
        return ()

    def active(self, phase: str) -> bool:
        return self.enabled and phase in self.phases

    def should_prune(self, module: str, layer_idx: int | None = None) -> bool:
        """Static decision: is this projection pruned at this layer?"""
        if not self.enabled:
            return False
        if module in self.skip_modules:
            return False
        if layer_idx is not None and layer_idx in self._skips_for(module):
            return False
        return True

    def with_(self, **kw) -> "SparsityPolicy":
        cur = dataclasses.asdict(self)
        cur["skip_layers"] = dict(self.skip_layers)  # type: ignore[arg-type]
        cur.update(kw)
        return SparsityPolicy(**cur)


DENSE = SparsityPolicy(enabled=False)


def paper_policy(
    n: int = 8,
    m: int = 16,
    qgate_skip_layers: Tuple[int, ...] = (),
    score_mode: str = "robust",
    tile_consensus: bool = False,
    use_pallas_kernels: bool = False,
) -> SparsityPolicy:
    """The paper's deployment: Amber-P with layer skipping.

    ``qgate_skip_layers`` is the per-model list of layers in which q_proj and
    gate_proj are additionally skipped (sensitivity-selected).
    """
    return SparsityPolicy(
        n=n,
        m=m,
        score_mode=score_mode,
        skip_modules=("k_proj", "v_proj", "o_proj", "up_proj"),
        skip_layers={
            "q_proj": frozenset(qgate_skip_layers),
            "gate_proj": frozenset(qgate_skip_layers),
        },
        tile_consensus=tile_consensus,
        use_pallas_kernels=use_pallas_kernels,
    )


def naive_policy(n: int, m: int) -> SparsityPolicy:
    """Naïve top-k baseline: |X| scores, prune everything, no skipping."""
    return SparsityPolicy(n=n, m=m, score_mode="naive", skip_modules=(), skip_layers={})

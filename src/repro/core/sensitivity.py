"""Layer-skipping sensitivity analysis (paper §Layer Skipping Strategy).

For a projection p at layer l, the sensitivity is the relative perturbation
of the *final model output* when only that projection's input is N:M-pruned:

    e_p(Y, Y') = ‖Y − Y'‖₂ / (‖Y‖₂ + ε)                     (paper Eq. 8)

The scan drives the paper's heuristic skip selection:
  * k_proj / v_proj     → non-prunable (GQA ⇒ tiny FLOP share, App. D);
  * o_proj / up_proj    → preserved (highest average sensitivity);
  * down_proj           → pruned everywhere (lowest sensitivity);
  * q_proj / gate_proj  → pruned except in the top-sensitivity layers,
                          subject to keeping coverage ≥ the target (55%).
"""
from __future__ import annotations

from typing import Callable, Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import SparsityPolicy

__all__ = [
    "relative_perturbation",
    "targeted_policy",
    "sensitivity_scan",
    "select_qgate_skips",
    "linear_flops",
    "coverage",
]

_EPS = 1e-6

ForwardFn = Callable[..., jax.Array]  # forward(params, batch, policy) -> output


def relative_perturbation(y: jax.Array, y_prime: jax.Array) -> jax.Array:
    """e = ‖Y − Y'‖₂ / (‖Y‖₂ + ε), computed in float32."""
    yf = y.astype(jnp.float32).reshape(-1)
    yp = y_prime.astype(jnp.float32).reshape(-1)
    return jnp.linalg.norm(yf - yp) / (jnp.linalg.norm(yf) + _EPS)


def targeted_policy(
    module: str,
    layer: int,
    n_layers: int,
    base: SparsityPolicy,
) -> SparsityPolicy:
    """Policy pruning ONLY ``module`` at ``layer`` (for sensitivity probes)."""
    from repro.core.policy import ALL_PROJS

    others = tuple(p for p in ALL_PROJS if p != module)
    skip = {module: frozenset(i for i in range(n_layers) if i != layer)}
    return base.with_(
        enabled=True, skip_modules=others, skip_layers=skip, phases=base.phases
    )


def sensitivity_scan(
    forward: ForwardFn,
    params,
    batch,
    modules: Sequence[str],
    n_layers: int,
    base_policy: SparsityPolicy,
    phase: str = "prefill",
) -> Dict[Tuple[str, int], float]:
    """e_p for every (module, layer) probe; returns a plain-float dict.

    ``forward(params, batch, policy=..., phase=...)`` must route the policy
    to every SparseLinear.  One jit per module (layer index is a traced
    constant inside skip_layers → policy is static, so we loop).
    """
    from repro.core.policy import DENSE

    y_dense = forward(params, batch, policy=DENSE, phase=phase)
    out: Dict[Tuple[str, int], float] = {}
    for module in modules:
        for layer in range(n_layers):
            pol = targeted_policy(module, layer, n_layers, base_policy)
            y_p = forward(params, batch, policy=pol, phase=phase)
            out[(module, layer)] = float(relative_perturbation(y_dense, y_p))
    return out


# ---------------------------------------------------------------------------
# FLOP accounting + the paper's skip heuristic
# ---------------------------------------------------------------------------

def linear_flops(dims: Mapping[str, Tuple[int, int]], tokens: int = 1) -> Dict[str, float]:
    """2·T·d_in·d_out per projection, from a {module: (d_in, d_out)} map."""
    return {m: 2.0 * tokens * di * do for m, (di, do) in dims.items()}


def coverage(
    flops: Mapping[str, float],
    policy: SparsityPolicy,
    n_layers: int,
) -> float:
    """Fraction of total linear FLOPs that run sparsified under ``policy``."""
    total = 0.0
    pruned = 0.0
    for module, f in flops.items():
        for layer in range(n_layers):
            total += f
            if policy.should_prune(module, layer):
                pruned += f
    return pruned / max(total, 1.0)


def select_qgate_skips(
    sens: Mapping[Tuple[str, int], float],
    flops: Mapping[str, float],
    n_layers: int,
    base_policy: SparsityPolicy,
    coverage_target: float = 0.55,
) -> Tuple[int, ...]:
    """Pick q_proj/gate_proj layers to skip, most-sensitive first, while
    keeping linear-FLOP coverage ≥ ``coverage_target``.

    Mirrors the paper's published skip lists (e.g. 5 layers for LLaMA3.1-8B
    at 56.1% coverage).  q_proj and gate_proj are skipped together per layer
    (combined score = sum of their sensitivities at that layer).
    """
    per_layer = []
    for layer in range(n_layers):
        s = sens.get(("q_proj", layer), 0.0) + sens.get(("gate_proj", layer), 0.0)
        per_layer.append((s, layer))
    per_layer.sort(reverse=True)  # most sensitive first

    skips: list[int] = []
    for _, layer in per_layer:
        cand = tuple(sorted(skips + [layer]))
        pol = base_policy.with_(
            skip_layers={"q_proj": frozenset(cand), "gate_proj": frozenset(cand)}
        )
        if coverage(flops, pol, n_layers) < coverage_target:
            break
        skips = list(cand)
    return tuple(sorted(skips))

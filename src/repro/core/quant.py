"""W8A8 quantization (SmoothQuant) and Outstanding-sparse.

SmoothQuant (Xiao et al. 2023) migrates activation outliers into the weights
with a per-input-channel factor

    s_j = max|X_:,j|^alpha / max|W_:,j|^(1-alpha)            (paper Eq. 9)

and rewrites  Y = X W  as  Y = (X diag(1/s)) (diag(s) W), after which both
factors are int8-quantizable (per-tensor activations, per-channel weights).

**Outstanding-sparse** (paper §Outstanding-sparse) inverts the factor:
``ŝ_j = 1/s_j`` with a small alpha (0.10), which *expands* the activation
dynamic range instead of compressing it — empirically this exposes the
structured sparsity pattern that Amber Pruner selects, letting sparsity and
W8A8 stack.

Everything here is calibration + offline graph rewrite; the runtime int8
matmul lives in ``repro/kernels/w8a8_matmul.py`` (Pallas) with
``quantized_matmul`` below as the jnp reference path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "ActCalib",
    "smooth_factors",
    "quantize_weight_per_channel",
    "quantize_act_per_tensor",
    "quantize_act_per_token",
    "quantized_matmul",
    "QuantizedLinear",
    "make_quantized_linear",
]

_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static W8A8 deployment description.

    Attributes:
      alpha:        SmoothQuant migration strength (paper uses 0.10 for
                    Outstanding-sparse, 0.5-0.85 for vanilla SmoothQuant).
      outstanding:  invert the smooth factor (ŝ = 1/s) to expand activations.
      per_token_act: dynamic per-token activation scales (paper: MoE layers
                    use per-token dynamic quant; attention uses static).
      skip_modules: projections excluded from quantization (e.g. down_proj
                    for LLaMA/Qwen2, gate_proj for Qwen3-30B-A3B).
      skip_layers:  layer indices where *all* linears stay bf16 (LLaMA3.1:
                    first 5 layers).
    """

    alpha: float = 0.10
    outstanding: bool = True
    per_token_act: bool = False
    skip_modules: tuple = ("down_proj",)
    skip_layers: tuple = ()

    def should_quantize(self, module: str, layer_idx: int | None = None) -> bool:
        if module in self.skip_modules:
            return False
        if layer_idx is not None and layer_idx in self.skip_layers:
            return False
        return True


class ActCalib:
    """Running per-channel absmax over calibration batches (host-side)."""

    def __init__(self) -> None:
        self._absmax: Dict[str, jax.Array] = {}

    def observe(self, name: str, x: jax.Array) -> None:
        am = jnp.max(jnp.abs(x.astype(jnp.float32).reshape(-1, x.shape[-1])), axis=0)
        if name in self._absmax:
            am = jnp.maximum(am, self._absmax[name])
        self._absmax[name] = am

    def absmax(self, name: str) -> jax.Array:
        return self._absmax[name]

    def names(self) -> Iterable[str]:
        return self._absmax.keys()


def smooth_factors(
    act_absmax: jax.Array,
    w: jax.Array,
    alpha: float,
    outstanding: bool,
) -> jax.Array:
    """Per-input-channel smooth factor s (or ŝ = 1/s for Outstanding-sparse).

    Args:
      act_absmax: ``(d_in,)`` calibrated per-channel activation absmax.
      w:          ``(d_in, d_out)`` weights (channel j = row j).
    Returns:
      ``(d_in,)`` float32 factor ``s`` such that the rewrite is
      ``Y = (X / s) (s ⊙ W)`` — for Outstanding-sparse the returned value is
      already inverted, so the same rewrite expression applies.
    """
    a = jnp.maximum(act_absmax.astype(jnp.float32), _EPS)
    wmax = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-1), _EPS)
    s = (a**alpha) / (wmax ** (1.0 - alpha))
    s = jnp.maximum(s, _EPS)
    if outstanding:
        s = 1.0 / s
    return s


def quantize_weight_per_channel(w: jax.Array):
    """Symmetric int8 per-output-channel weight quant → (q, scale(d_out,))."""
    wf = w.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(wf), axis=0), _EPS) / 127.0
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_act_per_tensor(x: jax.Array, scale: jax.Array):
    """Static symmetric per-tensor int8 activation quant with given scale."""
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q


def quantize_act_per_token(x: jax.Array):
    """Dynamic per-token int8 quant → (q, scale(..., 1))."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1, keepdims=True), _EPS) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantized_matmul(
    xq: jax.Array, wq: jax.Array, x_scale: jax.Array, w_scale: jax.Array
) -> jax.Array:
    """int8 × int8 → int32 matmul, dequantized to f32 (jnp reference).

    ``x_scale`` is scalar (per-tensor) or ``(..., 1)`` (per-token);
    ``w_scale`` is ``(d_out,)``.
    """
    acc = jax.lax.dot_general(
        xq,
        wq,
        (((xq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * x_scale * w_scale


@dataclasses.dataclass
class QuantizedLinear:
    """Offline-rewritten linear: smooth + int8 weights + static act scale."""

    wq: jax.Array          # (d_in, d_out) int8
    w_scale: jax.Array     # (d_out,) f32
    smooth: jax.Array      # (d_in,) f32 — divide X by this pre-quant
    act_scale: jax.Array   # scalar f32 (static per-tensor)
    per_token: bool = False

    def __call__(self, x: jax.Array) -> jax.Array:
        xs = x.astype(jnp.float32) / self.smooth
        if self.per_token:
            xq, ts = quantize_act_per_token(xs)
            return quantized_matmul(xq, self.wq, ts, self.w_scale).astype(x.dtype)
        xq = quantize_act_per_tensor(xs, self.act_scale)
        return quantized_matmul(xq, self.wq, self.act_scale, self.w_scale).astype(x.dtype)


def make_quantized_linear(
    w: jax.Array,
    act_absmax: jax.Array,
    cfg: QuantConfig,
) -> QuantizedLinear:
    """Offline rewrite of one linear under SmoothQuant / Outstanding-sparse."""
    s = smooth_factors(act_absmax, w, cfg.alpha, cfg.outstanding)
    w_smoothed = w.astype(jnp.float32) * s[:, None]
    wq, w_scale = quantize_weight_per_channel(w_smoothed)
    act_scale = jnp.maximum(jnp.max(act_absmax / s), _EPS) / 127.0
    return QuantizedLinear(
        wq=wq,
        w_scale=w_scale,
        smooth=s,
        act_scale=act_scale.astype(jnp.float32),
        per_token=cfg.per_token_act,
    )

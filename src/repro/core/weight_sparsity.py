"""Weight-sparsity baselines (paper Appendix A comparison set).

The paper contrasts Naïve top-k *activation* sparsity against N:M *weight*
sparsity methods — SparseGPT, Wanda, Pruner-Zero — and shows activation
sparsity dominates.  We implement the same comparison:

  * ``magnitude_nm``  — |W| scores (Pruner-Zero's seed metric).
  * ``wanda_nm``      — |W_ij| · ‖X_:,j‖₂ (Wanda, Eq. 1 of the paper).
  * ``sparsegpt_nm``  — OBS-style scores w²·h_j with diagonal-Hessian error
                        compensation (a faithful *diagonal* approximation of
                        SparseGPT's blocked Hessian solve; the full dense
                        Cholesky adds nothing to the comparison here and is
                        noted as an approximation).

Weight layout: ``(d_in, d_out)``; N:M groups run along d_in (the contraction
axis), independently for every output column — matching sparse-tensor-core
layout for the weight operand.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import nm

__all__ = ["magnitude_nm", "wanda_nm", "sparsegpt_nm"]


def _mask_along_din(scores_t: jax.Array, n: int, m: int) -> jax.Array:
    """scores_t: (d_out, d_in) → bool mask (d_out, d_in), N:M along d_in."""
    return nm.nm_topk_mask(scores_t, n, m)


def magnitude_nm(w: jax.Array, n: int, m: int) -> jax.Array:
    """Prune by |W| within N:M groups along the input dimension."""
    wt = w.T.astype(jnp.float32)                      # (d_out, d_in)
    mask = _mask_along_din(jnp.abs(wt), n, m)
    return (wt * mask).T.astype(w.dtype)


def wanda_nm(w: jax.Array, act_norm: jax.Array, n: int, m: int) -> jax.Array:
    """Wanda: S_ij = |W_ij| · ‖X_:,j‖₂ with per-output-row N:M groups.

    Args:
      w:        (d_in, d_out) weights.
      act_norm: (d_in,) calibration activation column norms ‖X_:,j‖₂.
    """
    wt = w.T.astype(jnp.float32)                      # (d_out, d_in)
    scores = jnp.abs(wt) * act_norm.astype(jnp.float32)[None, :]
    mask = _mask_along_din(scores, n, m)
    return (wt * mask).T.astype(w.dtype)


def sparsegpt_nm(
    w: jax.Array,
    hessian_diag: jax.Array,
    n: int,
    m: int,
    damp: float = 0.01,
) -> jax.Array:
    """Diagonal-Hessian SparseGPT with OBS error compensation.

    H ≈ diag(2·Σ_t X_tj²) + λI.  Score = w²·h (equivalently (w/√(H⁻¹)_jj)²).
    Pruned weights are compensated: processing groups left→right, the pruning
    error of group g is redistributed into later columns of the same row via
    the OBS update restricted to the diagonal (δw_k = 0 for k≠j under a
    diagonal H, so compensation degenerates to rescaling — we instead apply
    the standard within-group renormalization that preserves each row's
    H-weighted energy).

    Args:
      w:            (d_in, d_out).
      hessian_diag: (d_in,) — per input channel Σ X² from calibration.
    """
    h = hessian_diag.astype(jnp.float32) + damp * jnp.mean(hessian_diag) + 1e-8
    wt = w.T.astype(jnp.float32)                      # (d_out, d_in)
    scores = wt**2 * h[None, :]
    mask = _mask_along_din(scores, n, m)
    pruned = wt * mask

    # H-weighted row-energy preserving rescale of the survivors
    num = jnp.sum(wt**2 * h[None, :], axis=-1, keepdims=True)
    den = jnp.sum(pruned**2 * h[None, :], axis=-1, keepdims=True) + 1e-12
    gain = jnp.sqrt(num / den)
    return (pruned * gain).T.astype(w.dtype)

"""Amber Pruner core: N:M activation sparsity, scoring, policies, quant."""
from repro.core.nm import (
    apply_nm,
    compact_columns,
    nm_topk_mask,
    sparsity_fraction,
    tile_consensus_channels,
    validate_nm,
)
from repro.core.policy import DENSE, SparsityPolicy, naive_policy, paper_policy
from repro.core.pruner import precompute_scales, prune_input, sparse_matmul
from repro.core.quant import QuantConfig, make_quantized_linear, smooth_factors
from repro.core.scoring import (
    channel_norm_scale,
    precompute_scale,
    robust_norm_scale,
    score_activations,
)
from repro.core.sensitivity import (
    coverage,
    relative_perturbation,
    select_qgate_skips,
    sensitivity_scan,
)

__all__ = [
    "apply_nm",
    "compact_columns",
    "nm_topk_mask",
    "sparsity_fraction",
    "tile_consensus_channels",
    "validate_nm",
    "DENSE",
    "SparsityPolicy",
    "naive_policy",
    "paper_policy",
    "precompute_scales",
    "prune_input",
    "sparse_matmul",
    "QuantConfig",
    "make_quantized_linear",
    "smooth_factors",
    "channel_norm_scale",
    "precompute_scale",
    "robust_norm_scale",
    "score_activations",
    "coverage",
    "relative_perturbation",
    "select_qgate_skips",
    "sensitivity_scan",
]

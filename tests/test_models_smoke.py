"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (task requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, PAPER_ARCH_IDS, get_config, \
    get_smoke_config
from repro.core.policy import DENSE, paper_policy
from repro.models import build_model
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import make_train_step

ALL_ARCHS = list(ARCH_IDS) + list(PAPER_ARCH_IDS)


def _batch(cfg, b=2, t=16, with_labels=False):
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (b, t + (1 if with_labels else 0)), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["frame_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.encoder_seq, cfg.d_model),
            dtype=jnp.bfloat16)
    if cfg.vision_stub:
        batch["pixel_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.n_patches, cfg.d_model),
            dtype=jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch, rng):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg)
    pol = paper_policy(2, 4, cfg.qgate_skip_layers)
    logits = model.forward(params, batch, policy=pol, phase="prefill")
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch, rng):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    step = make_train_step(model, OptConfig(lr=1e-3, total_steps=10))
    opt = adamw_init(params)
    batch = _batch(cfg, with_labels=True)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(new_opt["step"]) == 1
    # params actually changed
    moved = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l.astype(jnp.float32)))),
        jax.tree_util.tree_map(jnp.subtract, new_params, params), 0.0)
    assert moved > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_loads_and_counts(arch):
    cfg = get_config(arch)
    assert cfg.n_params() > 1e9 or cfg.name in ("qwen2-vl-2b",
                                                "whisper-medium",
                                                "recurrentgemma-2b",
                                                "stablelm-3b")
    assert cfg.n_active_params() <= cfg.n_params()


def test_sparse_vs_dense_prefill_differs_but_bounded(rng):
    """Sanity: Amber prefill perturbs logits, not destroys them."""
    cfg = dataclasses.replace(get_smoke_config("llama31_8b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg)
    dense = model.forward(params, batch, policy=DENSE, phase="prefill")
    for n, m in [(2, 4), (4, 8), (8, 16)]:
        pol = paper_policy(n, m, cfg.qgate_skip_layers)
        sparse = model.forward(params, batch, policy=pol, phase="prefill")
        rel = float(jnp.linalg.norm(sparse - dense) /
                    (jnp.linalg.norm(dense) + 1e-9))
        assert 0 < rel < 1.0, (n, m, rel)


def test_policy_inactive_in_train_phase(rng):
    cfg = dataclasses.replace(get_smoke_config("llama31_8b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg)
    pol = paper_policy(2, 4)  # phases=("prefill",)
    a = model.forward(params, batch, policy=pol, phase="train")
    b = model.forward(params, batch, policy=DENSE, phase="train")
    assert float(jnp.max(jnp.abs(a - b))) == 0.0

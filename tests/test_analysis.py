"""The analyzer analyzed: ``repro.analysis`` (ISSUE 9) must catch each
seeded violation class through the real CLI (non-zero exit + structured
JSON finding), and its building blocks (jaxpr walk, VMEM estimator,
purity AST pass, trace-key declaration) must hold on known inputs.

The CLI tests run narrow rule selections so none of them pays for the
full engine-shaped sweeps; the full-repo clean run is CI's
``static-analysis`` job, not a test here.
"""
import json
import os
import subprocess
import sys

import pytest

_HERE = os.path.dirname(__file__)
_SRC = os.path.join(_HERE, "..", "src")
_FIX = os.path.join(_HERE, "fixtures", "analysis")


def _run_cli(*args, json_name="out.json", tmp_path=None):
    out = os.path.join(str(tmp_path), json_name)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args,
         "--json-out", out],
        env={**os.environ, "PYTHONPATH": _SRC}, capture_output=True,
        text=True)
    doc = None
    if os.path.exists(out):
        with open(out) as fh:
            doc = json.load(fh)
    return proc, doc


def _errors(doc, rule):
    return [f for f in doc["findings"]
            if f["rule"] == rule and f["severity"] == "error"]


# ------------------------------------------------------------ CLI, seeded

def test_cli_flags_oversized_kernel(tmp_path):
    """A kernel whose BlockSpec blows the per-core VMEM budget must fail
    the vmem.budget rule through the CLI."""
    proc, doc = _run_cli(
        "--rules", "vmem.budget", "--configs", "llama31_8b",
        "--vmem-extra", os.path.join(_FIX, "bad_kernel.py"),
        tmp_path=tmp_path)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert doc["failed"] is True
    hits = _errors(doc, "vmem.budget")
    assert any(f["obj"] == "oversized_copy" for f in hits), doc["findings"]
    (bad,) = [f for f in hits if f["obj"] == "oversized_copy"]
    assert bad["data"]["vmem_bytes"] > 16 * 2**20


def test_cli_flags_poisoned_scheduler(tmp_path):
    """A jax import in the scheduler host layer must fail the purity
    rule, with the offending chain reported."""
    proc, doc = _run_cli(
        "--rules", "purity",
        "--purity-root", os.path.join(_FIX, "poisoned_src"),
        tmp_path=tmp_path)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    hits = _errors(doc, "purity.scheduler-jax-free")
    assert hits and hits[0]["obj"] == "repro.serve.scheduler"
    assert hits[0]["data"]["chain"][-1] == "jax"


def test_cli_flags_pool_gather_step(tmp_path):
    """A step with a pool-shaped gather outside pallas_call must fail
    the jaxpr containment pin."""
    proc, doc = _run_cli(
        "--rules", "jaxpr.extra-entries",
        "--jaxpr-extra", os.path.join(_FIX, "pool_gather_step.py"),
        tmp_path=tmp_path)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    hits = _errors(doc, "jaxpr.extra-entries")
    assert hits and hits[0]["data"]["prim"] == "gather"


def test_cli_purity_clean_on_repo(tmp_path):
    """The shipped tree passes the purity family (exit 0, no errors) —
    the same pass CI runs over all families."""
    proc, doc = _run_cli("--rules", "purity", tmp_path=tmp_path)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert doc["failed"] is False
    assert doc["summary"].get("error", 0) == 0


def test_cli_rejects_unknown_family(tmp_path):
    proc, _ = _run_cli("--rules", "nonsense", tmp_path=tmp_path)
    assert proc.returncode == 2


# ------------------------------------------------------- library pieces

def test_vmem_estimator_flags_oversized_kernel():
    from repro.analysis.vmem import estimate_call
    sys.path.insert(0, _FIX)
    try:
        import bad_kernel
    finally:
        sys.path.pop(0)
    (name, fn, args), = bad_kernel.TRACE_ENTRIES
    (fp,) = estimate_call(fn, *args)
    assert fp.vmem_bytes > 16 * 2**20
    assert fp.double_buffered and fp.grid == (2,)


def test_vmem_estimator_shipped_kernels_fit():
    """In-process version of the budget rule on one config — the zoo
    entries must all lower a pallas_call and fit 16 MiB."""
    from repro.analysis import Context, run_rules
    findings = run_rules(Context(configs=("llama31_8b",)),
                         families=["vmem"])
    errs = [f for f in findings if f.severity == "error"]
    assert not errs, [f.message for f in errs]


def test_purity_layering_poisoned_vs_clean():
    from repro.analysis.purity import run_layering
    bad = run_layering(os.path.join(_FIX, "poisoned_src"))
    assert any(f.rule == "purity.scheduler-jax-free"
               and f.severity == "error" for f in bad)
    clean = run_layering(_SRC)
    assert not [f for f in clean if f.severity == "error"], \
        [f.message for f in clean]


def test_purity_lazy_contract_tracks_function_scope():
    from repro.analysis.purity import check_lazy_import, scan_tree
    tree = scan_tree(_SRC)
    paged = tree["repro.serve.paged"]
    assert not check_lazy_import(paged, "jax", ("init_paged_cache",))
    # the contract bites: pretend the allowance list is empty
    assert check_lazy_import(paged, "jax", ())


def test_pool_eqn_count_and_pallas_walk():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_utils import (count_pallas_calls,
                                            pool_eqn_count)
    pool = jax.ShapeDtypeStruct((8, 4, 2, 2), jnp.float32)
    idx = jax.ShapeDtypeStruct((3,), jnp.int32)

    def gather_in_scan(pool, idx):
        # nested under scan so the recursive walk is exercised
        def body(c, i):
            return c + jnp.take(pool, idx, axis=0).sum(), None
        out, _ = jax.lax.scan(body, jnp.float32(0.0), jnp.arange(2))
        return out

    closed = jax.make_jaxpr(gather_in_scan)(pool, idx)
    assert pool_eqn_count(closed, (8, 4, 2, 2), "gather") >= 1
    assert count_pallas_calls(closed) == 0


def test_declared_trace_keys_cover_buckets():
    from repro.serve.executor import STEP_BUCKETS, declared_trace_keys
    keys = declared_trace_keys()
    for name in STEP_BUCKETS.values():
        assert name in keys and name + "_oracle" in keys
    for legacy in ("prefill", "decode", "prefill_replay"):
        assert legacy in keys and legacy + "_oracle" in keys


def test_findings_json_schema():
    from repro.analysis import Finding, findings_to_json
    doc = json.loads(findings_to_json([
        Finding("vmem.budget", "error", "k", "boom", {"x": 1}),
        Finding("vmem.budget", "info", "k2", "fine"),
    ]))
    assert doc["schema_version"] == 1
    assert doc["failed"] is True
    assert doc["summary"] == {"error": 1, "info": 1}
    assert doc["findings"][0]["data"] == {"x": 1}


@pytest.mark.parametrize("shapes", [(8, 4), [(8, 4), (32,)]])
def test_pool_shape_normalization(shapes):
    """pool_eqn_count accepts one shape tuple or an iterable of them."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_utils import pool_eqn_count
    x = jax.ShapeDtypeStruct((8, 4), jnp.float32)
    i = jax.ShapeDtypeStruct((2,), jnp.int32)
    closed = jax.make_jaxpr(lambda x, i: jnp.take(x, i, axis=0))(x, i)
    assert pool_eqn_count(closed, shapes, "gather") == 1

"""The analyzer analyzed: ``repro.analysis`` (ISSUE 9) must catch each
seeded violation class through the real CLI (non-zero exit + structured
JSON finding), and its building blocks (jaxpr walk, VMEM estimator,
purity AST pass, trace-key declaration) must hold on known inputs.

The CLI tests run narrow rule selections so none of them pays for the
full engine-shaped sweeps; the full-repo clean run is CI's
``static-analysis`` job, not a test here.
"""
import json
import os
import subprocess
import sys

import pytest

_HERE = os.path.dirname(__file__)
_SRC = os.path.join(_HERE, "..", "src")
_FIX = os.path.join(_HERE, "fixtures", "analysis")


def _run_cli(*args, json_name="out.json", tmp_path=None):
    out = os.path.join(str(tmp_path), json_name)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args,
         "--json-out", out],
        env={**os.environ, "PYTHONPATH": _SRC}, capture_output=True,
        text=True)
    doc = None
    if os.path.exists(out):
        with open(out) as fh:
            doc = json.load(fh)
    return proc, doc


def _errors(doc, rule):
    return [f for f in doc["findings"]
            if f["rule"] == rule and f["severity"] == "error"]


# ------------------------------------------------------------ CLI, seeded


def test_cli_flags_oversized_kernel(tmp_path):
    """A kernel whose BlockSpec blows the per-core VMEM budget must fail
    the vmem.budget rule through the CLI."""
    proc, doc = _run_cli(
        "--rules", "vmem.budget", "--configs", "llama31_8b",
        "--vmem-extra", os.path.join(_FIX, "bad_kernel.py"),
        tmp_path=tmp_path)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert doc["failed"] is True
    hits = _errors(doc, "vmem.budget")
    assert any(f["obj"] == "oversized_copy" for f in hits), doc["findings"]
    (bad,) = [f for f in hits if f["obj"] == "oversized_copy"]
    assert bad["data"]["vmem_bytes"] > 16 * 2**20


def test_cli_flags_poisoned_scheduler(tmp_path):
    """A jax import in the scheduler host layer must fail the purity
    rule, with the offending chain reported."""
    proc, doc = _run_cli(
        "--rules", "purity",
        "--purity-root", os.path.join(_FIX, "poisoned_src"),
        tmp_path=tmp_path)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    hits = _errors(doc, "purity.scheduler-jax-free")
    assert hits and hits[0]["obj"] == "repro.serve.scheduler"
    assert hits[0]["data"]["chain"][-1] == "jax"


def test_cli_flags_pool_gather_step(tmp_path):
    """A step with a pool-shaped gather outside pallas_call must fail
    the jaxpr containment pin."""
    proc, doc = _run_cli(
        "--rules", "jaxpr.extra-entries",
        "--jaxpr-extra", os.path.join(_FIX, "pool_gather_step.py"),
        tmp_path=tmp_path)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    hits = _errors(doc, "jaxpr.extra-entries")
    assert hits and hits[0]["data"]["prim"] == "gather"


def test_cli_purity_clean_on_repo(tmp_path):
    """The shipped tree passes the purity family (exit 0, no errors) —
    the same pass CI runs over all families."""
    proc, doc = _run_cli("--rules", "purity", tmp_path=tmp_path)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert doc["failed"] is False
    assert doc["summary"].get("error", 0) == 0


def test_cli_rejects_unknown_family(tmp_path):
    proc, _ = _run_cli("--rules", "nonsense", tmp_path=tmp_path)
    assert proc.returncode == 2


# ------------------------------------------------------- library pieces


def test_vmem_estimator_flags_oversized_kernel():
    from repro.analysis.vmem import estimate_call
    sys.path.insert(0, _FIX)
    try:
        import bad_kernel
    finally:
        sys.path.pop(0)
    (name, fn, args), = bad_kernel.TRACE_ENTRIES
    (fp,) = estimate_call(fn, *args)
    assert fp.vmem_bytes > 16 * 2**20
    assert fp.double_buffered and fp.grid == (2,)


def test_vmem_estimator_shipped_kernels_fit():
    """In-process version of the budget rule on one config — the zoo
    entries must all lower a pallas_call and fit 16 MiB."""
    from repro.analysis import Context, run_rules
    findings = run_rules(Context(configs=("llama31_8b",)),
                         families=["vmem"])
    errs = [f for f in findings if f.severity == "error"]
    assert not errs, [f.message for f in errs]


def test_purity_layering_poisoned_vs_clean():
    from repro.analysis.purity import run_layering
    bad = run_layering(os.path.join(_FIX, "poisoned_src"))
    assert any(f.rule == "purity.scheduler-jax-free"
               and f.severity == "error" for f in bad)
    clean = run_layering(_SRC)
    assert not [f for f in clean if f.severity == "error"], \
        [f.message for f in clean]


def test_purity_lazy_contract_tracks_function_scope():
    from repro.analysis.purity import check_lazy_import, scan_tree
    tree = scan_tree(_SRC)
    paged = tree["repro.serve.paged"]
    assert not check_lazy_import(paged, "jax", ("init_paged_cache",))
    # the contract bites: pretend the allowance list is empty
    assert check_lazy_import(paged, "jax", ())


def test_pool_eqn_count_and_pallas_walk():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_utils import (count_pallas_calls,
                                            pool_eqn_count)
    pool = jax.ShapeDtypeStruct((8, 4, 2, 2), jnp.float32)
    idx = jax.ShapeDtypeStruct((3,), jnp.int32)

    def gather_in_scan(pool, idx):
        # nested under scan so the recursive walk is exercised
        def body(c, i):
            return c + jnp.take(pool, idx, axis=0).sum(), None
        out, _ = jax.lax.scan(body, jnp.float32(0.0), jnp.arange(2))
        return out

    closed = jax.make_jaxpr(gather_in_scan)(pool, idx)
    assert pool_eqn_count(closed, (8, 4, 2, 2), "gather") >= 1
    assert count_pallas_calls(closed) == 0


def test_declared_trace_keys_cover_buckets():
    from repro.serve.executor import STEP_BUCKETS, declared_trace_keys
    keys = declared_trace_keys()
    for name in STEP_BUCKETS.values():
        assert name in keys and name + "_oracle" in keys
    for legacy in ("prefill", "decode", "prefill_replay"):
        assert legacy in keys and legacy + "_oracle" in keys


def test_findings_json_schema():
    from repro.analysis import Finding, findings_to_json
    doc = json.loads(findings_to_json([
        Finding("vmem.budget", "error", "k", "boom", {"x": 1}),
        Finding("vmem.budget", "info", "k2", "fine"),
    ]))
    assert doc["schema_version"] == 1
    assert doc["failed"] is True
    assert doc["summary"] == {"error": 1, "info": 1}
    assert doc["findings"][0]["data"] == {"x": 1}


# ------------------------------------------------- races/hbm/numerics


@pytest.mark.parametrize("fixture,kind", [
    ("race_write_write", "aliased-raw"),
    ("race_oob_index", "oob"),
    ("race_discontiguous", "out-revisit"),
])
def test_cli_flags_seeded_grid_race(tmp_path, fixture, kind):
    """Each seeded racy grid yields EXACTLY ONE structured finding of
    its hazard class through the real CLI."""
    proc, doc = _run_cli(
        "--rules", "races.extra-entries",
        "--grid-extra", os.path.join(_FIX, fixture + ".py"),
        tmp_path=tmp_path)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    hits = _errors(doc, "races.extra-entries")
    assert len(hits) == 1, doc["findings"]
    assert hits[0]["obj"] == fixture
    assert hits[0]["data"]["kind"] == kind


def test_cli_flags_int8_accumulator(tmp_path):
    """int8×int8 dot_general without preferred_element_type must fail
    the numerics lint with exactly one finding."""
    proc, doc = _run_cli(
        "--rules", "numerics.extra-entries",
        "--numerics-extra", os.path.join(_FIX, "bad_int8_accum.py"),
        tmp_path=tmp_path)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    hits = _errors(doc, "numerics.extra-entries")
    assert len(hits) == 1, doc["findings"]
    assert hits[0]["data"]["kind"] == "int8-accum"


def test_cli_flags_stale_cost_model(tmp_path):
    """A cost formula 10x off its kernel's measured bytes must fail the
    hbm divergence check with exactly one finding."""
    proc, doc = _run_cli(
        "--rules", "hbm.extra-entries",
        "--hbm-extra", os.path.join(_FIX, "stale_cost_model.py"),
        tmp_path=tmp_path)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    hits = _errors(doc, "hbm.extra-entries")
    assert len(hits) == 1, doc["findings"]
    assert hits[0]["obj"] == "stale_cost_model"
    assert hits[0]["data"]["divergence"] > 0.10


def test_cli_baseline_demotes_known_error(tmp_path):
    """A (rule, obj) suppression in the baseline turns the error into a
    tracked warning: exit 0, finding kept with data.baselined."""
    base = os.path.join(str(tmp_path), "baseline.json")
    with open(base, "w") as fh:
        json.dump({"suppressions": [
            {"rule": "races.extra-entries", "obj": "race_oob_index",
             "reason": "tracked for the test"}]}, fh)
    proc, doc = _run_cli(
        "--rules", "races.extra-entries",
        "--grid-extra", os.path.join(_FIX, "race_oob_index.py"),
        "--baseline", base, tmp_path=tmp_path)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert doc["failed"] is False
    warns = [f for f in doc["findings"]
             if f["rule"] == "races.extra-entries"
             and f["severity"] == "warning"]
    assert len(warns) == 1 and warns[0]["data"]["baselined"] is True


def test_cli_severity_filters_report_not_exit(tmp_path):
    """--severity error hides info rows from the report; errors still
    fail and a clean run still exits 0."""
    proc, doc = _run_cli(
        "--rules", "hbm.doc-sync", "--severity", "error",
        tmp_path=tmp_path)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "[INFO " not in proc.stdout
    assert doc["summary"].get("info", 0) >= 1  # JSON keeps everything


def test_cli_rule_globs(tmp_path):
    """fnmatch globs select rules; a glob matching nothing is a usage
    error (a typo must not silently select zero checks)."""
    proc, doc = _run_cli(
        "--rules", "races.extra-*",
        "--grid-extra", os.path.join(_FIX, "race_oob_index.py"),
        tmp_path=tmp_path)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert _errors(doc, "races.extra-entries")
    proc, _ = _run_cli("--rules", "races.nomatch*", tmp_path=tmp_path)
    assert proc.returncode == 2


def test_races_coverage_spans_zoo_and_buckets():
    """The races sweep enumerates every kernel-zoo entry point AND every
    STEP_BUCKETS step program — the coverage counts are part of the
    contract, so a silently skipped kernel breaks this test."""
    from repro.analysis import Context
    from repro.analysis.grid_eval import (rule_races_kernel_zoo,
                                          rule_races_step_buckets)
    from repro.analysis.vmem import kernel_zoo_entries
    from repro.configs.base import get_smoke_config
    from repro.serve.executor import STEP_BUCKETS

    ctx = Context()
    zoo = rule_races_kernel_zoo(ctx)
    assert not [f for f in zoo if f.severity == "error"], \
        [f.message for f in zoo]
    (cov,) = [f for f in zoo if f.severity == "info"
              and "coverage" in f.data]
    required = {name for name, _ in
                kernel_zoo_entries(get_smoke_config(ctx.arch))}
    assert set(cov.data["coverage"]) == required
    assert all(n >= 1 for n in cov.data["coverage"].values())

    buckets = rule_races_step_buckets(ctx)
    assert not [f for f in buckets if f.severity == "error"], \
        [f.message for f in buckets]
    (bcov,) = [f for f in buckets if f.severity == "info"]
    assert set(bcov.data["coverage"]) == set(STEP_BUCKETS.values())
    assert all(n >= 1 for n in bcov.data["coverage"].values())


def test_grid_eval_sentinel_exemption():
    """The scatter kernel's parked steps (sentinel row) are exempt; the
    legacy park-on-live-block remap is precisely what gets flagged (the
    race_write_write fixture covers the flagged side)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.grid_eval import (check_grid, eval_pallas_eqn,
                                          trace_and_collect)
    from repro.kernels.paged_attention import paged_kv_scatter_pallas
    from repro.serve.paged import device_pool_rows

    bs, mb, nb, hkv, hd, t = 8, 8, 16, 2, 16, 16
    pool = jnp.zeros((device_pool_rows(nb), bs, hkv, hd), jnp.float32)
    tab = np.full((2, mb), -1, np.int32)
    tab[0, :2] = [1, 2]
    tab[1, 1:4] = [5, 6, 7]
    knew = jnp.zeros((2, t, hkv, hd), jnp.float32)
    calls = trace_and_collect(
        lambda *a: paged_kv_scatter_pallas(*a, interpret=True),
        knew, knew, pool, pool, jnp.asarray(tab),
        jnp.asarray([0, 12], jnp.int32), jnp.asarray([t, t], jnp.int32))
    assert len(calls) == 1
    ge = eval_pallas_eqn(calls[0].eqn, calls[0].invals)
    assert not isinstance(ge, str), ge
    issues = check_grid(ge)
    assert not [i for i in issues if not i.get("info")], issues
    # row 0's chunk [0,16) spans 3 logical steps but only 2 allocated
    # blocks — the third parks on the sentinel and is reported as info
    assert any(i["kind"] == "sentinel-parked" for i in issues)


def test_hbm_measured_matches_cost_model():
    """In-process version of hbm.cost-model: zero errors, and every
    COST_MODEL entry was exercised."""
    from repro.analysis import Context
    from repro.analysis.hbm import rule_hbm_cost_model
    from repro.kernels import COST_MODEL

    findings = rule_hbm_cost_model(Context())
    errs = [f for f in findings if f.severity == "error"]
    assert not errs, [f.message for f in errs]
    checked = {f.obj for f in findings if f.severity == "info"}
    assert checked == set(COST_MODEL)


@pytest.mark.parametrize("shapes", [(8, 4), [(8, 4), (32,)]])
def test_pool_shape_normalization(shapes):
    """pool_eqn_count accepts one shape tuple or an iterable of them."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_utils import pool_eqn_count
    x = jax.ShapeDtypeStruct((8, 4), jnp.float32)
    i = jax.ShapeDtypeStruct((2,), jnp.int32)
    closed = jax.make_jaxpr(lambda x, i: jnp.take(x, i, axis=0))(x, i)
    assert pool_eqn_count(closed, shapes, "gather") == 1

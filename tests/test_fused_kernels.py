"""Fused Pallas sparse-projection kernels vs the jnp oracles.

Covers the ISSUE-1 kernel family: ``nm_prune_matmul`` (score + N:M select +
mask + GEMM in one pallas_call), ``osparse_matmul`` (the Outstanding-sparse
smooth→prune→int8→GEMM→dequant chain), the k-blocked ``nm_spmm``, the
padding fallback in ``kernels.ops``, and the dispatch layer
(``use_pallas_kernels`` on the policy → exactly one pallas_call per
projection, jnp fallback for ``layer_flag`` models).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.policy import SparsityPolicy
from repro.core.pruner import SCALE_KEY, sparse_matmul
from repro.kernels import ops, ref
from repro.kernels.nm_spmm import nm_spmm_pallas
from repro.layers.linear import sparse_linear

PATTERNS = [(2, 4), (4, 8), (8, 16)]
DTYPES = [jnp.float32, jnp.bfloat16]
# (t, d, n_out): the last two force the token/odd-shape padding fallback
SHAPES = [(32, 64, 48), (128, 256, 128), (97, 160, 100), (33, 96, 130)]


def _tol(dtype):
    return dict(rtol=5e-2, atol=5e-1) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=1e-3)


def _policy(n, m, **kw):
    return SparsityPolicy(n=n, m=m, score_mode="naive", skip_modules=(),
                          skip_layers={}, **kw)


# --------------------------------------------------------- nm_prune_matmul

@pytest.mark.parametrize("t,d,no", SHAPES)
@pytest.mark.parametrize("n,m", PATTERNS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_nm_prune_matmul_parity(t, d, no, n, m, dtype, rng):
    if d % m:
        pytest.skip(f"d={d} not a multiple of m={m}")
    k1, k2, k3 = jax.random.split(rng, 3)
    x = jax.random.normal(k1, (t, d), dtype=dtype)
    w = jax.random.normal(k2, (d, no), dtype=dtype)
    scale = jax.random.uniform(k3, (d,)) + 0.5
    got = ops.nm_prune_matmul(x, w, scale, n, m)
    want = ref.nm_prune_matmul_ref(x, w, scale, n, m)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_nm_prune_matmul_no_scale_batched(rng):
    x = jax.random.normal(rng, (2, 16, 128))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (128, 64))
    got = ops.nm_prune_matmul(x, w, None, 4, 8)
    want = ref.nm_prune_matmul_ref(x.reshape(32, 128), w, None, 4, 8)
    np.testing.assert_allclose(np.asarray(got).reshape(32, 64),
                               np.asarray(want), rtol=2e-5, atol=1e-3)


def test_nm_prune_matmul_kblock_invariance(rng):
    """Per-token selection is local to each M-group → k-blocking is exact."""
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (64, 512))
    w = jax.random.normal(k2, (512, 128))
    a = ops.nm_prune_matmul(x, w, None, 8, 16, block_k=128)
    b = ops.nm_prune_matmul(x, w, None, 8, 16, block_k=512)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-4)


# ---------------------------------------------------------- osparse_matmul

@pytest.mark.parametrize("t,d,no", [(32, 64, 48), (97, 160, 100)])
@pytest.mark.parametrize("n,m", PATTERNS)
@pytest.mark.parametrize("per_token", [False, True])
def test_osparse_matmul_parity(t, d, no, n, m, per_token, rng):
    if d % m:
        pytest.skip(f"d={d} not a multiple of m={m}")
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    x = jax.random.normal(k1, (t, d))
    w = jax.random.normal(k2, (d, no))
    smooth = jax.random.uniform(k3, (d,)) + 0.5
    amber = jax.random.uniform(k4, (d,)) + 0.5
    wq, w_scale = quant.quantize_weight_per_channel(w)
    act_scale = None if per_token else jnp.float32(0.05)
    got = ops.osparse_matmul(x, wq, smooth, amber, w_scale, n, m,
                             act_scale=act_scale, per_token=per_token)
    want = ref.osparse_matmul_ref(x, wq, smooth, amber, w_scale, n, m,
                                  act_scale=act_scale, per_token=per_token)
    # int32 partial sums commute → bit-equal up to f32 dequant rounding
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


def test_osparse_per_tensor_requires_scale(rng):
    x = jax.random.normal(rng, (8, 32))
    wq = jnp.ones((32, 16), jnp.int8)
    with pytest.raises(ValueError):
        ops.osparse_matmul(x, wq, jnp.ones((32,)), None, jnp.ones((16,)),
                           2, 4, act_scale=None, per_token=False)


# ----------------------------------------------------- k-blocked nm_spmm

@pytest.mark.parametrize("dtype", DTYPES)
def test_nm_spmm_kblock_matches_single_block(dtype, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    x = jax.random.normal(k1, (64, 512), dtype=dtype)
    w = jax.random.normal(k2, (512, 128), dtype=dtype)
    scale = jax.random.uniform(k3, (512,)) + 0.5
    blocked = nm_spmm_pallas(x, w, scale, 4, 8, block_t=32, block_o=64,
                             block_k=128)
    single = nm_spmm_pallas(x, w, scale, 4, 8, block_t=32, block_o=64,
                            block_k=512)
    tol = dict(rtol=5e-2, atol=5e-1) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(blocked, np.float32),
                               np.asarray(single, np.float32), **tol)


def test_nm_spmm_d16384_tiles(rng):
    """Reduction depth the seed kernel's full-D BlockSpec could not tile:
    VMEM residency is now per k-block, so D = 16384 runs with bk = 2048."""
    d = 16384
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (8, d))
    w = jax.random.normal(k2, (d, 128)) * d**-0.5
    got = ops.nm_spmm(x, w, None, 8, 16, tile=8, block_k=2048)
    want = ref.nm_spmm_ref(x, w, None, 8, 16, tile=8)
    assert got.shape == (8, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-2)


# ----------------------------------------------- ops padding / divisor fix

def test_largest_divisor_never_returns_non_divisor():
    # seed bug: total=80, multiple_of=32 → returned 32, which 80 % 32 != 0
    assert ops._largest_divisor(80, 512, multiple_of=32) is None
    assert ops._largest_divisor(96, 512, multiple_of=16) == 96
    assert ops._largest_divisor(7, 256) == 7


def test_block_and_pad_covers_awkward_axes():
    for total, target, mult in [(80, 512, 32), (997, 256, 1), (7, 256, 1),
                                (300, 256, 1), (96, 512, 16)]:
        block, padded = ops._block_and_pad(total, target, mult)
        assert padded >= total and padded % block == 0
        assert block % mult == 0 and block <= max(target, mult)


def test_ops_wrappers_survive_padding_shapes(rng):
    """Shapes with no valid block divisor used to trip the shape asserts."""
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (97, 96))          # 97 prime tokens
    got = ops.nm_prune(x, None, 8, 32, block_d=64)   # no divisor mult of 32
    want = ref.nm_prune_ref(x, None, 8, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)

    xq = jax.random.randint(k1, (33, 80), -127, 128).astype(jnp.int8)
    wq = jax.random.randint(k2, (80, 130), -127, 128).astype(jnp.int8)
    ws = jax.random.uniform(k2, (130,)) * 0.02
    got = ops.w8a8_matmul(xq, wq, jnp.float32(0.01), ws)
    want = ref.w8a8_matmul_ref(xq, wq, jnp.float32(0.01), ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ----------------------------------------------------------- dispatch layer

from repro.analysis.jaxpr_utils import (  # noqa: E402
    count_pallas_calls as _count_pallas_calls)


def test_sparse_linear_lowers_to_single_pallas_call(rng):
    """ISSUE-1 acceptance: with use_pallas_kernels=True a per-token sparse
    projection is ONE fused pallas_call — no separate nm_prune pass."""
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (32, 128))
    p = {"w": jax.random.normal(k2, (128, 64))}
    pol = _policy(8, 16, use_pallas_kernels=True)

    fn = lambda x, w: sparse_linear(x, {"w": w}, "down_proj", pol, "prefill")
    closed = jax.make_jaxpr(fn)(x, p["w"])
    assert _count_pallas_calls(closed.jaxpr) == 1

    # jnp oracle path stays pallas-free
    pol_jnp = _policy(8, 16)
    fn2 = lambda x, w: sparse_linear(x, {"w": w}, "down_proj", pol_jnp,
                                     "prefill")
    assert _count_pallas_calls(jax.make_jaxpr(fn2)(x, p["w"]).jaxpr) == 0


def test_quantized_sparse_linear_single_pallas_call(rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    x = jax.random.normal(k1, (16, 64))
    w = jax.random.normal(k2, (64, 32))
    wq, w_scale = quant.quantize_weight_per_channel(w)
    p = {"wq": wq, "w_scale": w_scale,
         "smooth": jax.random.uniform(k3, (64,)) + 0.5,
         "act_scale": jnp.float32(0.05)}
    pol = _policy(4, 8, use_pallas_kernels=True)
    fn = lambda x: sparse_linear(x, p, "q_proj", pol, "prefill")
    assert _count_pallas_calls(jax.make_jaxpr(fn)(x).jaxpr) == 1


@pytest.mark.parametrize("tile_consensus", [False, True])
def test_sparse_matmul_dispatch_parity(tile_consensus, rng):
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (64, 128))
    w = jax.random.normal(k2, (128, 96))
    pol = _policy(4, 8, tile_consensus=tile_consensus, tile_size=32)
    want = sparse_matmul(x, w, None, pol)
    got = sparse_matmul(x, w, None, pol.with_(use_pallas_kernels=True))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


def test_nm_spmm_consensus_tile_is_semantic(rng):
    """Token counts not divisible by tile_size must not shrink the
    consensus tile (regression: bt=150 divisor vs the oracle's padded
    256-token tiles silently changed which tokens vote in each pool)."""
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (300, 64))
    w = jax.random.normal(k2, (64, 32))
    pol = _policy(2, 4, tile_consensus=True, tile_size=256)
    want = sparse_matmul(x, w, None, pol)
    got = sparse_matmul(x, w, None, pol.with_(use_pallas_kernels=True))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


def test_tile_consensus_honors_layer_flag(rng):
    """tile_consensus + layer_flag: flagged-off layers must stay dense,
    and the flag path must stay on the jnp fallback (no pallas_call)."""
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (32, 64))
    p = {"w": jax.random.normal(k2, (64, 32))}
    pol = _policy(2, 4, tile_consensus=True, tile_size=16,
                  use_pallas_kernels=True)
    dense = x @ p["w"]
    sparse = sparse_matmul(x, p["w"], None,
                           pol.with_(use_pallas_kernels=False))
    got_off = sparse_linear(x, p, "down_proj", pol, "prefill",
                            layer_flag=jnp.array(False))
    got_on = sparse_linear(x, p, "down_proj", pol, "prefill",
                           layer_flag=jnp.array(True))
    np.testing.assert_allclose(np.asarray(got_off), np.asarray(dense),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_on), np.asarray(sparse),
                               rtol=1e-6, atol=1e-6)
    fn = lambda x: sparse_linear(x, p, "down_proj", pol, "prefill",
                                 layer_flag=jnp.array(True))
    assert _count_pallas_calls(jax.make_jaxpr(fn)(x).jaxpr) == 0


def test_sparse_linear_pallas_matches_jnp_end_to_end(rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    x = jax.random.normal(k1, (33, 96))          # padding path too
    p = {"w": jax.random.normal(k2, (96, 100)),
         "b": jax.random.normal(k3, (100,)),
         SCALE_KEY: jax.random.uniform(k3, (96,)) + 0.5}
    pol = _policy(8, 16)
    want = sparse_linear(x, p, "down_proj", pol, "prefill")
    got = sparse_linear(x, p, "down_proj",
                        pol.with_(use_pallas_kernels=True), "prefill")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


def test_quantized_sparse_linear_pallas_matches_jnp(rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    x = jax.random.normal(k1, (16, 64))
    w = jax.random.normal(k2, (64, 32))
    wq, w_scale = quant.quantize_weight_per_channel(w)
    base = {"wq": wq, "w_scale": w_scale,
            "smooth": jax.random.uniform(k3, (64,)) + 0.5,
            "act_scale": jnp.float32(0.05),
            SCALE_KEY: jax.random.uniform(k3, (64,)) + 0.5}
    pol = _policy(4, 8)
    for extra in ({}, {"per_token": True}):
        p = dict(base, **extra)
        want = sparse_linear(x, p, "q_proj", pol, "prefill")
        got = sparse_linear(x, p, "q_proj",
                            pol.with_(use_pallas_kernels=True), "prefill")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_layer_flag_models_fall_back_to_mask_select(rng):
    """Scan-stacked models need pruned-vs-dense *input* selection; the fused
    GEMM can't express that, so the jnp mask form must be used (and agree)."""
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (16, 64))
    p = {"w": jax.random.normal(k2, (64, 32))}
    pol = _policy(4, 8, use_pallas_kernels=True)
    for flag in (jnp.array(True), jnp.array(False)):
        got = sparse_linear(x, p, "down_proj", pol, "prefill",
                            layer_flag=flag)
        want = sparse_linear(x, p, "down_proj", _policy(4, 8), "prefill",
                             layer_flag=flag)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)
        # the flag path must not lower any pallas_call
        fn = lambda x: sparse_linear(x, p, "down_proj", pol, "prefill",
                                     layer_flag=flag)
        assert _count_pallas_calls(jax.make_jaxpr(fn)(x).jaxpr) == 0

"""Integration extras: flash-attention model path, MoE capacity semantics,
straggler hook."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.policy import DENSE
from repro.launch.mesh import make_mesh_auto
from repro.models import build_model


def test_flash_attn_impl_matches_chunked(rng):
    """Model forward with the Pallas flash kernel == chunked-jnp path."""
    base = dataclasses.replace(get_smoke_config("stablelm_3b"),
                               dtype="float32", attn_chunk=16)
    cfg_flash = dataclasses.replace(base, attn_impl="flash")
    m1, m2 = build_model(base), build_model(cfg_flash)
    params = m1.init(rng)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          base.vocab_size)}
    y1 = m1.forward(params, batch, policy=DENSE, phase="prefill")
    y2 = m2.forward(params, batch, policy=DENSE, phase="prefill")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_matches_ragged_when_ample(rng):
    """The fixed-capacity shard_map dispatch must agree with the local
    ragged_dot path when no tokens are dropped (ample capacity)."""
    from repro.models import moe as moe_mod

    cfg = dataclasses.replace(get_smoke_config("mixtral_8x7b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                          cfg.vocab_size)}
    y_local = model.forward(params, batch, policy=DENSE, phase="prefill")

    # route through the shard_map body on a 1×1 mesh (capacity path)
    mesh = make_mesh_auto((1, 1), ("data", "model"))
    with mesh:
        y_sm = model.forward(params, batch, policy=DENSE, phase="prefill")
    # capacity = 1.25× mean load; random routing at B*T=32 tokens over 4
    # experts can exceed it → allow small deviation on dropped tokens
    rel = float(jnp.linalg.norm(y_sm - y_local) /
                (jnp.linalg.norm(y_local) + 1e-9))
    assert rel < 0.15, rel


def test_moe_capacity_drops_are_bounded(rng):
    """With adversarially-imbalanced routing, drops must only ever REMOVE
    expert contributions (never corrupt them)."""
    from repro.core.policy import DENSE
    from repro.models.moe import _moe_local

    d, f, e, t = 16, 32, 4, 64
    k1, k2 = jax.random.split(rng)
    p = {
        "router": {"w": jnp.zeros((d, e)).at[:, 0].set(10.0)},  # all → e0
        "experts": {
            "gate_proj": {"w": jax.random.normal(k1, (e, d, f)) * 0.1},
            "up_proj": {"w": jax.random.normal(k2, (e, d, f)) * 0.1},
            "down_proj": {"w": jax.random.normal(k1, (e, f, d)) * 0.1},
        },
    }
    x = jax.random.normal(k2, (t, d))
    y = _moe_local(x, p, DENSE, "prefill", top_k=1)
    assert jnp.all(jnp.isfinite(y))


def test_straggler_watermark():
    from repro.train.trainer import Trainer, TrainerConfig

    t = Trainer.__new__(Trainer)
    t.cfg = TrainerConfig(straggler_factor=2.0)
    t._times = []
    flags = [t._straggler(dt) for dt in [1.0] * 10 + [5.0]]
    assert not any(flags[:10])
    assert flags[10]  # 5× median flagged

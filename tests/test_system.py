"""End-to-end behaviour tests: the full Amber Pruner deployment pipeline
(offline scale precompute → sensitivity-driven skip selection → sparse
prefill serving → Outstanding-sparse quantization), on a reduced model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core import quant, sensitivity
from repro.core.policy import DENSE, naive_policy, paper_policy
from repro.core.pruner import precompute_scales
from repro.models import build_model
from repro.serve.engine import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def deployed():
    cfg = dataclasses.replace(get_smoke_config("llama31_8b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _fidelity(model, params, batch, policy):
    dense = model.forward(params, batch, policy=DENSE, phase="prefill")
    sparse = model.forward(params, batch, policy=policy, phase="prefill")
    return float(sensitivity.relative_perturbation(dense, sparse))


def test_pipeline_amber_beats_naive(deployed):
    """The paper's headline ordering: Amber-P (scoring + layer skipping)
    must have lower output perturbation than Naïve top-k, per ratio."""
    cfg, model, params = deployed
    params_s = precompute_scales(params, paper_policy(8, 16))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    for n, m in [(2, 4), (4, 8), (8, 16)]:
        e_naive = _fidelity(model, params, batch, naive_policy(n, m))
        e_amber = _fidelity(model, params_s, batch,
                            paper_policy(n, m, cfg.qgate_skip_layers))
        assert e_amber < e_naive, (n, m, e_amber, e_naive)


def test_pipeline_monotone_in_m(deployed):
    """2:4 must hurt more than 4:8 than 8:16 (paper finding)."""
    cfg, model, params = deployed
    params_s = precompute_scales(params, paper_policy(8, 16))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                          cfg.vocab_size)}
    errs = [
        _fidelity(model, params_s, batch,
                  paper_policy(n, m, cfg.qgate_skip_layers))
        for n, m in [(2, 4), (4, 8), (8, 16)]
    ]
    assert errs[0] > errs[2]  # 2:4 worse than 8:16


def test_outstanding_sparse_stacks_with_pruning(deployed):
    """W8A8 + Amber must stay close to the W8A8 baseline (paper: sparsity,
    not quantization, is the accuracy bottleneck)."""
    cfg, model, params = deployed
    x = jax.random.normal(jax.random.PRNGKey(3), (64, cfg.d_model))
    w = params["periods"]["b0"]["mlp"]["down_proj"]["w"][0]
    am = jnp.max(jnp.abs(x), axis=0)
    ql = quant.make_quantized_linear(
        w[: cfg.d_model, :] if w.shape[0] != cfg.d_model else w, am,
        quant.QuantConfig(alpha=0.10, outstanding=True))
    dense = x @ (w[: cfg.d_model] if w.shape[0] != cfg.d_model else w)
    yq = ql(x)
    rel = float(jnp.linalg.norm(yq - dense) / jnp.linalg.norm(dense))
    assert rel < 0.1


def test_generation_stability_under_sparse_prefill(deployed):
    """Paper Table 3 claim: sparse prefill does not destroy generation —
    the KV cache perturbation stays bounded (logit distance, greedy path)."""
    cfg, model, params = deployed
    params_s = precompute_scales(params, paper_policy(8, 16))
    engine_d = ServingEngine(model, DENSE, ServeConfig(max_seq=64))
    engine_s = ServingEngine(model, paper_policy(8, 16,
                                                 cfg.qgate_skip_layers),
                             ServeConfig(max_seq=64))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (4, 24), 0,
                                          cfg.vocab_size)}
    out_d = engine_d.generate(params_s, batch, max_new_tokens=8)
    out_s = engine_s.generate(params_s, batch, max_new_tokens=8)
    assert out_d["tokens"].shape == out_s["tokens"].shape == (4, 8)
    # both must be valid token ids
    for o in (out_d, out_s):
        assert int(o["tokens"].min()) >= 0
        assert int(o["tokens"].max()) < cfg.vocab_size

"""One-dispatch iterations (ISSUE 7): the fused hybrid step program.

Covers the tentpole contract and its satellites:

  * ``_dyadic_sizes`` properties — non-increasing powers of two ≤ cap that
    sum exactly to the requested length, and the empty ladder for a zero
    remainder (the infinite-loop / IndexError bugfix).
  * Token-identity: the fused one-dispatch engine matches both the legacy
    two-program split AND the one-shot oracle across staggered bucket
    shapes, with ``dispatches_per_iteration == 1`` on clean fused runs.
  * Compile discipline: exactly one step program per phase-presence
    bucket, and with kernels on the step program's jaxpr carries ZERO
    pool-shaped gathers or scatters outside a ``pallas_call`` (the KV
    scatter moved in-kernel; the jnp oracle keeps both, so the pin bites).
  * Chaos: seeds 0-2 stay green with the fused step enabled.
  * Latency report (bugfix): ``arrival_time`` is stamped unconditionally,
    so no terminal request — finished, cancelled, or timed out — reports
    the garbage ``-1.0`` default through the ``--trace`` latency report.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.configs.base import get_smoke_config
from repro.core.policy import DENSE, paper_policy
from repro.core.pruner import precompute_scales
from repro.models import build_model
from repro.serve import (ContinuousConfig, ContinuousServingEngine,
                         ServeConfig, ServingEngine)
from repro.serve.continuous import _TERMINAL, _dyadic_sizes
from repro.serve.faults import FaultInjector, FaultSpec

MAX_SEQ = 64


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_smoke_config("llama31_8b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens, seed0=700):
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(seed0 + i),
                                          (l,), 0, cfg.vocab_size))
            for i, l in enumerate(lens)]


def _oracle(model, params, policy, prompt, max_new):
    eng = ServingEngine(model, policy, ServeConfig(max_seq=MAX_SEQ))
    out = eng.generate(params, {"tokens": jnp.asarray(prompt)[None, :]},
                       max_new_tokens=max_new)
    return np.asarray(out["tokens"])[0].tolist()


def _serve(model, policy, params, prompts, arrivals, max_new, *,
           fused, **kw):
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("num_slots", 2)
    kw.setdefault("chunk_size", 8)
    kw.setdefault("block_size", 4)
    kw.setdefault("validate_pool", True)
    eng = ContinuousServingEngine(model, policy,
                                  ContinuousConfig(fused_step=fused, **kw))
    for p, a, mn in zip(prompts, arrivals, max_new):
        eng.submit(p, max_new_tokens=mn, arrival=a)
    return eng, eng.run(params)


# ------------------------------------------------ dyadic chunk ladder

def test_dyadic_zero_length_is_empty():
    """The bugfix: a zero/negative remainder terminates with an empty
    ladder instead of spinning the halving loop forever."""
    assert _dyadic_sizes(0, 16) == []
    assert _dyadic_sizes(-3, 16) == []
    assert _dyadic_sizes(0, 1) == []


def test_dyadic_known_ladders():
    assert _dyadic_sizes(13, 8) == [8, 4, 1]
    assert _dyadic_sizes(8, 8) == [8]
    assert _dyadic_sizes(1, 64) == [1]
    assert _dyadic_sizes(7, 2) == [2, 2, 2, 1]


@settings(max_examples=200, deadline=None)
@given(length=st.integers(min_value=0, max_value=4096),
       cap=st.integers(min_value=1, max_value=512))
def test_dyadic_properties(length, cap):
    """Every ladder: powers of two, ≤ cap, non-increasing, exact sum."""
    sizes = _dyadic_sizes(length, cap)
    assert sum(sizes) == max(length, 0)
    assert all(s & (s - 1) == 0 and 0 < s <= cap for s in sizes)
    assert all(a >= b for a, b in zip(sizes, sizes[1:]))
    assert (sizes == []) == (length <= 0)


# ------------------------------------- fused vs legacy vs one-shot oracle

def test_fused_token_identity_across_buckets(tiny):
    """Staggered mixed-length stream exercising every phase-presence
    bucket (prefill-only, hybrid, decode-only): the fused one-dispatch
    engine is token-identical to the legacy two-program split and to the
    per-request one-shot oracle, at exactly one dispatch per iteration."""
    cfg, model, params = tiny
    lens, arrivals = [9, 27, 14, 33, 21, 12], [0, 0, 2, 4, 5, 8]
    max_new = [12] * len(lens)
    prompts = _prompts(cfg, lens)
    ef, rf = _serve(model, DENSE, params, prompts, arrivals, max_new,
                    fused=True)
    el, rl = _serve(model, DENSE, params, prompts, arrivals, max_new,
                    fused=False)
    assert rf["outputs"] == rl["outputs"]
    for i, p in enumerate(prompts):
        assert rf["outputs"][i] == _oracle(model, params, DENSE, p,
                                           max_new[i]), f"request {i}"
    assert rf["metrics"]["dispatches_per_iteration"] == 1.0
    assert rl["metrics"]["dispatches_per_iteration"] > 1.0
    # all three hybrid buckets actually ran, each compiled exactly once
    assert ef.trace_counts == {"step_prefill": 1, "step_decode": 1,
                               "step_prefill_decode": 1}, ef.trace_counts
    assert el.trace_counts == {"prefill": 1, "decode": 1}, el.trace_counts


def test_fused_token_identity_sparse_prefill_kernels(tiny):
    """Same identity under an Amber-sparse prefill policy with the Pallas
    dispatch ladder on (in-kernel KV scatter + fused projections): fused
    matches the legacy split on the SAME backend."""
    cfg, model, params = tiny
    policy = paper_policy(2, 4, cfg.qgate_skip_layers,
                          use_pallas_kernels=True)
    params = precompute_scales(params, policy)
    lens, arrivals, max_new = [7, 17, 12], [0, 0, 2], [6, 8, 6]
    prompts = _prompts(cfg, lens, seed0=720)
    _, rf = _serve(model, policy, params, prompts, arrivals, max_new,
                   fused=True)
    _, rl = _serve(model, policy, params, prompts, arrivals, max_new,
                   fused=False)
    assert rf["outputs"] == rl["outputs"]
    assert rf["metrics"]["dispatches_per_iteration"] == 1.0


def test_env_override_forces_dispatch_mode(tiny, monkeypatch):
    """REPRO_FUSED_STEP=0/1 overrides the config (the CI chaos matrix
    pins either path without code changes)."""
    cfg, model, params = tiny
    monkeypatch.setenv("REPRO_FUSED_STEP", "0")
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, fused_step=True))
    assert eng.fused_step is False
    monkeypatch.setenv("REPRO_FUSED_STEP", "1")
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, fused_step=False))
    assert eng.fused_step is True


# --------------------------------------------------- jaxpr dispatch pins

from repro.analysis.jaxpr_utils import (  # noqa: E402
    pool_eqn_count as _pool_eqn_count)


def test_step_program_pool_ops_stay_in_kernel(tiny):
    """Acceptance pin: with kernels on, the fused hybrid step program
    (prefill chunk + batched decode in ONE jaxpr) contains zero gathers
    AND zero scatters on pool-shaped KV arrays — both the logical-view
    gather and the host-side flat-index KV scatter moved inside
    pallas_call.  With kernels off the oracle forms are still there, so
    the pin bites."""
    from repro.serve.paged import (device_pool_rows, init_paged_cache,
                                   max_blocks_per_slot)
    cfg, model, params = tiny
    slots, bs = 2, 8
    mb = max_blocks_per_slot(MAX_SEQ, bs)
    nb = slots * mb
    rows = device_pool_rows(nb)
    # the pooled-KV leaves (+1 sentinel row), 4D and as the flat row view
    # the host-side scatter used to write through
    pool_shapes = {(rows, bs, cfg.n_kv_heads, cfg.head_dim),
                   (rows * bs, cfg.n_kv_heads, cfg.head_dim)}

    def jaxpr_for(kernels):
        pol = DENSE.with_(use_pallas_kernels=kernels)
        eng = ContinuousServingEngine(model, pol, ContinuousConfig(
            max_seq=MAX_SEQ, num_slots=slots, chunk_size=8, block_size=bs))
        cache = init_paged_cache(model, slots, MAX_SEQ, bs, nb, eng._spec)
        tab = np.full((slots, mb), -1, np.int32)
        tab[0, :3], tab[1, :3] = [1, 2, 3], [4, 5, 6]
        cache["block_table"] = jnp.asarray(tab)
        cache["pos"] = jnp.asarray([10, 7], jnp.int32)
        step = eng._step_raw[(False, True, True)]   # the hybrid bucket
        args = (params, cache, jnp.asarray(0, jnp.int32),
                jnp.zeros((1, 8), jnp.int32), jnp.asarray(8, jnp.int32),
                {}, jnp.zeros((slots,), jnp.int32),
                jnp.asarray([False, True]), jnp.zeros((2,), jnp.uint32),
                jnp.zeros((2,), jnp.uint32), jnp.float32(0.0))
        return jax.make_jaxpr(step)(*args).jaxpr

    hot = jaxpr_for(True)
    assert _pool_eqn_count(hot, pool_shapes, "gather") == 0
    assert _pool_eqn_count(hot, pool_shapes, "scatter") == 0
    oracle = jaxpr_for(False)
    assert _pool_eqn_count(oracle, pool_shapes, "gather") > 0
    assert _pool_eqn_count(oracle, pool_shapes, "scatter") > 0


# ----------------------------------------------------- chaos, fused path

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_seeds_green_fused(tiny, seed):
    """The CI chaos matrix contract: mixed fault schedule under the fused
    step, seeds 0-2 — surviving outputs match the undisturbed fused run,
    nothing leaks, every request ends terminal."""
    cfg, model, params = tiny
    lens, arrivals, max_new = [11, 18, 7, 13], [0, 1, 2, 4], [7] * 4
    prompts = _prompts(cfg, lens, seed0=740)

    def serve(faults):
        eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
            max_seq=MAX_SEQ, num_slots=2, chunk_size=8, block_size=4,
            validate_pool=True, fused_step=True), faults=faults)
        for p, a, mn in zip(prompts, arrivals, max_new):
            eng.submit(p, max_new_tokens=mn, arrival=a)
        return eng, eng.run(params)

    _, base = serve(None)
    inj = FaultInjector(seed=seed, schedule=[
        FaultSpec("prefill", "nonfinite", p=0.2, limit=3),
        FaultSpec("decode", "nonfinite", p=0.2, limit=3),
        FaultSpec("pool.alloc", "exhausted", p=0.2, limit=3),
    ])
    eng, res = serve(inj)
    assert res["outputs"] == base["outputs"], \
        f"seed {seed}: faults changed tokens"
    assert all(r.state in _TERMINAL for r in eng.requests)
    assert all(not r.blocks and r.slot == -1 for r in eng.requests)
    assert eng.pool.in_use == 0
    deg = res["metrics"]["degraded_iterations"]
    assert deg == sum(1 for f in inj.fired
                      if f["site"] in ("prefill", "decode"))


# --------------------------------------- latency-report bugfix (--trace)

def test_terminal_latency_never_default(tiny):
    """Every terminal request — done, timed out, cancelled — carries a
    real non-negative wall-clock latency_s, including requests admitted
    the same iteration they became visible (previously stamped only while
    still WAITING → the -1.0 default leaked into the report)."""
    cfg, model, params = tiny
    prompts = _prompts(cfg, [9, 14, 40], seed0=760)
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=2, chunk_size=8, block_size=4,
        validate_pool=True, ttl_default=None))
    eng.submit(prompts[0], max_new_tokens=6, arrival=0)
    eng.submit(prompts[1], max_new_tokens=6, arrival=1)
    eng.submit(prompts[2], max_new_tokens=6, arrival=2, ttl=3)  # times out
    rid_cancel = eng.submit(prompts[1], max_new_tokens=6, arrival=3)
    eng.iteration_hook = lambda e, it: (it == 4 and e.cancel(rid_cancel))
    res = eng.run(params)
    states = {r["rid"]: r for r in res["metrics"]["requests"]}
    assert states[2]["state"] == "timed_out"
    assert states[rid_cancel]["state"] == "cancelled"
    for r in res["metrics"]["requests"]:
        assert r["latency_s"] >= 0.0, \
            f"rid {r['rid']} ({r['state']}): garbage latency {r['latency_s']}"


def test_trace_mode_latency_report(capsys):
    """launch.serve --trace end-to-end: exits 0 and the CSV latency column
    contains no -1.0 defaults (the arrival-stamp regression)."""
    from repro.launch.serve import main
    rc = main(["--smoke", "--arch", "llama31_8b", "--trace",
               "--num-requests", "4", "--rate", "0.7", "--len-range",
               "6:20", "--slots", "2", "--chunk", "8", "--new-tokens", "5"])
    out = capsys.readouterr().out
    assert rc == 0
    rows = [l for l in out.splitlines()
            if l and l[0].isdigit()]
    assert rows, out
    for row in rows:
        lat = float(row.split(",")[7])
        assert lat >= 0.0, row
    assert "dispatches" in out and "1.00 per work iteration" in out

"""Seeded-bad fixture: int8×int8 GEMM accumulating in int8.

No ``preferred_element_type`` on the dot_general → the MXU accumulates
in the operand dtype and wraps at ±127 on real hardware; CPU interpret
mode widens internally and hides it.  The ``numerics`` lint must flag
the body with exactly one ``int8-accum`` finding.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _body(x_ref, w_ref, o_ref):
    # BUG (seeded): accumulates in int8 — no preferred_element_type
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())))


def int8_matmul(x, w):
    return pl.pallas_call(
        _body,
        grid=(1,),
        in_specs=[pl.BlockSpec((8, 16), lambda i: (0, 0)),
                  pl.BlockSpec((16, 8), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 8), jnp.int8),
        interpret=True,
    )(x, w)


NUMERICS_ENTRIES = [
    ("bad_int8_accum", int8_matmul,
     (jnp.zeros((8, 16), jnp.int8), jnp.zeros((16, 8), jnp.int8))),
]

"""Known-bad kernel for the vmem.budget rule: a copy kernel whose
BlockSpec keeps a full 4096x4096 f32 operand (64 MiB) resident per grid
step — 256 MiB double-buffered, way past any per-core VMEM budget.
Loaded by ``python -m repro.analysis --vmem-extra`` in the analyzer's
own tests, which assert the rule fires."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SHAPE = (4096, 4096)


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def oversized_copy(x):
    return pl.pallas_call(
        _copy_kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec(_SHAPE, lambda i: (0, 0))],
        out_specs=pl.BlockSpec(_SHAPE, lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(_SHAPE, jnp.float32),
        interpret=True,
    )(x)


TRACE_ENTRIES = [
    ("oversized_copy", oversized_copy,
     (jax.ShapeDtypeStruct(_SHAPE, jnp.float32),)),
]

"""Seeded-bad fixture: aliased in-place update whose index map revisits
a block AFTER the pipeline moved off it.

Grid (3,) maps steps [0, 1, 0]: step 2 re-fetches block 0, which step 0
already wrote through the alias — a refetch-after-write race under
Mosaic pipelining (interpret mode hides it).  The ``races`` checker must
flag the aliased pair with exactly one ``aliased-raw`` finding.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _body(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def racing_update(x):
    return pl.pallas_call(
        _body,
        grid=(3,),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i % 2, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i: (i % 2, 0)),
        out_shape=jax.ShapeDtypeStruct((16, 8), jnp.float32),
        input_output_aliases={0: 0},
        interpret=True,
    )(x)


GRID_ENTRIES = [
    ("race_write_write", racing_update,
     (jnp.zeros((16, 8), jnp.float32),)),
]

"""Fixture: a scheduler that re-couples the host layer to device state.
The purity.scheduler-jax-free rule must flag this tree."""
import jax  # noqa: F401  — the violation under test

PLANS = []

"""Known-bad step program for the jaxpr pool-containment pin: a
pool-shaped ``jnp.take`` — exactly the O(pool) logical-view gather the
paged-attention kernel exists to eliminate.  Loaded by
``python -m repro.analysis --jaxpr-extra`` in the analyzer's own tests,
which assert the rule fires."""
import jax
import jax.numpy as jnp

POOL_SHAPE = (64, 16, 2, 8)          # (num_blocks, block_size, Hkv, hd)


def gathering_step(pool, idx):
    return jnp.take(pool, idx, axis=0)


JAXPR_ENTRIES = [
    ("pool-gather-step", gathering_step,
     (jax.ShapeDtypeStruct(POOL_SHAPE, jnp.float32),
      jax.ShapeDtypeStruct((4,), jnp.int32)),
     {POOL_SHAPE}),
]

"""Seeded-bad fixture: index map computes a block index past the array.

The input has 2 row-blocks but the map yields ``i * 2`` → step 1 asks
for block 2.  Pallas clamps out-of-bounds indices silently, so at
runtime this reads the WRONG block instead of failing — the ``races``
checker must flag it with exactly one ``oob`` finding.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _body(x_ref, o_ref):
    o_ref[...] = x_ref[...] + 1.0


def oob_read(x):
    return pl.pallas_call(
        _body,
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i * 2, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((16, 8), jnp.float32),
        interpret=True,
    )(x)


GRID_ENTRIES = [
    ("race_oob_index", oob_read, (jnp.zeros((16, 8), jnp.float32),)),
]

"""Seeded-bad fixture: a COST_MODEL entry that drifted from its kernel.

The kernel moves ``2 * 16 * 8 * 4`` bytes (one fetch + one write of a
(16, 8) f32 array in a single-step grid); the documented formula claims
10x that.  The ``hbm`` cost-model check must flag it with exactly one
divergence finding.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _body(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def tiny_scale(x):
    return pl.pallas_call(
        _body,
        grid=(1,),
        in_specs=[pl.BlockSpec((16, 8), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((16, 8), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((16, 8), jnp.float32),
        interpret=True,
    )(x)


def _stale_bytes(dims):
    # BUG (seeded): stale formula — 10x the kernel's actual traffic
    return 10 * 2 * dims["t"] * dims["d"] * 4


COST_ENTRIES = [
    ("stale_cost_model", tiny_scale, (jnp.zeros((16, 8), jnp.float32),),
     _stale_bytes, {"t": 16, "d": 8}),
]

"""Seeded-bad fixture: output block revisited discontiguously.

Grid (4,) writes output blocks [0, 1, 0, 1]: Mosaic writes a block back
when the index CHANGES, so block 0's step-0 contribution is flushed
before step 2 revisits it — the revisit starts from a stale VMEM copy
(write-after-write).  Interpret mode reuses one buffer and hides it.
The ``races`` checker must flag the output with exactly one
``out-revisit`` finding.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _body(x_ref, o_ref):
    o_ref[...] = o_ref[...] + x_ref[...]


def discontiguous_accumulate(x):
    return pl.pallas_call(
        _body,
        grid=(4,),
        in_specs=[pl.BlockSpec((4, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4, 8), lambda i: (i % 2, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
        interpret=True,
    )(x)


GRID_ENTRIES = [
    ("race_discontiguous", discontiguous_accumulate,
     (jnp.zeros((16, 8), jnp.float32),)),
]

"""Block-level prefix caching across requests (ISSUE 5).

The contract: a refcounted, content-addressed block pool may only ever
change WHEN prefill compute happens, never WHAT any request emits —
greedy outputs stay token-identical to the one-shot engine (and to the
same engine with caching off) while shared-system-prompt traffic skips
the shared blocks' prefill entirely.  Pool invariants: no block is ever
simultaneously writable from two slots, and refcounts drain to a fully
reclaimable pool.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.policy import DENSE, paper_policy
from repro.core.pruner import precompute_scales
from repro.models import build_model
from repro.serve import (ContinuousConfig, ContinuousServingEngine,
                         ServeConfig, ServingEngine)
from repro.serve.paged import BlockPool, chain_block_hashes

MAX_SEQ = 64


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_smoke_config("llama31_8b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _rand_tokens(cfg, n, seed):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                                         cfg.vocab_size), np.int32)


def _oracle(model, params, policy, prompt, max_new):
    eng = ServingEngine(model, policy, ServeConfig(max_seq=MAX_SEQ))
    out = eng.generate(params, {"tokens": jnp.asarray(prompt)[None, :]},
                       max_new_tokens=max_new)
    return np.asarray(out["tokens"])[0].tolist()


# ----------------------------------------------------------- chain hashes

def test_chain_hashes_address_the_whole_prefix():
    toks = np.arange(40, dtype=np.int32)
    h = chain_block_hashes(toks, 8)
    assert len(h) == 5 and len(set(h)) == 5
    # same block content, different prefix → different hash
    other = toks.copy()
    other[0] += 1
    assert chain_block_hashes(other, 8)[3] != h[3]
    # identical prefix → identical chain, regardless of suffix
    assert chain_block_hashes(toks[:17], 8) == h[:2]


def test_chain_hashes_salt_dense_written_rows():
    """Under a sparse prefill policy, rows a request EMITTED were written
    by the dense program; a different request whose own prompt spans those
    rows would prefill them sparsely, so the per-block dense-row count
    must split the hash space.  Pure-prompt blocks stay shared."""
    toks = np.arange(32, dtype=np.int32)
    a = chain_block_hashes(toks, 8, dense_from=20)   # emitted from row 20
    b = chain_block_hashes(toks, 8, dense_from=None)  # all one path
    assert a[:2] == b[:2], "blocks before the boundary must still match"
    assert a[2] != b[2] and a[3] != b[3]
    # same boundary reproduces the chain (preemption replay re-match)
    assert chain_block_hashes(toks, 8, dense_from=20) == a


# ------------------------------------------------------ BlockPool lifecycle

def test_pool_refcount_and_lru_lifecycle():
    pool = BlockPool(num_blocks=6, block_size=4)
    toks = np.arange(8, dtype=np.int32)
    h = chain_block_hashes(toks, 4)
    a = pool.alloc(2)
    for bid, hh in zip(a, h):
        assert pool.register(bid, hh)
    # a second copy of the same content loses the index race
    dup = pool.alloc(1)
    assert not pool.register(dup[0], h[0])
    assert pool.match(h) == a
    # share with a live holder: refcount 2, still matched
    for bid in pool.match(h):
        pool.acquire_cached(bid)
    assert pool.refcount(a[0]) == 2
    pool.release(a)
    assert pool.refcount(a[0]) == 1 and pool.match(h) == a
    # last ref dropped → parked in the LRU, still matchable, not free
    pool.release(a[::-1])
    assert pool.in_use == 1                      # only dup remains live
    assert pool.cached_blocks == 2 and pool.match(h) == a
    # revive from the LRU
    pool.acquire_cached(a[0])
    assert pool.refcount(a[0]) == 1 and pool.cached_blocks == 1
    pool.release([a[0]])
    # unregistered release goes straight back to the free list
    pool.release(dup)
    assert pool.in_use == 0
    assert pool.available == 6 and pool.cached_blocks == 2
    pool.check_invariants()


def test_pool_evicts_lru_before_reporting_exhaustion():
    pool = BlockPool(num_blocks=4, block_size=2)
    toks = np.arange(8, dtype=np.int32)
    h = chain_block_hashes(toks, 2)
    a = pool.alloc(4)
    for bid, hh in zip(a, h):
        pool.register(bid, hh)
    pool.release(a[::-1])                       # chain head at MRU end
    assert pool.available == 4 and pool.cached_blocks == 4
    # demand 3 blocks: served by evicting the LRU end (deepest blocks),
    # dropping their index entries; the chain head survives and matches
    got = pool.alloc(3)
    assert set(got) == set(a[1:]), "eviction should consume the LRU end"
    assert pool.evictions == 3
    assert pool.match(h) == a[:1]
    assert not pool.is_registered(a[1])
    with pytest.raises(RuntimeError):           # 1 cached + 0 free < 2
        pool.alloc(2)
    pool.check_invariants()


# ----------------------------------------------- engine: shared prefixes

@pytest.mark.parametrize("attn_kernel", [False, True],
                         ids=["gather-oracle", "pallas-kernel"])
def test_shared_system_prompt_skips_prefill_token_identical(
        tiny, attn_kernel, monkeypatch):
    """Acceptance: a shared-system-prompt stream reuses ≥ 1 block per
    following request and skips ≥ 50% of their prompt rows, while greedy
    outputs stay token-identical to BOTH the one-shot engine and the same
    engine with caching off — on the jnp gather oracle AND the Pallas
    block-walk kernel under REPRO_PALLAS_INTERPRET=1."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    cfg, model, params = tiny
    policy = DENSE.with_(use_pallas_kernels=True) if attn_kernel else DENSE
    sysp = _rand_tokens(cfg, 32, seed=70)
    prompts = [np.concatenate([sysp, _rand_tokens(cfg, 6 + i, seed=71 + i)])
               for i in range(4)]
    # staggered so request 0's prompt blocks are published before the rest
    # admit (registration happens as prefill chunks complete)
    arrivals, max_new = [0, 4, 6, 8], 8

    def serve(prefix_cache):
        eng = ContinuousServingEngine(model, policy, ContinuousConfig(
            max_seq=MAX_SEQ, num_slots=3, chunk_size=16, block_size=8,
            prefix_cache=prefix_cache, validate_pool=True))
        for p, a in zip(prompts, arrivals):
            eng.submit(p, max_new_tokens=max_new, arrival=a)
        return eng, eng.run(params)

    eng, res = serve(True)
    _, cold = serve(False)
    assert res["outputs"] == cold["outputs"], "caching changed outputs"
    for i, p in enumerate(prompts):
        assert res["outputs"][i] == _oracle(model, params, DENSE, p,
                                            max_new), f"request {i}"
    pg = res["metrics"]["paged"]
    assert pg["prefix_cache"] and pg["attention_kernel"] is attn_kernel
    assert cold["metrics"]["paged"]["prefix_hits"] == 0
    reqs = {r["rid"]: r for r in res["metrics"]["requests"]}
    for rid in (1, 2, 3):                        # every reusing request hit
        assert reqs[rid]["cached_tokens"] >= 32, reqs[rid]
    assert pg["prefix_hits"] == 3
    assert pg["tokens_skipped"] >= 3 * 32
    # ≥50% of the reusing requests' prompt rows came from the index
    reused_prompt_rows = sum(len(prompts[r]) for r in (1, 2, 3))
    assert pg["tokens_skipped"] / reused_prompt_rows >= 0.5
    assert eng.pool.in_use == 0
    # one step program per phase-presence bucket (fused default)
    assert all(v == 1 for v in eng.trace_counts.values()), eng.trace_counts


def test_preemption_replay_rematches_its_own_blocks(tiny):
    """Preemption-replay is nearly free when the released chain survives:
    the replayed prompt+emitted sequence re-acquires the blocks that were
    just parked in the LRU instead of recomputing them."""
    cfg, model, params = tiny
    # req0 (8-token prompt) decodes long; req1 (40-token prompt) is
    # preempted mid-prefill at full pool commitment (same deterministic
    # geometry as test_preempt_prefill_victim_interleaving); req0's growth
    # is then served from the free list, so req1's chain head survives
    # eviction and its re-admission matches its own blocks
    prompts = [_rand_tokens(cfg, 8, seed=85 + 10),
               _rand_tokens(cfg, 40, seed=85 + 11)]
    arrivals, max_new = [0, 2], [24, 8]
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=2, chunk_size=8, block_size=4,
        num_blocks=13, validate_pool=True))
    for p, a, mn in zip(prompts, arrivals, max_new):
        eng.submit(p, max_new_tokens=mn, arrival=a)
    res = eng.run(params)
    pg = res["metrics"]["paged"]
    assert pg["preemptions"] >= 1, "scenario drifted: no preemption"
    reqs = {r["rid"]: r for r in res["metrics"]["requests"]}
    assert reqs[1]["preemptions"] >= 1
    assert reqs[1]["cached_tokens"] > 0, "replay recomputed everything"
    assert pg["prefix_hits"] >= 1
    for i, p in enumerate(prompts):
        assert res["outputs"][i] == _oracle(model, params, DENSE, p,
                                            max_new[i]), f"request {i}"
    assert eng.pool.in_use == 0


def test_sparse_policy_does_not_share_across_the_emitted_boundary(tiny):
    """Under a sparse prefill policy a request whose prompt happens to
    reproduce another request's prompt+emitted tokens must NOT reuse the
    emitted-region blocks (their KV was dense-written); the salted chain
    hash splits them while pure-prompt blocks still share.  Outputs stay
    oracle-identical either way."""
    cfg, model, params = tiny
    policy = paper_policy(2, 4, cfg.qgate_skip_layers)
    sparams = precompute_scales(params, policy)
    p0 = _rand_tokens(cfg, 16, seed=120)
    eng = ContinuousServingEngine(model, policy, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=2, chunk_size=8, block_size=4,
        validate_pool=True))
    eng.submit(p0, max_new_tokens=8, arrival=0)
    res0 = eng.run(params=sparams)
    # second request's prompt = first's prompt ++ its emitted tokens
    p1 = np.concatenate([p0, np.asarray(res0["outputs"][0], np.int32)])
    eng.clear()                         # rids restart at 0 after clear()
    eng.submit(p1, max_new_tokens=6, arrival=0)
    res1 = eng.run(params=sparams)
    req = res1["metrics"]["requests"][0]
    # pure-prompt blocks (16 tokens = 4 blocks) shared; emitted-region
    # blocks correctly missed under the dense-row salt
    assert req["cached_tokens"] == 16, req
    assert res1["outputs"][0] == _oracle(model, params, policy, p1, 6)


def test_prefix_cache_auto_disabled_for_recurrent_archs():
    """Hybrid/recurrent archs carry scan state cached KV cannot restore —
    prefix caching must stay off even though their attention is paged."""
    cfg = dataclasses.replace(get_smoke_config("recurrentgemma_2b"),
                              dtype="float32")
    model = build_model(cfg)
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=2, chunk_size=8))
    if eng.paged:                       # hybrid: paged attn, no caching
        assert not eng.prefix_cache and not eng.pool.prefix_cache
    else:                               # pure recurrent: no paging at all
        assert eng.pool is None


# --------------------------------------------------- preemption storm

def test_preemption_storm_invariants_and_drain(tiny):
    """Satellite: a pool sized to force repeated preempt/replay cycles
    across ≥3 requests.  validate_pool audits refcount/ownership (incl.
    the no-block-writable-from-two-slots invariant) after EVERY scheduler
    iteration; outputs stay one-shot-identical and the pool drains with
    zero leaked blocks."""
    cfg, model, params = tiny
    lens, arrivals, max_new = [12, 12, 12], [0, 0, 0], [20, 20, 20]
    prompts = [_rand_tokens(cfg, l, seed=130 + i)
               for i, l in enumerate(lens)]
    # each request peaks at blocks_for(32) = 8; 11 blocks cannot carry
    # even two concurrently to completion, so the scheduler must thrash
    # preempt/replay (both younger requests cycle through WAITING)
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=3, chunk_size=8, block_size=4,
        num_blocks=11, validate_pool=True))
    for p, a, mn in zip(prompts, arrivals, max_new):
        eng.submit(p, max_new_tokens=mn, arrival=a)
    res = eng.run(params)
    pg = res["metrics"]["paged"]
    assert pg["preemptions"] >= 3, f"storm too mild: {pg['preemptions']}"
    assert sum(r["preemptions"] > 0
               for r in res["metrics"]["requests"]) >= 2
    for i, p in enumerate(prompts):
        assert res["outputs"][i] == _oracle(model, params, DENSE, p,
                                            max_new[i]), f"request {i}"
    # drained: every reference returned, cached + free cover the pool
    assert eng.pool.in_use == 0
    assert eng.pool.available == eng.pool.num_blocks
    eng.pool.check_invariants()
    # the pool can still hand out every block (nothing leaked/stuck)
    assert len(set(eng.pool.alloc(eng.pool.num_blocks))) == 11


def test_clear_drops_stale_extras_exclusions(tiny):
    """rids restart at 0 after clear(): a modality-extras exclusion from a
    previous stream must not leak onto an unrelated rid-colliding request
    and silently disable its caching."""
    cfg, model, params = tiny
    p = _rand_tokens(cfg, 20, seed=160)
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=2, chunk_size=8, block_size=4,
        validate_pool=True))
    eng.submit(p, max_new_tokens=4)
    res0 = eng.run(params, extras={0: {}})     # rid 0 marked extras-bearing
    assert res0["metrics"]["paged"]["prefix_hits"] == 0
    assert eng.pool.cached_blocks == 0         # excluded: nothing published
    eng.clear()
    eng.submit(p, max_new_tokens=4)            # rid 0 again, no extras now
    res1 = eng.run(params)
    eng.clear()
    eng.submit(p, max_new_tokens=4)
    res2 = eng.run(params)
    assert res2["metrics"]["paged"]["prefix_hits"] == 1, \
        "stale _extra_rids exclusion survived clear()"
    assert res2["outputs"][0] == res1["outputs"][0] == res0["outputs"][0]


def test_prefix_cache_off_matches_legacy_pool_semantics(tiny):
    """With prefix_cache=False released blocks go straight back to the
    free list: no index, no cached blocks, identical outputs."""
    cfg, model, params = tiny
    prompts = [_rand_tokens(cfg, 12, seed=150)]
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=2, chunk_size=8, block_size=4,
        prefix_cache=False, validate_pool=True))
    eng.submit(prompts[0], max_new_tokens=6)
    res = eng.run(params)
    assert not eng.prefix_cache
    assert eng.pool.cached_blocks == 0 and eng.pool.in_use == 0
    assert res["metrics"]["paged"]["prefix_hits"] == 0
    assert res["outputs"][0] == _oracle(model, params, DENSE, prompts[0], 6)


# ------------------------------------ hash-collision hardening (ISSUE 6)

def test_pool_detects_forced_hash_collision():
    """A chain-hash collision between DIFFERENT block contents must never
    share KV: match() verifies the stored (dense_rows, token_bytes) key
    and stops at the first mismatch, counting the collision."""
    from repro.serve.paged import chain_block_keys

    pool = BlockPool(num_blocks=4, block_size=4)
    toks_a = np.arange(8, dtype=np.int32)
    toks_b = np.arange(8, dtype=np.int32) + 100
    keys_a = chain_block_keys(toks_a, 4)
    keys_b = chain_block_keys(toks_b, 4)
    fake_chain = [12345, 67890]                # both contents hash here
    a = pool.alloc(2)
    for bid, h, k in zip(a, fake_chain, keys_a):
        assert pool.register(bid, h, key=k)
    # same content, verified keys → full match, no collision
    assert pool.match(fake_chain, keys=keys_a) == a
    assert pool.hash_collisions == 0
    # different content colliding on the hash → rejected, counted
    assert pool.match(fake_chain, keys=keys_b) == []
    assert pool.hash_collisions == 1
    # a sparse/dense row-split mismatch is content inequality too
    split = chain_block_keys(toks_a, 4, dense_from=2)
    assert pool.match(fake_chain, keys=split) == []
    assert pool.hash_collisions == 2
    # prefix verification is inductive: block 1 only reachable through a
    # verified block 0, so a tail collision truncates the match
    assert pool.match(fake_chain, keys=[keys_a[0], keys_b[1]]) == a[:1]
    pool.check_invariants()


def test_engine_survives_universal_hash_collisions(tiny, monkeypatch):
    """Regression: with chain_block_hashes forced to collide for EVERY
    sequence, the key check must refuse all false sharing — outputs stay
    oracle-identical and the collisions are metered."""
    cfg, model, params = tiny
    monkeypatch.setattr(
        "repro.serve.continuous.chain_block_hashes",
        lambda tokens, bs, n_blocks=None, dense_from=None, start=0, h0=None:
            list(range(start, n_blocks)))
    prompts = [_rand_tokens(cfg, 14, seed=160 + i) for i in range(3)]
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=3, chunk_size=8, block_size=4,
        validate_pool=True))
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=6, arrival=i)
    res = eng.run(params)
    assert eng.pool.hash_collisions >= 1, \
        "forced collisions never reached the key check"
    for i, p in enumerate(prompts):
        assert res["outputs"][i] == _oracle(model, params, DENSE, p, 6), \
            f"request {i} shared a colliding block"
    assert eng.pool.in_use == 0


def test_block_size_folded_into_chain_seed():
    """Identical tokens hashed at different block sizes must not collide
    structurally: the chain seed folds the block geometry."""
    toks = np.arange(32, dtype=np.int32)
    h4 = chain_block_hashes(toks, 4)
    h8 = chain_block_hashes(toks, 8)
    assert set(h4).isdisjoint(h8)

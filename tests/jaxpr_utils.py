"""Shared jaxpr traversal for dispatch-layer assertions.

Several suites assert what a traced program *lowers to* (exactly one
pallas_call, zero pool-view gathers, ...).  They all need the same
recursive walk over sub-jaxprs (scan / pjit / remat / custom_vjp carry
their bodies in eqn params), so the walk lives here once — jax API drift
in jaxpr internals (this repo already shims 0.4.37 drift elsewhere) then
has a single place to land.
"""


def iter_eqns(jaxpr):
    """Yield every equation in ``jaxpr`` and, recursively, in any jaxpr
    nested inside equation params (ClosedJaxpr, Jaxpr, or lists thereof)."""
    def sub(v):
        if hasattr(v, "jaxpr"):              # ClosedJaxpr
            return [v.jaxpr]
        if hasattr(v, "eqns"):               # Jaxpr
            return [v]
        if isinstance(v, (tuple, list)):
            out = []
            for item in v:
                out.extend(sub(item))
            return out
        return []

    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for j in sub(v):
                yield from iter_eqns(j)

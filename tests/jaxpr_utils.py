"""Thin re-export shim: the jaxpr traversal library moved into the
analysis subsystem (``repro.analysis.jaxpr_utils``, ISSUE 9) so the
contract checker and the test suites share one walk.  Keep importing
from here in tests; add new helpers THERE, not here."""
from repro.analysis.jaxpr_utils import (  # noqa: F401
    count_pallas_calls,
    eqn_dtypes,
    has_pallas_call,
    iter_eqns,
    pallas_call_eqns,
    pool_eqn_count,
)

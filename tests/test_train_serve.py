"""Training convergence, grad-accum equivalence, gradient compression,
serving engine behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.policy import DENSE, paper_policy
from repro.data.pipeline import DataConfig, lm_batch
from repro.distributed.compression import ef_int8_compress, ef_int8_init
from repro.models import build_model
from repro.serve.engine import ServeConfig, ServingEngine
from repro.train.optimizer import OptConfig, adamw_init, cosine_lr
from repro.train.train_step import loss_fn, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_smoke_config("llama31_8b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_cosine_schedule():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    assert float(cosine_lr(cfg, jnp.int32(0))) == 0.0
    assert float(cosine_lr(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(cosine_lr(cfg, jnp.int32(100))) == pytest.approx(0.1,
                                                                  abs=1e-3)


@pytest.mark.slow
def test_loss_decreases(tiny):
    cfg, model, params = tiny
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    step = jax.jit(make_train_step(model, OptConfig(lr=3e-3,
                                                    total_steps=60)))
    opt = adamw_init(params)
    losses = []
    p = params
    for i in range(40):
        p, opt, m = step(p, opt, lm_batch(data, i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_grad_accum_equivalence(tiny):
    cfg, model, params = tiny
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    batch = lm_batch(data, 0)
    opt = adamw_init(params)
    s1 = jax.jit(make_train_step(model, OptConfig()))
    s2 = jax.jit(make_train_step(model, OptConfig(), grad_accum=2))
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    d = jax.tree_util.tree_map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                               p1, p2)
    assert max(jax.tree_util.tree_leaves(d)) < 1e-5


def test_ef_int8_error_feedback_unbiased(rng):
    """Accumulated compressed grads converge to accumulated true grads."""
    g = {"w": jax.random.normal(rng, (32, 32)) * 0.01}
    ef = ef_int8_init(g)
    total_comp = jnp.zeros((32, 32))
    steps = 20
    for _ in range(steps):
        comp, ef = ef_int8_compress(g, ef)
        total_comp = total_comp + comp["w"]
    total_true = g["w"] * steps
    rel = float(jnp.linalg.norm(total_comp - total_true) /
                jnp.linalg.norm(total_true))
    assert rel < 0.01  # residual bounded by one step's quantization error


def test_serving_engine_shapes_and_sparse_prefill(tiny):
    cfg, model, params = tiny
    engine = ServingEngine(model, paper_policy(8, 16),
                           ServeConfig(max_seq=64))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0,
                                          cfg.vocab_size)}
    out = engine.generate(params, batch, max_new_tokens=8)
    assert out["tokens"].shape == (2, 8)
    assert out["tokens"].dtype in (jnp.int32, jnp.int64)
    # prefill(16) + 7 decode steps (the 1st new token is sampled from the
    # prefill logits and enters the cache on the next step)
    assert int(out["cache"]["pos"]) == 16 + 8 - 1


def test_sparse_prefill_changes_only_prefill(tiny):
    """With an 'always dense' policy vs sparse-prefill policy, the decode
    path must be identical given the same cache contents."""
    cfg, model, params = tiny
    toks = jax.random.randint(jax.random.PRNGKey(6), (1, 8), 0,
                              cfg.vocab_size)
    cache0 = model.init_cache(1, 32)
    _, cache_sparse = model.prefill(params, {"tokens": toks}, cache0,
                                    policy=paper_policy(2, 4))
    nxt = jnp.array([[3]], dtype=jnp.int32)
    l1, _ = model.decode_step(params, nxt, cache_sparse,
                              policy=paper_policy(2, 4))
    l2, _ = model.decode_step(params, nxt, cache_sparse, policy=DENSE)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)

"""Property-based N:M invariant suite (ISSUE 2 satellite).

The structural contract of the Amber pruning path, checked over random
shapes / dtypes / scoring modes / sparsity modes rather than the fixed
parity sweeps in test_fused_kernels.py:

  * every contiguous M-group of the pruned activations has ≤ N nonzeros
    (exactly N mask survivors — fewer *nonzeros* only when x itself holds
    zeros);
  * the survivors are exactly the per-group top-N by score (min kept score
    ≥ max dropped score; ties broken toward lower channel index);
  * tile-consensus picks exactly N channels per group, all inside the
    group, equal to the top-N of the tile-pooled score, and the compacted
    matmul matches the gather oracle — including padded non-divisor token
    counts;
  * the fused Pallas wrapper output stays consistent with a mask whose
    groups obey the same ≤ N bound, for padded non-divisor T/D/N_out.

Runs under ``hypothesis`` when installed; the deterministic ``_case``
parametrizations below keep real coverage when it is not
(tests/hypothesis_compat.py collects the ``@given`` tests as skips then).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import nm, pruner, scoring
from repro.core.policy import SparsityPolicy

MODES = ("naive", "wanda", "robust")
DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def _inputs(seed, t, groups, m, dtype, mode):
    """Random activations + the mode's offline channel scale."""
    d = groups * m
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (t, d)).astype(DTYPES[dtype])
    if mode == "naive":
        return x, None
    w = jax.random.normal(kw, (d, max(8, d // 2)))
    return x, scoring.precompute_scale(w, mode)


# ------------------------------------------------------------ core checkers

def check_per_token(x, scale, n, m):
    """≤ N nonzeros per M-group; survivors are the top-N by score."""
    pol = SparsityPolicy(n=n, m=m, score_mode="naive", skip_modules=(),
                         skip_layers={})
    xp = np.asarray(pruner.prune_input(x, scale, pol), np.float32)
    t, d = xp.shape
    g = xp.reshape(t, d // m, m)
    nnz = (g != 0).sum(-1)
    assert (nnz <= n).all(), f"group nonzeros exceed N={n}: max {nnz.max()}"

    scores = np.asarray(scoring.score_activations(x, scale), np.float32)
    mask = np.asarray(nm.nm_topk_mask(jnp.asarray(scores), n, m))
    assert (mask.reshape(t, d // m, m).sum(-1) == n).all()
    sg = scores.reshape(t, d // m, m)
    mg = mask.reshape(t, d // m, m)
    kept_min = np.where(mg, sg, np.inf).min(-1)
    dropped_max = np.where(~mg, sg, -np.inf).max(-1)
    assert (kept_min >= dropped_max - 1e-6).all(), "a dropped score beat a kept one"
    # survivors of the pruned tensor are x on the mask, zero elsewhere
    np.testing.assert_array_equal(
        xp, np.where(mask, np.asarray(x, np.float32), 0.0))


def check_tile_consensus(x, scale, n, m, tile):
    """Channel sets are per-group top-N of the pooled score; the compacted
    matmul equals the explicit gather oracle (padded tails included)."""
    t, d = x.shape
    kw = jax.random.PRNGKey(99)
    w = jax.random.normal(kw, (d, 24)).astype(x.dtype)
    pol = SparsityPolicy(n=n, m=m, score_mode="naive", skip_modules=(),
                         skip_layers={}, tile_consensus=True, tile_size=tile)
    y = pruner.sparse_matmul(x, w, scale, pol)
    assert y.shape == (t, 24)

    ts = min(tile, t)
    pad = (-t) % ts
    xf = np.asarray(x, np.float32)
    if pad:
        xf = np.concatenate([xf, np.zeros((pad, d), np.float32)])
    outs = []
    for i in range(xf.shape[0] // ts):
        xt = jnp.asarray(xf[i * ts:(i + 1) * ts]).astype(x.dtype)
        sc = scoring.score_activations(xt, scale)
        chans = np.asarray(nm.tile_consensus_channels(sc, n, m))
        # structural invariants of the shared channel set
        assert chans.shape == (d // m, n)
        base = np.arange(d // m)[:, None] * m
        assert ((chans >= base) & (chans < base + m)).all(), "channel left its group"
        assert (np.diff(chans, axis=-1) > 0).all(), "channels not strictly sorted"
        pooled = np.sqrt((np.asarray(sc, np.float32) ** 2).sum(0))
        pg = pooled.reshape(d // m, m)
        kept = np.take_along_axis(pg, chans - base, axis=-1)
        thresh = np.sort(pg, axis=-1)[:, m - n:m - n + 1]   # n-th largest
        assert (kept >= thresh - 1e-5).all(), "kept channel below top-N threshold"
        outs.append(np.asarray(nm.compact_columns(xt, jnp.asarray(chans)))
                    @ np.asarray(w, np.float32)[chans.reshape(-1)])
    want = np.concatenate(outs)[:t]
    tol = 2e-2 if x.dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32), want,
                               rtol=tol, atol=tol)


def check_fused_wrapper(seed, t, groups, m, n, dtype):
    """ops.nm_prune_matmul on padded non-divisor shapes: the result equals
    a masked matmul for SOME mask obeying the ≤ N per-group bound (here:
    the oracle mask, which the kernel reproduces structurally)."""
    from repro.kernels import ops
    d = groups * m
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (t, d)).astype(DTYPES[dtype])
    w = jax.random.normal(kw, (d, 13)).astype(DTYPES[dtype])  # odd N_out
    y = np.asarray(ops.nm_prune_matmul(x, w, None, n, m), np.float32)
    assert y.shape == (t, 13)
    mask = np.asarray(nm.nm_topk_mask(scoring.score_activations(x, None), n, m))
    assert nm.validate_nm(jnp.asarray(mask), n, m)
    want = (np.where(mask, np.asarray(x, np.float32), 0.0)
            @ np.asarray(w, np.float32))
    tol = dict(rtol=5e-2, atol=5e-1) if dtype == "bfloat16" else \
        dict(rtol=2e-5, atol=1e-3)
    np.testing.assert_allclose(y, want, **tol)


# ----------------------------------------------------- deterministic sweep

_CASES = [
    # seed, t, groups, n, m, dtype, mode
    (0, 1, 2, 1, 4, "float32", "naive"),
    (1, 7, 3, 2, 4, "float32", "wanda"),
    (2, 16, 2, 3, 8, "bfloat16", "robust"),
    (3, 5, 4, 8, 16, "float32", "robust"),
    (4, 33, 1, 4, 8, "bfloat16", "naive"),
    (5, 12, 5, 7, 8, "float32", "wanda"),
]


@pytest.mark.parametrize("seed,t,groups,n,m,dtype,mode", _CASES)
def test_per_token_invariants(seed, t, groups, n, m, dtype, mode):
    x, scale = _inputs(seed, t, groups, m, dtype, mode)
    check_per_token(x, scale, n, m)


@pytest.mark.parametrize("seed,t,groups,n,m,dtype,mode", _CASES)
@pytest.mark.parametrize("tile", [4, 16])
def test_tile_consensus_invariants(seed, t, groups, n, m, dtype, mode, tile):
    x, scale = _inputs(seed, t, groups, m, dtype, mode)
    check_tile_consensus(x, scale, n, m, tile)


@pytest.mark.parametrize("seed,t,groups,n,m,dtype", [
    (0, 5, 2, 2, 4, "float32"),      # t=5: token-padding fallback
    (1, 33, 3, 4, 8, "bfloat16"),    # 33 tokens, odd N_out
    (2, 97, 2, 8, 16, "float32"),
])
def test_fused_wrapper_padded_shapes(seed, t, groups, n, m, dtype):
    check_fused_wrapper(seed, t, groups, m, n, dtype)


# ------------------------------------------------------- hypothesis sweep

@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    t=st.integers(1, 40),
    groups=st.integers(1, 6),
    nm=st.sampled_from([(1, 4), (2, 4), (3, 8), (4, 8), (8, 16), (7, 8)]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    mode=st.sampled_from(MODES),
)
def test_per_token_invariants_prop(seed, t, groups, nm, dtype, mode):
    n, m = nm
    x, scale = _inputs(seed, t, groups, m, dtype, mode)
    check_per_token(x, scale, n, m)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    t=st.integers(1, 40),
    groups=st.integers(1, 4),
    nm=st.sampled_from([(2, 4), (4, 8), (8, 16)]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    mode=st.sampled_from(MODES),
    tile=st.sampled_from([4, 8, 16]),
)
def test_tile_consensus_invariants_prop(seed, t, groups, nm, dtype, mode,
                                        tile):
    n, m = nm
    x, scale = _inputs(seed, t, groups, m, dtype, mode)
    check_tile_consensus(x, scale, n, m, tile)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    t=st.integers(1, 70),
    groups=st.integers(1, 4),
    nm=st.sampled_from([(2, 4), (4, 8), (8, 16)]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_fused_wrapper_padded_shapes_prop(seed, t, groups, nm, dtype):
    n, m = nm
    check_fused_wrapper(seed, t, groups, m, n, dtype)

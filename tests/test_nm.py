"""N:M primitive unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import nm

PATTERNS = [(1, 4), (2, 4), (4, 8), (8, 16), (3, 8)]


@pytest.mark.parametrize("n,m", PATTERNS)
def test_mask_popcount_exact(n, m, rng):
    scores = jnp.abs(jax.random.normal(rng, (16, 8 * m)))
    mask = nm.nm_topk_mask(scores, n, m)
    groups = np.asarray(mask).reshape(16, 8, m).sum(-1)
    assert (groups == n).all()


def test_dense_pattern_is_identity(rng):
    s = jnp.abs(jax.random.normal(rng, (4, 16)))
    assert bool(nm.nm_topk_mask(s, 4, 4).all())


@pytest.mark.parametrize("n,m", PATTERNS)
def test_mask_keeps_top_scores(n, m, rng):
    scores = jnp.abs(jax.random.normal(rng, (8, 4 * m)))
    mask = np.asarray(nm.nm_topk_mask(scores, n, m))
    s = np.asarray(scores).reshape(8, 4, m)
    mk = mask.reshape(8, 4, m)
    kept_min = np.where(mk, s, np.inf).min(-1)
    dropped_max = np.where(~mk, s, -np.inf).max(-1)
    assert (kept_min >= dropped_max - 1e-6).all()


def test_apply_nm_zeroes_complement(rng):
    x = jax.random.normal(rng, (8, 32))
    y = nm.apply_nm(x, jnp.abs(x), 2, 4)
    assert float(nm.sparsity_fraction(y)) >= 0.5 - 1e-6
    # surviving entries are unchanged
    keep = np.asarray(y) != 0
    np.testing.assert_array_equal(np.asarray(y)[keep], np.asarray(x)[keep])


def test_validate_nm(rng):
    s = jnp.abs(jax.random.normal(rng, (4, 32)))
    mask = nm.nm_topk_mask(s, 2, 4)
    assert bool(nm.validate_nm(mask, 2, 4))
    assert not bool(nm.validate_nm(jnp.ones((4, 32), bool), 2, 4))


def test_bad_pattern_raises():
    with pytest.raises(ValueError):
        nm.nm_topk_mask(jnp.ones((4, 16)), 5, 4)
    with pytest.raises(ValueError):
        nm.nm_group_view(jnp.ones((4, 15)), 4)


# ---------------------------------------------------------- property tests

@settings(max_examples=40, deadline=None)
@given(
    t=st.integers(1, 8),
    g=st.integers(1, 8),
    nm_pair=st.sampled_from(PATTERNS),
    seed=st.integers(0, 2**30),
)
def test_property_mask_invariants(t, g, nm_pair, seed):
    n, m = nm_pair
    scores = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (t, g * m)))
    mask = nm.nm_topk_mask(scores, n, m)
    # exactly n per group, always
    assert bool(nm.validate_nm(mask, n, m))
    counts = np.asarray(mask).reshape(t, g, m).sum(-1)
    assert (counts == n).all()
    # idempotence: pruning twice == pruning once
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, g * m))
    once = nm.apply_nm(x, scores, n, m)
    twice = nm.apply_nm(once, scores, n, m)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


@settings(max_examples=25, deadline=None)
@given(
    g=st.integers(1, 6),
    nm_pair=st.sampled_from([(2, 4), (4, 8), (8, 16)]),
    seed=st.integers(0, 2**30),
)
def test_property_tile_consensus_channels(g, nm_pair, seed):
    n, m = nm_pair
    scores = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (7, g * m)))
    chans = np.asarray(nm.tile_consensus_channels(scores, n, m))
    assert chans.shape == (g, n)
    # channels stay inside their group and are unique
    for gi in range(g):
        assert (chans[gi] >= gi * m).all() and (chans[gi] < (gi + 1) * m).all()
        assert len(set(chans[gi].tolist())) == n

"""Partition rules: divisibility guarantees + lowering on a tiny mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.distributed import sharding as shd
from repro.launch.mesh import abstract_mesh, make_mesh_auto
from repro.models import build_model


def _mesh(shape=(2, 4), axes=("data", "model")):
    # tests run on 1 device; abstract mesh via make_mesh requires devices —
    # use the AbstractMesh to validate specs without hardware
    return abstract_mesh(shape, axes)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch):
    """Every sharded dim must divide the mesh axis — for the FULL configs
    on the production 16×16 mesh (the dry-run contract)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mesh = abstract_mesh((16, 16), ("data", "model"))
    specs = shd.param_specs(params, mesh, cfg.n_experts)

    def check(path, leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, params, specs)


def test_known_rules():
    mesh = abstract_mesh((16, 16), ("data", "model"))
    cfg = get_config("qwen2_5_32b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_specs(params, mesh, 0)
    blk = specs["periods"]["b0"]
    # column-parallel q: (L, d, qd) → model on last dim
    assert tuple(blk["q_proj"]["w"])[-1] == "model"
    # row-parallel o: model on d_in
    assert tuple(blk["o_proj"]["w"])[-2] == "model"
    assert tuple(blk["mlp"]["down_proj"]["w"])[-2] == "model"
    # norms replicated
    assert all(s is None for s in tuple(blk["ln1"]["w"]))
    # vocab sharding on embed + lm_head
    assert "model" in tuple(specs["embed"]["w"])
    assert tuple(specs["lm_head"]["w"])[-1] == "model"


def test_whisper_odd_vocab_replicates():
    """vocab 51865 is not divisible by 16 → embedding must not shard it."""
    mesh = abstract_mesh((16, 16), ("data", "model"))
    cfg = get_config("whisper_medium")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_specs(params, mesh, 0)
    emb_spec = tuple(specs["embed"]["w"])
    assert emb_spec[0] is None  # 51865 % 16 != 0
    # d_model 1024 divisible → second dim may shard
    assert emb_spec[1] == "model"


def test_batch_and_cache_specs():
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert shd.data_axes(mesh) == ("pod", "data")
    assert tuple(shd.batch_spec(mesh))[0] == ("pod", "data")

    cfg = get_config("granite_34b")  # kv_heads=1 → heads must NOT shard
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 1024))
    cspecs = shd.cache_specs(cache, cfg, mesh)
    k_spec = tuple(cspecs["periods"]["b0"]["k"])
    assert k_spec[-2] is None          # 1 kv head — replicate heads
    assert k_spec[-4] == ("pod", "data")


@pytest.mark.slow
def test_smoke_cell_lowers_on_multidevice_mesh():
    """End-to-end pjit lowering of a smoke config on an 8-way mesh shape
    (validates sharding rules agree with GSPMD propagation)."""
    if len(jax.devices()) < 2:
        mesh = abstract_mesh((2, 4), ("data", "model"))
    from repro.launch.cells import build_cell
    mesh_c = make_mesh_auto((1, 1), ("data", "model"))
    cell = build_cell("llama31_8b", "train_4k", mesh_c,
                      cfg=dataclasses.replace(get_smoke_config("llama31_8b")))
    lowered = cell.lower(mesh_c)
    compiled = lowered.compile()
    assert compiled.cost_analysis() is not None

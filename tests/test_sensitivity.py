"""Sensitivity scan + layer-skip selection (the paper's heuristic)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_smoke_config
from repro.core import sensitivity
from repro.core.policy import DENSE, paper_policy
from repro.models import build_model


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(get_smoke_config("llama31_8b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                          cfg.vocab_size)}
    return cfg, model, params, batch


def test_relative_perturbation_basics(rng):
    y = jax.random.normal(rng, (4, 8))
    assert float(sensitivity.relative_perturbation(y, y)) == 0.0
    assert float(sensitivity.relative_perturbation(y, -y)) == pytest.approx(
        2.0, rel=1e-3)


def test_targeted_policy_prunes_only_target():
    base = paper_policy(2, 4)
    pol = sensitivity.targeted_policy("q_proj", 2, n_layers=4, base=base)
    assert pol.should_prune("q_proj", 2)
    for layer in (0, 1, 3):
        assert not pol.should_prune("q_proj", layer)
    for mod in ("k_proj", "down_proj", "gate_proj", "o_proj"):
        for layer in range(4):
            assert not pol.should_prune(mod, layer)


def test_sensitivity_scan_and_selection(small_model):
    cfg, model, params, batch = small_model

    def forward(params, batch, policy, phase):
        return model.forward(params, batch, policy=policy, phase=phase)

    base = paper_policy(2, 4)
    sens = sensitivity.sensitivity_scan(
        forward, params, batch, ["q_proj", "gate_proj", "down_proj"],
        cfg.n_layers, base)
    assert len(sens) == 3 * cfg.n_layers
    assert all(v >= 0 for v in sens.values())
    assert any(v > 0 for v in sens.values())

    dims = {
        "q_proj": (cfg.d_model, cfg.q_dim),
        "k_proj": (cfg.d_model, cfg.kv_dim),
        "v_proj": (cfg.d_model, cfg.kv_dim),
        "o_proj": (cfg.q_dim, cfg.d_model),
        "gate_proj": (cfg.d_model, cfg.d_ff),
        "up_proj": (cfg.d_model, cfg.d_ff),
        "down_proj": (cfg.d_ff, cfg.d_model),
    }
    flops = sensitivity.linear_flops(dims)
    skips = sensitivity.select_qgate_skips(sens, flops, cfg.n_layers, base,
                                           coverage_target=0.55)
    pol = base.with_(skip_layers={"q_proj": frozenset(skips),
                                  "gate_proj": frozenset(skips)})
    assert sensitivity.coverage(flops, pol, cfg.n_layers) >= 0.55

"""SparsityPolicy semantics + pruner paths + coverage math vs the paper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nm, pruner, sensitivity
from repro.core.policy import DENSE, SparsityPolicy, naive_policy, paper_policy


def test_paper_policy_skip_semantics():
    pol = paper_policy(8, 16, qgate_skip_layers=(19, 21, 28, 30, 31))
    assert not pol.should_prune("k_proj", 0)
    assert not pol.should_prune("o_proj", 12)
    assert not pol.should_prune("up_proj", 3)
    assert pol.should_prune("down_proj", 19)       # down always pruned
    assert not pol.should_prune("q_proj", 19)      # skip list
    assert pol.should_prune("q_proj", 20)
    assert pol.should_prune("gate_proj", 0)
    assert not pol.should_prune("gate_proj", 31)
    assert pol.active("prefill") and not pol.active("decode")
    assert not DENSE.should_prune("down_proj", 0)
    hash(pol)  # static closure requirement


def test_policy_validation_bad_nm_raises():
    for n, m in [(0, 4), (-1, 4), (5, 4), (0, 0), (3, 0)]:
        with pytest.raises(ValueError):
            SparsityPolicy(n=n, m=m)
    # a bad pattern cannot hide behind enabled=False
    with pytest.raises(ValueError):
        SparsityPolicy(enabled=False, n=8, m=4)
    with pytest.raises(ValueError):
        SparsityPolicy(score_mode="magic")
    with pytest.raises(ValueError):
        SparsityPolicy(tile_consensus=True, tile_size=0)
    # non-dividing N:M is legal (3:8), as is dense N==M
    assert SparsityPolicy(n=3, m=8).m == 8
    assert SparsityPolicy(n=4, m=4).n == 4


def test_policy_with_roundtrips_skip_layers():
    pol = paper_policy(8, 16, qgate_skip_layers=(3, 7, 11))
    # unrelated update keeps the skip map (and its semantics) intact
    pol2 = pol.with_(n=4, m=8)
    assert pol2.skip_layers == pol.skip_layers
    assert not pol2.should_prune("q_proj", 7)
    assert pol2.should_prune("q_proj", 8)
    # identity round-trip reconstructs an equal, hashable policy
    assert pol.with_() == pol
    assert hash(pol.with_()) == hash(pol)
    # updating the map itself re-freezes to the canonical tuple form
    pol3 = pol.with_(skip_layers={"gate_proj": frozenset({1})})
    assert pol3.should_prune("q_proj", 3)
    assert not pol3.should_prune("gate_proj", 1)


def test_paper_coverage_matches_published_number():
    """LLaMA3.1-8B: skip q/gate in 5 of 32 layers → 56.1% coverage (paper)."""
    d, qd, kvd, ff = 4096, 4096, 1024, 14336
    dims = {
        "q_proj": (d, qd), "k_proj": (d, kvd), "v_proj": (d, kvd),
        "o_proj": (qd, d), "gate_proj": (d, ff), "up_proj": (d, ff),
        "down_proj": (ff, d),
    }
    flops = sensitivity.linear_flops(dims)
    pol = paper_policy(8, 16, qgate_skip_layers=(19, 21, 28, 30, 31))
    cov = sensitivity.coverage(flops, pol, n_layers=32)
    assert cov == pytest.approx(0.561, abs=0.005)


def test_qwen2_coverage_matches_published_number():
    """Qwen2-7B: skip q/gate in 5 of 28 layers → 57.6% (paper §Setup)."""
    d, qd, kvd, ff = 3584, 3584, 512, 18944
    dims = {
        "q_proj": (d, qd), "k_proj": (d, kvd), "v_proj": (d, kvd),
        "o_proj": (qd, d), "gate_proj": (d, ff), "up_proj": (d, ff),
        "down_proj": (ff, d),
    }
    flops = sensitivity.linear_flops(dims)
    pol = paper_policy(8, 16, qgate_skip_layers=(0, 6, 23, 26, 27))
    cov = sensitivity.coverage(flops, pol, n_layers=28)
    assert cov == pytest.approx(0.576, abs=0.006)


def test_prune_input_matches_manual(rng):
    x = jax.random.normal(rng, (8, 32))
    pol = naive_policy(2, 4)
    y = pruner.prune_input(x, None, pol)
    mask = nm.nm_topk_mask(jnp.abs(x), 2, 4)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x * mask))


def test_sparse_matmul_tile_consensus_flop_shape(rng):
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (64, 64))
    w = jax.random.normal(k2, (64, 48))
    pol = naive_policy(2, 4).with_(tile_consensus=True, tile_size=16)
    y = pruner.sparse_matmul(x, w, None, pol)
    assert y.shape == (64, 48)
    # error vs dense bounded (half the channels kept by magnitude)
    dense = x @ w
    rel = float(jnp.linalg.norm(y - dense) / jnp.linalg.norm(dense))
    assert rel < 1.0


def test_precompute_scales_walks_tree(rng):
    params = {
        "blocks": {
            "q_proj": {"w": jax.random.normal(rng, (16, 8))},
            "o_proj": {"w": jax.random.normal(rng, (8, 16))},
            "down_proj": {"w": jax.random.normal(rng, (3, 16, 8))},  # stacked
        }
    }
    pol = paper_policy(2, 4)
    out = pruner.precompute_scales(params, pol)
    assert "amber_scale" in out["blocks"]["q_proj"]
    assert out["blocks"]["q_proj"]["amber_scale"].shape == (16,)
    assert "amber_scale" not in out["blocks"]["o_proj"]  # skipped module
    assert out["blocks"]["down_proj"]["amber_scale"].shape == (3, 16)

    # naive mode: nothing attached
    out2 = pruner.precompute_scales(params, naive_policy(2, 4))
    assert "amber_scale" not in out2["blocks"]["q_proj"]


def test_per_token_vs_tile_consensus_divergence(rng):
    """Tile consensus is an approximation of per-token masks — quantify."""
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (128, 64))
    w = jax.random.normal(k2, (64, 32))
    pol_tok = naive_policy(8, 16)
    pol_tile = pol_tok.with_(tile_consensus=True, tile_size=128)
    y_tok = pruner.sparse_matmul(x, w, None, pol_tok)
    y_tile = pruner.sparse_matmul(x, w, None, pol_tile)
    dense = x @ w
    e_tok = float(jnp.linalg.norm(y_tok - dense))
    e_tile = float(jnp.linalg.norm(y_tile - dense))
    assert e_tile >= e_tok * 0.5  # tile mode can't beat per-token by much

"""Checkpointing + fault-tolerance: atomic writes, keep-K, crash/resume
equivalence (the restart contract for node failures)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, \
    save_checkpoint
from repro.configs.base import get_smoke_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_mesh_auto
from repro.models import build_model
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def test_roundtrip(tmp_path, rng):
    tree = {"a": {"w": jax.random.normal(rng, (4, 4))},
            "b": jnp.arange(3), "step": jnp.zeros((), jnp.int32)}
    p = str(tmp_path / "ckpt.npz")
    save_checkpoint(p, tree)
    out = load_checkpoint(p, tree)
    np.testing.assert_allclose(np.asarray(out["a"]["w"]),
                               np.asarray(tree["a"]["w"]))
    assert not os.path.exists(p + ".tmp")  # atomic: no tmp residue


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (10, 20, 30, 40):
        mgr.save(s, tree)
    assert mgr.steps() == [30, 40]
    assert mgr.latest() == 40


def _trainer(tmp_path, steps, resume="auto"):
    cfg = get_smoke_config("llama31_8b")
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=2)
    return Trainer(
        model, data_cfg, OptConfig(lr=1e-3, total_steps=steps),
        TrainerConfig(total_steps=steps, ckpt_every=5,
                      ckpt_dir=str(tmp_path), keep=5, resume=resume),
    )


@pytest.mark.slow
def test_crash_resume_bitexact(tmp_path):
    """Uninterrupted run == crash-at-7 + auto-resume run (same data stream,
    same checkpoints ⇒ identical final loss)."""
    key = jax.random.PRNGKey(0)

    t_ref = _trainer(tmp_path / "ref", steps=12, resume="none")
    ref = t_ref.run(key)

    t_crash = _trainer(tmp_path / "crash", steps=12)
    with pytest.raises(RuntimeError, match="simulated node failure"):
        t_crash.run(key, crash_at=7)
    # new trainer instance = restarted process; resumes from step 5 ckpt
    t_resume = _trainer(tmp_path / "crash", steps=12)
    out = t_resume.run(key)
    assert out["resumed_from"] == 5
    assert out["metrics"][-1]["loss"] == pytest.approx(
        ref["metrics"][-1]["loss"], rel=1e-5)


def test_elastic_restore_different_sharding(tmp_path, rng):
    """Checkpoints are topology-agnostic: restore onto a different mesh."""
    tree = {"w": jax.random.normal(rng, (8, 8))}
    p = str(tmp_path / "c.npz")
    save_checkpoint(p, tree)
    mesh = make_mesh_auto((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    out = load_checkpoint(p, tree, shardings=sh)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding == sh["w"]

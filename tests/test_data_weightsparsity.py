"""Data pipeline determinism + weight-sparsity baselines (Appendix A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nm, weight_sparsity
from repro.data.pipeline import DataConfig, calibration_stream, lm_batch


def test_lm_batch_deterministic():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=7)
    a = lm_batch(cfg, 12)["tokens"]
    b = lm_batch(cfg, 12)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = lm_batch(cfg, 13)["tokens"]
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert a.shape == (4, 33)
    assert int(a.min()) >= 0 and int(a.max()) < 1000


def test_lm_batch_zipf_marginal():
    cfg = DataConfig(vocab_size=5000, seq_len=256, global_batch=16)
    toks = np.asarray(lm_batch(cfg, 0)["tokens"]).ravel()
    # Zipf: low token ids dominate
    assert (toks < 50).mean() > 0.3
    assert (toks > 2500).mean() < 0.1


def test_calibration_stream():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    batches = list(calibration_stream(cfg, 3))
    assert len(batches) == 3
    assert not np.array_equal(np.asarray(batches[0]["tokens"]),
                              np.asarray(batches[1]["tokens"]))


@pytest.mark.parametrize("n,m", [(2, 4), (4, 8)])
def test_weight_sparsity_valid_nm(n, m, rng):
    w = jax.random.normal(rng, (32, 16))
    am = jnp.abs(jax.random.normal(rng, (32,))) + 0.1
    hd = am**2
    for pruned in (weight_sparsity.magnitude_nm(w, n, m),
                   weight_sparsity.wanda_nm(w, am, n, m),
                   weight_sparsity.sparsegpt_nm(w, hd, n, m)):
        mask = np.asarray(pruned) != 0
        groups = mask.T.reshape(16, 32 // m, m).sum(-1)
        assert (groups <= n).all()
        assert float(nm.sparsity_fraction(pruned)) >= (1 - n / m) - 0.05


def test_wanda_beats_magnitude_under_skewed_acts(rng):
    """Wanda's activation-aware score must beat plain magnitude when the
    calibration activations are strongly channel-skewed.

    The skew must vary WITHIN each M-group of adjacent input channels —
    N:M selection happens inside groups, so a smooth ramp (neighbouring
    channels nearly equal) collapses Wanda to magnitude up to ties and the
    comparison becomes a coin flip.  A fixed permutation of the ramp puts
    large and small norms in the same group, which is the regime Wanda's
    score is for.
    """
    k1, k2 = jax.random.split(rng)
    w = jax.random.normal(k1, (64, 32))
    x = jax.random.normal(k2, (128, 64))
    scales = (jnp.arange(64) + 1.0) ** 1.5        # skewed channels
    perm = jax.random.permutation(jax.random.PRNGKey(7), 64)
    x = x * scales[perm][None, :]                 # skew mixed across groups
    act_norm = jnp.linalg.norm(x, axis=0)
    y_ref = x @ w
    e_mag = jnp.linalg.norm(x @ weight_sparsity.magnitude_nm(w, 2, 4) - y_ref)
    e_wanda = jnp.linalg.norm(x @ weight_sparsity.wanda_nm(w, act_norm, 2, 4)
                              - y_ref)
    assert float(e_wanda) < float(e_mag)

"""SmoothQuant / Outstanding-sparse quantization tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import quant


def _calib(rng, t, d, outliers=4):
    x = jax.random.normal(rng, (t, d))
    # outlier channels (the SmoothQuant motivation)
    x = x.at[:, :outliers].multiply(30.0)
    return x


def test_weight_quant_roundtrip(rng):
    w = jax.random.normal(rng, (32, 16))
    q, s = quant.quantize_weight_per_channel(w)
    assert q.dtype == jnp.int8
    rel = float(jnp.max(jnp.abs(q * s - w)) / jnp.max(jnp.abs(w)))
    assert rel < 0.01


def test_smooth_factors_direction(rng):
    x = _calib(rng, 64, 32)
    w = jax.random.normal(rng, (32, 16))
    am = jnp.max(jnp.abs(x), axis=0)
    s_plain = quant.smooth_factors(am, w, alpha=0.5, outstanding=False)
    s_out = quant.smooth_factors(am, w, alpha=0.1, outstanding=True)
    # vanilla: outlier channels get larger s (shrinks activations)
    assert float(s_plain[0]) > float(jnp.median(s_plain[4:]))
    # Outstanding-sparse inverts: outlier channels get SMALLER ŝ (expands)
    assert float(s_out[0]) < float(jnp.median(s_out[4:]))


def test_quantized_linear_accuracy(rng):
    k1, k2 = jax.random.split(rng)
    x = _calib(k1, 64, 32)
    w = jax.random.normal(k2, (32, 16))
    am = jnp.max(jnp.abs(x), axis=0)
    dense = x @ w
    for outstanding, alpha in [(False, 0.5), (True, 0.1)]:
        ql = quant.make_quantized_linear(
            w, am, quant.QuantConfig(alpha=alpha, outstanding=outstanding))
        y = ql(x)
        rel = float(jnp.linalg.norm(y - dense) / jnp.linalg.norm(dense))
        assert rel < 0.05, (outstanding, rel)


def test_per_token_dynamic_quant(rng):
    x = _calib(rng, 32, 16)
    q, s = quant.quantize_act_per_token(x)
    assert q.dtype == jnp.int8 and s.shape == (32, 1)
    rel = float(jnp.max(jnp.abs(q * s - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.01


def test_quant_config_skips():
    cfg = quant.QuantConfig(skip_modules=("down_proj",), skip_layers=(0, 1))
    assert not cfg.should_quantize("down_proj", 5)
    assert not cfg.should_quantize("q_proj", 0)
    assert cfg.should_quantize("q_proj", 2)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**30), alpha=st.floats(0.05, 0.95))
def test_property_smooth_rewrite_exact(seed, alpha):
    """Y = (X/s)(s⊙W) must equal XW exactly in f32 (pre-quantization)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (8, 16))
    w = jax.random.normal(k2, (16, 4))
    am = jnp.max(jnp.abs(x), axis=0)
    for outstanding in (False, True):
        s = quant.smooth_factors(am, w, alpha, outstanding)
        y = (x / s) @ (w * s[:, None])
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)

"""Optional-``hypothesis`` shim.

The property tests use ``hypothesis``, which isn't guaranteed in every
container image.  Importing this module yields the real ``given`` /
``settings`` / ``st`` when the package is installed; otherwise drop-in
stand-ins that collect each property test as a single *skipped* item (the
plain unit tests in the same files keep running either way).

Usage in a test module::

    from hypothesis_compat import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the dep
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stub: every ``st.<name>(...)`` call returns an inert placeholder
        (strategies are only ever passed into ``given``, never evaluated)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def given(*_args, **_kwargs):
        def deco(fn):
            # Replace with a zero-arg skipped test: the original signature
            # holds strategy parameter names pytest would misread as
            # fixtures.
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass  # pragma: no cover

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

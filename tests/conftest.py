"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the host's single
device; only launch/dryrun.py forces 512 placeholder devices."""
import os

import jax
import pytest

# the whole serving suite runs with the per-iteration block-pool audit on
# (refcounts, ownership, writable-block exclusivity — see
# ContinuousServingEngine._audit_pool); export REPRO_VALIDATE_POOL=0 to
# opt out when profiling test runtime
os.environ.setdefault("REPRO_VALIDATE_POOL", "1")


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")

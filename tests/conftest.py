"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the host's single
device; only launch/dryrun.py forces 512 placeholder devices."""
import jax
import pytest


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")

"""Paged KV-cache allocation (ISSUE 3).

The contract: sizing the block pool well below ``num_slots * max_seq``
must change only WHEN requests run, never WHAT they emit — greedy outputs
stay token-identical to the one-shot engine through block-budget
admission, block-table scatter/gather, and pool-exhaustion preemption —
and paging must not add shape buckets (one compile per phase).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.policy import DENSE, paper_policy
from repro.core.pruner import precompute_scales
from repro.models import build_model
from repro.serve import (ContinuousConfig, ContinuousServingEngine,
                         ServeConfig, ServingEngine)
from repro.serve.paged import BlockPool

MAX_SEQ = 64


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_smoke_config("llama31_8b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens, seed0=10):
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(seed0 + i),
                                          (l,), 0, cfg.vocab_size))
            for i, l in enumerate(lens)]


def _oracle(model, params, policy, prompt, max_new):
    eng = ServingEngine(model, policy, ServeConfig(max_seq=MAX_SEQ))
    out = eng.generate(params, {"tokens": jnp.asarray(prompt)[None, :]},
                       max_new_tokens=max_new)
    return np.asarray(out["tokens"])[0].tolist()


def _serve(model, params, policy, prompts, arrivals, max_new, **cfg_kw):
    eng = ContinuousServingEngine(model, policy, ContinuousConfig(
        max_seq=MAX_SEQ, **cfg_kw))
    for p, a, mn in zip(prompts, arrivals, max_new):
        eng.submit(p, max_new_tokens=mn, arrival=a)
    return eng, eng.run(params)


# ------------------------------------------------------------- BlockPool

def test_block_pool_never_double_allocates():
    pool = BlockPool(num_blocks=8, block_size=4)
    a = pool.alloc(5)
    b = pool.alloc(3)
    assert len(set(a + b)) == 8, "same block handed out twice"
    assert pool.available == 0
    with pytest.raises(RuntimeError):
        pool.alloc(1)
    pool.release(b)
    c = pool.alloc(2)
    assert not set(c) & set(a), "released-and-reissued id collided with live"
    with pytest.raises(AssertionError):
        pool.release(b[:1] + b[:1])        # double free
    assert pool.peak_in_use == 8


def test_block_pool_fragmentation_roundtrip():
    """Interleaved alloc/free (fragmenting pattern) round-trips: every id
    returns exactly once and the pool refills completely."""
    pool = BlockPool(num_blocks=16, block_size=2)
    held = {}
    rng = np.random.default_rng(0)
    for step in range(200):
        if held and (pool.available == 0 or rng.random() < 0.45):
            key = rng.choice(list(held))
            pool.release(held.pop(key))
        else:
            n = int(rng.integers(1, min(4, pool.available) + 1))
            held[step] = pool.alloc(n)
        live = [i for ids in held.values() for i in ids]
        assert len(live) == len(set(live)) == pool.in_use
    for ids in held.values():
        pool.release(ids)
    assert pool.available == 16
    assert sorted(pool.alloc(16)) == list(range(16))


def test_blocks_for():
    pool = BlockPool(num_blocks=4, block_size=8)
    assert pool.blocks_for(1) == 1
    assert pool.blocks_for(8) == 1
    assert pool.blocks_for(9) == 2


def test_block_pool_alloc_validates_before_mutating():
    """ISSUE-5 bugfix: the double-allocation check must fire BEFORE any
    block leaves the free list — the old implementation popped first and
    asserted after, so the failing path corrupted pool state.  Inject a
    duplicate id into the free list and check the failed alloc leaves the
    pool exactly as it found it."""
    pool = BlockPool(num_blocks=4, block_size=2)
    live = pool.alloc(2)
    pool._free.appendleft(live[0])          # simulated corruption
    free_before = list(pool._free)
    ref_before = dict(pool._ref)
    with pytest.raises(AssertionError, match="double allocation"):
        pool.alloc(2)
    assert list(pool._free) == free_before, "failed alloc mutated free list"
    assert dict(pool._ref) == ref_before, "failed alloc leaked references"
    # exhaustion is still validated first and still RuntimeError
    pool._free.popleft()                    # undo the corruption
    with pytest.raises(RuntimeError):
        pool.alloc(3)
    assert pool.available == 2 and pool.in_use == 2


# --------------------------------------------- engine under a 50% pool

@pytest.mark.parametrize("attn_kernel", [False, True],
                         ids=["gather-oracle", "pallas-kernel"])
def test_half_pool_token_identical_one_trace(tiny, attn_kernel, monkeypatch):
    """Acceptance: pool at 50% of num_slots*max_seq, staggered greedy
    outputs token-identical to the one-shot engine, one compile per shape
    bucket — on the jnp gather oracle AND (ISSUE-4) on the Pallas
    block-table-walk kernel under REPRO_PALLAS_INTERPRET=1."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    cfg, model, params = tiny
    policy = DENSE.with_(use_pallas_kernels=True) if attn_kernel else DENSE
    slots, bs = 3, 8
    half_pool = (slots * MAX_SEQ) // (2 * bs)          # 50% of the slab
    lens, arrivals, max_new = [5, 21, 13, 30, 9], [0, 0, 2, 4, 7], \
        [8, 10, 6, 8, 12]
    prompts = _prompts(cfg, lens)
    eng, res = _serve(model, params, policy, prompts, arrivals, max_new,
                      num_slots=slots, chunk_size=16,
                      block_size=bs, num_blocks=half_pool)
    assert eng.paged and eng.pool.num_blocks == half_pool
    assert res["metrics"]["paged"]["attention_kernel"] is attn_kernel
    for i, p in enumerate(prompts):
        assert res["outputs"][i] == _oracle(model, params, DENSE, p,
                                            max_new[i]), f"request {i}"
    # fused one-dispatch default: one step program per phase-presence bucket
    assert all(v == 1 for v in eng.trace_counts.values()), eng.trace_counts
    assert set(eng.trace_counts) <= {"step_prefill", "step_decode",
                                     "step_prefill_decode"}, eng.trace_counts
    assert res["metrics"]["dispatches_per_iteration"] == 1.0
    pg = res["metrics"]["paged"]
    assert pg["enabled"] and pg["peak_blocks_in_use"] <= half_pool
    # the pool must have been genuinely shared/recycled, not just sliced
    assert eng.pool.total_allocs > half_pool
    assert eng.pool.in_use == 0                         # all released


def test_pool_exhaustion_preempts_and_preserves_tokens(tiny):
    """Two long-decoding requests over a pool that cannot hold both:
    the youngest is preempted (blocks released, requeued) and every
    output stream still matches the one-shot engine."""
    cfg, model, params = tiny
    bs = 4
    # each request peaks at ceil((10+24)/4) = 9 blocks; pool of 12 admits
    # both (3+3 at admission) but cannot carry both through decode
    lens, arrivals, max_new = [10, 10], [0, 0], [24, 24]
    prompts = _prompts(cfg, lens, seed0=40)
    eng, res = _serve(model, params, DENSE, prompts, arrivals, max_new,
                      num_slots=2, chunk_size=8, block_size=bs,
                      num_blocks=12)
    pg = res["metrics"]["paged"]
    assert pg["preemptions"] > 0, "scenario failed to exhaust the pool"
    for i, p in enumerate(prompts):
        assert res["outputs"][i] == _oracle(model, params, DENSE, p,
                                            max_new[i]), f"request {i}"
    reqs = {r["rid"]: r for r in res["metrics"]["requests"]}
    assert reqs[1]["preemptions"] > 0          # youngest was the victim
    assert reqs[0]["preemptions"] == 0         # oldest never requeued
    assert eng.pool.in_use == 0


def test_preemption_sparse_prefill_replays_dense(tiny):
    """Preemption under an Amber-sparse prefill policy: emitted tokens are
    replayed through the DENSE program (their KV was first written by the
    dense decode step), so outputs still match the one-shot engine."""
    cfg, model, params = tiny
    policy = paper_policy(2, 4, cfg.qgate_skip_layers)
    params = precompute_scales(params, policy)
    lens, arrivals, max_new = [10, 10], [0, 0], [24, 24]
    prompts = _prompts(cfg, lens, seed0=60)
    eng, res = _serve(model, params, policy, prompts, arrivals, max_new,
                      num_slots=2, chunk_size=8, block_size=4,
                      num_blocks=12)
    assert res["metrics"]["paged"]["preemptions"] > 0
    for i, p in enumerate(prompts):
        assert res["outputs"][i] == _oracle(model, params, policy, p,
                                            max_new[i]), f"request {i}"
    # replay is its own step bucket, compiled once per phase-presence combo
    assert any(k.startswith("step_replay") for k in eng.trace_counts), \
        eng.trace_counts
    assert all(v == 1 for v in eng.trace_counts.values()), eng.trace_counts


def test_admission_gated_by_block_budget(tiny):
    """A pool that fits one request at a time serializes admission instead
    of preempting: the second request waits for blocks, outputs and the
    free list stay intact."""
    cfg, model, params = tiny
    lens, arrivals, max_new = [16, 16], [0, 0], [8, 8]
    prompts = _prompts(cfg, lens, seed0=80)
    eng, res = _serve(model, params, DENSE, prompts, arrivals, max_new,
                      num_slots=2, chunk_size=8, block_size=8,
                      num_blocks=3)   # ceil(24/8)=3 → one request at a time
    for i, p in enumerate(prompts):
        assert res["outputs"][i] == _oracle(model, params, DENSE, p,
                                            max_new[i]), f"request {i}"
    reqs = {r["rid"]: r for r in res["metrics"]["requests"]}
    assert reqs[1]["admitted_iter"] >= reqs[0]["done_iter"]
    assert res["metrics"]["paged"]["preemptions"] == 0


def test_paged_auto_disabled_where_pointless():
    """Archs with no full-attention KV (pure recurrent) fall back to the
    dense slab automatically and still serve correctly."""
    cfg = dataclasses.replace(get_smoke_config("rwkv6_7b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=2, chunk_size=8))
    assert not eng.paged and eng.pool is None
    eng.submit(_prompts(cfg, [9], seed0=90)[0], max_new_tokens=4)
    res = eng.run(params)
    assert res["metrics"]["paged"] == {"enabled": False}
    assert len(res["outputs"][0]) == 4


def test_unservable_request_rejected_not_livelocked(tiny):
    """ISSUE-5 bugfix (head-of-line livelock): a request whose replay
    sequence can never fit the pool must be REJECTED at admission, not
    waited on forever — strict FCFS would otherwise starve every request
    behind it.  ``submit`` guards the normal path, so craft the oversized
    request directly (as a preemption-grown replay would look)."""
    from repro.serve.continuous import REJECTED, Request
    cfg, model, params = tiny
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=2, chunk_size=8, block_size=8,
        num_blocks=3))                      # pool holds 24 tokens
    big = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (30,), 0,
                                        cfg.vocab_size), np.int32)
    # blocks_for(30) = 4 > 3: unservable forever; enqueue behind nothing
    # and ahead (by rid order at equal arrival) of a healthy request
    eng.requests.append(Request(rid=0, tokens=big, max_new_tokens=4))
    ok = _prompts(cfg, [10], seed0=95)[0]
    eng.submit(ok, max_new_tokens=4, arrival=0)
    res = eng.run(params)
    reqs = {r["rid"]: r for r in res["metrics"]["requests"]}
    assert reqs[0]["state"] == REJECTED and reqs[0]["n_out"] == 0
    assert res["metrics"]["paged"]["rejections"] == 1
    # the queue behind the dead request made progress and fully completed
    assert reqs[1]["state"] == "done"
    assert res["outputs"][1] == _oracle(model, params, DENSE, ok, 4)
    assert eng.pool.in_use == 0


def test_preempt_prefill_victim_interleaving(tiny):
    """ISSUE-5 audit pin: ``_ensure_decode_blocks`` may preempt a victim
    that is still in PREFILL, in the same scheduler iteration in which the
    victim's chunk program already ran — its freed blocks can be handed to
    a decoding slot immediately.  The host-table write ordering (victim row
    -1'd and re-synced before the next device program) plus kv_len fencing
    must keep the interleaving invisible: outputs stay token-identical.
    Engineered deterministically: req0 decodes and crosses a block
    boundary exactly while req1 (40-token prompt, 5 chunks) is mid-prefill
    with the pool fully committed."""
    from repro.serve.continuous import PREFILL
    cfg, model, params = tiny
    lens, arrivals, max_new = [8, 40], [0, 2], [24, 8]
    prompts = _prompts(cfg, lens, seed0=85)
    eng, res = _serve(model, params, DENSE, prompts, arrivals, max_new,
                      num_slots=2, chunk_size=8, block_size=4,
                      num_blocks=13, validate_pool=True)
    assert any(rid == 1 and st == PREFILL for rid, st in eng.preempt_log), \
        f"scenario drifted: preempt_log={eng.preempt_log}"
    for i, p in enumerate(prompts):
        assert res["outputs"][i] == _oracle(model, params, DENSE, p,
                                            max_new[i]), f"request {i}"
    assert eng.pool.in_use == 0


def test_submit_rejects_over_pool_capacity(tiny):
    cfg, model, params = tiny
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=2, chunk_size=8, block_size=8,
        num_blocks=2))                     # 16 tokens of pool capacity
    with pytest.raises(AssertionError):
        eng.submit(np.arange(10, dtype=np.int32), max_new_tokens=10)


# ------------------------------------------- unallocated-block fencing

def test_unallocated_block_fence_survives_poison():
    """Regression for the ``-1`` → block-0 clip contract: unallocated table
    entries resolve to physical block 0 during the gather, so whatever
    block 0 holds must NEVER reach an output.  Poison it with NaN (the one
    value a 0-probability softmax fence cannot absorb, 0·NaN = NaN) and
    assert paged prefill- and decode-shaped attention outputs are
    bit-identical to the clean pool — on the jnp oracle and the kernel."""
    from repro.models.attention import paged_attention
    rng = np.random.default_rng(3)
    nb, bs, mb, B, Hq, Hkv, hd = 12, 8, 6, 3, 4, 2, 16
    kp = np.asarray(rng.normal(size=(nb, bs, Hkv, hd)), np.float32)
    vp = np.asarray(rng.normal(size=(nb, bs, Hkv, hd)), np.float32)
    # disjoint per-row prefixes over blocks 1..11; block 0 stays free
    tab = np.full((B, mb), -1, np.int32)
    tab[0, :3] = [5, 1, 8]
    tab[1, :5] = [3, 9, 2, 7, 4]
    tab[2, :2] = [6, 10]
    assert (tab != 0).all()
    poisoned_k = kp.copy()
    poisoned_v = vp.copy()
    poisoned_k[0] = np.nan
    poisoned_v[0] = np.nan

    q_pre = np.asarray(rng.normal(size=(B, 8, Hq, hd)), np.float32)
    q_dec = np.asarray(rng.normal(size=(B, 1, Hq, hd)), np.float32)
    posv = jnp.asarray([20, 37, 10], jnp.int32)
    calls = {
        "prefill": (q_pre, dict(causal=True,
                                q_offset=jnp.asarray(13, jnp.int32),
                                kv_len=jnp.asarray([21, 38, 15], jnp.int32),
                                chunk=16)),
        "decode": (q_dec, dict(causal=False, q_offset=posv,
                               kv_len=posv + 1, chunk=16)),
    }
    for name, (q, kw) in calls.items():
        for use_kernel in (False, True):
            clean = paged_attention(
                jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                jnp.asarray(tab), use_kernel=use_kernel, interpret=True,
                **kw)
            dirty = paged_attention(
                jnp.asarray(q), jnp.asarray(poisoned_k),
                jnp.asarray(poisoned_v), jnp.asarray(tab),
                use_kernel=use_kernel, interpret=True, **kw)
            assert np.isfinite(np.asarray(dirty)).all(), \
                f"{name} kernel={use_kernel}: NaN leaked through the fence"
            np.testing.assert_array_equal(
                np.asarray(clean), np.asarray(dirty),
                err_msg=f"{name} kernel={use_kernel}")


# -------------------------------------- no full-view gather on the hot path

def _pool_gather_count(jaxpr, pool_shape) -> int:
    from repro.analysis.jaxpr_utils import pool_eqn_count
    return pool_eqn_count(jaxpr, pool_shape, "gather")


def test_paged_hot_path_has_no_full_view_gather(tiny):
    """Acceptance: with the kernel enabled, the jitted paged prefill-chunk
    and decode programs contain NO gather that reads the pooled KV leaves
    (the O(max_blocks·block_size) logical-view materialization) — and with
    it disabled the oracle gather is still there (the check bites)."""
    from repro.serve import slots as slot_ops
    from repro.serve.paged import (device_pool_rows, init_paged_cache,
                                   max_blocks_per_slot)
    cfg, model, params = tiny
    slots, bs = 2, 8
    mb = max_blocks_per_slot(MAX_SEQ, bs)
    nb = slots * mb
    spec = model.paged_kv_spec()
    cache = init_paged_cache(model, slots, MAX_SEQ, bs, nb, spec)
    tab = np.full((slots, mb), -1, np.int32)
    tab[0, :3] = [1, 2, 3]
    tab[1, :3] = [4, 5, 6]
    cache["block_table"] = jnp.asarray(tab)
    cache["pos"] = jnp.asarray([10, 7], jnp.int32)
    pool_shape = (device_pool_rows(nb), bs, cfg.n_kv_heads, cfg.head_dim)
    kernel_pol = DENSE.with_(use_pallas_kernels=True)

    toks = jnp.zeros((slots, 1), jnp.int32)
    dec = lambda pol: jax.make_jaxpr(
        lambda t, c: model.decode_step(params, t, c, policy=pol))(toks, cache)
    assert _pool_gather_count(dec(kernel_pol).jaxpr, pool_shape) == 0
    assert _pool_gather_count(dec(DENSE).jaxpr, pool_shape) > 0

    sub = slot_ops.slice_slot(cache, jnp.asarray(0, jnp.int32), spec)
    batch = {"tokens": jnp.zeros((1, 16), jnp.int32),
             "chunk_len": jnp.asarray(16, jnp.int32)}
    pre = lambda pol: jax.make_jaxpr(
        lambda b, c: model.prefill_chunk(params, b, c, policy=pol))(batch,
                                                                    sub)
    assert _pool_gather_count(pre(kernel_pol).jaxpr, pool_shape) == 0
    assert _pool_gather_count(pre(DENSE).jaxpr, pool_shape) > 0

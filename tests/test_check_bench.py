"""The bench-smoke CI gate (benchmarks/check_bench.py) must catch the two
silent failure modes: a kernel row dropping out of the trajectory and a
row carrying a non-finite timing."""
import json

from benchmarks.check_bench import REQUIRED_KERNEL_ROWS, check_trajectory


def _run(rows):
    return [{"utc": "2026-01-01T00:00:00", "tables": ["kernels"],
             "rows": rows}]


def _healthy_rows():
    return [{"name": p + "256x2048", "us_per_call": 12.5, "derived": "x"}
            for p in REQUIRED_KERNEL_ROWS]


def test_healthy_trajectory_passes(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps(_run(_healthy_rows())))
    assert check_trajectory(str(p)) == []


def test_missing_row_fails(tmp_path):
    rows = [r for r in _healthy_rows() if "nm_spmm" not in r["name"]]
    p = tmp_path / "b.json"
    p.write_text(json.dumps(_run(rows)))
    errs = check_trajectory(str(p))
    assert errs and "nm_spmm" in errs[0]


def test_nonfinite_row_fails(tmp_path):
    for bad in (float("nan"), float("inf"), 0.0, None):
        rows = _healthy_rows()
        rows[0]["us_per_call"] = bad
        p = tmp_path / "b.json"
        p.write_text(json.dumps(_run(rows)))   # NaN/Infinity round-trip
        errs = check_trajectory(str(p))
        assert errs, f"accepted us_per_call={bad!r}"


def test_only_latest_run_is_gated(tmp_path):
    """Older broken runs don't fail the gate — the trajectory is history,
    the gate guards the current commit."""
    old = _run([])[0]
    new = _run(_healthy_rows())[0]
    p = tmp_path / "b.json"
    p.write_text(json.dumps([old, new]))
    assert check_trajectory(str(p)) == []


def test_unreadable_or_empty_fails(tmp_path):
    p = tmp_path / "missing.json"
    assert check_trajectory(str(p))
    p.write_text("[]")
    assert check_trajectory(str(p))

"""The bench-smoke CI gate (benchmarks/check_bench.py) must catch the
silent failure modes: a required row dropping out of the trajectory, a
row carrying a non-finite timing, and a derived column whose embedded
correctness claim says FAIL."""
import json

from benchmarks.check_bench import (REQUIRED_KERNEL_ROWS, REQUIRED_ROWS,
                                    REQUIRED_SERVING_ROWS, check_regressions,
                                    check_since_seed, check_trajectory, main)


def _run(rows):
    return [{"utc": "2026-01-01T00:00:00", "tables": ["kernels", "serving"],
             "rows": rows}]


def _healthy_rows():
    return [{"name": p + "256x2048", "us_per_call": 12.5, "derived": "x"}
            for p in REQUIRED_ROWS]


def test_healthy_trajectory_passes(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps(_run(_healthy_rows())))
    assert check_trajectory(str(p)) == []


def test_missing_row_fails(tmp_path):
    rows = [r for r in _healthy_rows() if "nm_spmm" not in r["name"]]
    p = tmp_path / "b.json"
    p.write_text(json.dumps(_run(rows)))
    errs = check_trajectory(str(p))
    assert errs and "nm_spmm" in errs[0]


def test_nonfinite_row_fails(tmp_path):
    for bad in (float("nan"), float("inf"), 0.0, None):
        rows = _healthy_rows()
        rows[0]["us_per_call"] = bad
        p = tmp_path / "b.json"
        p.write_text(json.dumps(_run(rows)))   # NaN/Infinity round-trip
        errs = check_trajectory(str(p))
        assert errs, f"accepted us_per_call={bad!r}"


def test_missing_serving_row_fails(tmp_path):
    """The prefix-reuse scheduler row is gated like the kernel rows —
    dropping the serving table from bench-smoke must fail the check."""
    assert REQUIRED_SERVING_ROWS and REQUIRED_KERNEL_ROWS
    rows = [r for r in _healthy_rows()
            if not r["name"].startswith("serving/prefix_reuse")]
    p = tmp_path / "b.json"
    p.write_text(json.dumps(_run(rows)))
    errs = check_trajectory(str(p))
    assert errs and "serving/prefix_reuse" in errs[0]


def test_skipped_required_row_fails_with_real_cause(tmp_path):
    """A required row that self-reports SKIP (paging auto-disabled, say)
    fails with the skip reason, not a confusing 0.0-timing error."""
    rows = _healthy_rows()
    rows[-1]["us_per_call"] = 0.0
    rows[-1]["derived"] = "paging auto-disabled for this arch;SKIP"
    p = tmp_path / "b.json"
    p.write_text(json.dumps(_run(rows)))
    errs = check_trajectory(str(p))
    assert len(errs) == 1 and "skipped" in errs[0]
    assert "non-finite" not in errs[0]


def test_derived_fail_claim_fails(tmp_path):
    """A required row whose derived column embeds FAIL (broken ordering
    claim, token-identity miss, reuse-rate miss) fails the artifact gate
    even though the timing itself is finite."""
    rows = _healthy_rows()
    rows[-1]["derived"] = "hit_requests=0/5;reuse_and_token_identical_vs_cold=FAIL"
    p = tmp_path / "b.json"
    p.write_text(json.dumps(_run(rows)))
    errs = check_trajectory(str(p))
    assert errs and "FAIL" in errs[0]


def test_only_latest_run_is_gated(tmp_path):
    """Older broken runs don't fail the gate — the trajectory is history,
    the gate guards the current commit."""
    old = _run([])[0]
    new = _run(_healthy_rows())[0]
    p = tmp_path / "b.json"
    p.write_text(json.dumps([old, new]))
    assert check_trajectory(str(p)) == []


def test_unreadable_or_empty_fails(tmp_path):
    p = tmp_path / "missing.json"
    assert check_trajectory(str(p))
    p.write_text("[]")
    assert check_trajectory(str(p))


# ------------------------- latest-vs-previous regression gate (ISSUE 7)

def _two_runs(prev_us, cur_us):
    prev = _run(_healthy_rows())[0]
    cur = _run(_healthy_rows())[0]
    prev["rows"][0]["us_per_call"] = prev_us
    cur["rows"][0]["us_per_call"] = cur_us
    return [prev, cur]


def test_regression_beyond_threshold_flagged(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps(_two_runs(10.0, 16.0)))   # +60% > 50%
    probs = check_regressions(str(p))
    assert len(probs) == 1 and "+60%" in probs[0], probs
    # ...and fails main() unless --no-regress-gate demotes it
    assert main(["check_bench.py", str(p)]) == 1
    assert main(["check_bench.py", str(p), "--no-regress-gate"]) == 0


def test_within_threshold_passes(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps(_two_runs(10.0, 14.0)))   # +40% < 50%
    assert check_regressions(str(p)) == []
    assert main(["check_bench.py", str(p)]) == 0


def test_threshold_is_configurable(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps(_two_runs(10.0, 14.0)))
    assert check_regressions(str(p), threshold=0.25)
    assert main(["check_bench.py", str(p), "--threshold", "0.25"]) == 1
    assert check_regressions(str(p), threshold=1.0) == []


def test_single_run_has_nothing_to_compare(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps(_run(_healthy_rows())))
    assert check_regressions(str(p)) == []


def test_new_and_vanished_rows_not_regression_compared(tmp_path):
    """Row-set churn is the required-row scan's job; the regression gate
    only compares names present in BOTH runs."""
    prev = _run(_healthy_rows())[0]
    cur = _run(_healthy_rows())[0]
    prev["rows"] = prev["rows"][:-1]               # row added in cur
    cur["rows"][0]["name"] = "kernel/renamed/1"    # row vanished from cur
    p = tmp_path / "b.json"
    p.write_text(json.dumps([prev, cur]))
    assert check_regressions(str(p)) == []


# ------------------------------ since-seed anti-compounding gate (ISSUE 10)

def _seed_and_current(tmp_path, seed_us, *step_us):
    """A seed trajectory (first entry = baseline at ``seed_us``) and a
    current trajectory whose steps each grew gently to the last value."""
    seed = tmp_path / "seed.json"
    seed.write_text(json.dumps(_run(
        [dict(r, us_per_call=seed_us) for r in _healthy_rows()])))
    runs = [_run([dict(r, us_per_call=us) for r in _healthy_rows()])[0]
            for us in step_us]
    cur = tmp_path / "b.json"
    cur.write_text(json.dumps(runs))
    return str(cur), str(seed)


def test_since_seed_catches_compounded_drift(tmp_path):
    """Four +40% steps each pass the 50% latest-vs-previous gate, but
    the cumulative ~3.8x fails the since-seed gate — the compounding
    loophole this mode exists to close."""
    cur, seed = _seed_and_current(tmp_path, 10.0, 14.0, 19.6, 27.4, 38.4)
    assert check_regressions(cur) == []            # each step looks fine
    probs = check_since_seed(cur, seed)
    assert probs and all("since-seed" in m for m in probs)
    # only kernel/* rows are seed-gated (serving rows churn by design)
    assert all(m.startswith("kernel/") for m in probs)
    assert main(["check_bench.py", cur, "--since-seed", seed]) == 1
    assert main(["check_bench.py", cur]) == 0


def test_since_seed_threshold_and_new_rows(tmp_path):
    """Growth inside the (wider) seed threshold passes; rows without a
    seed baseline are skipped, not failed."""
    cur, seed = _seed_and_current(tmp_path, 10.0, 25.0)   # +150% < 200%
    assert check_since_seed(cur, seed) == []
    assert check_since_seed(cur, seed, threshold=1.0)     # tighter fails
    data = json.load(open(cur))
    data[-1]["rows"].append({"name": "kernel/brand_new/1",
                             "us_per_call": 999.0, "derived": "x"})
    open(cur, "w").write(json.dumps(data))
    assert check_since_seed(cur, seed) == []


def test_since_seed_missing_baseline_is_an_error(tmp_path):
    """An unreadable or kernel-row-less seed file must FAIL, not turn
    the gate off silently."""
    cur, seed = _seed_and_current(tmp_path, 10.0, 10.0)
    assert check_since_seed(cur, str(tmp_path / "nope.json"))
    (tmp_path / "seed.json").write_text("[]")
    assert check_since_seed(cur, seed)
    (tmp_path / "seed.json").write_text(json.dumps(_run(
        [{"name": "serving/only", "us_per_call": 1.0, "derived": "x"}])))
    assert check_since_seed(cur, seed)

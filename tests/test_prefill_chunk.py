"""Model-level chunked prefill vs one-shot prefill across the zoo.

Feeding a prompt through ``prefill_chunk`` in fixed chunks (padded tail,
masked) or exact dyadic chunks (recurrent archs) must fill the cache and
produce last-token logits matching the one-shot ``prefill``, and decode
must continue identically from either cache.  Covers the offset KV writes,
the ring-buffer concat path (SWA), encdec cross-KV caching, and the VLM
patch stub.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.policy import DENSE, paper_policy
from repro.core.pruner import precompute_scales
from repro.models import build_model

MAX_SEQ = 48


def _batch(cfg, toks):
    batch = {"tokens": toks}
    if cfg.is_encdec:
        batch["frame_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (1, cfg.encoder_seq, cfg.d_model))
    if cfg.vision_stub:
        batch["pixel_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (1, cfg.n_patches, cfg.d_model))
    return batch


def _chunk_plan(total, c, exact):
    if not exact:
        return [(off, min(c, total - off), c)
                for off in range(0, total, c)]
    plan, off = [], 0
    while off < total:
        size = c
        while size > total - off:
            size //= 2
        plan.append((off, size, size))
        off += size
    return plan


@pytest.mark.parametrize("arch,nm,exact", [
    ("llama31_8b", None, False),
    ("llama31_8b", (2, 4), False),
    ("recurrentgemma_2b", None, True),   # rglru + SWA ring attention
    ("whisper_medium", None, False),     # encdec cross-KV chunk-0 caching
    ("qwen2_vl_2b", None, False),        # VLM patch stub on chunk 0
])
def test_prefill_chunk_matches_oneshot(arch, nm, exact):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    policy = DENSE if nm is None else paper_policy(*nm, cfg.qgate_skip_layers)
    params = precompute_scales(params, policy)
    T, C = 23, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, T), 0,
                              cfg.vocab_size)
    batch = _batch(cfg, toks)

    cache1 = model.init_cache(1, MAX_SEQ)
    l1, cache1 = model.prefill(params, batch, cache1, policy=policy)

    cache2 = model.init_cache(1, MAX_SEQ)
    for off, v, size in _chunk_plan(T, C, exact):
        chunk = jnp.zeros((1, size), toks.dtype)
        chunk = chunk.at[:, :v].set(toks[:, off:off + v])
        b2 = {"tokens": chunk, "chunk_len": jnp.asarray(v, jnp.int32)}
        if off == 0:
            for k in ("frame_embeds", "pixel_embeds"):
                if k in batch:
                    b2[k] = batch[k]
        l2, cache2 = model.prefill_chunk(params, b2, cache2, policy=policy)

    assert int(cache2["pos"]) == T
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=5e-5)

    # decode continues identically from either cache
    tok = jnp.argmax(l1, -1)[:, None].astype(jnp.int32)
    d1, _ = model.decode_step(params, tok, cache1, policy=DENSE)
    d2, _ = model.decode_step(params, tok, cache2, policy=DENSE)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=5e-5)
    assert int(jnp.argmax(d1, -1)[0]) == int(jnp.argmax(d2, -1)[0])

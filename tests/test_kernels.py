"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(32, 64, 64), (64, 128, 96), (128, 256, 128), (256, 512, 256)]
PATTERNS = [(2, 4), (4, 8), (8, 16)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("t,d,no", SHAPES)
@pytest.mark.parametrize("n,m", PATTERNS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_nm_prune_kernel(t, d, no, n, m, dtype, rng):
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (t, d), dtype=dtype)
    scale = jax.random.uniform(k2, (d,)) + 0.5
    got = ops.nm_prune(x, scale, n, m)
    want = ref.nm_prune_ref(x, scale, n, m)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    # structural check: valid N:M sparsity
    groups = np.asarray(got != 0, np.int32).reshape(t, d // m, m).sum(-1)
    assert (groups <= n).all()


@pytest.mark.parametrize("t,d,no", SHAPES)
@pytest.mark.parametrize("n,m", PATTERNS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_nm_spmm_kernel(t, d, no, n, m, dtype, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    x = jax.random.normal(k1, (t, d), dtype=dtype)
    w = jax.random.normal(k2, (d, no), dtype=dtype)
    scale = jax.random.uniform(k3, (d,)) + 0.5
    tile = min(32, t)
    got = ops.nm_spmm(x, w, scale, n, m, tile=tile)
    want = ref.nm_spmm_ref(x, w, scale, n, m, tile=tile)
    tol = dict(rtol=5e-2, atol=5e-1) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("t,d,no", SHAPES)
def test_w8a8_kernel(t, d, no, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    xq = jax.random.randint(k1, (t, d), -127, 128).astype(jnp.int8)
    wq = jax.random.randint(k2, (d, no), -127, 128).astype(jnp.int8)
    xs = jnp.float32(0.013)
    ws = jax.random.uniform(k3, (no,)) * 0.02
    got = ops.w8a8_matmul(xq, wq, xs, ws)
    want = ref.w8a8_matmul_ref(xq, wq, xs, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_nm_prune_no_scale_matches_naive(rng):
    x = jax.random.normal(rng, (64, 128))
    got = ops.nm_prune(x, None, 2, 4)
    want = ref.nm_prune_ref(x, None, 2, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)


def test_kernel_batched_inputs(rng):
    x = jax.random.normal(rng, (2, 16, 128))
    got = ops.nm_prune(x, None, 4, 8)
    want = ref.nm_prune_ref(x.reshape(32, 128), None, 4, 8).reshape(2, 16, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)


@pytest.mark.parametrize("b,h,t,s,d,causal", [
    (2, 4, 64, 64, 32, True),
    (1, 2, 128, 128, 64, True),
    (2, 2, 64, 128, 32, False),
    (1, 8, 256, 256, 128, True),
])
def test_flash_attention_kernel(b, h, t, s, d, causal, rng):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.ref import flash_attention_ref
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (b, h, t, d))
    k = jax.random.normal(k2, (b, h, s, d))
    v = jax.random.normal(k3, (b, h, s, d))
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=32,
                                 block_k=32)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("t,d,w", [(128, 32, 32), (256, 64, 64),
                                   (128, 32, 96)])
def test_flash_attention_sliding_window(t, d, w, rng):
    """SWA band variant (mixtral/recurrentgemma prefill) vs oracle —
    off-band KV blocks are skipped at block granularity (O(T·window))."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.ref import flash_attention_ref
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (1, 2, t, d))
    k = jax.random.normal(k2, (1, 2, t, d))
    v = jax.random.normal(k3, (1, 2, t, d))
    got = flash_attention_pallas(q, k, v, causal=True, window=w,
                                 block_q=32, block_k=32)
    want = flash_attention_ref(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_dtypes(dtype, rng):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.ref import flash_attention_ref
    q = jax.random.normal(rng, (1, 2, 64, 32), dtype=dtype)
    got = flash_attention_pallas(q, q, q, block_q=32, block_k=32)
    want = flash_attention_ref(q, q, q)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_nm_spmm_flop_advantage_structure(rng):
    """The compacted contraction must touch exactly D·n/m weight rows/tile."""
    from repro.core import nm as nmod
    from repro.core import scoring
    x = jax.random.normal(rng, (32, 64))
    s = scoring.score_activations(x, None)
    chans = nmod.tile_consensus_channels(s, 2, 4)
    assert chans.shape == (16, 2)        # D/m groups × n survivors
    xc = nmod.compact_columns(x, chans)
    assert xc.shape == (32, 32)          # D·n/m = 64·2/4

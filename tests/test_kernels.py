"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(32, 64, 64), (64, 128, 96), (128, 256, 128), (256, 512, 256)]
PATTERNS = [(2, 4), (4, 8), (8, 16)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("t,d,no", SHAPES)
@pytest.mark.parametrize("n,m", PATTERNS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_nm_prune_kernel(t, d, no, n, m, dtype, rng):
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (t, d), dtype=dtype)
    scale = jax.random.uniform(k2, (d,)) + 0.5
    got = ops.nm_prune(x, scale, n, m)
    want = ref.nm_prune_ref(x, scale, n, m)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    # structural check: valid N:M sparsity
    groups = np.asarray(got != 0, np.int32).reshape(t, d // m, m).sum(-1)
    assert (groups <= n).all()


@pytest.mark.parametrize("t,d,no", SHAPES)
@pytest.mark.parametrize("n,m", PATTERNS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_nm_spmm_kernel(t, d, no, n, m, dtype, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    x = jax.random.normal(k1, (t, d), dtype=dtype)
    w = jax.random.normal(k2, (d, no), dtype=dtype)
    scale = jax.random.uniform(k3, (d,)) + 0.5
    tile = min(32, t)
    got = ops.nm_spmm(x, w, scale, n, m, tile=tile)
    want = ref.nm_spmm_ref(x, w, scale, n, m, tile=tile)
    tol = dict(rtol=5e-2, atol=5e-1) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("t,d,no", SHAPES)
def test_w8a8_kernel(t, d, no, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    xq = jax.random.randint(k1, (t, d), -127, 128).astype(jnp.int8)
    wq = jax.random.randint(k2, (d, no), -127, 128).astype(jnp.int8)
    xs = jnp.float32(0.013)
    ws = jax.random.uniform(k3, (no,)) * 0.02
    got = ops.w8a8_matmul(xq, wq, xs, ws)
    want = ref.w8a8_matmul_ref(xq, wq, xs, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_nm_prune_no_scale_matches_naive(rng):
    x = jax.random.normal(rng, (64, 128))
    got = ops.nm_prune(x, None, 2, 4)
    want = ref.nm_prune_ref(x, None, 2, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)


def test_kernel_batched_inputs(rng):
    x = jax.random.normal(rng, (2, 16, 128))
    got = ops.nm_prune(x, None, 4, 8)
    want = ref.nm_prune_ref(x.reshape(32, 128), None, 4, 8).reshape(2, 16, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)


@pytest.mark.parametrize("b,h,t,s,d,causal", [
    (2, 4, 64, 64, 32, True),
    (1, 2, 128, 128, 64, True),
    (2, 2, 64, 128, 32, False),
    (1, 8, 256, 256, 128, True),
])
def test_flash_attention_kernel(b, h, t, s, d, causal, rng):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.ref import flash_attention_ref
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (b, h, t, d))
    k = jax.random.normal(k2, (b, h, s, d))
    v = jax.random.normal(k3, (b, h, s, d))
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=32,
                                 block_k=32)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("t,d,w", [(128, 32, 32), (256, 64, 64),
                                   (128, 32, 96)])
def test_flash_attention_sliding_window(t, d, w, rng):
    """SWA band variant (mixtral/recurrentgemma prefill) vs oracle —
    off-band KV blocks are skipped at block granularity (O(T·window))."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.ref import flash_attention_ref
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (1, 2, t, d))
    k = jax.random.normal(k2, (1, 2, t, d))
    v = jax.random.normal(k3, (1, 2, t, d))
    got = flash_attention_pallas(q, k, v, causal=True, window=w,
                                 block_q=32, block_k=32)
    want = flash_attention_ref(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_dtypes(dtype, rng):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.ref import flash_attention_ref
    q = jax.random.normal(rng, (1, 2, 64, 32), dtype=dtype)
    got = flash_attention_pallas(q, q, q, block_q=32, block_k=32)
    want = flash_attention_ref(q, q, q)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


# -------------------------------------------------------- paged attention

def _paged_inputs(rng, nb=10, bs=8, mb=6, B=3, Hkv=2, hd=16,
                  dtype=jnp.float32):
    """Pool + permuted per-row block tables with -1 tails (physical block 0
    left unreferenced so the fencing tests can poison it)."""
    k1, k2 = jax.random.split(rng)
    kp = jax.random.normal(k1, (nb, bs, Hkv, hd), dtype=dtype)
    vp = jax.random.normal(k2, (nb, bs, Hkv, hd), dtype=dtype)
    tab = np.full((B, mb), -1, np.int32)
    perm = np.random.default_rng(7).permutation(np.arange(1, nb))
    tab[0, :3] = perm[:3]
    tab[1, :5] = perm[3:8]
    tab[2, :2] = perm[8:10][:2] if len(perm) > 9 else perm[-2:]
    return kp, vp, jnp.asarray(tab)


@pytest.mark.parametrize("dtype", DTYPES)
def test_paged_attention_kernel_prefill_parity(dtype, rng):
    """Chunked-prefill shape (scalar q_offset, causal) through the
    in-kernel block-table walk vs the jnp gather oracle."""
    from repro.models.attention import paged_attention
    kp, vp, tab = _paged_inputs(rng, dtype=dtype)
    q = jax.random.normal(jax.random.fold_in(rng, 1), (3, 8, 4, 16),
                          dtype=dtype)
    kvl = jnp.asarray([21, 38, 13], jnp.int32)
    kw = dict(causal=True, q_offset=jnp.asarray(13, jnp.int32),
              kv_len=kvl, chunk=32)
    got = paged_attention(q, kp, vp, tab, use_kernel=True, interpret=True,
                          **kw)
    want = paged_attention(q, kp, vp, tab, use_kernel=False, **kw)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_paged_attention_kernel_decode_parity(rng):
    """Vector-position decode (per-row q_offset / kv_len, non-causal
    single-query) through the kernel vs the gather oracle."""
    from repro.models.attention import paged_attention
    kp, vp, tab = _paged_inputs(rng)
    q = jax.random.normal(jax.random.fold_in(rng, 2), (3, 1, 4, 16))
    posv = jnp.asarray([20, 37, 10], jnp.int32)
    kw = dict(causal=False, q_offset=posv, kv_len=posv + 1, chunk=32)
    got = paged_attention(q, kp, vp, tab, use_kernel=True, interpret=True,
                          **kw)
    want = paged_attention(q, kp, vp, tab, use_kernel=False, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_kernel_window_parity(rng):
    """The kernel's sliding-window band (the hook for paging SWA caches —
    not yet reachable through the model dispatch, which keeps windowed
    paged shapes on the oracle) vs the gather oracle with the same
    window."""
    from repro.kernels.paged_attention import paged_attention_pallas
    from repro.models.attention import paged_attention
    kp, vp, tab = _paged_inputs(rng)
    q = jax.random.normal(jax.random.fold_in(rng, 3), (3, 8, 4, 16))
    qoff = jnp.asarray([13, 30, 5], jnp.int32)
    kvl = jnp.asarray([21, 38, 13], jnp.int32)
    w = 6
    got = paged_attention_pallas(q, kp, vp, tab, qoff, kvl, causal=True,
                                 window=w, block_q=4, interpret=True)
    want = paged_attention(q, kp, vp, tab, causal=True, window=w,
                           q_offset=qoff, kv_len=kvl, chunk=32,
                           use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_kernel_gqa_and_single_head(rng):
    from repro.models.attention import paged_attention
    for hq in (2, 8):                       # G = 1 and G = 4
        kp, vp, tab = _paged_inputs(rng)
        q = jax.random.normal(jax.random.fold_in(rng, hq), (3, 4, hq, 16))
        kvl = jnp.asarray([17, 33, 9], jnp.int32)
        kw = dict(causal=True, q_offset=jnp.asarray(5, jnp.int32),
                  kv_len=kvl, chunk=32)
        got = paged_attention(q, kp, vp, tab, use_kernel=True,
                              interpret=True, **kw)
        want = paged_attention(q, kp, vp, tab, use_kernel=False, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5, err_msg=f"Hq={hq}")


# --------------------------------------------- windowed flash self-attention

from repro.analysis.jaxpr_utils import (  # noqa: E402
    has_pallas_call as _has_pallas_call)


@pytest.mark.parametrize("t,d,w", [(128, 32, 32), (256, 64, 96)])
def test_windowed_self_attention_routes_through_flash(t, d, w, rng):
    """ISSUE-4 satellite: ``attention(impl="flash")`` with a sliding window
    used to fall back to the jnp scans even though the kernel implements
    windowed masking + KV-block skipping — the windowed T == S case must
    now lower a pallas_call and match the ``_banded_attention`` path
    (``impl="chunked"`` routes there for exactly this shape)."""
    from repro.models.attention import attention
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (2, t, 4, d))
    k = jax.random.normal(k2, (2, t, 2, d))      # GQA
    v = jax.random.normal(k3, (2, t, 2, d))

    flash = lambda q, k, v: attention(q, k, v, causal=True, window=w,
                                      impl="flash")
    banded = lambda q, k, v: attention(q, k, v, causal=True, window=w,
                                       impl="chunked", chunk=64)
    np.testing.assert_allclose(np.asarray(flash(q, k, v)),
                               np.asarray(banded(q, k, v)),
                               rtol=2e-5, atol=2e-5)
    # the windowed branch is no longer dead code …
    assert _has_pallas_call(jax.make_jaxpr(flash)(q, k, v).jaxpr)
    # … and the banded jnp oracle stays kernel-free
    assert not _has_pallas_call(jax.make_jaxpr(banded)(q, k, v).jaxpr)


def test_nm_spmm_flop_advantage_structure(rng):
    """The compacted contraction must touch exactly D·n/m weight rows/tile."""
    from repro.core import nm as nmod
    from repro.core import scoring
    x = jax.random.normal(rng, (32, 64))
    s = scoring.score_activations(x, None)
    chans = nmod.tile_consensus_channels(s, 2, 4)
    assert chans.shape == (16, 2)        # D/m groups × n survivors
    xc = nmod.compact_columns(x, chans)
    assert xc.shape == (32, 32)          # D·n/m = 64·2/4

"""Fault-injection chaos harness for the continuous serving engine (ISSUE 6).

The contract: faults may change WHEN work happens — never WHAT surviving
requests emit.  Every scenario runs a seeded request stream under one
fault family (pool exhaustion, eviction storms, non-finite kernel output,
kernel compile failure, mid-iteration crash + restore, deadline/cancel
storms, admission livelock) and asserts

  * token-identity with the undisturbed run for every surviving request,
  * zero block leaks after drain (``pool.in_use == 0``, plus the
    per-iteration refcount/ownership audit — on for the whole suite via
    ``REPRO_VALIDATE_POOL=1`` in conftest.py),
  * terminal-state accounting (every request ends in exactly one of
    DONE/REJECTED/TIMED_OUT/CANCELLED).

Replay: injector seeds derive from ``REPRO_CHAOS_SEED`` (CI runs a small
seed matrix); on failure, ``chaos_guard`` dumps the injector's schedule +
fired log as JSON into ``REPRO_CHAOS_ARTIFACT_DIR`` so the exact scenario
replays locally with ``FaultInjector.from_json``.
"""
import contextlib
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.policy import DENSE, paper_policy
from repro.core.pruner import precompute_scales
from repro.models import build_model
from repro.serve import (ContinuousConfig, ContinuousServingEngine,
                         ServeConfig, ServingEngine)
from repro.serve.continuous import (CANCELLED, DONE, REJECTED, TIMED_OUT,
                                    _TERMINAL)
from repro.serve.faults import EngineCrash, FaultInjector, FaultSpec

MAX_SEQ = 64
CHAOS_SEED = int(os.environ.get("REPRO_CHAOS_SEED", "0"))


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_smoke_config("llama31_8b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens, seed0=400):
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(seed0 + i),
                                          (l,), 0, cfg.vocab_size))
            for i, l in enumerate(lens)]


def _oracle(model, params, policy, prompt, max_new):
    eng = ServingEngine(model, policy, ServeConfig(max_seq=MAX_SEQ))
    out = eng.generate(params, {"tokens": jnp.asarray(prompt)[None, :]},
                       max_new_tokens=max_new)
    return np.asarray(out["tokens"])[0].tolist()


def _engine(model, policy=DENSE, faults=None, **kw):
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("num_slots", 2)
    kw.setdefault("chunk_size", 8)
    kw.setdefault("block_size", 4)
    kw.setdefault("validate_pool", True)
    return ContinuousServingEngine(model, policy, ContinuousConfig(**kw),
                                   faults=faults)


def _drained(eng):
    """Post-drain leak check: every request terminal and holding nothing,
    every block back in the free list or parked zero-ref in the LRU."""
    assert all(r.state in _TERMINAL for r in eng.requests)
    assert all(not r.blocks and r.slot == -1 for r in eng.requests)
    if eng.paged:
        assert eng.pool.in_use == 0, "leaked live blocks after drain"
        eng.pool.check_invariants()


@contextlib.contextmanager
def chaos_guard(injector, name):
    """Dump the fault schedule + fired log on test failure so CI uploads
    it and the scenario replays locally (FaultInjector.from_json)."""
    try:
        yield
    except BaseException:
        art = os.environ.get("REPRO_CHAOS_ARTIFACT_DIR")
        if art and injector is not None:
            os.makedirs(art, exist_ok=True)
            with open(os.path.join(art, f"{name}.json"), "w") as f:
                f.write(injector.to_json())
        raise


# ------------------------------------------------------- injector mechanics

def test_injector_deterministic_replay():
    sched = [FaultSpec("pool.alloc", "exhausted", p=0.3),
             FaultSpec("decode", "nonfinite", calls=(2, 5), limit=1),
             FaultSpec("admit", "transient", iters=(1,))]

    def drive(inj):
        for it in range(4):
            inj.tick(it)
            for site in ("admit", "pool.alloc", "decode", "pool.alloc"):
                inj.fire(site)
        return inj.fired

    a = drive(FaultInjector(seed=7, schedule=sched))
    b = drive(FaultInjector(seed=7, schedule=sched))
    assert a == b and len(a) >= 2
    # round-trip through the CI artifact format reproduces the scenario
    c = drive(FaultInjector.from_json(
        FaultInjector(seed=7, schedule=sched).to_json()))
    assert c == a
    # a different seed perturbs only the probabilistic spec
    d = drive(FaultInjector(seed=8, schedule=sched))
    assert ([f for f in d if f["site"] != "pool.alloc"]
            == [f for f in a if f["site"] != "pool.alloc"])

    with pytest.raises(AssertionError):
        FaultSpec("no.such.site", "boom")


def test_clean_run_records_no_degradation(tiny):
    """Acceptance: zero degraded iterations, retries, or fault counters on
    an undisturbed run — the hardening is pay-per-fault."""
    cfg, model, params = tiny
    eng = _engine(model)
    for p, a in zip(_prompts(cfg, [9, 14]), [0, 1]):
        eng.submit(p, max_new_tokens=6, arrival=a)
    res = eng.run(params)
    m = res["metrics"]
    assert m["degraded_iterations"] == 0
    lc = m["lifecycle"]
    assert lc["admission_retries"] == lc["watchdog_trips"] == 0
    assert lc["timeouts"] == lc["cancellations"] == lc["faults_fired"] == 0
    assert lc["terminal_states"] == {DONE: 2, REJECTED: 0, TIMED_OUT: 0,
                                     CANCELLED: 0}
    assert not any(k.endswith("_oracle") for k in eng.trace_counts)
    _drained(eng)


# ------------------------------------- family 1: pool exhaustion + retries

def test_pool_exhaustion_retries_token_identical(tiny):
    """Injected allocation failures during admission are absorbed by
    bounded retry-with-backoff: every request still completes with the
    undisturbed outputs, and the rolled-back admissions leak nothing."""
    cfg, model, params = tiny
    lens, arrivals, max_new = [9, 17, 6, 12], [0, 0, 2, 3], 6
    prompts = _prompts(cfg, lens, seed0=410)

    def serve(faults):
        eng = _engine(model, faults=faults, num_slots=3)
        for p, a in zip(prompts, arrivals):
            eng.submit(p, max_new_tokens=max_new, arrival=a)
        return eng, eng.run(params)

    _, base = serve(None)
    inj = FaultInjector(seed=CHAOS_SEED, schedule=[
        # the first two admissions fail outright, then a random 30% of
        # later allocations (capped so the retry budget always wins)
        FaultSpec("pool.alloc", "exhausted", calls=(0, 1)),
        FaultSpec("pool.alloc", "exhausted", p=0.3, limit=4),
    ])
    with chaos_guard(inj, "pool_exhaustion"):
        eng, res = serve(inj)
        assert res["outputs"] == base["outputs"], \
            "injected exhaustion changed surviving outputs"
        lc = res["metrics"]["lifecycle"]
        assert lc["admission_retries"] >= 2
        assert lc["terminal_states"][DONE] == len(prompts)
        assert inj.total_fired >= 2
        _drained(eng)


def test_eviction_storm_token_identical(tiny):
    """Flushing the zero-ref prefix LRU at random allocations (cache-
    pressure storm) may cost recompute but never changes tokens."""
    cfg, model, params = tiny
    sysp = _prompts(cfg, [16], seed0=420)[0]
    prompts = [np.concatenate([sysp, p])
               for p in _prompts(cfg, [6, 9, 7], seed0=421)]
    arrivals, max_new = [0, 3, 5], 6

    def serve(faults):
        eng = _engine(model, faults=faults, num_slots=3)
        for p, a in zip(prompts, arrivals):
            eng.submit(p, max_new_tokens=max_new, arrival=a)
        return eng, eng.run(params)

    _, base = serve(None)
    inj = FaultInjector(seed=CHAOS_SEED, schedule=[
        FaultSpec("pool.alloc", "evict_storm", calls=(3,)),
        FaultSpec("pool.alloc", "evict_storm", p=0.25),
    ])
    with chaos_guard(inj, "evict_storm"):
        eng, res = serve(inj)
        assert res["outputs"] == base["outputs"]
        assert inj.total_fired >= 1
        assert res["metrics"]["lifecycle"]["terminal_states"][DONE] \
            == len(prompts)
        _drained(eng)


# --------------------------- family 2: non-finite logits → oracle re-run

@pytest.mark.parametrize("site,calls", [("prefill", (1, 3)),
                                        ("decode", (0, 4))])
def test_nonfinite_output_degrades_to_oracle(tiny, site, calls):
    """Acceptance: a NaN-producing iteration is detected host-side, the
    faulted outputs are discarded, and the same operands re-run on the
    jnp oracle program — tokens match the undisturbed run and the
    degradation is metered."""
    cfg, model, params = tiny
    lens, arrivals, max_new = [11, 18, 7], [0, 1, 2], 7
    prompts = _prompts(cfg, lens, seed0=430)

    def serve(faults):
        eng = _engine(model, faults=faults)
        for p, a in zip(prompts, arrivals):
            eng.submit(p, max_new_tokens=max_new, arrival=a)
        return eng, eng.run(params)

    _, base = serve(None)
    inj = FaultInjector(seed=CHAOS_SEED, schedule=[
        FaultSpec(site, "nonfinite", calls=calls)])
    with chaos_guard(inj, f"nonfinite_{site}"):
        eng, res = serve(inj)
        assert res["outputs"] == base["outputs"], \
            "degraded iterations changed tokens"
        assert res["metrics"]["degraded_iterations"] == len(calls)
        # the lazily-traced oracle twins compiled exactly once per step
        # bucket the faulted iterations landed in (fused default: the
        # whole hybrid step degrades, so the oracle key is the bucket's)
        oracle = {k: v for k, v in eng.trace_counts.items()
                  if k.endswith("_oracle")}
        assert oracle and all(v == 1 for v in oracle.values()), \
            eng.trace_counts
        assert len(oracle) <= len(calls)
        _drained(eng)


# ------------------------ family 3: kernel faults at the dispatch ladder

@pytest.mark.parametrize("site", ["kernel.projection",
                                  "kernel.paged_attention"])
def test_kernel_compile_failure_degrades_to_oracle(tiny, site, monkeypatch):
    """A simulated Mosaic lowering failure aborts the trace; the engine
    re-runs the iteration on the kernels-off oracle jit and the request
    stream completes token-identically (kernel ≡ oracle math)."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    cfg, model, params = tiny
    if site == "kernel.projection":
        policy = paper_policy(8, 16, cfg.qgate_skip_layers,
                              use_pallas_kernels=True)
        params = precompute_scales(params, policy)
    else:
        policy = DENSE.with_(use_pallas_kernels=True)
    lens, arrivals, max_new = [9, 13], [0, 1], 5
    prompts = _prompts(cfg, lens, seed0=440)

    def serve(faults):
        eng = _engine(model, policy, faults=faults)
        for p, a in zip(prompts, arrivals):
            eng.submit(p, max_new_tokens=max_new, arrival=a)
        return eng, eng.run(params)

    _, base = serve(None)
    # fire on the first dispatch consult: kernel dispatch runs at trace
    # time, so only the first call per shape bucket ever consults the site
    # (exactly like a real compile — it happens once)
    inj = FaultInjector(seed=CHAOS_SEED, schedule=[
        FaultSpec(site, "compile_error", calls=(0,), limit=1)])
    with chaos_guard(inj, f"compile_{site.split('.')[-1]}"):
        eng, res = serve(inj)
        assert res["outputs"] == base["outputs"]
        assert res["metrics"]["degraded_iterations"] == 1
        assert inj.fired_kinds(site) == ["compile_error"]
        # the aborted trace was not cached: the primary program re-traced
        # on the next call and served the rest of the run
        _drained(eng)


def test_kernel_fallback_is_silent(tiny, monkeypatch):
    """The "fallback" kind routes a dispatch onto the jnp oracle branch
    WITHOUT an exception: same tokens, no degradation recorded (it is the
    ladder's ordinary uncovered-shape path, not a failure)."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    cfg, model, params = tiny
    policy = DENSE.with_(use_pallas_kernels=True)
    prompts = _prompts(cfg, [10, 15], seed0=450)

    def serve(faults):
        eng = _engine(model, policy, faults=faults)
        for p, a in zip(prompts, [0, 1]):
            eng.submit(p, max_new_tokens=5, arrival=a)
        return eng, eng.run(params)

    _, base = serve(None)
    inj = FaultInjector(seed=CHAOS_SEED, schedule=[
        FaultSpec("kernel.paged_attention", "fallback", calls=(0,))])
    with chaos_guard(inj, "kernel_fallback"):
        eng, res = serve(inj)
        assert res["outputs"] == base["outputs"]
        assert res["metrics"]["degraded_iterations"] == 0
        assert inj.total_fired == 1
        _drained(eng)


# --------------------- family 4: mid-iteration crash + snapshot/restore

def test_crash_restore_resumes_token_identical(tiny):
    """Acceptance: EngineCrash mid-decode kills the engine; a NEW engine
    restored from the last auto-snapshot (request lifecycles, pool state,
    iteration clock, PRNG) finishes the stream with exactly the
    undisturbed outputs — in-flight requests replay through prefill, the
    same recompute path preemption uses."""
    cfg, model, params = tiny
    lens, arrivals, max_new = [9, 16, 12], [0, 1, 2], 8
    prompts = _prompts(cfg, lens, seed0=460)

    def submit_all(eng):
        for p, a in zip(prompts, arrivals):
            eng.submit(p, max_new_tokens=max_new, arrival=a)

    base_eng = _engine(model)
    submit_all(base_eng)
    base = base_eng.run(params)

    inj = FaultInjector(seed=CHAOS_SEED, schedule=[
        FaultSpec("decode", "crash", iters=tuple(range(4, 9)), limit=1),
        FaultSpec("prefill", "crash", iters=tuple(range(11, 15)), limit=1),
    ])
    with chaos_guard(inj, "crash_restore"):
        eng = _engine(model, faults=inj, snapshot_every=1)
        submit_all(eng)
        res, crashes = None, 0
        for _ in range(5):
            try:
                res = eng.run(params)
                break
            except EngineCrash:
                crashes += 1
                snap = eng.last_snapshot
                assert snap is not None
                # the crashed engine is dead: rebuild from scratch and
                # restore host state (device KV is lost by construction)
                eng = _engine(model, faults=inj, snapshot_every=1)
                eng.restore(snap)
        assert res is not None, "engine never finished after restores"
        assert crashes >= 1 and eng.restores == crashes
        assert res["outputs"] == base["outputs"], \
            "crash+restore changed tokens"
        lc = res["metrics"]["lifecycle"]
        assert lc["terminal_states"][DONE] == len(prompts)
        _drained(eng)


def test_snapshot_is_deep_and_reusable(tiny):
    """A snapshot is isolated from the live engine (deep-copied requests)
    and restoring the same snapshot twice yields the same completion."""
    cfg, model, params = tiny
    prompts = _prompts(cfg, [10, 14], seed0=470)
    inj = FaultInjector(seed=CHAOS_SEED, schedule=[
        FaultSpec("decode", "crash", iters=(5,), limit=1)])
    eng = _engine(model, faults=inj, snapshot_every=2)
    for p, a in zip(prompts, [0, 1]):
        eng.submit(p, max_new_tokens=6, arrival=a)
    with pytest.raises(EngineCrash):
        eng.run(params)
    snap = eng.last_snapshot
    outs = []
    for _ in range(2):
        e2 = _engine(model)
        e2.restore(snap)
        outs.append(e2.run(params)["outputs"])
        _drained(e2)
    assert outs[0] == outs[1]
    base = _engine(model)
    for p, a in zip(prompts, [0, 1]):
        base.submit(p, max_new_tokens=6, arrival=a)
    assert outs[0] == base.run(params)["outputs"]


# ----------------------------- family 5: deadline / cancellation storms

def test_deadline_and_cancel_storm(tiny):
    """TTL expiry and cancel() unwind requests from every lifecycle phase
    (waiting, mid-prefill, decoding) without touching the survivors'
    tokens or leaking a single block."""
    cfg, model, params = tiny
    lens = [9, 16, 20, 8, 11]
    arrivals = [0, 0, 1, 2, 3]
    max_new = 8
    prompts = _prompts(cfg, lens, seed0=480)
    eng = _engine(model, num_slots=2)
    for i, (p, a) in enumerate(zip(prompts, arrivals)):
        # rid 1 gets a deadline it cannot meet (prefill alone outlasts it)
        eng.submit(p, max_new_tokens=max_new, arrival=a,
                   ttl=3 if i == 1 else None)

    seen = {}

    def hook(engine, it):
        r2 = engine.requests[2]
        if r2.state == "prefill" and r2.filled > 0 and 2 not in seen:
            seen[2] = ("mid-prefill", it)       # cancel with a hot slot
            assert engine.cancel(2)
        r3 = engine.requests[3]
        if it == 1 and r3.state == "waiting":
            seen[3] = ("waiting", it)           # cancel before admission
            assert engine.cancel(3)

    eng.iteration_hook = hook
    res = eng.run(params)
    states = {r.rid: r.state for r in eng.requests}
    assert states[1] == TIMED_OUT
    assert states[2] == CANCELLED and seen[2][0] == "mid-prefill"
    assert states[3] == CANCELLED and seen[3][0] == "waiting"
    assert states[0] == states[4] == DONE
    for rid in (0, 4):
        assert res["outputs"][rid] == _oracle(model, params, DENSE,
                                              prompts[rid], max_new), \
            f"survivor {rid} drifted"
    lc = res["metrics"]["lifecycle"]
    assert lc["timeouts"] == 1 and lc["cancellations"] == 2
    assert sum(lc["terminal_states"].values()) == len(prompts)
    # double-cancel and cancelling a finished request are clean no-ops
    assert not eng.cancel(2) and not eng.cancel(0)
    _drained(eng)


# ------------------------------------ watchdog: livelock → forced reject

def test_watchdog_breaks_admission_livelock(tiny):
    """With a persistent allocation fault and an effectively unbounded
    retry budget, nothing can ever admit — the no-progress watchdog must
    force-reject the stuck requests instead of spinning to max_iters."""
    cfg, model, params = tiny
    inj = FaultInjector(seed=CHAOS_SEED, schedule=[
        FaultSpec("pool.alloc", "exhausted", p=1.0)])
    eng = _engine(model, faults=inj, admission_retries=10 ** 6,
                  watchdog_iters=8)
    for p, a in zip(_prompts(cfg, [9, 12], seed0=490), [0, 1]):
        eng.submit(p, max_new_tokens=4, arrival=a)
    with chaos_guard(inj, "watchdog_livelock"):
        res = eng.run(params)
        lc = res["metrics"]["lifecycle"]
        assert lc["watchdog_trips"] >= 1
        assert lc["terminal_states"][REJECTED] == 2
        assert res["metrics"]["iterations"] < 200, "livelock not bounded"
        assert all(not out for out in res["outputs"].values())
        _drained(eng)


# ---------------- satellite: preemption storm × cancellation × kernels

@pytest.mark.parametrize("attn_kernel", [False, True],
                         ids=["gather-oracle", "pallas-kernel"])
def test_preemption_storm_cancel_interleaving(tiny, attn_kernel,
                                              monkeypatch):
    """Undersized pool → sustained preemption churn, plus a cancel landing
    mid-prefill: survivors stay token-identical on both the jnp gather
    oracle and the Pallas block-walk kernel, and the cancelled request's
    unwind never leaves a writable shared block (per-iteration audit +
    post-drain reclaim check)."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    cfg, model, params = tiny
    policy = DENSE.with_(use_pallas_kernels=True) if attn_kernel else DENSE
    lens, arrivals, max_new = [16, 18, 14, 15], [0, 1, 2, 3], 8
    prompts = _prompts(cfg, lens, seed0=500)

    seen = {}

    def hook(engine, it):
        r1 = engine.requests[1]
        if r1.state == "prefill" and r1.filled > 0 and 1 not in seen:
            seen[1] = it
            engine.cancel(1)

    # 3 slots over a pool that cannot hold 3 fully-grown requests:
    # decode growth must preempt, and the cancel frees blocks mid-storm
    eng = _engine(model, policy, num_slots=3, num_blocks=14)
    eng.iteration_hook = hook
    for p, a in zip(prompts, arrivals):
        eng.submit(p, max_new_tokens=max_new, arrival=a)
    res = eng.run(params)
    assert 1 in seen, "cancel never landed mid-prefill"
    r1 = eng.requests[1]
    assert r1.state == CANCELLED and not r1.blocks and r1.slot == -1
    for rid in (0, 2, 3):
        assert res["outputs"][rid] == _oracle(model, params, DENSE,
                                              prompts[rid], max_new), \
            f"survivor {rid} drifted under preemption+cancel"
    assert res["metrics"]["paged"]["attention_kernel"] is attn_kernel
    assert res["metrics"]["paged"]["preemptions"] >= 1, \
        "pool was not actually under pressure"
    _drained(eng)

"""Scoring (naive / Wanda-like / Robust-Norm) unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import scoring


def test_channel_norm_scale_min_normalized(rng):
    w = jax.random.normal(rng, (32, 64))
    s = scoring.channel_norm_scale(w)
    assert s.shape == (32,)
    assert float(jnp.min(s)) == pytest.approx(1.0, rel=1e-5)


def test_robust_norm_scale_clips_outliers(rng):
    w = jax.random.normal(rng, (64, 128))
    # inject a huge outlier into channel 0 — robust scale must not explode
    w_out = w.at[0, 0].set(1e6)
    s_plain = scoring.channel_norm_scale(w_out)
    s_robust = scoring.robust_norm_scale(w_out)
    ratio_plain = float(s_plain[0] / jnp.median(s_plain))
    ratio_robust = float(s_robust[0] / jnp.median(s_robust))
    assert ratio_robust < ratio_plain / 100  # outlier influence crushed


def test_score_activations_naive_vs_scaled(rng):
    x = jax.random.normal(rng, (8, 32))
    s_naive = scoring.score_activations(x, None)
    np.testing.assert_allclose(np.asarray(s_naive),
                               np.abs(np.asarray(x)), rtol=1e-6)
    scale = jnp.full((32,), 2.0)
    s2 = scoring.score_activations(x, scale)
    np.testing.assert_allclose(np.asarray(s2), 2 * np.abs(np.asarray(x)),
                               rtol=1e-6)


def test_precompute_scale_modes(rng):
    w = jax.random.normal(rng, (16, 8))
    assert scoring.precompute_scale(w, "naive") is None
    assert scoring.precompute_scale(w, "wanda").shape == (16,)
    assert scoring.precompute_scale(w, "robust").shape == (16,)
    with pytest.raises(ValueError):
        scoring.precompute_scale(w, "bogus")


@settings(max_examples=30, deadline=None)
@given(
    din=st.integers(4, 64),
    dout=st.integers(4, 64),
    seed=st.integers(0, 2**30),
)
def test_property_scales_positive_finite(din, dout, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (din, dout))
    for mode in ("wanda", "robust"):
        s = np.asarray(scoring.precompute_scale(w, mode))
        assert np.isfinite(s).all()
        assert (s > 0).all()
        assert s.min() >= 1.0 - 1e-4  # min-normalization

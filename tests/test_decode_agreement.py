"""Serving-path integration: prefill + decode must reproduce the full
forward pass token-for-token (cache correctness for every arch family,
including SWA ring buffers and recurrent states)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.core.policy import DENSE
from repro.models import build_model


def _inputs(cfg, b, t):
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.is_encdec:
        batch["frame_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.encoder_seq, cfg.d_model))
    if cfg.vision_stub:
        batch["pixel_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch, rng):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    B, T, extra = 2, 12, 3
    batch = _inputs(cfg, B, T + extra)
    full = model.forward(params, batch, policy=DENSE, phase="prefill")

    cache = model.init_cache(B, T + extra + 4)
    bpre = dict(batch)
    bpre["tokens"] = batch["tokens"][:, :T]
    logits, cache = model.prefill(params, bpre, cache, policy=DENSE)
    errs = [float(jnp.max(jnp.abs(logits - full[:, T - 1])))]
    for i in range(extra):
        logits, cache = model.decode_step(
            params, batch["tokens"][:, T + i : T + i + 1], cache,
            policy=DENSE)
        errs.append(float(jnp.max(jnp.abs(logits - full[:, T + i]))))
    assert max(errs) < 5e-3, errs


def test_swa_ring_buffer_wraps(rng):
    """Prompt longer than the attention window: ring cache must stay exact."""
    cfg = dataclasses.replace(get_smoke_config("mixtral_8x7b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    B, T = 1, 40  # window = 16 << T
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T + 2), 0,
                              cfg.vocab_size)
    full = model.forward(params, {"tokens": toks}, policy=DENSE,
                         phase="prefill")
    cache = model.init_cache(B, T + 8)
    logits, cache = model.prefill(params, {"tokens": toks[:, :T]}, cache,
                                  policy=DENSE)
    errs = [float(jnp.max(jnp.abs(logits - full[:, T - 1])))]
    for i in range(2):
        logits, cache = model.decode_step(params, toks[:, T + i : T + i + 1],
                                          cache, policy=DENSE)
        errs.append(float(jnp.max(jnp.abs(logits - full[:, T + i]))))
    assert max(errs) < 5e-3, errs
    # ring cache holds exactly `window` slots
    k = jax.tree_util.tree_leaves(cache["periods"])[0]
    assert cfg.window in k.shape

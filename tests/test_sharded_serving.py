"""Sharded serving (ISSUE 8): the scheduler/executor API split, the
dp-replicated Router behind ``repro.serve.api``, and the tp-sharded
kernel path.

Contracts pinned here:

* the Scheduler layer is pure host code — importing it must not pull in
  jax (plans are numpy + ints, device arrays never cross the boundary);
* ``Executor.step_program(bucket)`` is a pure, effect-free function of
  ``(params, cache, plan operands)`` — the property that makes it
  ``shard_map``-able;
* dp routing never changes tokens: ``dp=2`` outputs are token-identical
  per request to a ``dp=1`` run, and prefix-affinity pins same-prefix
  requests to one replica;
* a replica crash drains to a survivor with outputs still identical
  (chaos seeds 0–2 against the Router);
* tp-sharded kernels are bit-exact vs the single-device oracle (own
  subprocess with 4 fake host devices), and the full dp=2/tp=2 engine is
  token-identical when the test process itself has ≥4 devices (the CI
  sharded job);
* ``make_mesh_auto`` fails up front, with the XLA_FLAGS fix in the
  message, when the mesh outgrows the backend.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.policy import DENSE
from repro.models import build_model
from repro.serve.api import Engine, EngineConfig
from repro.serve.continuous import ContinuousConfig, ContinuousServingEngine
from repro.serve.faults import FaultInjector, FaultSpec
from repro.serve.metrics import MetricsSnapshot
from repro.serve.router import Router

MAX_SEQ = 64
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_smoke_config("llama31_8b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens, seed0=10):
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(seed0 + i),
                                          (l,), 0, cfg.vocab_size))
            for i, l in enumerate(lens)]


def _serve_cfg(**kw):
    base = dict(max_seq=MAX_SEQ, num_slots=2, chunk_size=8)
    base.update(kw)
    return ContinuousConfig(**base)


def _run_dp(model, params, cfg, prompts, arrivals, *, dp, faults=None,
            max_new=6):
    eng = Engine.from_config(
        model, EngineConfig(dp=dp, serving=_serve_cfg()), policy=DENSE,
        faults=faults)
    rids = [eng.submit(p, max_new, arrival=a)
            for p, a in zip(prompts, arrivals)]
    res = eng.run(params)
    return eng, [res["outputs"][r] for r in rids]


# ------------------------------------------------------- layer separation

def test_scheduler_layer_is_pure_host():
    """The Scheduler half of the split must stay importable without jax:
    its plans are the host-side contract, and a jax import sneaking in
    would silently re-couple admission logic to device state.  Asserted
    through ``repro.analysis.purity`` (the AST import-graph pass the
    ``python -m repro.analysis`` CLI runs), which also covers the
    metrics module and paged.py's lazy-jax contract — and reports the
    offending import chain instead of a bare subprocess exit code."""
    from repro.analysis.purity import (check_jax_free, check_lazy_import,
                                       scan_tree)
    tree = scan_tree(_SRC)
    for mod in ("repro.serve.scheduler", "repro.serve.metrics",
                "repro.serve"):
        assert mod in tree, f"{mod} missing from the scanned tree"
        chain = check_jax_free(tree, mod)
        assert chain is None, \
            f"{mod} reaches jax at import time: {' -> '.join(chain)}"
    # paged.py may import jax ONLY inside init_paged_cache (device
    # arrays are built there and nowhere else)
    problems = check_lazy_import(tree["repro.serve.paged"], "jax",
                                 ("init_paged_cache",))
    assert not problems, problems


def test_analysis_purity_rule_matches_subprocess_truth():
    """Ground-truth the AST pass once against a real interpreter: the
    static claim "importing the scheduler never pulls in jax" must agree
    with what an actual import does."""
    code = ("import sys; import repro.serve.scheduler; "
            "sys.exit(1 if 'jax' in sys.modules else 0)")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env={**os.environ, "PYTHONPATH": _SRC}, capture_output=True)
    assert proc.returncode == 0, \
        "importing repro.serve.scheduler pulled in jax"


def test_executor_step_program_is_pure(tiny):
    """``Executor.step_program(bucket)`` must trace as a pure, effect-free
    function of its operands — the property that lets the Router shard_map
    it.  An in-place cache mutation or host callback would surface as a
    jax effect on the jaxpr."""
    from repro.serve.paged import init_paged_cache, max_blocks_per_slot
    cfg, model, params = tiny
    slots, bs = 2, 8
    mb = max_blocks_per_slot(MAX_SEQ, bs)
    eng = ContinuousServingEngine(model, DENSE, _serve_cfg(block_size=bs),
                                  _via_api=True)
    cache = init_paged_cache(model, slots, MAX_SEQ, bs, slots * mb,
                             eng._spec)
    tab = np.full((slots, mb), -1, np.int32)
    tab[0, :2], tab[1, :2] = [1, 2], [3, 4]
    cache["block_table"] = jnp.asarray(tab)
    cache["pos"] = jnp.asarray([9, 5], jnp.int32)
    step = eng.exec.step_program((False, True, True))
    args = (params, cache, jnp.asarray(0, jnp.int32),
            jnp.zeros((1, 8), jnp.int32), jnp.asarray(8, jnp.int32),
            {}, jnp.zeros((slots,), jnp.int32),
            jnp.asarray([False, True]), jnp.zeros((2,), jnp.uint32),
            jnp.zeros((2,), jnp.uint32), jnp.float32(0.0))
    closed = jax.make_jaxpr(step)(*args)
    assert not closed.effects, \
        f"step program carries jax effects: {closed.effects}"
    # tracing twice from identical operands must give identical programs
    # (no trace-time dependence on mutable executor state)
    again = jax.make_jaxpr(step)(*args)
    assert str(closed) == str(again)


# ------------------------------------------------------------ dp identity

def test_dp2_token_identical_to_dp1(tiny):
    cfg, model, params = tiny
    prompts = _prompts(cfg, (9, 17, 12, 21, 11))
    arrivals = (0, 0, 2, 3, 5)
    e1, out1 = _run_dp(model, params, cfg, prompts, arrivals, dp=1)
    e2, out2 = _run_dp(model, params, cfg, prompts, arrivals, dp=2)
    assert out1 == out2
    # both replicas actually served traffic (the router load-balances)
    served = [len(r.requests) for r in e2.replicas]
    assert all(s > 0 for s in served), served
    m = e2.metrics
    assert m.replicas is not None and len(m.replicas) == 2
    assert m.generated_tokens == sum(len(o) for o in out2)
    # the fused one-dispatch property holds per replica, not amortized
    assert m.dispatches_per_iteration == max(
        p.dispatches_per_iteration for p in m.replicas) == 1.0


def test_prefix_affinity_routes_to_one_replica(tiny):
    cfg, model, params = tiny
    shared = np.asarray(jax.random.randint(
        jax.random.PRNGKey(7), (16,), 0, cfg.vocab_size))
    prompts = [np.concatenate([shared, p])
               for p in _prompts(cfg, (5, 6, 7, 8), seed0=30)]
    router = Router(model, DENSE, _serve_cfg(), dp=2)
    rids = [router.submit(p, 4) for p in prompts]
    reps = {router._rid_map[r][0] for r in rids}
    assert len(reps) == 1, \
        f"same-prefix requests split across replicas {reps}"
    # distinct leading blocks spread by load instead
    other = router.submit(_prompts(cfg, (20,), seed0=50)[0], 4)
    assert router._rid_map[other][0] not in reps


# --------------------------------------------------------- crash failover

@pytest.mark.parametrize("seed,site,it", [(0, "decode", 3),
                                          (1, "prefill", 1),
                                          (2, "decode", 5)])
def test_replica_crash_drains_to_survivor(tiny, seed, site, it):
    """Chaos seeds 0–2 vs the Router: a mid-run EngineCrash in one replica
    must drain it — terminal outputs kept, in-flight requests re-admitted
    to the survivor — with every output still token-identical to a clean
    dp=1 run and no request leaked non-terminal."""
    cfg, model, params = tiny
    prompts = _prompts(cfg, (9, 17, 12, 21, 11), seed0=60 + seed)
    arrivals = (0, 0, 2, 3, 5)
    _, clean = _run_dp(model, params, cfg, prompts, arrivals, dp=1)
    fi = FaultInjector(seed=seed, schedule=[
        FaultSpec(site, "crash", iters=(it,), limit=1)])
    eng, out = _run_dp(model, params, cfg, prompts, arrivals, dp=2,
                       faults=fi)
    router = eng._router
    assert router.crashes == 1
    assert router.transplants >= 1
    assert sum(router.alive) == 1
    assert out == clean
    terminal = ("done", "rejected", "timed_out", "cancelled")
    for g in range(len(prompts)):
        assert eng.request_state(g) in terminal
    # a degraded fleet refuses to snapshot (shape changed under it)
    with pytest.raises(AssertionError):
        eng.snapshot()


def test_dp1_crash_propagates(tiny):
    """With no survivor the crash must reach the caller — dp=1 keeps the
    single-engine snapshot/restore recovery contract."""
    from repro.serve.faults import EngineCrash
    cfg, model, params = tiny
    prompts = _prompts(cfg, (9, 17), seed0=80)
    fi = FaultInjector(seed=0, schedule=[
        FaultSpec("decode", "crash", iters=(2,), limit=1)])
    with pytest.raises(EngineCrash):
        _run_dp(model, params, cfg, prompts, (0, 0), dp=1, faults=fi)


# ----------------------------------------------------------- api adapters

def test_direct_engine_construction_warns(tiny):
    cfg, model, params = tiny
    with pytest.warns(DeprecationWarning, match="Engine.from_config"):
        ContinuousServingEngine(model, DENSE, _serve_cfg())
    from repro.serve.engine import ServeConfig, ServingEngine
    with pytest.warns(DeprecationWarning, match="Engine.from_config"):
        ServingEngine(model, DENSE, ServeConfig(max_seq=MAX_SEQ))
    import warnings as w
    with w.catch_warnings():
        w.simplefilter("error", DeprecationWarning)
        Engine.from_config(model, EngineConfig(serving=_serve_cfg()))


def test_engine_generate_oneshot_adapter(tiny):
    """``Engine.generate`` replaces ``ServingEngine.generate``: the whole
    batch submitted at arrival 0, admission closed, outputs in submission
    order — token-identical to the continuous run of the same requests."""
    cfg, model, params = tiny
    prompts = _prompts(cfg, (9, 14, 11), seed0=90)
    eng = Engine.from_config(model, EngineConfig(serving=_serve_cfg()),
                             policy=DENSE)
    outs = eng.generate(params, prompts, max_new_tokens=5)
    _, ref = _run_dp(model, params, cfg, prompts, (0, 0, 0), max_new=5,
                     dp=1)
    assert outs == ref


def test_router_snapshot_restore_roundtrip(tiny):
    cfg, model, params = tiny
    prompts = _prompts(cfg, (9, 17, 12), seed0=95)
    eng = Engine.from_config(model, EngineConfig(
        dp=2, serving=_serve_cfg()), policy=DENSE)
    rids = [eng.submit(p, 5) for p in prompts]
    res = eng.run(params)
    snap = eng.snapshot()
    eng2 = Engine.from_config(model, EngineConfig(
        dp=2, serving=_serve_cfg()), policy=DENSE)
    eng2.restore(snap)
    for r in rids:
        assert eng2.request_state(r) == eng.request_state(r)


# ---------------------------------------------------------------- metrics

def test_metrics_snapshot_roundtrip(tiny):
    cfg, model, params = tiny
    prompts = _prompts(cfg, (9, 17), seed0=97)
    eng, _ = _run_dp(model, params, cfg, prompts, (0, 1), dp=2)
    m = eng.metrics
    back = MetricsSnapshot.from_dict(m.to_dict())
    assert back.to_dict() == m.to_dict()
    d = m.to_dict()
    # legacy dict shape intact for existing consumers
    for key in ("iterations", "trace_counts", "lifecycle", "paged",
                "requests", "dispatches_per_iteration"):
        assert key in d
    assert d["schema_version"] == 1
    assert len(d["replicas"]) == 2
    # merged counters are the sum of the parts
    assert m.generated_tokens == sum(p.generated_tokens
                                     for p in m.replicas)
    rids = sorted(r.rid for r in m.requests)
    assert rids == list(range(len(prompts)))   # relabeled to global rids


# -------------------------------------------------------------- tp shards

def test_mesh_device_count_error():
    from repro.launch.mesh import make_serving_mesh
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_serving_mesh(64, 64)


_TP_PARITY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.distributed import tp
from repro.kernels import ops
from repro.launch.mesh import make_serving_mesh
from repro.models import attention as attn

mesh = make_serving_mesh(1, 4)
sub = tp.replica_meshes(mesh)[0]
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((5, 32)), jnp.float32)
w = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
b = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
scale = jnp.asarray(rng.random(32) + 0.5, jnp.float32)
wq = jnp.asarray(rng.integers(-127, 127, (32, 64)), jnp.int8)
smooth = jnp.asarray(rng.random(32) + 0.5, jnp.float32)
amber = jnp.asarray(rng.random(32) + 0.5, jnp.float32)
ws = jnp.asarray(rng.random(64) * 0.01 + 0.001, jnp.float32)
act = jnp.asarray([0.02], jnp.float32)

def check(name, fn, *args):
    ref = jax.jit(fn)(*args)
    with tp.scope(sub, "model"):
        got = jax.jit(fn)(*args)
    ok = jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool(jnp.array_equal(a, b)), ref, got))
    assert ok, name
    print(name, "bitexact")

check("nm_prune_matmul",
      lambda x_, w_, b_: ops.nm_prune_matmul(x_, w_, scale, 2, 4, bias=b_),
      x, w, b)
check("nm_spmm", lambda x_, w_: ops.nm_spmm(x_, w_, scale, 2, 4), x, w)
check("osparse_matmul",
      lambda x_, wq_, ws_, b_: ops.osparse_matmul(
          x_, wq_, smooth, amber, ws_, 2, 4, act_scale=act, bias=b_),
      x, wq, ws, b)
check("w8a8_matmul",
      lambda xq_, wq_: ops.w8a8_matmul(xq_, wq_, act, ws),
      jnp.asarray(rng.integers(-127, 127, (5, 32)), jnp.int8), wq)

B, Hq, Hkv, D, bs, nb, T = 2, 4, 2, 8, 4, 16, 12
q = jnp.asarray(rng.standard_normal((B, T, Hq, D)), jnp.float32)
kp = jnp.asarray(rng.standard_normal((nb, bs, Hkv, D)), jnp.float32)
vp = jnp.asarray(rng.standard_normal((nb, bs, Hkv, D)), jnp.float32)
bt = jnp.asarray(np.arange(2 * 8).reshape(2, 8), jnp.int32)
qo = jnp.zeros((B,), jnp.int32)
kvl = jnp.full((B,), T, jnp.int32)
check("paged_attention",
      lambda q_, k_, v_: attn.paged_attention(
          q_, k_, v_, bt, q_offset=qo, kv_len=kvl, use_kernel=True),
      q, kp, vp)
kn = jnp.asarray(rng.standard_normal((B, 3, Hkv, D)), jnp.float32)
vn = jnp.asarray(rng.standard_normal((B, 3, Hkv, D)), jnp.float32)
check("paged_kv_update",
      lambda k_, v_, kn_, vn_: attn.paged_kv_update(
          k_, v_, kn_, vn_, bt, jnp.full((B,), T, jnp.int32),
          jnp.full((B,), 3, jnp.int32), use_kernel=True),
      kp, vp, kn, vn)
print("OK")
"""


def test_tp_kernel_parity_vs_single_device_oracle():
    """Every tp-sharded kernel entry point — the four column-parallel
    projections and the head-sharded paged attention/scatter — must be
    BIT-exact (jit-vs-jit) against the unsharded oracle.  The sweep runs
    in its own interpreter because faking host devices needs XLA_FLAGS
    set before the first jax call."""
    env = {**os.environ,
           "PYTHONPATH": _SRC,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "REPRO_PALLAS_INTERPRET": "1",
           "JAX_PLATFORMS": "cpu"}
    proc = subprocess.run([sys.executable, "-c", _TP_PARITY], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs 4 devices (CI sharded job sets "
                           "XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=4)")
def test_dp2_tp2_engine_token_identical(tiny):
    """The sized acceptance scenario: llama31_8b smoke on a (2, 2) mesh —
    two router replicas, each tp-sharding its kernels over 2 devices —
    token-identical to the plain single-device engine."""
    cfg, model, params = tiny
    prompts = _prompts(cfg, (9, 17, 12, 21), seed0=40)
    arrivals = (0, 0, 2, 3)
    _, ref = _run_dp(model, params, cfg, prompts, arrivals, dp=1)
    eng = Engine.from_config(model, EngineConfig(
        dp=2, tp=2, serving=_serve_cfg()), policy=DENSE)
    rids = [eng.submit(p, 6, arrival=a)
            for p, a in zip(prompts, arrivals)]
    res = eng.run(params)
    assert [res["outputs"][r] for r in rids] == ref
    assert all(p.dispatches_per_iteration == 1.0
               for p in eng.metrics.replicas)

"""Continuous-batching scheduler vs the legacy one-shot engine.

The contract (ISSUE 2): for greedy decode, the continuous path — staggered
arrivals, chunked sparse prefill at cache offsets, slot reuse — must
produce token-identical output to ``ServingEngine.generate`` for every
request, and a stream of varied prompt lengths inside one shape bucket
must compile each phase exactly once.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.policy import DENSE, paper_policy
from repro.core.pruner import precompute_scales
from repro.models import build_model
from repro.serve import (ContinuousConfig, ContinuousServingEngine,
                         ServeConfig, ServingEngine)

MAX_SEQ = 64


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_smoke_config("llama31_8b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens, seed0=10):
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(seed0 + i),
                                          (l,), 0, cfg.vocab_size))
            for i, l in enumerate(lens)]


def _oracle(model, params, policy, prompt, max_new, eos=-1):
    """Per-request one-shot generation, truncated at eos (inclusive)."""
    eng = ServingEngine(model, policy,
                        ServeConfig(max_seq=MAX_SEQ, eos_token=eos))
    out = eng.generate(params, {"tokens": jnp.asarray(prompt)[None, :]},
                       max_new_tokens=max_new)
    seq = np.asarray(out["tokens"])[0].tolist()
    if eos in seq:
        seq = seq[:seq.index(eos) + 1]
    return seq


def _serve(model, params, policy, prompts, arrivals, max_new, *,
           slots=2, chunk=8, eos=-1):
    eng = ContinuousServingEngine(model, policy, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=slots, chunk_size=chunk, eos_token=eos))
    for p, a, mn in zip(prompts, arrivals, max_new):
        eng.submit(p, max_new_tokens=mn, arrival=a)
    return eng, eng.run(params)


def test_staggered_arrivals_token_identical(tiny):
    """4 mixed-length requests over 2 slots: queueing + slot reuse + padded
    final chunks, all token-identical to the one-shot engine."""
    cfg, model, params = tiny
    lens, arrivals, max_new = [5, 13, 21, 9], [0, 1, 3, 6], [8, 6, 10, 7]
    prompts = _prompts(cfg, lens)
    _, res = _serve(model, params, DENSE, prompts, arrivals, max_new)
    for i, p in enumerate(prompts):
        assert res["outputs"][i] == _oracle(model, params, DENSE, p,
                                            max_new[i]), f"request {i}"
    # staggered requests actually overlapped in the scheduler
    reqs = res["metrics"]["requests"]
    assert max(r["arrival"] for r in reqs) > 0
    assert all(r["first_token_iter"] >= 0 for r in reqs)


def test_sparse_prefill_token_identical(tiny):
    """Chunked Amber-sparse prefill (per-token masks are chunking-invariant)
    matches one-shot sparse prefill."""
    cfg, model, params = tiny
    policy = paper_policy(2, 4, cfg.qgate_skip_layers)
    params = precompute_scales(params, policy)
    lens, arrivals, max_new = [7, 17, 12], [0, 0, 2], [6, 8, 6]
    prompts = _prompts(cfg, lens, seed0=30)
    _, res = _serve(model, params, policy, prompts, arrivals, max_new)
    for i, p in enumerate(prompts):
        assert res["outputs"][i] == _oracle(model, params, policy, p,
                                            max_new[i]), f"request {i}"


def test_eos_mid_batch_frees_slot(tiny):
    """A request hitting eos mid-stream truncates identically to the
    one-shot engine and releases its slot to a queued request."""
    cfg, model, params = tiny
    lens, max_new = [11, 6, 15], [8, 8, 8]
    prompts = _prompts(cfg, lens, seed0=50)
    # pick an eos that genuinely fires mid-generation for request 0: the
    # first token whose first occurrence is past the first decode step
    probe = _oracle(model, params, DENSE, prompts[0], max_new[0])
    j = next(j for j in range(1, len(probe)) if probe[j] not in probe[:j])
    eos = probe[j]
    eng, res = _serve(model, params, DENSE, prompts, [0, 0, 1], max_new,
                      slots=2, eos=eos)
    for i, p in enumerate(prompts):
        want = _oracle(model, params, DENSE, p, max_new[i], eos=eos)
        assert res["outputs"][i] == want, f"request {i}"
    assert res["outputs"][0][-1] == eos
    assert len(res["outputs"][0]) == j + 1 < max_new[0]
    reqs = {r["rid"]: r for r in res["metrics"]["requests"]}
    # request 2 was queued behind a full slot pool and entered after the
    # eos'd request released its slot
    assert reqs[2]["admitted_iter"] >= reqs[0]["done_iter"]


def test_single_trace_per_bucket(tiny):
    """Varied prompt lengths within one chunk bucket: exactly one compile
    per phase (the 'jitted once per shape bucket' claim, now enforced)."""
    cfg, model, params = tiny
    lens = [3, 9, 14, 23, 31, 6]
    prompts = _prompts(cfg, lens, seed0=70)
    eng, res = _serve(model, params, DENSE, prompts,
                      [0, 0, 1, 2, 5, 9], [5] * len(lens),
                      slots=3, chunk=16)
    # fused one-dispatch default: ONE compiled step program per
    # (prefill?, decode?) phase-presence bucket, each traced exactly once
    assert eng.trace_counts == {"step_prefill": 1, "step_decode": 1,
                                "step_prefill_decode": 1}, eng.trace_counts
    assert res["metrics"]["dispatches_per_iteration"] == 1.0
    assert all(len(res["outputs"][i]) == 5 for i in range(len(lens)))


def test_single_trace_per_bucket_legacy(tiny):
    """Same stream through the legacy two-program split (fused_step=False):
    the original per-phase pins still hold."""
    cfg, model, params = tiny
    lens = [3, 9, 14, 23, 31, 6]
    prompts = _prompts(cfg, lens, seed0=70)
    eng = ContinuousServingEngine(model, DENSE, ContinuousConfig(
        max_seq=MAX_SEQ, num_slots=3, chunk_size=16, fused_step=False))
    for p, a in zip(prompts, [0, 0, 1, 2, 5, 9]):
        eng.submit(p, max_new_tokens=5, arrival=a)
    res = eng.run(params)
    assert eng.trace_counts == {"prefill": 1, "decode": 1}, eng.trace_counts
    assert res["metrics"]["dispatches_per_iteration"] > 1.0
    assert all(len(res["outputs"][i]) == 5 for i in range(len(lens)))


def test_recurrent_arch_dyadic_chunks():
    """rwkv6: recurrent state carries across exact dyadic chunks; outputs
    stay token-identical and the trace count is bounded by the ladder."""
    cfg = dataclasses.replace(get_smoke_config("rwkv6_7b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lens, arrivals, max_new = [13, 7], [0, 1], [6, 6]
    prompts = _prompts(cfg, lens, seed0=90)
    eng, res = _serve(model, params, DENSE, prompts, arrivals, max_new,
                      slots=2, chunk=8)
    for i, p in enumerate(prompts):
        assert res["outputs"][i] == _oracle(model, params, DENSE, p,
                                            max_new[i]), f"request {i}"
    # dyadic ladder: at most log2(chunk)+1 prefill shapes per step bucket,
    # one decode-only shape
    pf = sum(v for k, v in eng.trace_counts.items() if "prefill" in k)
    assert pf <= 8, eng.trace_counts
    assert eng.trace_counts.get("step_decode", 0) <= 1, eng.trace_counts

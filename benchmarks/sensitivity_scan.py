"""Paper Appendix D analogue: average sensitivity per linear projection.

Claims validated: down_proj has the LOWEST average sensitivity (always
pruned), o_proj / up_proj rank at the top (never pruned)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_eval_model, csv_row, eval_batches
from repro.core import sensitivity
from repro.core.policy import paper_policy


def run() -> list[str]:
    rows = []
    cfg, model, params = build_eval_model("llama31_8b")
    batch = eval_batches(cfg, n=1)[0]
    batch = {"tokens": batch["tokens"][:, :32]}

    def forward(params, batch, policy, phase):
        return model.forward(params, batch, policy=policy, phase=phase)

    modules = ["q_proj", "k_proj", "v_proj", "o_proj", "gate_proj",
               "up_proj", "down_proj"]
    base = paper_policy(2, 4)
    sens = sensitivity.sensitivity_scan(forward, params, batch, modules,
                                        cfg.n_layers, base)
    avg = {m: float(np.mean([sens[(m, l)] for l in range(cfg.n_layers)]))
           for m in modules}
    order = sorted(avg, key=avg.get)
    for m in modules:
        rows.append(csv_row(f"sensitivity/{m}", 0.0, f"e_avg={avg[m]:.5f}"))
    rows.append(csv_row("sensitivity/ranking", 0.0, ">".join(
        sorted(avg, key=avg.get, reverse=True))))
    rows.append(csv_row(
        "sensitivity/check/down_proj_low", 0.0,
        "PASS" if order.index("down_proj") <= 2 else "FAIL"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Serving-throughput benchmark: continuous batching vs one-shot batching.

A mixed-length staggered request stream through the continuous scheduler
(chunked Amber-sparse prefill + slot-batched dense decode) against the same
requests served sequentially by the legacy one-shot engine.  Both rows are
measured after a warmup pass so they time compute, not tracing.  The row's
``us_per_call`` is microseconds per generated token; the derived column
carries tok/s, scheduler shape-bucket trace counts, and an ordering check —
the continuous engine must not retrace across mixed prompt lengths.

Caveat for reading the numbers: at smoke scale the one-shot engine's fused
``lax.scan`` decode can beat the scheduler's per-iteration dispatch; the
continuous engine's structural win is the trace count (1+1 buckets vs one
compile per prompt shape), which is what dominates real mixed traffic.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_eval_model, csv_row, with_scales
from repro.core.policy import paper_policy
from repro.serve.api import Engine, EngineConfig
from repro.serve.continuous import ContinuousConfig, ContinuousServingEngine
from repro.serve.engine import ServeConfig, ServingEngine

_LENS = (9, 27, 14, 33, 21, 12)
_ARRIVALS = (0, 0, 2, 4, 5, 8)
_NEW = 12
_MAX_SEQ = 64


def _prompts(cfg):
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(40 + i),
                                          (l,), 0, cfg.vocab_size))
            for i, l in enumerate(_LENS)]


def run() -> list[str]:
    rows = []
    cfg, model, params = build_eval_model("llama31_8b")
    policy = paper_policy(8, 16, cfg.qgate_skip_layers)
    params = with_scales(params, policy)
    prompts = _prompts(cfg)

    def warmed_run(eng):
        # warmup pass compiles both phases so the measured run times
        # compute, not tracing (same shape buckets → zero new traces)
        for _ in range(2):
            eng.clear()
            for p, a in zip(prompts, _ARRIVALS):
                eng.submit(p, max_new_tokens=_NEW, arrival=a)
            out = eng.run(params)
        return out

    # --- continuous scheduler over the staggered stream -------------------
    eng = ContinuousServingEngine(model, policy, ContinuousConfig(
        max_seq=_MAX_SEQ, num_slots=3, chunk_size=16), _via_api=True)
    res = warmed_run(eng)
    m = res["metrics"]
    cont_us = m["wall_s"] / max(m["generated_tokens"], 1) * 1e6
    # fused one-dispatch default: one step program per phase-presence bucket
    no_retrace = all(v == 1 for v in m["trace_counts"].values())
    traces = "+".join(str(v) for _, v in sorted(m["trace_counts"].items()))
    rows.append(csv_row(
        "serving/continuous", cont_us,
        f"tok_s={m['tokens_per_s']:.1f};traces={traces};"
        f"single_trace_per_bucket={'PASS' if no_retrace else 'FAIL'}"))

    # --- one-dispatch iterations vs the legacy two-program split ----------
    # same staggered stream through the legacy split (fused_step=False);
    # the fused engine above must emit identical greedy tokens at exactly
    # one compiled dispatch per work iteration
    legacy = ContinuousServingEngine(model, policy, ContinuousConfig(
        max_seq=_MAX_SEQ, num_slots=3, chunk_size=16, fused_step=False),
        _via_api=True)
    lres = warmed_run(legacy)
    lm = lres["metrics"]
    identical = lres["outputs"] == res["outputs"]
    one_dispatch = m["dispatches_per_iteration"] == 1.0
    rows.append(csv_row(
        "serving/one_dispatch", cont_us,
        f"fused_tok_s={m['tokens_per_s']:.1f};"
        f"legacy_tok_s={lm['tokens_per_s']:.1f};"
        f"dpi={m['dispatches_per_iteration']:.2f}"
        f"_vs_{lm['dispatches_per_iteration']:.2f};"
        f"one_dispatch={'PASS' if one_dispatch else 'FAIL'};"
        f"token_identity={'PASS' if identical else 'FAIL'}"))

    # --- dp=2 sharded serving through the Router/api facade ---------------
    # the same staggered stream load-balanced across two host-level engine
    # replicas (independent schedulers + block pools).  Gates: outputs
    # token-identical to the single-replica run above, and each replica
    # keeps the fused one-dispatch property (dpi ≤ the single-engine
    # baseline — sharding must not reintroduce extra dispatches)
    sharded = Engine.from_config(model, EngineConfig(
        dp=2, serving=ContinuousConfig(max_seq=_MAX_SEQ, num_slots=3,
                                       chunk_size=16)), policy=policy)
    sres = warmed_run(sharded)
    sm = sharded.metrics
    shard_us = sm.wall_s / max(sm.generated_tokens, 1) * 1e6
    identical = sres["outputs"] == res["outputs"]
    rep_dpi = [p.dispatches_per_iteration for p in sm.replicas]
    dpi_ok = max(rep_dpi) <= m["dispatches_per_iteration"]
    rows.append(csv_row(
        "serving/sharded_dp2", shard_us,
        f"tok_s={sm.tokens_per_s:.1f};"
        f"replica_dpi={'/'.join(f'{d:.2f}' for d in rep_dpi)};"
        f"replica_tok={'/'.join(str(p.generated_tokens) for p in sm.replicas)};"
        f"per_replica_one_dispatch={'PASS' if dpi_ok else 'FAIL'};"
        f"token_identity_vs_dp1={'PASS' if identical else 'FAIL'}"))

    # --- same traffic under memory pressure: 50% block pool ---------------
    # the paged allocator's reason to exist — serve the identical stream
    # with the pool sized well below num_slots * max_seq and check the
    # outputs are still token-identical (preemption replays, block-budget
    # admission); derived carries peak blocks + preemption count
    bs = 8
    half_pool = (3 * _MAX_SEQ) // (2 * bs)
    press = ContinuousServingEngine(model, policy, ContinuousConfig(
        max_seq=_MAX_SEQ, num_slots=3, chunk_size=16,
        block_size=bs, num_blocks=half_pool), _via_api=True)
    pres = warmed_run(press)
    pm = pres["metrics"]
    pg = pm["paged"]
    if pg["enabled"]:
        press_us = pm["wall_s"] / max(pm["generated_tokens"], 1) * 1e6
        identical = pres["outputs"] == res["outputs"]
        rows.append(csv_row(
            "serving/paged_pressure_50pct", press_us,
            f"tok_s={pm['tokens_per_s']:.1f};"
            f"pool={pg['num_blocks']}x{bs}rows;"
            f"peak_blocks={pg['peak_blocks_in_use']};"
            f"preemptions={pg['preemptions']};"
            f"token_identical_vs_full={'PASS' if identical else 'FAIL'}"))
    else:  # arch swapped to one without full-attn KV: row inapplicable
        rows.append(csv_row("serving/paged_pressure_50pct", 0.0,
                            "paging auto-disabled for this arch;SKIP"))

    # --- shared-system-prompt workload: block-level prefix caching --------
    # realistic reuse traffic: every request opens with the same system
    # prompt, so with the refcounted content-addressed pool only the first
    # request pays for those blocks' prefill.  Arrivals are staggered past
    # the first request's prompt ingestion so its blocks are published
    # before the followers admit.  Gates: ≥1 prefix hit per reusing
    # request, ≥50% of reusing-request prompt rows skipped, and outputs
    # token-identical to the same engine with caching off.
    sysp = np.asarray(jax.random.randint(jax.random.PRNGKey(70), (32,), 0,
                                         cfg.vocab_size))
    shared_prompts = [
        np.concatenate([sysp, np.asarray(jax.random.randint(
            jax.random.PRNGKey(71 + i), (6 + i,), 0, cfg.vocab_size))])
        for i in range(5)]
    shared_arrivals = (0, 4, 6, 8, 10)

    def shared_run(prefix_cache):
        eng = ContinuousServingEngine(model, policy, ContinuousConfig(
            max_seq=_MAX_SEQ, num_slots=3, chunk_size=16, block_size=8,
            prefix_cache=prefix_cache), _via_api=True)
        for _ in range(2):              # warmup compiles AND warms the index
            eng.clear()
            for p, a in zip(shared_prompts, shared_arrivals):
                eng.submit(p, max_new_tokens=_NEW, arrival=a)
            out = eng.run(params)
        return out

    warm = shared_run(True)
    cold = shared_run(False)
    wm, wp = warm["metrics"], warm["metrics"]["paged"]
    if wp["enabled"]:
        warm_us = wm["wall_s"] / max(wm["generated_tokens"], 1) * 1e6
        hit_reqs = sum(r["cached_tokens"] > 0
                       for r in wm["requests"])
        # measured run rides a warm index: every request reuses
        reusing = len(shared_prompts)
        prompt_rows = sum(len(p) for p in shared_prompts)
        skipped = wp["tokens_skipped"]
        ok = (hit_reqs >= reusing and skipped / prompt_rows >= 0.5
              and warm["outputs"] == cold["outputs"])
        rows.append(csv_row(
            "serving/prefix_reuse", warm_us,
            f"tok_s={wm['tokens_per_s']:.1f};"
            f"cold_tok_s={cold['metrics']['tokens_per_s']:.1f};"
            f"hit_requests={hit_reqs}/{reusing};"
            f"skipped_rows={skipped}/{prompt_rows};"
            f"cached_blocks={wp['cached_blocks']};"
            f"reuse_and_token_identical_vs_cold={'PASS' if ok else 'FAIL'}"))
    else:
        rows.append(csv_row("serving/prefix_reuse", 0.0,
                            "paging auto-disabled for this arch;SKIP"))

    # --- legacy one-shot engine, one request at a time --------------------
    one = ServingEngine(model, policy, ServeConfig(max_seq=_MAX_SEQ),
                        _via_api=True)

    def oneshot_sweep():
        n = 0
        for p in prompts:
            out = one.generate(params, {"tokens": jnp.asarray(p)[None, :]},
                               max_new_tokens=_NEW)
            jax.block_until_ready(out["tokens"])
            n += out["tokens"].shape[1]
        return n

    oneshot_sweep()                     # warmup: compile every prompt shape
    t0 = time.perf_counter()
    gen = oneshot_sweep()
    dt = time.perf_counter() - t0
    rows.append(csv_row(
        "serving/oneshot_sequential", dt / gen * 1e6,
        f"tok_s={gen / dt:.1f};requests={len(prompts)}"))
    return rows

"""Paper Table 3 analogue: generation stability under sparse prefill.

The paper's claim: confining N:M sparsity to prefill does not perturb the
KV cache enough to damage decoding.  Proxies here: (a) greedy-decode token
agreement dense-prefill vs sparse-prefill, (b) per-step decode logit
distance, at 2:4 / 4:8 / 8:16 — agreement should improve with larger M.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import build_eval_model, csv_row, with_scales
from repro.core.policy import DENSE, paper_policy
from repro.serve.engine import ServeConfig, ServingEngine


def run() -> list[str]:
    rows = []
    cfg, model, params = build_eval_model("llama31_8b")
    pol816 = paper_policy(8, 16, cfg.qgate_skip_layers)
    params = with_scales(params, pol816)
    scfg = ServeConfig(max_seq=96)
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (8, 32),
                                            0, cfg.vocab_size)}
    dense_eng = ServingEngine(model, DENSE, scfg)
    out_d = dense_eng.generate(params, prompts, max_new_tokens=16)

    agreements = {}
    for n, m in [(2, 4), (4, 8), (8, 16)]:
        pol = paper_policy(n, m, cfg.qgate_skip_layers)
        eng = ServingEngine(model, pol, scfg)
        out_s = eng.generate(params, prompts, max_new_tokens=16)
        agree = float((out_d["tokens"] == out_s["tokens"]).mean())
        first_tok = float((out_d["tokens"][:, 0] ==
                           out_s["tokens"][:, 0]).mean())
        agreements[(n, m)] = agree
        rows.append(csv_row(
            f"table3/{n}:{m}", 0.0,
            f"greedy_agree={agree:.3f};first_token_agree={first_tok:.3f}"))
    ok = agreements[(8, 16)] >= agreements[(2, 4)]
    rows.append(csv_row("table3/check/agree_monotone_in_M", 0.0,
                        "PASS" if ok else "FAIL"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

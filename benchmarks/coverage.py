"""Coverage accounting: >55% of linear-projection FLOPs accelerated
(paper §Setup publishes 56.1% / 57.6% / 56.9% for its three models)."""
from __future__ import annotations

from benchmarks.common import csv_row
from repro.configs.base import get_config
from repro.core import sensitivity
from repro.core.policy import paper_policy

PUBLISHED = {"llama31_8b": 0.561, "qwen2_7b": 0.576, "qwen3_30b_a3b": 0.569}


def _dims(cfg):
    d = {
        "q_proj": (cfg.d_model, cfg.q_dim),
        "k_proj": (cfg.d_model, cfg.kv_dim),
        "v_proj": (cfg.d_model, cfg.kv_dim),
        "o_proj": (cfg.q_dim, cfg.d_model),
    }
    ff = cfg.moe_d_ff * cfg.top_k if cfg.n_experts else cfg.d_ff
    d["gate_proj"] = (cfg.d_model, ff)
    d["up_proj"] = (cfg.d_model, ff)
    d["down_proj"] = (ff, cfg.d_model)
    return d


def run() -> list[str]:
    rows = []
    for arch, published in PUBLISHED.items():
        cfg = get_config(arch)
        flops = sensitivity.linear_flops(_dims(cfg))
        pol = paper_policy(8, 16, cfg.qgate_skip_layers)
        cov = sensitivity.coverage(flops, pol, cfg.n_layers)
        ok = abs(cov - published) < 0.02 and cov > 0.55
        rows.append(csv_row(
            f"coverage/{arch}", 0.0,
            f"ours={cov:.3f};published={published:.3f};"
            f"{'PASS' if ok else 'FAIL'}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""CI gate over a ``BENCH_*.json`` trajectory: the latest run must carry
every expected kernel row with a finite, positive wall-time.

    PYTHONPATH=src python benchmarks/check_bench.py bench_ci.json

A kernel that stops lowering under ``REPRO_PALLAS_INTERPRET=1`` (or starts
returning NaN timings) would otherwise just drop out of the trajectory and
the regression would go unnoticed until someone eyeballed the JSON —
``benchmarks/run.py`` only exits non-zero on ordering-claim FAILs, not on
missing rows.
"""
from __future__ import annotations

import json
import math
import sys
from typing import List

# one prefix per fused-kernel hot path benchmarked by kernel_bench.run()
REQUIRED_KERNEL_ROWS = (
    "kernel/nm_prune/",
    "kernel/nm_prune_matmul/",
    "kernel/nm_spmm/",
    "kernel/w8a8/",
    "kernel/osparse_matmul/",
    "kernel/paged_attention/",
)
# scheduler-level rows gated by bench-smoke (serving table): prefix_reuse
# embeds its own hit-rate / skip-fraction / token-identity PASS gate in
# the derived column, which the FAIL scan below enforces
REQUIRED_SERVING_ROWS = (
    "serving/prefix_reuse",
)
REQUIRED_ROWS = REQUIRED_KERNEL_ROWS + REQUIRED_SERVING_ROWS


def check_trajectory(path: str,
                     required=REQUIRED_ROWS) -> List[str]:
    """Returns a list of problems with the LATEST run in the trajectory
    (empty = healthy)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable trajectory ({e})"]
    if not isinstance(data, list) or not data:
        return [f"{path}: not a non-empty trajectory list"]
    run = data[-1]
    rows = run.get("rows", [])
    errors = []
    for prefix in required:
        matches = [r for r in rows if str(r.get("name", "")).startswith(prefix)]
        if not matches:
            errors.append(f"missing required row {prefix}*")
        for r in matches:
            derived = str(r.get("derived", ""))
            # a required scenario that self-reports SKIP (e.g. paging
            # auto-disabled for the bench arch) still fails the gate, but
            # with the real cause instead of a bogus 0.0-timing complaint
            if "SKIP" in derived:
                errors.append(
                    f"{r['name']}: required row was skipped ({derived})")
                continue
            us = r.get("us_per_call")
            if not (isinstance(us, (int, float)) and math.isfinite(us)
                    and us > 0):
                errors.append(
                    f"{r['name']}: non-finite us_per_call {us!r}")
            # required rows embed their correctness claims (ordering,
            # token-identity, reuse rates) as PASS/FAIL in derived —
            # a FAIL must fail the artifact gate, not just run.py's exit
            if "FAIL" in derived:
                errors.append(f"{r['name']}: derived claims FAIL "
                              f"({derived})")
    return errors


def main(argv=None) -> int:
    argv = sys.argv if argv is None else argv
    path = argv[1] if len(argv) > 1 else "bench_ci.json"
    errors = check_trajectory(path)
    if errors:
        for e in errors:
            print(f"BENCH CHECK FAIL: {e}")
        return 1
    with open(path) as f:
        run = json.load(f)[-1]
    print(f"bench check OK: {len(run.get('rows', []))} rows "
          f"@ {run.get('utc', '?')} "
          f"(tables: {','.join(run.get('tables', []))})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
